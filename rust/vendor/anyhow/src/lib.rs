//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored shim provides the subset of the anyhow API the workspace
//! uses: `Error`, `Result<T>`, the `anyhow!` / `bail!` / `ensure!`
//! macros, and the `Context` extension trait for `Result` and `Option`.
//! The error is a simple context chain of strings; `{}` prints the
//! outermost message, `{:#}` prints the whole chain joined by `: `
//! (matching anyhow's alternate formatting, which the CLI and tests rely
//! on). If a real `anyhow` becomes available, deleting this directory and
//! switching the workspace dependency back to the registry is all that's
//! needed — no call sites change.

use std::fmt;

/// A context-chain error. Deliberately NOT `std::error::Error`, so the
/// blanket `From<E: std::error::Error>` below stays coherent (the same
/// trick the real anyhow uses).
pub struct Error {
    /// chain[0] is the outermost context, chain.last() the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `: `-joined context chain (what `{:#}` prints).
    pub fn chain_string(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain_string())
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to this crate's `Error`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion used by the `Context` impls: both plain std errors and
/// already-wrapped `Error`s can gain context.
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// Attach context to a fallible value (anyhow's extension trait). The
/// second type parameter disambiguates the `Result` and `Option` impls,
/// exactly as in the real crate.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted `Error`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted `Error` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e = Error::msg("root").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<usize> {
            let n: usize = "17".parse()?;
            Ok(n)
        }
        fn g() -> Result<usize> {
            let n: usize = "x".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 17);
        assert!(g().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").err().unwrap();
        assert_eq!(format!("{e:#}"), "reading file: gone");

        let o: Option<usize> = None;
        let e = o.with_context(|| format!("slot {}", 3)).err().unwrap();
        assert_eq!(format!("{e}"), "slot 3");

        let already: Result<()> = Err(Error::msg("inner"));
        let e = already.context("outer").err().unwrap();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 2, "x too small: {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(1).err().unwrap()), "x too small: 1");
        assert_eq!(format!("{}", f(200).err().unwrap()), "x too big: 200");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}

//! Offline stub of the `xla` crate (xla-rs PJRT bindings over
//! xla_extension).
//!
//! The container this workspace builds in has neither crates.io access
//! nor the xla_extension C library, so this stub keeps the whole
//! coordinator compiling and the non-neural pipeline fully functional:
//!
//! - `Literal` is a real host-side implementation (dims + typed buffer,
//!   `vec1` / `reshape` / `to_vec` / `to_tuple`), which is all the
//!   environment/tensor layers need — feature extraction, the simulator,
//!   the baselines and every table that doesn't run a policy work
//!   end-to-end.
//! - The PJRT client/executable surface exists but `compile`/`execute`
//!   return a descriptive `Error` — exactly the paths that also require
//!   the AOT artifacts from `make artifacts`, which the callers already
//!   gate on. Swapping in the real xla-rs (same API) re-enables them
//!   without touching any call site.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (the real crate wraps XLA status codes).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: this build uses the vendored xla stub \
         (no PJRT runtime / xla_extension in the environment)"
    ))
}

/// Element buffer of a literal.
#[derive(Debug, Clone, PartialEq)]
pub enum ElemData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl ElemData {
    fn len(&self) -> usize {
        match self {
            ElemData::F32(v) => v.len(),
            ElemData::I32(v) => v.len(),
            ElemData::U32(v) => v.len(),
        }
    }
}

/// Element types a `Literal` can hold (mirrors xla-rs's NativeType).
pub trait NativeType: Copy + Sized + fmt::Debug + 'static {
    const DTYPE: &'static str;
    fn wrap(data: Vec<Self>) -> ElemData;
    fn extract(data: &ElemData) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($ty:ty, $variant:ident, $name:literal) => {
        impl NativeType for $ty {
            const DTYPE: &'static str = $name;
            fn wrap(data: Vec<Self>) -> ElemData {
                ElemData::$variant(data)
            }
            fn extract(data: &ElemData) -> Option<Vec<Self>> {
                match data {
                    ElemData::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32, "f32");
native!(i32, I32, "i32");
native!(u32, U32, "u32");

/// A host-side literal: shape + typed buffer. Fully functional in the
/// stub (no device memory involved).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: ElemData,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Reinterpret with new dimensions (element count must match; empty
    /// dims = scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = if dims.is_empty() { 1 } else { dims.iter().product() };
        if numel < 0 || numel as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the buffer out as a host vector of the matching dtype.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data)
            .ok_or_else(|| Error(format!("to_vec: literal is not {}", T::DTYPE)))
    }

    /// Decompose a tuple literal. The stub never produces tuples (they
    /// only come out of executions), so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literals (produced only by PJRT executions)"))
    }
}

/// Device buffer handle returned by executions (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module proto (stub: carries nothing).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text '{path}'")))
    }
}

/// An XLA computation (stub: carries nothing).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (stub: never constructed — `compile` errors).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. Construction succeeds (so error paths that only need
/// a client, e.g. artifact-directory validation, behave normally);
/// compilation reports the stub.
#[derive(Debug)]
pub struct PjRtClient {
    platform: String,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu-stub (xla unavailable)".to_string() })
    }

    pub fn platform_name(&self) -> String {
        self.platform.clone()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_all_dtypes() {
        let f = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(f.dims(), &[2, 2]);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(f.to_vec::<i32>().is_err());

        let i = Literal::vec1(&[7i32, -1]).reshape(&[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, -1]);

        let u = Literal::vec1(&[5u32]).reshape(&[]).unwrap(); // scalar
        assert_eq!(u.to_vec::<u32>().unwrap(), vec![5]);
    }

    #[test]
    fn reshape_checks_numel() {
        let l = Literal::vec1(&[0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn runtime_paths_report_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let err = HloModuleProto::from_text_file("x.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("stub"), "{err}");
    }
}

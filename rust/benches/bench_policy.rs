//! `cargo bench` target for the native policy backend: per-call timings
//! of the three PolicyBackend entry points (fwd / placer / train) on each
//! paper benchmark, plus the batched multi-rollout path, so kernel
//! optimizations (blocking, SIMD, sparsity, arenas) have a recorded
//! baseline to beat.
//!
//! The train timing measures one full Eq. 14 window: `update_timestep`
//! re-forwards with dropout, the hand-written backward pass, and Adam.
//!
//! Flags (after `--`): `--json` emits one `hsdag-bench-v1` document on
//! stdout (the BENCH_POLICY.json snapshot format); `--quick` trims the
//! iteration counts for CI smoke runs; `--workers N` installs N kernel
//! workers (0 = auto) and, when N != 1, first asserts the parallel
//! forward pass is bit-identical to the serial one — CI's thread sweep
//! runs this binary at 1/2/4 workers and relies on that gate:
//!
//!   cargo bench --bench bench_policy -- --json > BENCH_POLICY.json

use hsdag::config::Config;
use hsdag::models::Benchmark;
use hsdag::obs::metrics;
use hsdag::parsing::parse;
use hsdag::rl::{Env, NativeBackend, PolicyBackend, TrainBatch};
use hsdag::util::bench::BenchSession;
use hsdag::util::pool;

/// `--workers N` from the forwarded bench args ([`BenchSession`] ignores
/// flags it does not know, so the sweep flag parses here). 0 = auto.
fn parse_workers() -> usize {
    let args: Vec<String> = std::env::args().skip(1).collect();
    args.iter()
        .position(|a| a == "--workers")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

fn main() {
    let workers = parse_workers();
    pool::set_global_workers(workers);
    let mut session = BenchSession::from_args("bench_policy");
    session.note("== native policy backend (fwd / placer / train per call) ==");
    session.note(&format!("-- workers: {} (0 = auto) --", workers));
    session.counter("workers/requested", workers as f64);
    let cfg = Config { backend: "native".to_string(), seed: 3, workers, ..Default::default() };
    for b in Benchmark::ALL {
        let env = Env::new(b, &cfg).unwrap();
        let mut backend = NativeBackend::new(&env, &cfg).unwrap();
        session.note(&format!(
            "-- {} ({} working nodes, {} edges, {} actions) --",
            b.id(),
            env.n_nodes,
            env.n_edges,
            env.n_actions()
        ));
        let h = cfg.hidden;
        let fb = vec![0f32; env.v_pad * h];

        // Identity gate: before timing anything at workers != 1, prove
        // the banded kernels return the serial bits on this graph. A
        // mismatch is a correctness bug, not a perf result — abort.
        if workers != 1 {
            pool::set_global_workers(1);
            let serial = backend.fwd(&env, &fb).unwrap();
            pool::set_global_workers(workers);
            let par = backend.fwd(&env, &fb).unwrap();
            let same = serial.scores.len() == par.scores.len()
                && serial.z.len() == par.z.len()
                && serial.scores.iter().zip(&par.scores).all(|(a, b)| a.to_bits() == b.to_bits())
                && serial.z.iter().zip(&par.z).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{}: fwd at workers={} diverged from workers=1", b.id(), workers);
        }

        // fwd: encoder + edge scorer at the real graph size.
        session.run(&format!("policy/fwd/{}", b.id()), 1, 10, || {
            backend.fwd(&env, &fb).unwrap()
        });

        // placer: group pooling + device head over a real partition.
        let out = backend.fwd(&env, &fb).unwrap();
        let part = parse(env.working_graph(), &out.scores);
        let mut cids = vec![0i32; env.v_pad];
        let mut gmask = vec![0f32; env.v_pad];
        for (node, &c) in part.cluster_of.iter().enumerate() {
            cids[node] = c as i32;
        }
        for m in gmask.iter_mut().take(part.n_groups) {
            *m = 1.0;
        }
        session.run(&format!("policy/placer/{}", b.id()), 1, 20, || {
            backend.placer(&env, &out, &cids, &gmask).unwrap()
        });

        // placer_many: the serve daemon's batched path — 1 greedy + 4
        // stochastic rollouts through one stacked weight pass, vs five
        // independent placer calls above.
        let roll = 5usize;
        let fwds: Vec<&hsdag::rl::PolicyFwd> = vec![&out; roll];
        let cids_refs: Vec<&[i32]> = vec![&cids; roll];
        let gmask_refs: Vec<&[f32]> = vec![&gmask; roll];
        session.run(&format!("policy/placer_many:5/{}", b.id()), 1, 20, || {
            backend.placer_many(&env, &fwds, &cids_refs, &gmask_refs).unwrap()
        });

        // train: one full buffered window built from the partition above
        // (identical planes per step — timing, not learning, is the
        // point here).
        let (t, v, e) = (cfg.update_timestep, env.v_pad, env.e_pad);
        let mut fb_buf = vec![0f32; t * v * h];
        let mut cids_buf = vec![0i32; t * v];
        let mut actions_buf = vec![0i32; t * v];
        let mut gmask_buf = vec![0f32; t * v];
        let mut retained_buf = vec![0f32; t * e];
        for ti in 0..t {
            fb_buf[ti * v * h..ti * v * h + env.n_nodes * h]
                .copy_from_slice(&out.z[..env.n_nodes * h]);
            cids_buf[ti * v..(ti + 1) * v].copy_from_slice(&cids);
            gmask_buf[ti * v..(ti + 1) * v].copy_from_slice(&gmask);
            for g in 0..part.n_groups {
                actions_buf[ti * v + g] = (g % env.n_actions()) as i32;
            }
            for (ei, &r) in part.retained.iter().enumerate() {
                retained_buf[ti * e + ei] = if r { 1.0 } else { 0.0 };
            }
        }
        let coeff: Vec<f32> = (0..t).map(|i| 0.5 - 0.02 * i as f32).collect();
        session.run(&format!("policy/train/{}", b.id()), 0, 3, || {
            let batch = TrainBatch {
                t,
                v,
                e,
                fb: &fb_buf,
                cids: &cids_buf,
                actions: &actions_buf,
                gmask: &gmask_buf,
                retained: &retained_buf,
                coeff: &coeff,
                key: [11, 13],
            };
            backend.train(&env, &batch).unwrap()
        });
    }

    // Telemetry overhead gate: the metrics registry must be invisible on
    // the policy hot path — the acceptance bar is enabled within 3% of
    // disabled. Same backend, same inputs, only the global switch moves.
    {
        let b = Benchmark::ALL[0];
        let env = Env::new(b, &cfg).unwrap();
        let mut backend = NativeBackend::new(&env, &cfg).unwrap();
        let fb = vec![0f32; env.v_pad * cfg.hidden];
        session.note("-- telemetry overhead (metrics registry on vs off) --");
        metrics::set_enabled(true);
        session.run(&format!("policy/fwd_metrics_on/{}", b.id()), 1, 10, || {
            backend.fwd(&env, &fb).unwrap()
        });
        metrics::set_enabled(false);
        session.run(&format!("policy/fwd_metrics_off/{}", b.id()), 1, 10, || {
            backend.fwd(&env, &fb).unwrap()
        });
        metrics::set_enabled(true);

        // Profiling tier (--profile): per-kernel calls / wall ns / flops
        // and pool busy time, surfaced as bench counters so the JSON
        // snapshot records kernel-level utilization.
        metrics::set_profiling(true);
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            backend.fwd(&env, &fb).unwrap();
        }
        let wall_ns = t0.elapsed().as_nanos() as f64;
        metrics::set_profiling(false);
        for name in [
            "kernel.matmul.calls",
            "kernel.matmul.ns",
            "kernel.matmul.flops",
            "kernel.aggregate.calls",
            "kernel.aggregate.ns",
            "kernel.aggregate.flops",
            "pool.tasks",
            "pool.busy_ns",
        ] {
            session.counter(&format!("profile/{name}"), metrics::counter(name).get() as f64);
        }
        session.counter("profile/fwd_wall_ns", wall_ns);
    }
    session.finish();
}

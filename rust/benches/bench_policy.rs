//! `cargo bench` target for the native policy backend: per-call timings
//! of the three PolicyBackend entry points (fwd / placer / train) on each
//! paper benchmark, plus the batched multi-rollout path, so kernel
//! optimizations (blocking, SIMD, sparsity, arenas) have a recorded
//! baseline to beat.
//!
//! The train timing measures one full Eq. 14 window: `update_timestep`
//! re-forwards with dropout, the hand-written backward pass, and Adam.
//!
//! Flags (after `--`): `--json` emits one `hsdag-bench-v1` document on
//! stdout (the BENCH_POLICY.json snapshot format); `--quick` trims the
//! iteration counts for CI smoke runs:
//!
//!   cargo bench --bench bench_policy -- --json > BENCH_POLICY.json

use hsdag::config::Config;
use hsdag::models::Benchmark;
use hsdag::parsing::parse;
use hsdag::rl::{Env, NativeBackend, PolicyBackend, TrainBatch};
use hsdag::util::bench::BenchSession;

fn main() {
    let mut session = BenchSession::from_args("bench_policy");
    session.note("== native policy backend (fwd / placer / train per call) ==");
    let cfg = Config { backend: "native".to_string(), seed: 3, ..Default::default() };
    for b in Benchmark::ALL {
        let env = Env::new(b, &cfg).unwrap();
        let mut backend = NativeBackend::new(&env, &cfg).unwrap();
        session.note(&format!(
            "-- {} ({} working nodes, {} edges, {} actions) --",
            b.id(),
            env.n_nodes,
            env.n_edges,
            env.n_actions()
        ));
        let h = cfg.hidden;
        let fb = vec![0f32; env.v_pad * h];

        // fwd: encoder + edge scorer at the real graph size.
        session.run(&format!("policy/fwd/{}", b.id()), 1, 10, || {
            backend.fwd(&env, &fb).unwrap()
        });

        // placer: group pooling + device head over a real partition.
        let out = backend.fwd(&env, &fb).unwrap();
        let part = parse(env.working_graph(), &out.scores);
        let mut cids = vec![0i32; env.v_pad];
        let mut gmask = vec![0f32; env.v_pad];
        for (node, &c) in part.cluster_of.iter().enumerate() {
            cids[node] = c as i32;
        }
        for m in gmask.iter_mut().take(part.n_groups) {
            *m = 1.0;
        }
        session.run(&format!("policy/placer/{}", b.id()), 1, 20, || {
            backend.placer(&env, &out, &cids, &gmask).unwrap()
        });

        // placer_many: the serve daemon's batched path — 1 greedy + 4
        // stochastic rollouts through one stacked weight pass, vs five
        // independent placer calls above.
        let roll = 5usize;
        let fwds: Vec<&hsdag::rl::PolicyFwd> = vec![&out; roll];
        let cids_refs: Vec<&[i32]> = vec![&cids; roll];
        let gmask_refs: Vec<&[f32]> = vec![&gmask; roll];
        session.run(&format!("policy/placer_many:5/{}", b.id()), 1, 20, || {
            backend.placer_many(&env, &fwds, &cids_refs, &gmask_refs).unwrap()
        });

        // train: one full buffered window built from the partition above
        // (identical planes per step — timing, not learning, is the
        // point here).
        let (t, v, e) = (cfg.update_timestep, env.v_pad, env.e_pad);
        let mut fb_buf = vec![0f32; t * v * h];
        let mut cids_buf = vec![0i32; t * v];
        let mut actions_buf = vec![0i32; t * v];
        let mut gmask_buf = vec![0f32; t * v];
        let mut retained_buf = vec![0f32; t * e];
        for ti in 0..t {
            fb_buf[ti * v * h..ti * v * h + env.n_nodes * h]
                .copy_from_slice(&out.z[..env.n_nodes * h]);
            cids_buf[ti * v..(ti + 1) * v].copy_from_slice(&cids);
            gmask_buf[ti * v..(ti + 1) * v].copy_from_slice(&gmask);
            for g in 0..part.n_groups {
                actions_buf[ti * v + g] = (g % env.n_actions()) as i32;
            }
            for (ei, &r) in part.retained.iter().enumerate() {
                retained_buf[ti * e + ei] = if r { 1.0 } else { 0.0 };
            }
        }
        let coeff: Vec<f32> = (0..t).map(|i| 0.5 - 0.02 * i as f32).collect();
        session.run(&format!("policy/train/{}", b.id()), 0, 3, || {
            let batch = TrainBatch {
                t,
                v,
                e,
                fb: &fb_buf,
                cids: &cids_buf,
                actions: &actions_buf,
                gmask: &gmask_buf,
                retained: &retained_buf,
                coeff: &coeff,
                key: [11, 13],
            };
            backend.train(&env, &batch).unwrap()
        });
    }
    session.finish();
}

//! Serving-layer micro-bench: what does one placement request cost on
//! each of the three service paths, and how fast do checkpoints move?
//!
//!   cargo bench --bench bench_serve [-- --json --quick]
//!
//! Covers: the cold path (workload resolution + env construction +
//! batched policy inference), the cache-hit path (fingerprint + LRU
//! lookup), the budget-exhausted fallback path (baselines only), raw
//! fingerprint throughput, checkpoint serialize / parse / disk
//! round-trip, and a TCP loadgen against a live server on an ephemeral
//! loopback port — the end-to-end req/s number the ROADMAP's serving
//! goal cares about. The fleet sweep then spawns 1/2/4 *separate shard
//! processes* (the real `hsdag serve` binary), routes a fixed offered
//! load across them with the same rendezvous hash the router uses, and
//! reports req/s plus p50/p99 per shard count, cold vs warmed cache —
//! the saturation curve behind BENCH_FLEET.json. `--json` renders
//! everything as one `hsdag-bench-v1` document; `--quick` trims
//! iteration counts for CI smoke runs.

use std::io::{BufRead, BufReader, Read};
use std::path::Path;
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::models::Workload;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::serve::{
    client, discover_testbed, fingerprint, protocol, shard_for, Checkpoint, CheckpointMeta,
    PlacementService, ServeOptions, Server,
};
use hsdag::util::bench::{BenchResult, BenchSession};
use hsdag::util::stats;

fn main() {
    let mut session = BenchSession::from_args("bench_serve");
    // One small trained policy drives every case.
    let cfg = Config {
        backend: "native".to_string(),
        hidden: 32,
        update_timestep: 8,
        seed: 7,
        ..Default::default()
    };
    let train_spec = "layered:6x4:1";
    let env = Env::for_workload(Workload::resolve(train_spec).unwrap(), &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    agent.search(&env, 4).unwrap();
    let ckpt = Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: train_spec.to_string(),
            best_latency: None,
        },
    );

    session.note(&format!("== request paths (in-process service, {train_spec}) =="));
    let service = Arc::new(
        PlacementService::new(ckpt.clone(), &cfg, ServeOptions::default()).unwrap(),
    );
    let cold_line =
        protocol::render_place_request(Some(train_spec), None, None, None, None, true);
    session.run("serve/place/cold (no_cache)", 2, 12, || {
        let (resp, _) = service.handle_line(&cold_line);
        resp.len()
    });
    let warm_line =
        protocol::render_place_request(Some(train_spec), None, None, None, None, false);
    let (_, _) = service.handle_line(&warm_line); // prime the cache
    session.run("serve/place/cache-hit", 3, 200, || {
        let (resp, _) = service.handle_line(&warm_line);
        resp.len()
    });
    let fallback_line =
        protocol::render_place_request(Some(train_spec), None, None, Some(0.0), None, true);
    session.run("serve/place/fallback (budget 0)", 2, 20, || {
        let (resp, _) = service.handle_line(&fallback_line);
        resp.len()
    });

    session.note("== fingerprinting ==");
    for spec in ["layered:6x4:1", "resnet"] {
        let g = Workload::resolve(spec).unwrap().graph;
        let r = session.run(&format!("serve/fingerprint/{spec}"), 3, 50, || {
            fingerprint(&g, "cpu_gpu")
        });
        session.note(&format!(
            "  -> {spec}: {} nodes, {:.1} ns/node",
            g.n(),
            r.median_ns / g.n() as f64
        ));
    }

    session.note("== checkpoint serialize / parse ==");
    let text = ckpt.to_json();
    let scalars = 3 * ckpt.store.n_scalars() + 1;
    session.note(&format!(
        "  checkpoint document: {} bytes for {scalars} scalars",
        text.len()
    ));
    session.run("serve/checkpoint/serialize", 2, 10, || ckpt.to_json().len());
    session.run("serve/checkpoint/parse", 2, 10, || {
        Checkpoint::parse(&text).unwrap().store.n()
    });
    let dir = std::env::temp_dir().join("hsdag_bench_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.ckpt.json");
    session.run("serve/checkpoint/save+load (disk)", 2, 6, || {
        ckpt.save(&path).unwrap();
        Checkpoint::load(&path).unwrap().store.n()
    });

    session.note("== TCP loadgen (ephemeral loopback server, cache-hit path) ==");
    let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn(4).unwrap();
    let timeout = Duration::from_secs(30);
    let n = if session.is_quick() { 25 } else { 500 };
    let t0 = Instant::now();
    let mut conn = client::Connection::open(&addr, timeout).unwrap();
    for _ in 0..n {
        conn.send(&warm_line).unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let per_req_ns = secs / n as f64 * 1e9;
    session.note(&format!(
        "  {n} pipelined requests in {secs:.3}s ({:.0} req/s, {:.1} us/req)",
        n as f64 / secs,
        per_req_ns / 1e3
    ));
    // The loadgen is one aggregate measurement, so the three summary
    // statistics collapse to the per-request mean.
    session.push(BenchResult {
        name: "serve/tcp/pipelined-request".to_string(),
        iters: n,
        median_ns: per_req_ns,
        mean_ns: per_req_ns,
        min_ns: per_req_ns,
    });
    client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    handle.join().unwrap();
    let s = service.stats_view();
    session.note(&format!(
        "  server counters: {} placements, hit rate {:.1}%, {} trivial evals, \
         p50 {:.3} ms, p99 {:.3} ms",
        s.placements,
        100.0 * s.cache_hit_rate,
        s.trivial_evals,
        s.p50_ms,
        s.p99_ms
    ));

    session.note("== fleet sweep (multi-process shards, rendezvous-routed) ==");
    ckpt.save(&path).unwrap();
    fleet_sweep(&mut session, &path);

    session.finish();
}

/// One shard subprocess: the real `hsdag serve` binary on an ephemeral
/// loopback port. The stdout reader stays alive until [`shutdown`] so
/// the child's final summary `println!` can't die on a closed pipe.
struct Shard {
    child: Child,
    stdout: BufReader<ChildStdout>,
    addr: String,
}

impl Shard {
    fn spawn(ckpt: &Path) -> Shard {
        let mut child = Command::new(env!("CARGO_BIN_EXE_hsdag"))
            .args([
                "serve",
                "--load",
                ckpt.to_str().unwrap(),
                "--addr",
                "127.0.0.1:0",
                "--serve-workers",
                "2",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning shard subprocess");
        let mut stdout = BufReader::new(child.stdout.take().unwrap());
        // The serve banner ends "... listening on IP:PORT (...)".
        let addr = loop {
            let mut line = String::new();
            if stdout.read_line(&mut line).expect("reading shard banner") == 0 {
                panic!("shard exited before printing its listen address");
            }
            if let Some(rest) = line.split("listening on ").nth(1) {
                break rest.split_whitespace().next().unwrap().to_string();
            }
        };
        Shard { child, stdout, addr }
    }

    fn shutdown(mut self, timeout: Duration) {
        let _ = client::roundtrip(&self.addr, &protocol::render_shutdown_request(), timeout);
        let mut rest = String::new();
        let _ = self.stdout.read_to_string(&mut rest);
        let _ = self.child.wait();
    }
}

/// Saturation sweep: the same offered load (a fixed spec mix, routed by
/// the production rendezvous hash) against fleets of 1/2/4 shard
/// processes. Cold pass = first touch per spec (env build + policy
/// inference on the owning shard); warm passes = pipelined cache hits
/// from concurrent clients. Fleet cache disjointness is asserted, not
/// assumed: the shards' caches together must hold each spec exactly once.
fn fleet_sweep(session: &mut BenchSession, ckpt: &Path) {
    let timeout = Duration::from_secs(30);
    let specs: Vec<String> = (5..13).map(|n| format!("seq:{n}")).collect();
    let (shard_counts, rounds, threads) = if session.is_quick() {
        (vec![1usize, 2], 2usize, 2usize)
    } else {
        (vec![1usize, 2, 4], 12usize, 2usize)
    };
    for &n in &shard_counts {
        let shards: Vec<Shard> = (0..n).map(|_| Shard::spawn(ckpt)).collect();
        let addrs: Vec<String> = shards.iter().map(|s| s.addr.clone()).collect();
        let testbed = discover_testbed(&addrs, timeout).unwrap();
        // (owning shard, request line) per spec — exactly what the
        // router / sharded client would compute.
        let reqs: Vec<(usize, String)> = specs
            .iter()
            .map(|spec| {
                let g = Workload::resolve(spec).unwrap().graph;
                (
                    shard_for(fingerprint(&g, &testbed), &addrs),
                    protocol::render_place_request(
                        Some(spec.as_str()),
                        None,
                        None,
                        None,
                        None,
                        false,
                    ),
                )
            })
            .collect();

        let mut conns: Vec<client::Connection> = addrs
            .iter()
            .map(|a| client::Connection::open(a, timeout).unwrap())
            .collect();
        let mut cold: Vec<f64> = Vec::with_capacity(reqs.len());
        for (owner, line) in &reqs {
            let t0 = Instant::now();
            let resp = conns[*owner].send(line).unwrap();
            cold.push(t0.elapsed().as_nanos() as f64);
            protocol::parse_response(&resp).unwrap();
        }
        drop(conns);
        session.push(BenchResult {
            name: format!("serve/fleet/cold/shards:{n}"),
            iters: cold.len(),
            median_ns: stats::percentile(&cold, 50.0),
            mean_ns: stats::mean(&cold),
            min_ns: cold.iter().cloned().fold(f64::INFINITY, f64::min),
        });

        // Warm passes: `threads` concurrent clients, each with its own
        // pipelined connection per shard, interleaved over the spec mix.
        let work: Vec<(usize, String)> =
            (0..rounds).flat_map(|_| reqs.iter().cloned()).collect();
        let t0 = Instant::now();
        let mut warm: Vec<f64> = Vec::with_capacity(work.len());
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let addrs = &addrs;
                let chunk: Vec<(usize, String)> =
                    work.iter().skip(t).step_by(threads).cloned().collect();
                handles.push(scope.spawn(move || {
                    let mut conns: Vec<client::Connection> = addrs
                        .iter()
                        .map(|a| client::Connection::open(a, timeout).unwrap())
                        .collect();
                    let mut lat = Vec::with_capacity(chunk.len());
                    for (owner, line) in &chunk {
                        let t1 = Instant::now();
                        let resp = conns[*owner].send(line).unwrap();
                        lat.push(t1.elapsed().as_nanos() as f64);
                        protocol::parse_response(&resp).unwrap();
                    }
                    lat
                }));
            }
            for h in handles {
                warm.extend(h.join().unwrap());
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        session.push(BenchResult {
            name: format!("serve/fleet/warm/shards:{n}"),
            iters: warm.len(),
            median_ns: stats::percentile(&warm, 50.0),
            mean_ns: stats::mean(&warm),
            min_ns: warm.iter().cloned().fold(f64::INFINITY, f64::min),
        });
        session.counter(
            &format!("serve/fleet/warm/p99_ns/shards:{n}"),
            stats::percentile(&warm, 99.0),
        );
        session.counter(
            &format!("serve/fleet/warm/req_per_s/shards:{n}"),
            warm.len() as f64 / wall,
        );

        // The point of routing: fleet caches *partition* the keyspace.
        let mut cache_total = 0usize;
        for a in &addrs {
            let resp =
                client::roundtrip(a, &protocol::render_stats_request(), timeout).unwrap();
            let doc = protocol::parse_response(&resp).unwrap();
            cache_total += doc.get("cache_len").unwrap().as_usize().unwrap();
        }
        assert_eq!(
            cache_total,
            specs.len(),
            "fleet caches must hold each spec exactly once"
        );
        session.note(&format!(
            "  shards:{n}: {} warm reqs in {wall:.3}s ({:.0} req/s), \
             fleet cache_len {cache_total} (disjoint)",
            warm.len(),
            warm.len() as f64 / wall
        ));
        for s in shards {
            s.shutdown(timeout);
        }
    }
}

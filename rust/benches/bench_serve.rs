//! Serving-layer micro-bench: what does one placement request cost on
//! each of the three service paths, and how fast do checkpoints move?
//!
//!   cargo bench --bench bench_serve [-- --json --quick]
//!
//! Covers: the cold path (workload resolution + env construction +
//! batched policy inference), the cache-hit path (fingerprint + LRU
//! lookup), the budget-exhausted fallback path (baselines only), raw
//! fingerprint throughput, checkpoint serialize / parse / disk
//! round-trip, and a TCP loadgen against a live server on an ephemeral
//! loopback port — the end-to-end req/s number the ROADMAP's serving
//! goal cares about. `--json` renders everything as one `hsdag-bench-v1`
//! document; `--quick` trims iteration counts for CI smoke runs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::models::Workload;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::serve::{
    client, fingerprint, protocol, Checkpoint, CheckpointMeta, PlacementService, ServeOptions,
    Server,
};
use hsdag::util::bench::{BenchResult, BenchSession};

fn main() {
    let mut session = BenchSession::from_args("bench_serve");
    // One small trained policy drives every case.
    let cfg = Config {
        backend: "native".to_string(),
        hidden: 32,
        update_timestep: 8,
        seed: 7,
        ..Default::default()
    };
    let train_spec = "layered:6x4:1";
    let env = Env::for_workload(Workload::resolve(train_spec).unwrap(), &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    agent.search(&env, 4).unwrap();
    let ckpt = Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: train_spec.to_string(),
            best_latency: None,
        },
    );

    session.note(&format!("== request paths (in-process service, {train_spec}) =="));
    let service = Arc::new(
        PlacementService::new(ckpt.clone(), &cfg, ServeOptions::default()).unwrap(),
    );
    let cold_line =
        protocol::render_place_request(Some(train_spec), None, None, None, None, true);
    session.run("serve/place/cold (no_cache)", 2, 12, || {
        let (resp, _) = service.handle_line(&cold_line);
        resp.len()
    });
    let warm_line =
        protocol::render_place_request(Some(train_spec), None, None, None, None, false);
    let (_, _) = service.handle_line(&warm_line); // prime the cache
    session.run("serve/place/cache-hit", 3, 200, || {
        let (resp, _) = service.handle_line(&warm_line);
        resp.len()
    });
    let fallback_line =
        protocol::render_place_request(Some(train_spec), None, None, Some(0.0), None, true);
    session.run("serve/place/fallback (budget 0)", 2, 20, || {
        let (resp, _) = service.handle_line(&fallback_line);
        resp.len()
    });

    session.note("== fingerprinting ==");
    for spec in ["layered:6x4:1", "resnet"] {
        let g = Workload::resolve(spec).unwrap().graph;
        let r = session.run(&format!("serve/fingerprint/{spec}"), 3, 50, || {
            fingerprint(&g, "cpu_gpu")
        });
        session.note(&format!(
            "  -> {spec}: {} nodes, {:.1} ns/node",
            g.n(),
            r.median_ns / g.n() as f64
        ));
    }

    session.note("== checkpoint serialize / parse ==");
    let text = ckpt.to_json();
    let scalars = 3 * ckpt.store.n_scalars() + 1;
    session.note(&format!(
        "  checkpoint document: {} bytes for {scalars} scalars",
        text.len()
    ));
    session.run("serve/checkpoint/serialize", 2, 10, || ckpt.to_json().len());
    session.run("serve/checkpoint/parse", 2, 10, || {
        Checkpoint::parse(&text).unwrap().store.n()
    });
    let dir = std::env::temp_dir().join("hsdag_bench_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.ckpt.json");
    session.run("serve/checkpoint/save+load (disk)", 2, 6, || {
        ckpt.save(&path).unwrap();
        Checkpoint::load(&path).unwrap().store.n()
    });

    session.note("== TCP loadgen (ephemeral loopback server, cache-hit path) ==");
    let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn(4).unwrap();
    let timeout = Duration::from_secs(30);
    let n = if session.is_quick() { 25 } else { 500 };
    let t0 = Instant::now();
    let mut conn = client::Connection::open(&addr, timeout).unwrap();
    for _ in 0..n {
        conn.send(&warm_line).unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let per_req_ns = secs / n as f64 * 1e9;
    session.note(&format!(
        "  {n} pipelined requests in {secs:.3}s ({:.0} req/s, {:.1} us/req)",
        n as f64 / secs,
        per_req_ns / 1e3
    ));
    // The loadgen is one aggregate measurement, so the three summary
    // statistics collapse to the per-request mean.
    session.push(BenchResult {
        name: "serve/tcp/pipelined-request".to_string(),
        iters: n,
        median_ns: per_req_ns,
        mean_ns: per_req_ns,
        min_ns: per_req_ns,
    });
    client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    handle.join().unwrap();
    let s = service.stats_view();
    session.note(&format!(
        "  server counters: {} placements, hit rate {:.1}%, {} trivial evals, \
         p50 {:.3} ms, p99 {:.3} ms",
        s.placements,
        100.0 * s.cache_hit_rate,
        s.trivial_evals,
        s.p50_ms,
        s.p99_ms
    ));
    session.finish();
}

//! `cargo bench` target covering the paper-table harnesses.
//!
//! One section per table/figure (DESIGN.md §6): Table 1 (graph pipeline),
//! Table 2 (baseline placements + a short HSDAG search), Table 3 (ablation
//! feature extraction), Table 4 (numerics drift), Table 5 (per-episode
//! search cost per method), Figure 2 (parsing + DOT emission). Learned
//! searches run with a tiny episode budget — these benches measure the
//! machinery; the full-budget numbers live in EXPERIMENTS.md.

use hsdag::config::Config;
use hsdag::features::{extract, FeatureConfig};
use hsdag::harness::{figure2, table1, table4};
use hsdag::models::Benchmark;
use hsdag::rl::{BaselineAgent, BaselineKind, Env, HsdagAgent};
use hsdag::runtime::Engine;
use hsdag::sim::{numerics, Placement, CPU, DGPU};
use hsdag::util::bench::bench_fn;
use hsdag::{baselines, coarsen};

fn main() {
    println!("== Table 1: graph construction pipeline ==");
    for b in Benchmark::ALL {
        bench_fn(&format!("table1/build/{}", b.id()), 1, 10, || b.build());
    }
    let g = Benchmark::BertBase.build();
    bench_fn("table1/colocate/bert", 1, 10, || coarsen::colocate(&g));
    bench_fn("table1/render", 1, 20, || table1::run().render());

    println!("\n== Table 2: baseline placements + short HSDAG search ==");
    for b in Benchmark::ALL {
        let g = b.build();
        let tb = hsdag::sim::Testbed::paper();
        bench_fn(&format!("table2/static_baselines/{}", b.id()), 1, 10, || {
            baselines::BASELINE_NAMES.map(|m| baselines::baseline_latency(m, &g, &tb).unwrap())
        });
    }
    let cfg = Config { seed: 1, ..Default::default() };
    {
        let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
        bench_fn("table2/hsdag_search_1ep/resnet50", 0, 3, || {
            let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
            agent.search(&env, 1).unwrap().best_latency
        });
    }

    println!("\n== Table 3: ablation feature extraction ==");
    let wg = coarsen::colocate(&Benchmark::BertBase.build()).coarse;
    for (name, fcfg) in [
        ("full", FeatureConfig::default()),
        ("no_shape", FeatureConfig { no_shape: true, ..Default::default() }),
        ("no_node_id", FeatureConfig { no_node_id: true, ..Default::default() }),
        ("no_structural", FeatureConfig { no_structural: true, ..Default::default() }),
    ] {
        bench_fn(&format!("table3/features/{name}"), 1, 10, || extract(&wg, fcfg));
    }

    println!("\n== Table 4: downstream numerics ==");
    let bert = Benchmark::BertBase.build();
    bench_fn("table4/output_embedding/gpu", 1, 10, || {
        numerics::output_embedding(&bert, &Placement::all(bert.n(), DGPU))
    });
    let a = numerics::output_embedding(&bert, &Placement::all(bert.n(), CPU));
    let b = numerics::output_embedding(&bert, &Placement::all(bert.n(), DGPU));
    bench_fn("table4/drift_metrics", 10, 100, || numerics::drift(&a, &b));
    bench_fn("table4/full", 1, 5, || table4::run(&cfg, None).unwrap());

    println!("\n== Table 5: per-episode search cost by method ==");
    {
        let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
        bench_fn("table5/episode/hsdag/resnet50", 0, 3, || {
            let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
            agent.search(&env, 1).unwrap().wall_secs
        });
        // The learned baselines exist only as AOT artifacts (pjrt path).
        if let Ok(mut engine) = Engine::cpu(&cfg.artifacts_dir) {
            for kind in [BaselineKind::Placeto, BaselineKind::Rnn] {
                bench_fn(&format!("table5/episode/{}/resnet50", kind.id()), 0, 3, || {
                    let mut agent = BaselineAgent::new(&env, &mut engine, &cfg, kind).unwrap();
                    agent.search(&env, &mut engine, 1).unwrap().wall_secs
                });
            }
        } else {
            println!("  (artifacts missing: skipping Placeto/RNN baseline benches)");
        }
    }

    println!("\n== Figure 2: parsing + DOT emission ==");
    let dir = std::env::temp_dir().join("hsdag_bench_fig2");
    bench_fn("figure2/untrained_all", 0, 3, || {
        figure2::run_untrained(dir.to_str().unwrap()).unwrap()
    });
}

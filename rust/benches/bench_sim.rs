//! Scheduler micro-bench: the lazy-BinaryHeap list scheduler (`execute`)
//! against the retained linear re-scan reference (`execute_reference`),
//! on the three paper benchmarks across every registered testbed plus a
//! wide synthetic DAG where the ready set actually gets large (the
//! re-scan is O(|ready|) per scheduled op, so wide graphs are where the
//! heap pays off) — and the batched cost-model paths: parallel
//! `evaluate_many` / `measure_many` against their serial loops, asserted
//! bit-identical.
//!
//!   cargo bench --bench bench_sim
//!
//! Quote the heap/ vs scan/ and serial/ vs parallel/ lines as the
//! before/after in perf notes.

use hsdag::baselines::random_placement;
use hsdag::graph::CompGraph;
use hsdag::models::Benchmark;
use hsdag::sim::{
    execute, execute_reference, measure, request_rng, AnalyticCostModel, CostModel,
    ParallelCostModel, Testbed,
};
use hsdag::util::bench::bench_fn;
use hsdag::util::Rng;

fn main() {
    println!("== benchmark graphs ==");
    for tb in Testbed::registered() {
        for b in Benchmark::ALL {
            let g = b.build();
            let mut rng = Rng::new(11);
            let p = random_placement(&g, &tb, &mut rng);
            let heap = bench_fn(&format!("sim/heap/{}/{}", tb.id, b.id()), 3, 30, || {
                execute(&g, &p, &tb).makespan
            });
            let scan = bench_fn(&format!("sim/scan/{}/{}", tb.id, b.id()), 3, 30, || {
                execute_reference(&g, &p, &tb).makespan
            });
            println!(
                "  -> heap/scan median ratio {:.2}x",
                scan.median_ns / heap.median_ns.max(1.0)
            );
            // The two schedulers must agree exactly (also enforced by the
            // differential tests in sim::scheduler).
            assert_eq!(
                execute(&g, &p, &tb).makespan,
                execute_reference(&g, &p, &tb).makespan
            );
        }
    }

    println!("\n== wide synthetic DAG (large ready set) ==");
    let mut rng = Rng::new(5);
    let g = CompGraph::random(&mut rng, 3000, 1500);
    let tb = Testbed::multi_gpu(8);
    let p = random_placement(&g, &tb, &mut rng);
    let heap = bench_fn("sim/heap/random3k/multi_gpu:8", 2, 15, || {
        execute(&g, &p, &tb).makespan
    });
    let scan = bench_fn("sim/scan/random3k/multi_gpu:8", 2, 15, || {
        execute_reference(&g, &p, &tb).makespan
    });
    println!(
        "  -> heap/scan median ratio {:.2}x",
        scan.median_ns / heap.median_ns.max(1.0)
    );

    println!("\n== batched evaluation: serial loop vs parallel worker pool ==");
    let serial = AnalyticCostModel;
    let parallel = ParallelCostModel::new(AnalyticCostModel, 0);
    let g = Benchmark::ResNet50.build();
    let tb = Testbed::multi_gpu(4);
    let mut rng = Rng::new(17);
    let placements: Vec<_> = (0..64).map(|_| random_placement(&g, &tb, &mut rng)).collect();

    let s = bench_fn("sim/evaluate_many/serial/resnet50 x64", 1, 8, || {
        serial.evaluate_many(&g, &placements, &tb).len()
    });
    let p = bench_fn("sim/evaluate_many/parallel/resnet50 x64", 1, 8, || {
        parallel.evaluate_many(&g, &placements, &tb).len()
    });
    println!("  -> parallel speedup {:.2}x", s.median_ns / p.median_ns.max(1.0));
    // Identical results, report for report (also enforced in the tests).
    assert_eq!(
        serial.evaluate_many(&g, &placements, &tb),
        parallel.evaluate_many(&g, &placements, &tb)
    );

    // Request-stream serving: the naive per-request `measure` loop (one
    // full simulation per request — the pre-cost-model serving path)
    // against `measure_many`, which simulates the invariant base once.
    let p0 = &placements[0];
    let s = bench_fn("sim/measure_stream/per-request-loop/resnet50 x256", 1, 8, || {
        (0..256)
            .map(|i| measure(&g, p0, &tb, 0.03, &mut request_rng(7, i)))
            .sum::<f64>()
    });
    let p = bench_fn("sim/measure_stream/measure_many/resnet50 x256", 1, 8, || {
        parallel.measure_many(&g, p0, &tb, 0.03, 7, 256).iter().sum::<f64>()
    });
    println!("  -> measure_many speedup {:.2}x", s.median_ns / p.median_ns.max(1.0));
    let naive: Vec<f64> =
        (0..256).map(|i| measure(&g, p0, &tb, 0.03, &mut request_rng(7, i))).collect();
    assert_eq!(naive, serial.measure_many(&g, p0, &tb, 0.03, 7, 256));
    assert_eq!(naive, parallel.measure_many(&g, p0, &tb, 0.03, 7, 256));
}

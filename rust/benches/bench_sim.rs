//! Scheduler micro-bench: the lazy-BinaryHeap list scheduler (`execute`)
//! against the retained linear re-scan reference (`execute_reference`),
//! on the three paper benchmarks across every registered testbed plus a
//! wide synthetic DAG where the ready set actually gets large (the
//! re-scan is O(|ready|) per scheduled op, so wide graphs are where the
//! heap pays off) — the batched cost-model paths: parallel
//! `evaluate_many` / `measure_many` against their serial loops, asserted
//! bit-identical — and the incremental re-simulation scaling curve
//! (`IncrementalEvaluator` after a small placement edit vs a full
//! re-simulation, asserted report-identical).
//!
//!   cargo bench --bench bench_sim
//!   cargo bench --bench bench_sim -- --json --quick   # hsdag-bench-v1 doc
//!
//! Quote the heap/ vs scan/ and serial/ vs parallel/ lines as the
//! before/after in perf notes.

use hsdag::baselines::random_placement;
use hsdag::graph::CompGraph;
use hsdag::models::{Benchmark, Workload};
use hsdag::sim::{
    execute, execute_reference, measure, request_rng, AnalyticCostModel, CostModel,
    IncrementalEvaluator, ParallelCostModel, Placement, Testbed,
};
use hsdag::util::bench::BenchSession;
use hsdag::util::Rng;

fn main() {
    let mut s = BenchSession::from_args("bench_sim");

    s.note("== benchmark graphs ==");
    for tb in Testbed::registered() {
        for b in Benchmark::ALL {
            let g = b.build();
            let mut rng = Rng::new(11);
            let p = random_placement(&g, &tb, &mut rng);
            let heap = s.run(&format!("sim/heap/{}/{}", tb.id, b.id()), 3, 30, || {
                execute(&g, &p, &tb).makespan
            });
            let scan = s.run(&format!("sim/scan/{}/{}", tb.id, b.id()), 3, 30, || {
                execute_reference(&g, &p, &tb).makespan
            });
            s.note(&format!(
                "  -> heap/scan median ratio {:.2}x",
                scan.median_ns / heap.median_ns.max(1.0)
            ));
            // The two schedulers must agree exactly (also enforced by the
            // differential tests in sim::scheduler).
            assert_eq!(execute(&g, &p, &tb).makespan, execute_reference(&g, &p, &tb).makespan);
        }
    }

    s.note("\n== wide synthetic DAG (large ready set) ==");
    let mut rng = Rng::new(5);
    let g = CompGraph::random(&mut rng, 3000, 1500);
    let tb = Testbed::multi_gpu(8);
    let p = random_placement(&g, &tb, &mut rng);
    let heap = s.run("sim/heap/random3k/multi_gpu:8", 2, 15, || execute(&g, &p, &tb).makespan);
    let scan = s.run("sim/scan/random3k/multi_gpu:8", 2, 15, || {
        execute_reference(&g, &p, &tb).makespan
    });
    s.note(&format!(
        "  -> heap/scan median ratio {:.2}x",
        scan.median_ns / heap.median_ns.max(1.0)
    ));

    s.note("\n== batched evaluation: serial loop vs parallel worker pool ==");
    let serial = AnalyticCostModel;
    let parallel = ParallelCostModel::new(AnalyticCostModel, 0);
    let g = Benchmark::ResNet50.build();
    let tb = Testbed::multi_gpu(4);
    let mut rng = Rng::new(17);
    let placements: Vec<_> = (0..64).map(|_| random_placement(&g, &tb, &mut rng)).collect();

    let ser = s.run("sim/evaluate_many/serial/resnet50 x64", 1, 8, || {
        serial.evaluate_many(&g, &placements, &tb).len()
    });
    let par = s.run("sim/evaluate_many/parallel/resnet50 x64", 1, 8, || {
        parallel.evaluate_many(&g, &placements, &tb).len()
    });
    s.note(&format!("  -> parallel speedup {:.2}x", ser.median_ns / par.median_ns.max(1.0)));
    // Identical results, report for report (also enforced in the tests).
    assert_eq!(
        serial.evaluate_many(&g, &placements, &tb),
        parallel.evaluate_many(&g, &placements, &tb)
    );

    // Request-stream serving: the naive per-request `measure` loop (one
    // full simulation per request — the pre-cost-model serving path)
    // against `measure_many`, which simulates the invariant base once.
    let p0 = &placements[0];
    let ser = s.run("sim/measure_stream/per-request-loop/resnet50 x256", 1, 8, || {
        (0..256).map(|i| measure(&g, p0, &tb, 0.03, &mut request_rng(7, i))).sum::<f64>()
    });
    let par = s.run("sim/measure_stream/measure_many/resnet50 x256", 1, 8, || {
        parallel.measure_many(&g, p0, &tb, 0.03, 7, 256).iter().sum::<f64>()
    });
    s.note(&format!("  -> measure_many speedup {:.2}x", ser.median_ns / par.median_ns.max(1.0)));
    let naive: Vec<f64> =
        (0..256).map(|i| measure(&g, p0, &tb, 0.03, &mut request_rng(7, i))).collect();
    assert_eq!(naive, serial.measure_many(&g, p0, &tb, 0.03, 7, 256));
    assert_eq!(naive, parallel.measure_many(&g, p0, &tb, 0.03, 7, 256));

    // ---------------------------------------------------------------
    // Incremental re-simulation scaling: flip one late node's device
    // and re-evaluate. The incremental path replays the memoized event
    // prefix and only re-simulates the affected suffix; the full path
    // re-runs the whole schedule. Reports are asserted identical.
    // ---------------------------------------------------------------
    s.note("\n== incremental re-simulation after a one-node edit ==");
    let sizes: &[usize] = if s.is_quick() { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    let tb = Testbed::cpu_gpu();
    for &n in sizes {
        let spec = format!("random:{n}:1");
        let g = Workload::resolve(&spec).unwrap().graph;
        let base: Vec<usize> = (0..g.n()).map(|v| tb.placeable[v % tb.placeable.len()]).collect();
        // Edit a node near the sink so the unaffected prefix is long.
        let victim = g.n() - 2;
        let mut edited = base.clone();
        edited[victim] =
            if edited[victim] == tb.placeable[0] { tb.placeable[1] } else { tb.placeable[0] };

        let (warmup, iters) = if n >= 100_000 { (1, 3) } else { (1, 5) };
        let mut eval = IncrementalEvaluator::new(g.clone(), tb.clone());
        eval.evaluate(&base); // prime the memo
        let mut flip = false;
        let inc = s.run(&format!("sim/incremental/edit1/{spec}"), warmup, iters, || {
            // Alternate between the two placements so every iteration
            // really is a one-node delta against the previous memo.
            flip = !flip;
            eval.evaluate(if flip { &edited } else { &base }).makespan
        });
        let full = s.run(&format!("sim/full/edit1/{spec}"), warmup, iters, || {
            execute(&g, &Placement(edited.clone()), &tb).makespan
        });
        s.note(&format!(
            "  -> incremental/full median ratio {:.2}x",
            full.median_ns / inc.median_ns.max(1.0)
        ));
        // Bit-identical to full re-evaluation (also property-tested in
        // sim::scheduler).
        let mut eval = IncrementalEvaluator::new(g.clone(), tb.clone());
        eval.evaluate(&base);
        assert_eq!(eval.evaluate(&edited), execute(&g, &Placement(edited.clone()), &tb));
    }

    s.finish();
}

//! Scheduler micro-bench: the lazy-BinaryHeap list scheduler (`execute`)
//! against the retained linear re-scan reference (`execute_reference`),
//! on the three paper benchmarks across every registered testbed plus a
//! wide synthetic DAG where the ready set actually gets large (the
//! re-scan is O(|ready|) per scheduled op, so wide graphs are where the
//! heap pays off).
//!
//!   cargo bench --bench bench_sim
//!
//! Quote the heap/ vs scan/ lines as the before/after in perf notes.

use hsdag::baselines::random_placement;
use hsdag::graph::CompGraph;
use hsdag::models::Benchmark;
use hsdag::sim::{execute, execute_reference, Testbed};
use hsdag::util::bench::bench_fn;
use hsdag::util::Rng;

fn main() {
    println!("== benchmark graphs ==");
    for tb in Testbed::registered() {
        for b in Benchmark::ALL {
            let g = b.build();
            let mut rng = Rng::new(11);
            let p = random_placement(&g, &tb, &mut rng);
            let heap = bench_fn(&format!("sim/heap/{}/{}", tb.id, b.id()), 3, 30, || {
                execute(&g, &p, &tb).makespan
            });
            let scan = bench_fn(&format!("sim/scan/{}/{}", tb.id, b.id()), 3, 30, || {
                execute_reference(&g, &p, &tb).makespan
            });
            println!(
                "  -> heap/scan median ratio {:.2}x",
                scan.median_ns / heap.median_ns.max(1.0)
            );
            // The two schedulers must agree exactly (also enforced by the
            // differential tests in sim::scheduler).
            assert_eq!(
                execute(&g, &p, &tb).makespan,
                execute_reference(&g, &p, &tb).makespan
            );
        }
    }

    println!("\n== wide synthetic DAG (large ready set) ==");
    let mut rng = Rng::new(5);
    let g = CompGraph::random(&mut rng, 3000, 1500);
    let tb = Testbed::multi_gpu(8);
    let p = random_placement(&g, &tb, &mut rng);
    let heap = bench_fn("sim/heap/random3k/multi_gpu:8", 2, 15, || {
        execute(&g, &p, &tb).makespan
    });
    let scan = bench_fn("sim/scan/random3k/multi_gpu:8", 2, 15, || {
        execute_reference(&g, &p, &tb).makespan
    });
    println!(
        "  -> heap/scan median ratio {:.2}x",
        scan.median_ns / heap.median_ns.max(1.0)
    );
}

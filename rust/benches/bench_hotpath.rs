//! `cargo bench` target for the system hot paths (the §Perf targets):
//!
//!   L3: simulator executes/sec, Algorithm-2 parsing, feature extraction,
//!       normalized adjacency, co-location — everything on the per-step
//!       critical path of the search loop.
//!   L2/L1: policy fwd, placer, and train-step execution latency through
//!       whichever backend the config resolves to (native kernels by
//!       default; the AOT artifacts via PJRT when artifacts/ exists).
//!       Per-kernel native timings live in benches/bench_policy.rs.

use hsdag::config::Config;
use hsdag::features::{extract, normalized_adjacency, FeatureConfig};
use hsdag::models::Benchmark;
use hsdag::parsing::parse;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::baselines::random_placement;
use hsdag::sim::{execute, Testbed};
use hsdag::util::bench::bench_fn;
use hsdag::util::Rng;

fn main() {
    println!("== L3 hot paths ==");
    let tb = Testbed::paper();
    for b in Benchmark::ALL {
        let g = b.build();
        let mut rng = Rng::new(7);
        let placement = random_placement(&g, &tb, &mut rng);
        bench_fn(&format!("sim/execute/{}", b.id()), 3, 30, || {
            execute(&g, &placement, &tb).makespan
        });
    }

    let wg = hsdag::coarsen::colocate(&Benchmark::BertBase.build()).coarse;
    let mut rng = Rng::new(9);
    let scores: Vec<f32> = (0..wg.m()).map(|_| rng.next_f32()).collect();
    bench_fn("parsing/parse/bert_coarse", 3, 100, || parse(&wg, &scores));
    bench_fn("features/extract/bert_coarse", 1, 10, || {
        extract(&wg, FeatureConfig::default())
    });
    bench_fn("features/a_norm/bert_coarse", 1, 10, || normalized_adjacency(&wg));

    println!("\n== L2/L1 policy execution (resolved backend) ==");
    let cfg = Config { seed: 2, ..Default::default() };
    for b in Benchmark::ALL {
        let env = Env::new(b, &cfg).unwrap();
        let mut agent = match HsdagAgent::new(&env, &cfg) {
            Ok(a) => a,
            Err(e) => {
                println!("  (skipping {}: {e:#})", b.id());
                continue;
            }
        };
        println!("  backend: {}", agent.backend_desc());
        // One full step = fwd + parse + placer + sample + simulate.
        bench_fn(&format!("step/full/{}", b.id()), 1, 10, || {
            agent.step(&env, true).unwrap().latency
        });
        bench_fn(&format!("train/update/{}", b.id()), 0, 3, || {
            // Re-prime and update (measures the train step + the
            // parameter round-trip).
            for _ in 0..cfg.update_timestep {
                agent.step(&env, true).unwrap();
            }
            agent.update(&env).unwrap()
        });
    }
}

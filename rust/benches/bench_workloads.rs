//! Workload-subsystem micro-bench: graph load / generate / feature-extract
//! throughput for the registry sources — how fast can the system open a
//! new workload?
//!
//!   cargo bench --bench bench_workloads
//!
//! Covers: the synthetic generators (pure CPU), JSON serialize + parse of
//! a paper-sized graph, the `file:` source end to end (disk read + parse
//! + validate), the DOT round-trip, and feature extraction + coarsening
//! on the loaded graphs — the per-workload setup cost that fronts every
//! search.

use hsdag::coarsen::colocate;
use hsdag::features::{extract, FeatureConfig};
use hsdag::graph::{dot, json};
use hsdag::models::{Benchmark, Workload};
use hsdag::util::bench::bench_fn;

fn main() {
    println!("== synthetic generators ==");
    for spec in ["seq:256", "layered:16x8:3", "transformer:4:4", "random:256:9"] {
        let r = bench_fn(&format!("workload/generate/{spec}"), 3, 20, || {
            Workload::resolve(spec).unwrap().graph.n()
        });
        let n = Workload::resolve(spec).unwrap().graph.n();
        println!("  -> {spec}: {n} nodes, {:.1} us/node", r.median_ns / 1e3 / n as f64);
    }

    println!("== serialize / parse (ResNet-50, Table-1 size) ==");
    let g = Benchmark::ResNet50.build();
    let text = json::to_json(&g);
    println!("  JSON document: {} bytes for {} nodes", text.len(), g.n());
    bench_fn("workload/json/serialize/resnet50", 3, 20, || json::to_json(&g).len());
    bench_fn("workload/json/parse/resnet50", 3, 20, || json::from_json(&text).unwrap().n());
    let dot_text = dot::to_dot(&g);
    bench_fn("workload/dot/serialize/resnet50", 3, 20, || dot::to_dot(&g).len());
    bench_fn("workload/dot/parse/resnet50", 3, 20, || dot::from_dot(&dot_text).unwrap().n());
    // Parsers must reproduce the graph they serialized.
    assert_eq!(json::from_json(&text).unwrap().edges, g.edges);
    assert_eq!(dot::from_dot(&dot_text).unwrap().edges, g.edges);

    println!("== file source end to end (disk read + parse + validate) ==");
    let dir = std::env::temp_dir().join("hsdag_bench_workloads");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resnet50.json");
    std::fs::write(&path, &text).unwrap();
    let spec = format!("file:{}", path.display());
    bench_fn("workload/file/resnet50.json", 3, 20, || {
        Workload::resolve(&spec).unwrap().graph.n()
    });

    println!("== per-workload setup: coarsen + feature extraction ==");
    for spec in ["resnet", "layered:16x8:3", "transformer:4:4"] {
        let w = Workload::resolve(spec).unwrap();
        bench_fn(&format!("workload/coarsen/{spec}"), 3, 20, || colocate(&w.graph).n_sets);
        let colo = colocate(&w.graph);
        bench_fn(&format!("workload/features/{spec}"), 3, 20, || {
            extract(&colo.coarse, FeatureConfig::default()).x.len()
        });
    }
}

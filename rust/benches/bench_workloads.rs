//! Workload-subsystem micro-bench: graph load / generate / feature-extract
//! throughput for the registry sources — how fast can the system open a
//! new workload? — plus the pipeline scaling curve (generate -> features
//! -> coarsen -> evaluate at n = 1k / 10k / 100k) behind
//! BENCH_SCALING.json.
//!
//!   cargo bench --bench bench_workloads               # human report
//!   cargo bench --bench bench_workloads -- --json --quick
//!                                                     # hsdag-bench-v1 doc
//!
//! `--quick` trims the scaling tier to 1k / 10k so CI can assert the
//! growth stays near-linear in seconds; the full run adds the 100k tier
//! (regenerate BENCH_SCALING.json from it, never by hand).
//!
//! Covers: the synthetic generators (pure CPU), JSON serialize + parse of
//! a paper-sized graph, the `file:` source end to end (disk read + parse
//! + validate), the DOT round-trip, and feature extraction + coarsening
//! on the loaded graphs — the per-workload setup cost that fronts every
//! search.

use hsdag::coarsen::{coarsen_to_budget, colocate, DEFAULT_COARSEN_BUDGET};
use hsdag::features::{extract, FeatureConfig};
use hsdag::graph::{dot, json};
use hsdag::models::{Benchmark, Workload};
use hsdag::runtime::nn::normalized_adjacency_csr;
use hsdag::sim::{execute, Placement, Testbed};
use hsdag::util::bench::BenchSession;

fn main() {
    let mut s = BenchSession::from_args("bench_workloads");

    s.note("== synthetic generators ==");
    for spec in ["seq:256", "layered:16x8:3", "transformer:4:4", "random:256:9"] {
        let r = s.run(&format!("workload/generate/{spec}"), 3, 20, || {
            Workload::resolve(spec).unwrap().graph.n()
        });
        let n = Workload::resolve(spec).unwrap().graph.n();
        s.note(&format!("  -> {spec}: {n} nodes, {:.1} us/node", r.median_ns / 1e3 / n as f64));
    }

    s.note("== serialize / parse (ResNet-50, Table-1 size) ==");
    let g = Benchmark::ResNet50.build();
    let text = json::to_json(&g);
    s.note(&format!("  JSON document: {} bytes for {} nodes", text.len(), g.n()));
    s.run("workload/json/serialize/resnet50", 3, 20, || json::to_json(&g).len());
    s.run("workload/json/parse/resnet50", 3, 20, || json::from_json(&text).unwrap().n());
    let dot_text = dot::to_dot(&g);
    s.run("workload/dot/serialize/resnet50", 3, 20, || dot::to_dot(&g).len());
    s.run("workload/dot/parse/resnet50", 3, 20, || dot::from_dot(&dot_text).unwrap().n());
    // Parsers must reproduce the graph they serialized.
    assert_eq!(json::from_json(&text).unwrap().edges, g.edges);
    assert_eq!(dot::from_dot(&dot_text).unwrap().edges, g.edges);

    s.note("== file source end to end (disk read + parse + validate) ==");
    let dir = std::env::temp_dir().join("hsdag_bench_workloads");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resnet50.json");
    std::fs::write(&path, &text).unwrap();
    let spec = format!("file:{}", path.display());
    s.run("workload/file/resnet50.json", 3, 20, || Workload::resolve(&spec).unwrap().graph.n());

    s.note("== per-workload setup: coarsen + feature extraction ==");
    for spec in ["resnet", "layered:16x8:3", "transformer:4:4"] {
        let w = Workload::resolve(spec).unwrap();
        s.run(&format!("workload/coarsen/{spec}"), 3, 20, || colocate(&w.graph).n_sets);
        let colo = colocate(&w.graph);
        s.run(&format!("workload/features/{spec}"), 3, 20, || {
            extract(&colo.coarse, FeatureConfig::default()).x.len()
        });
    }

    // ---------------------------------------------------------------
    // Pipeline scaling curve: every stage at 1k / 10k (/ 100k without
    // --quick). Each stage must grow near-linearly — the snapshot (and
    // CI's growth gate on the quick tier) is the regression fence
    // against anything O(n^2) sneaking back onto the default path.
    // ---------------------------------------------------------------
    s.note("== pipeline scaling curve (generate / features / coarsen / evaluate) ==");
    let sizes: &[usize] = if s.is_quick() { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    let tb = Testbed::cpu_gpu();
    for &n in sizes {
        let spec = format!("random:{n}:1");
        let (warmup, iters) = if n >= 100_000 { (1, 3) } else { (1, 5) };
        s.run(&format!("scaling/generate/{spec}"), warmup, iters, || {
            Workload::resolve(&spec).unwrap().graph.n()
        });
        let g = Workload::resolve(&spec).unwrap().graph;
        // Feature extraction on the raw graph: exercises the sampled
        // (landmark) fractal path past FRACTAL_EXACT_THRESHOLD.
        s.run(&format!("scaling/features/{spec}"), warmup, iters, || {
            extract(&g, FeatureConfig::default()).x.len()
        });
        s.run(&format!("scaling/coarsen/{spec}"), warmup, iters, || {
            coarsen_to_budget(&g, DEFAULT_COARSEN_BUDGET).flatten().n_sets
        });
        let p = Placement((0..g.n()).map(|v| tb.placeable[v % tb.placeable.len()]).collect());
        s.run(&format!("scaling/evaluate/{spec}"), warmup, iters, || {
            execute(&g, &p, &tb).makespan
        });
        // Peak-memory proxies: the sparse operator and the feature
        // matrix are the two largest live buffers on the native path.
        let csr = normalized_adjacency_csr(g.n(), &g.edges);
        s.counter(&format!("scaling/bytes/csr/{spec}"), csr.bytes() as f64);
        let feats = extract(&g, FeatureConfig::default());
        s.counter(&format!("scaling/bytes/features/{spec}"), (feats.x.len() * 4) as f64);
        s.counter(&format!("scaling/edges/{spec}"), g.m() as f64);
    }

    s.finish();
}

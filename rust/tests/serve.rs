//! Placement-service integration tests: the persistence contract.
//!
//! - checkpoint save → load is a bit-identical `ParamStore` round-trip,
//!   and corrupt / truncated files are located errors, never panics;
//! - fingerprints are deterministic across runs and sensitive to exactly
//!   the structure they hash (edge flip, kind change, shape change,
//!   testbed change — but NOT node renaming);
//! - the in-process service serves policy placements, answers repeats
//!   from the cache, falls back under an exhausted budget, and counts it
//!   all in its stats;
//! - the TCP server round-trips the wire protocol and shuts down cleanly;
//! - the acceptance proof: a policy trained and saved by one *process* is
//!   loaded by `hsdag serve` in a fresh process, beats-or-ties every
//!   static single-device deployment on the training workload (the
//!   service's structural guarantee — provenance reports whether the
//!   policy itself won), and answers the repeated identical request from
//!   the cache without re-running inference.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::models::Workload;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::serve::{
    client, fingerprint, protocol, Checkpoint, CheckpointMeta, PlacementService, ServeOptions,
    Server,
};
use hsdag::sim::{execute, Placement, Testbed};
use hsdag::util::json::Json;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hsdag_serve_test_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train a small native policy and wrap it as a checkpoint.
fn tiny_checkpoint(train_spec: &str, episodes: usize) -> (Checkpoint, Config) {
    let cfg = Config {
        backend: "native".to_string(),
        hidden: 16,
        update_timestep: 4,
        seed: 5,
        ..Default::default()
    };
    let env = Env::for_workload(Workload::resolve(train_spec).unwrap(), &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    agent.search(&env, episodes).unwrap();
    let ckpt = Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: train_spec.to_string(),
            best_latency: None,
        },
    );
    (ckpt, cfg)
}

#[test]
fn checkpoint_roundtrips_bit_identically_through_disk() {
    let (ckpt, _) = tiny_checkpoint("layered:3x3:1", 2);
    // Training ran, so params moved and the Adam moments are non-zero —
    // the round-trip is exercised on non-trivial float values.
    assert!(ckpt.store.step > 0.0);
    assert!(ckpt.store.m.iter().any(|t| t.as_f32().iter().any(|&x| x != 0.0)));
    let path = tmp_dir("roundtrip").join("ckpt.json");
    ckpt.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back.store.step, ckpt.store.step);
    assert_eq!(back.store.names, ckpt.store.names);
    for i in 0..ckpt.store.n() {
        // Bit-identical: f32 -> JSON text -> f32 must be exact.
        assert_eq!(back.store.params[i].as_f32(), ckpt.store.params[i].as_f32(), "params {i}");
        assert_eq!(back.store.m[i].as_f32(), ckpt.store.m[i].as_f32(), "m {i}");
        assert_eq!(back.store.v[i].as_f32(), ckpt.store.v[i].as_f32(), "v {i}");
    }
}

#[test]
fn corrupt_and_truncated_checkpoint_files_are_errors() {
    let (ckpt, _) = tiny_checkpoint("seq:8", 1);
    let dir = tmp_dir("corrupt");
    let good = ckpt.to_json();
    for (name, text) in [
        ("truncated.json", &good[..good.len() / 3]),
        ("garbage.json", "not even json {"),
        ("empty.json", ""),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(name), "{name}: {msg}");
    }
    // A wrong-format file names the expected tag.
    let path = dir.join("wrong_tag.json");
    std::fs::write(&path, good.replace("hsdag-params-v1", "hsdag-params-v0")).unwrap();
    let msg = format!("{:#}", Checkpoint::load(&path).unwrap_err());
    assert!(msg.contains("hsdag-params-v1"), "{msg}");
}

#[test]
fn fingerprints_are_deterministic_and_structure_sensitive() {
    // Determinism across independent resolves of the same spec.
    let a = Workload::resolve("transformer:2:2").unwrap().graph;
    let b = Workload::resolve("transformer:2:2").unwrap().graph;
    assert_eq!(fingerprint(&a, "cpu_gpu"), fingerprint(&b, "cpu_gpu"));

    // Renaming every node does not move the hash...
    let mut renamed = a.clone();
    for (i, n) in renamed.nodes.iter_mut().enumerate() {
        n.name = format!("renamed_{i}");
    }
    assert_eq!(fingerprint(&a, "cpu_gpu"), fingerprint(&renamed, "cpu_gpu"));

    // ...but structure, op identity, shapes and the testbed all do.
    let base = fingerprint(&a, "cpu_gpu");
    let mut edge_flip = a.clone();
    let (s, t) = edge_flip.edges[0];
    edge_flip.edges[0] = (s, (t + 1) % edge_flip.n());
    let mut kind_change = a.clone();
    kind_change.nodes[1].kind = if kind_change.nodes[1].kind == hsdag::graph::OpKind::Softmax {
        hsdag::graph::OpKind::Relu
    } else {
        hsdag::graph::OpKind::Softmax
    };
    let mut shape_change = a.clone();
    shape_change.nodes[1].output_shape.push(2);
    for (label, fp) in [
        ("edge flip", fingerprint(&edge_flip, "cpu_gpu")),
        ("kind change", fingerprint(&kind_change, "cpu_gpu")),
        ("shape change", fingerprint(&shape_change, "cpu_gpu")),
        ("testbed change", fingerprint(&a, "paper3")),
    ] {
        assert_ne!(fp, base, "{label} did not change the fingerprint");
    }
}

#[test]
fn service_serves_caches_falls_back_and_counts() {
    let (ckpt, cfg) = tiny_checkpoint("layered:4x3:2", 2);
    let service =
        PlacementService::new(ckpt, &cfg, ServeOptions { cache_capacity: 8, ..Default::default() })
            .unwrap();

    let place = |line: &str| -> Json {
        let (resp, shut) = service.handle_line(line);
        assert!(!shut);
        Json::parse(&resp).unwrap()
    };

    // Cold: inference runs; the exact provenance (policy vs fallback)
    // depends on training quality, but it is never "cache".
    let line = protocol::render_place_request(Some("layered:4x3:2"), None, None, None, None, false);
    let d1 = place(&line);
    assert_eq!(d1.get("ok").unwrap().as_bool(), Some(true));
    let prov1 = d1.get("provenance").unwrap().as_str().unwrap().to_string();
    assert_ne!(prov1, "cache");
    assert_eq!(d1.get("feasible").unwrap().as_bool(), Some(true));
    let lat1 = d1.get("latency_s").unwrap().as_f64().unwrap();
    let ref1 = d1.get("ref_latency_s").unwrap().as_f64().unwrap();
    assert!(lat1.is_finite() && lat1 > 0.0 && ref1 > 0.0);
    // Structural guarantee: never worse than any single-device deployment.
    let g = Workload::resolve("layered:4x3:2").unwrap().graph;
    let tb = Testbed::by_id(&service.config().testbed).unwrap();
    let best_single = tb
        .placeable
        .iter()
        .map(|&d| execute(&g, &Placement::all(g.n(), d), &tb).makespan)
        .fold(f64::INFINITY, f64::min);
    assert!(lat1 <= best_single + 1e-12, "served {lat1}, best single {best_single}");

    // Repeat: answered from the cache, same numbers.
    let d2 = place(&line);
    assert_eq!(d2.get("provenance").unwrap().as_str(), Some("cache"));
    assert_eq!(d2.get("latency_s").unwrap().as_f64(), Some(lat1));
    assert_eq!(
        d2.get("fingerprint").unwrap().as_str(),
        d1.get("fingerprint").unwrap().as_str()
    );

    // no_cache bypasses the cache in both directions.
    let line_nc =
        protocol::render_place_request(Some("layered:4x3:2"), None, None, None, None, true);
    let d3 = place(&line_nc);
    assert_ne!(d3.get("provenance").unwrap().as_str(), Some("cache"));

    // Budget 0: the policy stage is skipped, a baseline is served — and
    // the degraded answer must NOT enter the cache.
    let line_b0 =
        protocol::render_place_request(Some("random:24:4"), None, None, Some(0.0), None, false);
    let d4 = place(&line_b0);
    let prov4 = d4.get("provenance").unwrap().as_str().unwrap();
    assert!(prov4.starts_with("fallback:"), "{prov4}");
    // The same graph without a budget runs the full pipeline (no cache
    // poisoning by the truncated request above).
    let line_full =
        protocol::render_place_request(Some("random:24:4"), None, None, None, None, false);
    let d5 = place(&line_full);
    assert_ne!(d5.get("provenance").unwrap().as_str(), Some("cache"));

    // Unknown workloads are error responses naming the registry problem.
    let bad = place(&protocol::render_place_request(
        Some("warehouse"),
        None,
        None,
        None,
        None,
        false,
    ));
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    assert!(bad.get("error").unwrap().as_str().unwrap().contains("workload"));

    // Stats saw all of it.
    let s = service.stats_view();
    assert_eq!(s.requests, 6);
    assert_eq!(s.placements, 5);
    assert_eq!(s.cache_hits, 1);
    assert!(s.fallbacks >= 1);
    assert_eq!(s.errors, 1);
    // Cached: the layered cold answer and the full random:24:4 answer —
    // not the no_cache repeat, not the budget-truncated one.
    assert_eq!(s.cache_len, 2);
    assert!(s.p99_ms >= s.p50_ms);

    // The ctrl message acknowledges and raises the shutdown flag.
    let (resp, shut) = service.handle_line(&protocol::render_shutdown_request());
    assert!(shut);
    assert!(Json::parse(&resp).unwrap().get("ok").unwrap().as_bool().unwrap());
}

#[test]
fn trivial_candidates_evaluate_once_per_fingerprint() {
    let (ckpt, cfg) = tiny_checkpoint("layered:3x3:1", 2);
    let service = PlacementService::new(
        ckpt,
        &cfg,
        ServeOptions { cache_capacity: 8, ..Default::default() },
    )
    .unwrap();

    // A knob-overridden request: its *answer* must never be cached, but
    // the single-device + memory-greedy evaluations are knob-independent
    // and enter the fingerprint's cache entry.
    let line =
        protocol::render_place_request(Some("layered:3x3:1"), None, None, None, Some(1), false);
    let (resp, _) = service.handle_line(&line);
    assert_eq!(Json::parse(&resp).unwrap().get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(service.stats_view().trivial_evals, 1);

    // The repeat re-runs inference (no cached answer) yet reuses the
    // trivial evaluations instead of recomputing them.
    let (resp, _) = service.handle_line(&line);
    let doc = Json::parse(&resp).unwrap();
    assert_ne!(doc.get("provenance").unwrap().as_str(), Some("cache"));
    assert_eq!(service.stats_view().trivial_evals, 1);

    // A different graph is a fresh fingerprint and a fresh evaluation;
    // no_cache bypasses the reuse in both directions.
    let other = protocol::render_place_request(Some("seq:8"), None, None, None, None, true);
    service.handle_line(&other);
    service.handle_line(&other);
    assert_eq!(service.stats_view().trivial_evals, 3);
}

#[test]
fn concurrent_identical_requests_single_flight() {
    let (ckpt, cfg) = tiny_checkpoint("layered:3x3:1", 2);
    let service = Arc::new(
        PlacementService::new(
            ckpt,
            &cfg,
            ServeOptions { cache_capacity: 8, ..Default::default() },
        )
        .unwrap(),
    );

    // N identical default-shaped requests in parallel: exactly one leader
    // runs the inference and the trivial evaluation; every other request
    // waits for it (or arrives later) and answers from the cache.
    let line = protocol::render_place_request(Some("seq:12"), None, None, None, None, false);
    let n = 6;
    let handles: Vec<_> = (0..n)
        .map(|_| {
            let svc = Arc::clone(&service);
            let l = line.clone();
            std::thread::spawn(move || svc.handle_line(&l).0)
        })
        .collect();
    let mut cached = 0;
    for h in handles {
        let doc = Json::parse(&h.join().unwrap()).unwrap();
        assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true));
        if doc.get("provenance").unwrap().as_str() == Some("cache") {
            cached += 1;
        }
    }
    assert_eq!(cached, n - 1, "exactly one request may run inference");
    let s = service.stats_view();
    assert_eq!(s.placements, n as u64);
    assert_eq!(s.cache_hits, (n - 1) as u64);
    assert_eq!(s.trivial_evals, 1);
    assert_eq!(s.cache_len, 1);
}

#[test]
fn tcp_server_roundtrips_and_shuts_down_cleanly() {
    let (ckpt, cfg) = tiny_checkpoint("seq:12", 1);
    let service =
        Arc::new(PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap());
    let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn(2).unwrap();
    let timeout = Duration::from_secs(30);

    let line = protocol::render_place_request(Some("seq:12"), None, None, None, None, false);
    let d1 = protocol::parse_response(&client::roundtrip(&addr, &line, timeout).unwrap()).unwrap();
    assert_ne!(d1.get("provenance").unwrap().as_str(), Some("cache"));
    // Pipelined second exchange over one connection hits the cache.
    let mut conn = client::Connection::open(&addr, timeout).unwrap();
    let d2 = protocol::parse_response(&conn.send(&line).unwrap()).unwrap();
    assert_eq!(d2.get("provenance").unwrap().as_str(), Some("cache"));
    let st =
        protocol::parse_response(&conn.send(&protocol::render_stats_request()).unwrap()).unwrap();
    assert_eq!(st.get("placements").unwrap().as_usize(), Some(2));
    // Malformed lines come back as error responses, not dropped conns.
    let bad = conn.send("{oops").unwrap();
    assert!(protocol::parse_response(&bad).is_err());

    let bye = client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    assert!(protocol::parse_response(&bye).is_ok());
    handle.join().unwrap();
    assert!(service.stats_view().requests >= 4);
}

/// Kill the serve daemon if the test dies before the clean shutdown.
struct KillOnDrop(Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn persistence_proof_across_processes() {
    let bin = env!("CARGO_BIN_EXE_hsdag");
    let dir = tmp_dir("e2e");
    let ckpt_path = dir.join("trained.ckpt.json");
    let train_spec = "random:48:7";

    // Process 1: train and save.
    let out = Command::new(bin)
        .args([
            "train",
            "--backend",
            "native",
            "--workload",
            train_spec,
            "--episodes",
            "8",
            "--seed",
            "3",
            "--save",
            ckpt_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "train failed: {}", String::from_utf8_lossy(&out.stderr));
    assert!(ckpt_path.exists());

    // Process 2: serve the checkpoint on an ephemeral port.
    let mut child = KillOnDrop(
        Command::new(bin)
            .args([
                "serve",
                "--load",
                ckpt_path.to_str().unwrap(),
                "--addr",
                "127.0.0.1:0",
                "--serve-workers",
                "2",
                "--rollouts",
                "8",
            ])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .unwrap(),
    );
    let mut reader = BufReader::new(child.0.stdout.take().unwrap());
    let mut banner = String::new();
    reader.read_line(&mut banner).unwrap();
    assert!(banner.contains("listening on"), "unexpected banner: {banner}");
    let addr = banner
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap()
        .to_string();

    // Cold request for the very workload the policy was trained on.
    let timeout = Duration::from_secs(60);
    let line = protocol::render_place_request(Some(train_spec), None, None, None, None, false);
    let d1 = protocol::parse_response(&client::roundtrip(&addr, &line, timeout).unwrap()).unwrap();
    let prov1 = d1.get("provenance").unwrap().as_str().unwrap().to_string();
    assert_ne!(prov1, "cache", "first request cannot be a cache hit");
    assert_eq!(d1.get("feasible").unwrap().as_bool(), Some(true));
    let lat = d1.get("latency_s").unwrap().as_f64().unwrap();
    let ref_lat = d1.get("ref_latency_s").unwrap().as_f64().unwrap();
    let speedup = d1.get("speedup_pct").unwrap().as_f64().unwrap();
    assert!(lat.is_finite() && lat > 0.0);
    assert!((speedup - 100.0 * (1.0 - lat / ref_lat)).abs() < 1e-6);

    // The served placement never loses to a static single-device
    // deployment (and with this training budget the learned placement
    // should be at least as fast as the best of them).
    let g = Workload::resolve(train_spec).unwrap().graph;
    let tb = Testbed::by_id("cpu_gpu").unwrap();
    let cpu = execute(&g, &Placement::all(g.n(), tb.reference), &tb).makespan;
    assert!((ref_lat - cpu).abs() / cpu < 1e-9, "reference drifted: {ref_lat} vs {cpu}");
    let best_single = tb
        .placeable
        .iter()
        .map(|&d| execute(&g, &Placement::all(g.n(), d), &tb).makespan)
        .fold(f64::INFINITY, f64::min);
    assert!(lat <= best_single + 1e-12, "served {lat}, best single-device {best_single}");

    // The identical repeat is answered from the cache with the same
    // numbers — no inference re-run.
    let d2 = protocol::parse_response(&client::roundtrip(&addr, &line, timeout).unwrap()).unwrap();
    assert_eq!(d2.get("provenance").unwrap().as_str(), Some("cache"));
    assert_eq!(d2.get("latency_s").unwrap().as_f64(), Some(lat));
    assert_eq!(d2.get("fingerprint").unwrap().as_str(), d1.get("fingerprint").unwrap().as_str());

    // Live metrics agree, then shut down cleanly.
    let st = protocol::parse_response(
        &client::roundtrip(&addr, &protocol::render_stats_request(), timeout).unwrap(),
    )
    .unwrap();
    assert_eq!(st.get("cache_hits").unwrap().as_usize(), Some(1));
    assert_eq!(st.get("placements").unwrap().as_usize(), Some(2));
    let bye = client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    assert!(protocol::parse_response(&bye).is_ok());
    let status = child.0.wait().unwrap();
    assert!(status.success(), "serve did not exit cleanly");
}

#[test]
fn mismatched_checkpoints_are_clear_errors_not_panics() {
    let (ckpt, cfg) = tiny_checkpoint("seq:8", 1);
    // Serving a 2-action checkpoint on a 3-action testbed is refused
    // with both testbeds named.
    let wide = Config { testbed: "paper3".to_string(), ..cfg.clone() };
    let err = PlacementService::new(ckpt.clone(), &wide, ServeOptions::default()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("cpu_gpu") && msg.contains("paper3"), "{msg}");
    // The matching testbed constructs fine.
    assert!(PlacementService::new(ckpt, &cfg, ServeOptions::default()).is_ok());
}

//! Workload-subsystem integration tests: the open-world contract.
//!
//! - every registry scheme resolves to a valid graph, and the paper
//!   benchmarks reach Table-1 sizes through the same registry;
//! - a JSON graph exported by the serializer loads via `file:` and runs
//!   the whole pipeline end to end (coarsen → features → native-backend
//!   search → placement report) with no recompile — the acceptance
//!   criterion of the workload refactor;
//! - serialize → load round-trips preserve the graph, its features and
//!   its coarsening (property test over random + custom-kind graphs);
//! - the generalization harness trains one policy across workloads and
//!   zero-shot evaluates held-out graphs.

use hsdag::config::Config;
use hsdag::features::{extract, FeatureConfig};
use hsdag::graph::{dot, json, CompGraph, OpKind, OpNode};
use hsdag::harness::generalize;
use hsdag::models::{Benchmark, Workload};
use hsdag::rl::{Env, HsdagAgent};
use hsdag::util::prop::{check, PropConfig};
use hsdag::util::Rng;

fn native_cfg() -> Config {
    Config {
        backend: "native".to_string(),
        hidden: 16,
        update_timestep: 4,
        seed: 3,
        ..Default::default()
    }
}

#[test]
fn every_registry_scheme_resolves_and_validates() {
    for spec in [
        "inception",
        "resnet",
        "bert",
        "seq:16",
        "layered:4x3:2",
        "transformer:1:2",
        "random:24:5",
    ] {
        let w = Workload::resolve(spec).unwrap();
        w.graph.validate().unwrap();
        let env = Env::for_workload(w, &native_cfg()).unwrap();
        assert!(env.ref_latency > 0.0, "{spec}");
        assert!(env.n_nodes >= 1 && env.n_nodes <= env.v_pad, "{spec}");
    }
}

#[test]
fn paper_benchmarks_via_registry_match_direct_builders() {
    for b in Benchmark::ALL {
        let via_registry = Workload::resolve(b.id()).unwrap();
        let direct = b.build();
        assert_eq!(via_registry.graph.n(), direct.n(), "{}", b.id());
        assert_eq!(via_registry.graph.m(), direct.m(), "{}", b.id());
        assert_eq!(via_registry.graph.edges, direct.edges, "{}", b.id());
        assert_eq!(via_registry.bench, Some(b));
    }
}

#[test]
fn json_file_workload_runs_end_to_end() {
    // Export a synthetic workload with the new serializer, reload it via
    // the `file:` source, and run the full placement pipeline on it.
    let dir = std::env::temp_dir().join("hsdag_workloads_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("layered.json");
    let original = Workload::resolve("layered:5x3:4").unwrap();
    std::fs::write(&path, json::to_json(&original.graph)).unwrap();

    let cfg = native_cfg();
    let loaded = Workload::resolve(&format!("file:{}", path.display())).unwrap();
    assert!(loaded.bench.is_none());
    let env = Env::for_workload(loaded, &cfg).unwrap();
    assert_eq!(env.graph.n(), original.graph.n());

    // Native-backend search: a couple of episodes, then a report.
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    assert!(agent.backend_desc().contains("native"));
    let res = agent.search(&env, 2).unwrap();
    assert!(res.best_latency.is_finite() && res.best_latency > 0.0);
    assert!(!res.best_actions.is_empty());
    let rep = env.report(&res.best_actions).unwrap();
    assert!(rep.feasible());
    assert_eq!(rep.mem_peak.len(), env.testbed.n_devices());
    // Best-of-search never loses to the worst static baseline (the same
    // bound the native-backend suite pins on the paper graphs).
    let worst = hsdag::baselines::BASELINE_NAMES
        .iter()
        .filter_map(|&m| hsdag::baselines::baseline_latency(m, &env.graph, &env.testbed))
        .fold(0f64, f64::max);
    assert!(
        res.best_latency <= worst * 1.05,
        "search best {} worse than worst baseline {worst}",
        res.best_latency
    );
}

#[test]
fn dot_file_workload_loads_through_registry() {
    let dir = std::env::temp_dir().join("hsdag_workloads_dot");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sp.dot");
    let original = Workload::resolve("random:20:8").unwrap();
    std::fs::write(&path, dot::to_dot(&original.graph)).unwrap();
    let loaded = Workload::resolve(&format!("file:{}", path.display())).unwrap();
    assert_eq!(loaded.graph.n(), original.graph.n());
    assert_eq!(loaded.graph.edges, original.graph.edges);
}

/// Random graph with a sprinkling of custom-kind nodes for round-trip
/// property testing.
fn random_graph_with_customs(rng: &mut Rng, size: usize) -> CompGraph {
    let mut g = CompGraph::random(rng, size.max(4), size / 3);
    let n = g.n();
    for v in 1..n - 1 {
        if rng.below(4) == 0 {
            g.nodes[v].custom_kind = Some(format!("Custom{}", rng.below(5)));
        }
    }
    g
}

#[test]
fn json_roundtrip_preserves_graph_features_and_coarsening_prop() {
    check(
        "workload-json-roundtrip",
        PropConfig { cases: 32, max_size: 60, ..Default::default() },
        |rng, size| {
            let g = random_graph_with_customs(rng, size);
            let h = json::from_json(&json::to_json(&g)).map_err(|e| format!("{e:#}"))?;
            if h.n() != g.n() || h.edges != g.edges {
                return Err("structure drifted".into());
            }
            for (a, b) in g.nodes.iter().zip(h.nodes.iter()) {
                if a.name != b.name
                    || a.kind != b.kind
                    || a.output_shape != b.output_shape
                    || a.attrs != b.attrs
                    || a.custom_kind != b.custom_kind
                {
                    return Err(format!("node '{}' drifted", a.name));
                }
            }
            // Identical features...
            let fa = extract(&g, FeatureConfig::default());
            let fb = extract(&h, FeatureConfig::default());
            if fa.x != fb.x {
                return Err("features drifted".into());
            }
            // ...and identical coarsening.
            let ca = hsdag::coarsen::colocate(&g);
            let cb = hsdag::coarsen::colocate(&h);
            if ca.set_of != cb.set_of || ca.coarse.edges != cb.coarse.edges {
                return Err("coarsening drifted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn custom_kinds_survive_load_and_reach_features() {
    let mut g = CompGraph::new("custom_e2e");
    let a = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 8]));
    let b = g.add_node(
        OpNode::new("gate", OpKind::MatMul, vec![1, 8]).with_custom_kind("PallasFusedGate"),
    );
    let c = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 8]));
    g.add_edge(a, b);
    g.add_edge(b, c);
    let h = json::from_json(&json::to_json(&g)).unwrap();
    assert_eq!(h.nodes[1].kind_label(), "PallasFusedGate");
    let f = extract(&h, FeatureConfig::default());
    assert_eq!(f.row(1)[hsdag::graph::hash_kind_slot("PallasFusedGate")], 1.0);
}

#[test]
fn generalization_trains_on_suite_and_zero_shots_held_out() {
    // Acceptance criterion: >= 3 training workloads, >= 2 held-out, one
    // shared policy, zero-shot speedups reported vs the reference device.
    let cfg = native_cfg();
    let train = vec!["seq:12".to_string(), "layered:3x2:1".to_string(), "random:14:2".to_string()];
    let eval = vec!["layered:4x3:5".to_string(), "transformer:1:1".to_string()];
    let (table, outcomes) = generalize::run(&cfg, &train, &eval, 1, 2, None).unwrap();
    assert_eq!(outcomes.len(), 5);
    assert_eq!(table.rows.len(), 5);
    assert_eq!(outcomes.iter().filter(|o| o.held_out).count(), 2);
    for o in &outcomes {
        assert!(o.policy_latency.is_finite(), "{}: no feasible rollout", o.workload);
        assert!(o.ref_latency > 0.0 && o.static_latency.is_finite(), "{}", o.workload);
        // Speedup vs reference is well-defined (can be negative; just
        // not degenerate).
        assert!(o.policy_latency > 0.0, "{}", o.workload);
    }
}

#[test]
fn malformed_file_workloads_fail_with_messages() {
    let dir = std::env::temp_dir().join("hsdag_workloads_bad");
    std::fs::create_dir_all(&dir).unwrap();
    // Cyclic graph: loader must report, not panic.
    let bad = dir.join("cyclic.json");
    std::fs::write(
        &bad,
        r#"{
  "format": "hsdag-graph-v1",
  "name": "cyc",
  "nodes": [
    {"name": "a", "kind": "Parameter", "shape": [1]},
    {"name": "b", "kind": "Relu", "shape": [1]},
    {"name": "c", "kind": "Result", "shape": [1]}
  ],
  "edges": [[0, 1], [1, 2], [2, 1]]
}"#,
    )
    .unwrap();
    let err = Workload::resolve(&format!("file:{}", bad.display())).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("cycle") || msg.contains("invalid graph"), "{msg}");
    // Not JSON at all.
    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "definitely not json").unwrap();
    assert!(Workload::resolve(&format!("file:{}", garbage.display())).is_err());
}

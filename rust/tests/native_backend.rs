//! End-to-end native-backend training: the full HSDAG loop (fwd → parse
//! → place → reward → train update) with NO `artifacts/` directory and no
//! real xla crate — the CI smoke path for the learned pipeline.
//!
//! A small custom graph keeps the debug-mode cost trivial; one test also
//! steps the policy on a real benchmark graph. Everything here must be
//! deterministically reproducible from a fixed seed.

use hsdag::baselines;
use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::graph::{CompGraph, OpKind};
use hsdag::models::builder::GraphBuilder;
use hsdag::models::Benchmark;
use hsdag::parsing::parse;
use hsdag::rl::{Env, HsdagAgent, NativeBackend, PolicyBackend};
use hsdag::sim::Testbed;

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// A small two-branch network (~20 ops with their weight constants):
/// enough structure for non-trivial partitions, tiny enough for debug
/// builds.
fn small_graph() -> CompGraph {
    let mut b = GraphBuilder::new("mini");
    let input = b.node("input", OpKind::Parameter, vec![1, 3, 32, 32]);
    let mut trunk = b.conv_unit("stem", input, 3, 3, vec![1, 16, 16, 16], Some(OpKind::Relu));
    trunk = b.conv_unit("mid", trunk, 16, 3, vec![1, 32, 8, 8], Some(OpKind::Relu));
    let mut ctx = b.op("pool", OpKind::AvgPool, vec![1, 3, 8, 8], &[input]);
    ctx = b.conv_unit("proj", ctx, 3, 1, vec![1, 32, 8, 8], Some(OpKind::Relu));
    let fused = b.op("fuse", OpKind::Concat, vec![1, 64, 8, 8], &[trunk, ctx]);
    let gap = b.op("gap", OpKind::AvgPool, vec![1, 64, 1, 1], &[fused]);
    let flat = b.op("flat", OpKind::Reshape, vec![1, 64], &[gap]);
    let logits = b.fc_unit("head", flat, 64, vec![1, 10]);
    b.op("output", OpKind::Result, vec![1, 10], &[logits]);
    b.finish()
}

fn small_cfg() -> Config {
    Config {
        backend: "native".to_string(),
        hidden: 32,
        update_timestep: 6,
        seed: 11,
        ..Default::default()
    }
}

fn small_env() -> Env {
    let g = small_graph();
    g.validate().unwrap();
    Env::from_graph(Benchmark::ResNet50, g, FeatureConfig::default()).unwrap()
}

#[test]
fn full_search_trains_without_artifacts() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    assert!(agent.backend_desc().contains("native"), "{}", agent.backend_desc());

    let res = agent.search(&env, 3).unwrap();
    assert_eq!(res.curve.len(), 3);
    // Every episode fills the 6-step window, so every episode trains:
    // the recorded losses must be finite (never NaN after episode 0).
    for p in &res.curve {
        assert!(p.loss.is_finite(), "episode {} loss {}", p.episode, p.loss);
        assert!(p.mean_reward.is_finite());
    }
    // One Adam step per episode (k_epochs = 1).
    assert_eq!(agent.params().step, 3.0);

    // The searched placement never loses to the worst static baseline.
    let worst = baselines::BASELINE_NAMES
        .iter()
        .filter_map(|&m| baselines::baseline_latency(m, &env.graph, &env.testbed))
        .fold(0f64, f64::max);
    assert!(res.best_latency.is_finite() && res.best_latency > 0.0);
    assert!(
        res.best_latency <= worst,
        "search best {} worse than worst baseline {}",
        res.best_latency,
        worst
    );
    assert!(res.peak_bytes > 0);
}

#[test]
fn search_is_deterministic_from_seed() {
    let cfg = small_cfg();
    let env = small_env();
    let mut a = HsdagAgent::new(&env, &cfg).unwrap();
    let mut b = HsdagAgent::new(&env, &cfg).unwrap();
    let ra = a.search(&env, 2).unwrap();
    let rb = b.search(&env, 2).unwrap();
    assert_eq!(ra.best_actions, rb.best_actions);
    assert_eq!(ra.best_latency.to_bits(), rb.best_latency.to_bits());
    for (pa, pb) in ra.curve.iter().zip(&rb.curve) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits());
        assert_eq!(pa.mean_reward.to_bits(), pb.mean_reward.to_bits());
    }
    // A different seed diverges.
    let cfg2 = Config { seed: 12, ..small_cfg() };
    let mut c = HsdagAgent::new(&env, &cfg2).unwrap();
    let rc = c.search(&env, 2).unwrap();
    assert!(
        rc.best_latency.to_bits() != ra.best_latency.to_bits()
            || rc.best_actions != ra.best_actions
            || rc.curve[0].mean_reward.to_bits() != ra.curve[0].mean_reward.to_bits(),
        "seeds 11 and 12 produced identical searches"
    );
}

#[test]
fn explicit_update_moves_parameters() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let before: Vec<f32> = agent.params().params[0].as_f32().to_vec();
    for _ in 0..cfg.update_timestep {
        let o = agent.step(&env, true).unwrap();
        assert!(o.latency.is_finite() && o.latency > 0.0);
        assert!(o.feasible, "unbounded default testbed can never OOM");
        assert!(o.n_groups >= 1 && o.n_groups <= env.n_nodes);
    }
    let loss = agent.update(&env).unwrap().expect("buffer full");
    assert!(loss.is_finite());
    assert_eq!(agent.params().step, 1.0);
    let after = agent.params().params[0].as_f32();
    let changed = before.iter().zip(after).filter(|(a, b)| a != b).count();
    assert!(changed > 0, "no weight moved after a train update");
}

#[test]
fn greedy_step_is_noise_free() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let o = agent.step(&env, false).unwrap();
    assert_eq!(o.latency, o.det_latency, "greedy step carries no noise");
    assert_eq!(o.actions.len(), env.n_nodes);
}

#[test]
fn native_backend_steps_on_a_real_benchmark() {
    let cfg = Config { hidden: 32, ..small_cfg() };
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let o = agent.step(&env, false).unwrap();
    assert_eq!(o.actions.len(), env.n_nodes);
    assert!(o.latency.is_finite() && o.latency > 0.0);
    assert!(o.n_groups > 1 && o.n_groups < env.n_nodes);
}

#[test]
fn batched_fwd_and_placer_match_independent_calls_bitwise() {
    let cfg = small_cfg();
    let env = small_env();
    let mut backend = NativeBackend::new(&env, &cfg).unwrap();
    let h = cfg.hidden;

    // Three distinct feedback states: zero, a ramp, an alternating sign
    // pattern — the batched path must reproduce each row exactly.
    let fb0 = vec![0f32; env.v_pad * h];
    let fb1: Vec<f32> = (0..env.v_pad * h).map(|i| (i % 7) as f32 * 0.125).collect();
    let fb2: Vec<f32> =
        (0..env.v_pad * h).map(|i| if i % 2 == 0 { 0.5 } else { -0.25 }).collect();
    let fbs: Vec<&[f32]> = vec![&fb0, &fb1, &fb2];
    let batched = backend.fwd_many(&env, &fbs).unwrap();
    assert_eq!(batched.len(), 3);
    for (fb, b) in fbs.iter().zip(&batched) {
        let solo = backend.fwd(&env, fb).unwrap();
        assert_eq!(bits(&solo.z), bits(&b.z));
        assert_eq!(bits(&solo.scores), bits(&b.scores));
    }

    // placer_many over two different partitions of the same forward: the
    // raw-score parse and a coarser one with a third of the edges cut.
    let out = backend.fwd(&env, &fb0).unwrap();
    let mut cut = out.scores.clone();
    for s in cut.iter_mut().step_by(3) {
        *s = -1.0;
    }
    let mut cids_all = Vec::new();
    let mut gmask_all = Vec::new();
    for scores in [&out.scores, &cut] {
        let part = parse(env.working_graph(), scores);
        let mut cids = vec![0i32; env.v_pad];
        for (node, &c) in part.cluster_of.iter().enumerate() {
            cids[node] = c as i32;
        }
        let mut gmask = vec![0f32; env.v_pad];
        for m in gmask.iter_mut().take(part.n_groups) {
            *m = 1.0;
        }
        cids_all.push(cids);
        gmask_all.push(gmask);
    }
    let many = backend
        .placer_many(
            &env,
            &[&out, &out],
            &[cids_all[0].as_slice(), cids_all[1].as_slice()],
            &[gmask_all[0].as_slice(), gmask_all[1].as_slice()],
        )
        .unwrap();
    for i in 0..2 {
        let solo = backend.placer(&env, &out, &cids_all[i], &gmask_all[i]).unwrap();
        assert_eq!(bits(&solo), bits(&many[i]), "partition {i}");
    }
}

#[test]
fn rollout_batch_is_deterministic_and_greedy_matches_step() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let outs = agent.rollout_batch(&env, 3).unwrap();
    assert_eq!(outs.len(), 4, "1 greedy + 3 stochastic rollouts");
    for o in &outs {
        assert_eq!(o.actions.len(), env.n_nodes);
        assert!(o.latency.is_finite() && o.latency > 0.0);
        // Serving ranks by deterministic makespan: no measurement noise.
        assert_eq!(o.latency.to_bits(), o.det_latency.to_bits());
        assert!(o.feasible, "unbounded default testbed can never OOM");
    }
    // Rollout 0 is the greedy rollout: bit-identical to a fresh greedy
    // step through the sequential path.
    let mut fresh = HsdagAgent::new(&env, &cfg).unwrap();
    let g = fresh.step(&env, false).unwrap();
    assert_eq!(outs[0].actions, g.actions);
    assert_eq!(outs[0].latency.to_bits(), g.latency.to_bits());
    // The whole batch is deterministic from the seed.
    let mut twin = HsdagAgent::new(&env, &cfg).unwrap();
    let outs2 = twin.rollout_batch(&env, 3).unwrap();
    for (a, b) in outs.iter().zip(&outs2) {
        assert_eq!(a.actions, b.actions);
        assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
    }
}

#[test]
fn native_backend_trains_on_wider_testbeds() {
    // The native policy head takes its width from the testbed — no
    // re-lowered artifacts needed for K-device placement.
    let cfg = small_cfg();
    let env = Env::from_graph_on(
        Benchmark::ResNet50,
        small_graph(),
        FeatureConfig::default(),
        Testbed::paper3(),
    )
    .unwrap();
    assert_eq!(env.n_actions(), 3);
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let res = agent.search(&env, 1).unwrap();
    assert!(res.best_latency.is_finite() && res.best_latency > 0.0);
    assert!(res.best_actions.iter().all(|&a| a < 3));
    assert!(res.curve[0].loss.is_finite());
}

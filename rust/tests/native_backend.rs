//! End-to-end native-backend training: the full HSDAG loop (fwd → parse
//! → place → reward → train update) with NO `artifacts/` directory and no
//! real xla crate — the CI smoke path for the learned pipeline.
//!
//! A small custom graph keeps the debug-mode cost trivial; one test also
//! steps the policy on a real benchmark graph. Everything here must be
//! deterministically reproducible from a fixed seed.

use hsdag::baselines;
use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::graph::{CompGraph, OpKind};
use hsdag::models::builder::GraphBuilder;
use hsdag::models::Benchmark;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::sim::Testbed;

/// A small two-branch network (~20 ops with their weight constants):
/// enough structure for non-trivial partitions, tiny enough for debug
/// builds.
fn small_graph() -> CompGraph {
    let mut b = GraphBuilder::new("mini");
    let input = b.node("input", OpKind::Parameter, vec![1, 3, 32, 32]);
    let mut trunk = b.conv_unit("stem", input, 3, 3, vec![1, 16, 16, 16], Some(OpKind::Relu));
    trunk = b.conv_unit("mid", trunk, 16, 3, vec![1, 32, 8, 8], Some(OpKind::Relu));
    let mut ctx = b.op("pool", OpKind::AvgPool, vec![1, 3, 8, 8], &[input]);
    ctx = b.conv_unit("proj", ctx, 3, 1, vec![1, 32, 8, 8], Some(OpKind::Relu));
    let fused = b.op("fuse", OpKind::Concat, vec![1, 64, 8, 8], &[trunk, ctx]);
    let gap = b.op("gap", OpKind::AvgPool, vec![1, 64, 1, 1], &[fused]);
    let flat = b.op("flat", OpKind::Reshape, vec![1, 64], &[gap]);
    let logits = b.fc_unit("head", flat, 64, vec![1, 10]);
    b.op("output", OpKind::Result, vec![1, 10], &[logits]);
    b.finish()
}

fn small_cfg() -> Config {
    Config {
        backend: "native".to_string(),
        hidden: 32,
        update_timestep: 6,
        seed: 11,
        ..Default::default()
    }
}

fn small_env() -> Env {
    let g = small_graph();
    g.validate().unwrap();
    Env::from_graph(Benchmark::ResNet50, g, FeatureConfig::default()).unwrap()
}

#[test]
fn full_search_trains_without_artifacts() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    assert!(agent.backend_desc().contains("native"), "{}", agent.backend_desc());

    let res = agent.search(&env, 3).unwrap();
    assert_eq!(res.curve.len(), 3);
    // Every episode fills the 6-step window, so every episode trains:
    // the recorded losses must be finite (never NaN after episode 0).
    for p in &res.curve {
        assert!(p.loss.is_finite(), "episode {} loss {}", p.episode, p.loss);
        assert!(p.mean_reward.is_finite());
    }
    // One Adam step per episode (k_epochs = 1).
    assert_eq!(agent.params().step, 3.0);

    // The searched placement never loses to the worst static baseline.
    let worst = baselines::BASELINE_NAMES
        .iter()
        .filter_map(|&m| baselines::baseline_latency(m, &env.graph, &env.testbed))
        .fold(0f64, f64::max);
    assert!(res.best_latency.is_finite() && res.best_latency > 0.0);
    assert!(
        res.best_latency <= worst,
        "search best {} worse than worst baseline {}",
        res.best_latency,
        worst
    );
    assert!(res.peak_bytes > 0);
}

#[test]
fn search_is_deterministic_from_seed() {
    let cfg = small_cfg();
    let env = small_env();
    let mut a = HsdagAgent::new(&env, &cfg).unwrap();
    let mut b = HsdagAgent::new(&env, &cfg).unwrap();
    let ra = a.search(&env, 2).unwrap();
    let rb = b.search(&env, 2).unwrap();
    assert_eq!(ra.best_actions, rb.best_actions);
    assert_eq!(ra.best_latency.to_bits(), rb.best_latency.to_bits());
    for (pa, pb) in ra.curve.iter().zip(&rb.curve) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits());
        assert_eq!(pa.mean_reward.to_bits(), pb.mean_reward.to_bits());
    }
    // A different seed diverges.
    let cfg2 = Config { seed: 12, ..small_cfg() };
    let mut c = HsdagAgent::new(&env, &cfg2).unwrap();
    let rc = c.search(&env, 2).unwrap();
    assert!(
        rc.best_latency.to_bits() != ra.best_latency.to_bits()
            || rc.best_actions != ra.best_actions
            || rc.curve[0].mean_reward.to_bits() != ra.curve[0].mean_reward.to_bits(),
        "seeds 11 and 12 produced identical searches"
    );
}

#[test]
fn explicit_update_moves_parameters() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let before: Vec<f32> = agent.params().params[0].as_f32().to_vec();
    for _ in 0..cfg.update_timestep {
        let o = agent.step(&env, true).unwrap();
        assert!(o.latency.is_finite() && o.latency > 0.0);
        assert!(o.feasible, "unbounded default testbed can never OOM");
        assert!(o.n_groups >= 1 && o.n_groups <= env.n_nodes);
    }
    let loss = agent.update(&env).unwrap().expect("buffer full");
    assert!(loss.is_finite());
    assert_eq!(agent.params().step, 1.0);
    let after = agent.params().params[0].as_f32();
    let changed = before.iter().zip(after).filter(|(a, b)| a != b).count();
    assert!(changed > 0, "no weight moved after a train update");
}

#[test]
fn greedy_step_is_noise_free() {
    let cfg = small_cfg();
    let env = small_env();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let o = agent.step(&env, false).unwrap();
    assert_eq!(o.latency, o.det_latency, "greedy step carries no noise");
    assert_eq!(o.actions.len(), env.n_nodes);
}

#[test]
fn native_backend_steps_on_a_real_benchmark() {
    let cfg = Config { hidden: 32, ..small_cfg() };
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let o = agent.step(&env, false).unwrap();
    assert_eq!(o.actions.len(), env.n_nodes);
    assert!(o.latency.is_finite() && o.latency > 0.0);
    assert!(o.n_groups > 1 && o.n_groups < env.n_nodes);
}

#[test]
fn native_backend_trains_on_wider_testbeds() {
    // The native policy head takes its width from the testbed — no
    // re-lowered artifacts needed for K-device placement.
    let cfg = small_cfg();
    let env = Env::from_graph_on(
        Benchmark::ResNet50,
        small_graph(),
        FeatureConfig::default(),
        Testbed::paper3(),
    )
    .unwrap();
    assert_eq!(env.n_actions(), 3);
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let res = agent.search(&env, 1).unwrap();
    assert!(res.best_latency.is_finite() && res.best_latency > 0.0);
    assert!(res.best_actions.iter().all(|&a| a < 3));
    assert!(res.curve[0].loss.is_finite());
}

//! Calibration harness: single-device latency ratios vs Table 2.
use hsdag::models::Benchmark;
use hsdag::sim::{execute, Placement, Testbed, CPU, DGPU, IGPU};

#[test]
fn single_device_ratios_match_table2_shape() {
    // Paper Table 2 single-device ratios: Inception 1.07, ResNet 2.05,
    // BERT 2.30 (CPU latency / dGPU latency). The calibrated simulator
    // must land in the right ordering with each ratio within ~25%.
    let targets = [1.067, 2.048, 2.303];
    let tb = Testbed::paper();
    for b in Benchmark::ALL {
        let g = b.build();
        let cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let igpu = execute(&g, &Placement::all(g.n(), IGPU), &tb).makespan;
        let dgpu = execute(&g, &Placement::all(g.n(), DGPU), &tb).makespan;
        println!(
            "{:<14} cpu={:.5}s igpu={:.5}s dgpu={:.5}s  cpu/dgpu={:.3}",
            b.display(), cpu, igpu, dgpu, cpu / dgpu
        );
        let target = targets[Benchmark::ALL.iter().position(|&x| x == b).unwrap()];
        let ratio = cpu / dgpu;
        assert!(
            (ratio - target).abs() / target < 0.25,
            "{}: ratio {ratio:.3} vs paper {target:.3}",
            b.display()
        );
        assert!(igpu > cpu && igpu > dgpu, "{}: iGPU must be dominated", b.display());
    }
}

#[test]
fn print_op_size_distribution() {
    for b in Benchmark::ALL {
        let g = b.build();
        let mut contraction: Vec<f64> = g
            .nodes
            .iter()
            .filter(|n| n.kind.is_contraction())
            .map(|n| n.flops())
            .collect();
        contraction.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let total: f64 = contraction.iter().sum();
        let other: f64 = g
            .nodes
            .iter()
            .filter(|n| !n.kind.is_contraction())
            .map(|n| n.flops())
            .sum();
        let n_real = g.nodes.iter().filter(|n| !n.kind.is_boundary()).count();
        println!(
            "{:<14} ncontr={} total_c={:.2}G other={:.2}G real_ops={} median_c={:.1}M p10={:.1}M p90={:.1}M",
            b.display(),
            contraction.len(),
            total / 1e9,
            other / 1e9,
            n_real,
            contraction[contraction.len() / 2] / 1e6,
            contraction[contraction.len() / 10] / 1e6,
            contraction[contraction.len() * 9 / 10] / 1e6,
        );
    }
}

/// Calibration *tool*, not a correctness test: sweeps dGPU model
/// constants against the Table 2 ratio targets. Run explicitly with
/// `cargo test --release --test calibration grid -- --ignored --nocapture`.
#[test]
#[ignore]
fn grid_search_dgpu() {
    let graphs: Vec<_> = Benchmark::ALL.iter().map(|b| b.build()).collect();
    let targets = [1.067, 2.048, 2.303];
    let mut best = (f64::INFINITY, 0.0, 0.0, 0.0, 0.0);
    for &pc in &[5.0e12, 5.5e12, 6.0e12, 6.5e12, 7.0e12] {
        for &pm in &[9.0e12, 10.0e12, 11.0e12, 12.0e12] {
            for &sat in &[1.0e5, 1.4e5, 1.8e5, 2.4e5, 3.0e5] {
                for &launch in &[3.0e-6, 3.5e-6, 4.0e-6, 4.5e-6] {
                    let mut tb = Testbed::paper();
                    tb.devices[DGPU].flops_conv = pc;
                    tb.devices[DGPU].flops_matmul = pm;
                    tb.devices[DGPU].sat_half_elems = sat;
                    tb.devices[DGPU].launch_overhead = launch;
                    let mut err = 0.0;
                    for (g, t) in graphs.iter().zip(targets) {
                        let cpu = execute(g, &Placement::all(g.n(), CPU), &tb).makespan;
                        let gpu = execute(g, &Placement::all(g.n(), DGPU), &tb).makespan;
                        let r = cpu / gpu;
                        err += ((r - t) / t).powi(2);
                    }
                    if err < best.0 {
                        best = (err, pc, pm, sat, launch);
                    }
                }
            }
        }
    }
    println!("best err={:.4} pc={:.1e} pm={:.1e} sat={:.1e} launch={:.1e}", best.0, best.1, best.2, best.3, best.4);
    let mut tb = Testbed::paper();
    tb.devices[DGPU].flops_conv = best.1;
    tb.devices[DGPU].flops_matmul = best.2;
    tb.devices[DGPU].sat_half_elems = best.3;
    tb.devices[DGPU].launch_overhead = best.4;
    for (g, b) in graphs.iter().zip(Benchmark::ALL) {
        let cpu = execute(g, &Placement::all(g.n(), CPU), &tb).makespan;
        let gpu = execute(g, &Placement::all(g.n(), DGPU), &tb).makespan;
        println!("  {:<14} ratio={:.3}", b.display(), cpu / gpu);
    }
}

//! Data-parallel determinism suite (PR 9).
//!
//! The threading contract this repo ships: on the default kernel path,
//! `--workers N` is **bit-identical** to `--workers 1` everywhere — the
//! row-banded kernels, the batched cost model, a full `search()`, a
//! served placement request. Parallelism changes which thread computes a
//! value, never the value. The opt-in `--fast-math` lane kernels are the
//! one exception: they reassociate sums, so they are *tolerance*-equal
//! to the default kernels — but still deterministic and worker-invariant
//! within the fast path, and their answers never touch the serve cache.

use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::models::Workload;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::runtime::nn;
use hsdag::serve::{protocol, Checkpoint, CheckpointMeta, PlacementService, ServeOptions};
use hsdag::util::Rng;

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn kernel_entry_points_bit_identical_across_worker_counts() {
    let mut rng = Rng::new(0xC0FFEE);
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (33, 46, 32), (67, 31, 29)] {
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let bt = randn(&mut rng, n * k);
        let g = randn(&mut rng, m * n);

        let mut c1 = vec![0f32; m * n];
        nn::matmul_into_workers(&a, &b, m, k, n, &mut c1, 1);
        let mut abt1 = vec![0f32; m * n];
        nn::matmul_a_bt_into_workers(&a, &bt, m, n, k, &mut abt1, 1);
        let mut acc1 = randn(&mut Rng::new(9), k * n);
        nn::matmul_at_b_acc_workers(&a, &g, m, k, n, &mut acc1, 1);

        for workers in [2usize, 4, 8] {
            let mut c = vec![0f32; m * n];
            nn::matmul_into_workers(&a, &b, m, k, n, &mut c, workers);
            assert_eq!(bits(&c1), bits(&c), "matmul {m}x{k}x{n} workers {workers}");

            let mut abt = vec![0f32; m * n];
            nn::matmul_a_bt_into_workers(&a, &bt, m, n, k, &mut abt, workers);
            assert_eq!(bits(&abt1), bits(&abt), "a_bt {m}x{n}x{k} workers {workers}");

            let mut acc = randn(&mut Rng::new(9), k * n);
            nn::matmul_at_b_acc_workers(&a, &g, m, k, n, &mut acc, workers);
            assert_eq!(bits(&acc1), bits(&acc), "at_b_acc {m}x{k}x{n} workers {workers}");
        }
    }

    // The sparse aggregation kernels, over a real normalized adjacency.
    let g = Workload::resolve("random:60:3").unwrap().graph;
    let csr = nn::normalized_adjacency_csr(g.n(), &g.edges);
    for cols in [1usize, 5, 16] {
        let x = randn(&mut rng, g.n() * cols);
        let bias = randn(&mut rng, cols);
        let mut agg1 = vec![0f32; g.n() * cols];
        nn::aggregate_into_workers(&csr, &x, cols, &mut agg1, 1);
        let mut rel1 = vec![0f32; g.n() * cols];
        nn::aggregate_bias_relu_into_workers(&csr, &x, &bias, cols, &mut rel1, 1);
        for workers in [2usize, 4, 8] {
            let mut agg = vec![0f32; g.n() * cols];
            nn::aggregate_into_workers(&csr, &x, cols, &mut agg, workers);
            assert_eq!(bits(&agg1), bits(&agg), "aggregate cols {cols} workers {workers}");
            let mut rel = vec![0f32; g.n() * cols];
            nn::aggregate_bias_relu_into_workers(&csr, &x, &bias, cols, &mut rel, workers);
            assert_eq!(bits(&rel1), bits(&rel), "agg+relu cols {cols} workers {workers}");
        }
    }
}

fn worker_cfg(workers: usize) -> Config {
    Config {
        backend: "native".to_string(),
        hidden: 16,
        update_timestep: 4,
        seed: 21,
        workers,
        ..Default::default()
    }
}

#[test]
fn search_trajectory_identical_at_any_worker_count() {
    // The whole Alg. 1 loop — forwards, parses, samples, batched
    // simulations, Adam updates, the final parallel rollout sweep — must
    // not change a single bit when the evaluation pool widens.
    let spec = "layered:4x3:2";
    let run = |workers: usize| {
        let cfg = worker_cfg(workers);
        let env = Env::for_workload(Workload::resolve(spec).unwrap(), &cfg).unwrap();
        let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
        agent.search(&env, 2).unwrap()
    };
    let serial = run(1);
    for workers in [2usize, 4] {
        let par = run(workers);
        assert_eq!(serial.best_actions, par.best_actions, "workers {workers}");
        assert_eq!(
            serial.best_latency.to_bits(),
            par.best_latency.to_bits(),
            "workers {workers}"
        );
        assert_eq!(serial.curve.len(), par.curve.len());
        for (a, b) in serial.curve.iter().zip(&par.curve) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "workers {workers}");
            assert_eq!(a.mean_reward.to_bits(), b.mean_reward.to_bits(), "workers {workers}");
        }
    }
}

/// Train a small native policy and wrap it as a checkpoint.
fn tiny_checkpoint(train_spec: &str, workers: usize) -> (Checkpoint, Config) {
    let cfg = worker_cfg(workers);
    let env = Env::for_workload(Workload::resolve(train_spec).unwrap(), &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    agent.search(&env, 1).unwrap();
    let ckpt = Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: train_spec.to_string(),
            best_latency: None,
        },
    );
    (ckpt, cfg)
}

fn place_req(
    spec: &str,
    no_cache: bool,
    fast_math: bool,
) -> protocol::PlaceRequest {
    let line = protocol::render_place_request_for(
        Some(spec),
        None,
        None,
        None,
        None,
        no_cache,
        fast_math,
        None,
    );
    match protocol::parse_request(&line).unwrap() {
        protocol::Request::Place(p) => p,
        _ => panic!("not a place request"),
    }
}

#[test]
fn served_request_identical_at_any_worker_count() {
    // Two services over the SAME trained checkpoint, differing only in
    // the evaluation worker count, must serve byte-identical placements.
    let (ckpt, cfg1) = tiny_checkpoint("layered:3x3:1", 1);
    let serial = PlacementService::new(
        Checkpoint::new(ckpt.store.clone(), ckpt.meta.clone()),
        &cfg1,
        ServeOptions::default(),
    )
    .unwrap();
    let req = place_req("seq:9", false, false);
    let base = serial.handle_place(&req).unwrap();
    for workers in [2usize, 4] {
        let cfg = Config { workers, ..cfg1.clone() };
        let par = PlacementService::new(
            Checkpoint::new(ckpt.store.clone(), ckpt.meta.clone()),
            &cfg,
            ServeOptions::default(),
        )
        .unwrap();
        let out = par.handle_place(&req).unwrap();
        assert_eq!(base.placement, out.placement, "workers {workers}");
        assert_eq!(base.latency_s.to_bits(), out.latency_s.to_bits(), "workers {workers}");
        assert_eq!(base.provenance, out.provenance, "workers {workers}");
        assert_eq!(base.fingerprint, out.fingerprint, "workers {workers}");
    }
}

#[test]
fn fast_math_kernels_are_tolerance_equal_and_worker_invariant() {
    let mut rng = Rng::new(7);
    let (m, k, n) = (33usize, 46usize, 32usize);
    let a = randn(&mut rng, m * k);
    let b = randn(&mut rng, k * n);
    let mut exact = vec![0f32; m * n];
    nn::matmul_into(&a, &b, m, k, n, &mut exact);
    let mut fast = vec![0f32; m * n];
    nn::matmul_into_fast(&a, &b, m, k, n, &mut fast);
    for (i, (&e, &f)) in exact.iter().zip(&fast).enumerate() {
        let tol = 1e-4 * (1.0 + e.abs());
        assert!((e - f).abs() <= tol, "[{i}] exact {e} fast {f}");
    }
    // Within the fast path, the worker count still changes nothing: the
    // reassociated order is fixed per row, and rows are banded disjointly.
    for workers in [2usize, 4] {
        let mut fw = vec![0f32; m * n];
        nn::matmul_into_fast_workers(&a, &b, m, k, n, &mut fw, workers);
        assert_eq!(bits(&fast), bits(&fw), "fast workers {workers}");
    }
    // dot_fast: deterministic, tolerance-equal to the reference sum.
    let x = randn(&mut rng, 1000);
    let y = randn(&mut rng, 1000);
    let reference: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    let d = nn::dot_fast(&x, &y);
    assert!((reference - d).abs() <= 1e-4 * (1.0 + reference.abs()), "{reference} vs {d}");
    assert_eq!(d.to_bits(), nn::dot_fast(&x, &y).to_bits());
}

#[test]
fn fast_math_answers_never_enter_or_leave_the_serve_cache() {
    let (ckpt, cfg) = tiny_checkpoint("layered:3x3:1", 1);
    let svc = PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap();
    let spec = "seq:10";

    // A cold fast-math request computes fresh (not from cache)...
    let fast = svc.handle_place(&place_req(spec, false, true)).unwrap();
    assert_ne!(fast.provenance, protocol::Provenance::Cache);
    // ...and must NOT have populated the answer cache: the next default
    // request still computes fresh.
    let cold = svc.handle_place(&place_req(spec, false, false)).unwrap();
    assert_ne!(cold.provenance, protocol::Provenance::Cache, "fast-math answer was cached");
    // The default answer IS cached...
    let warm = svc.handle_place(&place_req(spec, false, false)).unwrap();
    assert_eq!(warm.provenance, protocol::Provenance::Cache);
    // ...but a fast-math request refuses to read it back.
    let fast2 = svc.handle_place(&place_req(spec, false, true)).unwrap();
    assert_ne!(fast2.provenance, protocol::Provenance::Cache, "fast-math read the cache");
}

//! End-to-end runtime integration: load real AOT artifacts, execute the
//! policy fwd / placer / train path from rust through the pjrt backend,
//! and run whole agent steps.
//!
//! Requires `make artifacts` to have populated artifacts/ AND a real
//! PJRT-backed `xla` crate. When either is missing (the offline CI
//! environment), each test skips with a note instead of failing — the
//! native-backend twin of this suite (tests/native_backend.rs) always
//! runs, and the non-neural pipeline is covered by the unit suites and
//! tests/testbeds.rs regardless.

use hsdag::config::Config;
use hsdag::models::Benchmark;
use hsdag::rl::{BaselineAgent, BaselineKind, Env, HsdagAgent};
use hsdag::runtime::Engine;

fn engine() -> Option<Engine> {
    let mut eng = match Engine::cpu("artifacts") {
        Ok(e) => e,
        Err(err) => {
            eprintln!("skipping runtime integration test: {err:#}");
            return None;
        }
    };
    // The directory existing is not enough: loading an artifact also
    // exercises HLO parsing + PJRT compilation, which the vendored xla
    // stub cannot do — probe one so the suite skips (not panics) there.
    if let Err(err) = eng.load("resnet50_hsdag_train") {
        eprintln!("skipping runtime integration test: {err:#}");
        return None;
    }
    Some(eng)
}

fn small_cfg() -> Config {
    Config { max_episodes: 2, seed: 42, backend: "pjrt".to_string(), ..Default::default() }
}

#[test]
fn fwd_artifact_runs_and_shapes_match() {
    let Some(_eng) = engine() else { return };
    let cfg = small_cfg();
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    assert!(agent.backend_desc().contains("pjrt"), "{}", agent.backend_desc());
    let out = agent.step(&env, false).unwrap();
    assert_eq!(out.actions.len(), env.n_nodes);
    assert!(out.latency > 0.0 && out.latency.is_finite());
    assert!(out.n_groups > 1 && out.n_groups < env.n_nodes);
}

#[test]
fn train_step_updates_parameters() {
    let Some(_eng) = engine() else { return };
    let cfg = small_cfg();
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let before: Vec<f32> = agent.params().params[0].as_f32().to_vec();
    for _ in 0..cfg.update_timestep {
        agent.step(&env, true).unwrap();
    }
    let loss = agent.update(&env).unwrap().expect("buffer full");
    assert!(loss.is_finite());
    assert!(agent.params().step == 1.0);
    // Many rows of trans_w0 see zero gradient (one-hot feature columns
    // that never fire); require a substantial but not total update.
    let after = agent.params().params[0].as_f32();
    let changed = before.iter().zip(after).filter(|(a, b)| a != b).count();
    assert!(changed > before.len() / 10, "only {changed} weights moved");
    // The placer head sits on dense activations: nearly all must move.
    let pw_idx = agent.params().names.iter().position(|n| n == "place_w0").unwrap();
    let pw = agent.params().params[pw_idx].as_f32();
    assert!(pw.iter().filter(|&&x| x != 0.0).count() > pw.len() / 2);
}

#[test]
fn mini_search_improves_over_random_start() {
    let Some(_eng) = engine() else { return };
    let cfg = Config { max_episodes: 3, seed: 7, ..small_cfg() };
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let res = agent.search(&env, 3).unwrap();
    assert_eq!(res.curve.len(), 3);
    // Best found must at least beat the all-CPU reference (GPU-only is in
    // the search space and trivially better on ResNet).
    assert!(
        res.best_latency < env.ref_latency,
        "best {} vs reference {}",
        res.best_latency,
        env.ref_latency
    );
    assert!(res.wall_secs > 0.0);
}

#[test]
fn placeto_agent_runs() {
    let Some(mut eng) = engine() else { return };
    let cfg = small_cfg();
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = BaselineAgent::new(&env, &mut eng, &cfg, BaselineKind::Placeto).unwrap();
    let out = agent.step(&env, &mut eng, true).unwrap();
    assert_eq!(out.actions.len(), env.n_nodes);
    assert!(out.latency.is_finite() && out.latency > 0.0);
    assert!(out.feasible, "unbounded default testbed can never OOM");
    for _ in 1..cfg.update_timestep {
        agent.step(&env, &mut eng, true).unwrap();
    }
    let loss = agent.update(&env, &mut eng).unwrap().expect("full buffer");
    assert!(loss.is_finite());
}

#[test]
fn rnn_agent_runs() {
    let Some(mut eng) = engine() else { return };
    let cfg = small_cfg();
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut agent = BaselineAgent::new(&env, &mut eng, &cfg, BaselineKind::Rnn).unwrap();
    let out = agent.step(&env, &mut eng, false).unwrap();
    assert_eq!(out.actions.len(), env.n_nodes);
    assert!(out.latency.is_finite() && out.latency > 0.0);
    assert_eq!(out.latency, out.det_latency, "greedy step carries no noise");
    assert!(out.feasible, "unbounded default testbed can never OOM");
}

#[test]
fn deterministic_given_seed() {
    let Some(_eng) = engine() else { return };
    let cfg = small_cfg();
    let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
    let mut a1 = HsdagAgent::new(&env, &cfg).unwrap();
    let mut a2 = HsdagAgent::new(&env, &cfg).unwrap();
    let o1 = a1.step(&env, true).unwrap();
    let o2 = a2.step(&env, true).unwrap();
    assert_eq!(o1.actions, o2.actions);
    assert_eq!(o1.latency, o2.latency);
}

//! Cost-model subsystem integration: memory-capacity feasibility on the
//! constrained testbeds and serial/parallel batched-evaluation identity.
//!
//! Acceptance contract of the CostModel refactor:
//! - on a memory-constrained testbed, all-on-accelerator placements are
//!   reported infeasible (OOM) by `execute` without changing the schedule;
//! - the memory-aware greedy baseline returns a feasible placement there;
//! - `evaluate_many` / `measure_many` through the parallel worker pool
//!   return results identical to the serial loop.

use hsdag::baselines;
use hsdag::graph::CompGraph;
use hsdag::models::Benchmark;
use hsdag::sim::{
    execute, AnalyticCostModel, CostModel, ParallelCostModel, Placement, ReferenceCostModel,
    Testbed,
};
use hsdag::util::Rng;

#[test]
fn all_on_accelerator_ooms_on_tight_testbed() {
    let tb = Testbed::by_id("cpu_gpu_tight").unwrap();
    // Both large benchmarks carry far more than 64 MB of resident f32
    // weights (ResNet-50 ~102 MB, BERT-base ~438 MB): all-accelerator
    // placements must be flagged OOM on the tight dGPU.
    for b in [Benchmark::ResNet50, Benchmark::BertBase] {
        let g = b.build();
        let all_accel = Placement::all(g.n(), tb.accel());
        let rep = execute(&g, &all_accel, &tb);
        assert!(!rep.feasible(), "{}: all-accel should OOM", b.id());
        assert!(rep.oom_devices.contains(&tb.accel()), "{}", b.id());
        assert!(
            rep.mem_peak[tb.accel()] > tb.devices[tb.accel()].mem_capacity,
            "{}",
            b.id()
        );
        // The capacity is observational: the schedule itself is the one
        // the unconstrained paper testbed produces.
        let loose = execute(&g, &all_accel, &Testbed::cpu_gpu());
        assert!(loose.feasible(), "{}", b.id());
        assert_eq!(loose.makespan, rep.makespan, "{}", b.id());
        assert_eq!(loose.mem_peak, rep.mem_peak, "{}", b.id());
    }
}

#[test]
fn memory_greedy_stays_feasible_on_constrained_testbeds() {
    for tb in [Testbed::cpu_gpu_tight(), Testbed::multi_gpu_mem(2, 1)] {
        for b in Benchmark::ALL {
            let g = b.build();
            let p = baselines::memory_greedy_placement(&g, &tb);
            let rep = execute(&g, &p, &tb);
            assert!(
                rep.feasible(),
                "{}/{}: memory-greedy overflowed {:?}",
                tb.id,
                b.id(),
                rep.oom_devices
            );
            assert!(rep.makespan.is_finite() && rep.makespan > 0.0);
        }
    }
}

#[test]
fn tight_capacity_changes_feasibility_not_latency() {
    // The same placement scores identically on cpu_gpu and cpu_gpu_tight
    // (same hardware); only the feasibility verdict differs.
    let g = Benchmark::ResNet50.build();
    let tight = Testbed::cpu_gpu_tight();
    let loose = Testbed::cpu_gpu();
    let mut rng = Rng::new(0xFEA5);
    for _ in 0..4 {
        let p = baselines::random_placement(&g, &tight, &mut rng);
        let a = execute(&g, &p, &tight);
        let b = execute(&g, &p, &loose);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.busy, b.busy);
        assert_eq!(a.mem_peak, b.mem_peak);
        assert!(b.feasible());
    }
}

#[test]
fn parallel_evaluate_many_matches_serial_loop() {
    let serial = AnalyticCostModel;
    let parallel = ParallelCostModel::new(AnalyticCostModel, 0);
    for tb in Testbed::registered() {
        for b in Benchmark::ALL {
            let g = b.build();
            let mut rng = Rng::new(0xE7A1);
            let placements: Vec<Placement> =
                (0..8).map(|_| baselines::random_placement(&g, &tb, &mut rng)).collect();
            let want = serial.evaluate_many(&g, &placements, &tb);
            let got = parallel.evaluate_many(&g, &placements, &tb);
            assert_eq!(want, got, "{}/{}", tb.id, b.id());
        }
    }
}

#[test]
fn parallel_measure_many_matches_serial_loop() {
    let serial = AnalyticCostModel;
    let g = Benchmark::InceptionV3.build();
    let tb = Testbed::paper3();
    let p = Placement::all(g.n(), tb.accel());
    for workers in [1, 2, 0] {
        let parallel = ParallelCostModel::new(AnalyticCostModel, workers);
        assert_eq!(
            serial.measure_many(&g, &p, &tb, 0.05, 42, 64),
            parallel.measure_many(&g, &p, &tb, 0.05, 42, 64),
            "workers {workers}"
        );
    }
}

#[test]
fn reference_cost_model_agrees_with_analytic() {
    // Pluggability sanity: the retained-reference model is bit-identical
    // to the default analytic model (the schedulers are differential-
    // tested; this pins the trait wiring on top of them).
    let g = Benchmark::ResNet50.build();
    let tb = Testbed::cpu_gpu_tight();
    let mut rng = Rng::new(3);
    let p = baselines::random_placement(&g, &tb, &mut rng);
    assert_eq!(
        AnalyticCostModel.evaluate(&g, &p, &tb),
        ReferenceCostModel.evaluate(&g, &p, &tb)
    );
}

#[test]
fn random_graphs_memory_accounting_is_scheduler_independent() {
    // Property-flavored: on random DAGs and random placements, the heap
    // and re-scan schedulers agree on the full memory report too.
    let mut rng = Rng::new(0xD06);
    for case in 0..16 {
        let g = CompGraph::random(&mut rng, 20 + case * 5, 8);
        let tbs = Testbed::registered();
        let tb = &tbs[case % tbs.len()];
        let p = baselines::random_placement(&g, tb, &mut rng);
        let a = AnalyticCostModel.evaluate(&g, &p, tb);
        let b = ReferenceCostModel.evaluate(&g, &p, tb);
        assert_eq!(a, b, "case {case} on {}", tb.id);
    }
}

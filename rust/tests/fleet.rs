//! Fleet-tier integration tests: routing, hot reload, backpressure.
//!
//! - the router partitions the keyspace: requests for distinct specs
//!   land on their rendezvous-assigned shards, repeats are cache hits on
//!   the owning shard, and the fleet's caches together hold each spec
//!   exactly once (no duplication);
//! - `ctrl: reload` fans out through the router, bumps every shard's
//!   checkpoint generation with the cache kept (same hidden width), and
//!   a reload hammered by concurrent placement traffic drops nothing;
//! - a saturated shard (one worker, zero queue depth) sheds the surplus
//!   connection with an explicit `busy` line instead of stalling it,
//!   counts the reject, and serves normally again once the pinned
//!   connection goes away;
//! - `ctrl: clear-cache` over the wire empties the LRU so the next
//!   repeat is a fresh inference, not a cache hit;
//! - the retry client backs off on transport errors (connection refused
//!   costs the full backoff schedule before the final error) and never
//!   retries a server-reported failure (the shard sees exactly one
//!   request).

use std::io::{BufRead, BufReader};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::models::Workload;
use hsdag::rl::{Env, HsdagAgent};
use hsdag::serve::{
    client, fingerprint, protocol, shard_for, Checkpoint, CheckpointMeta, LineHandler,
    PlacementService, Router, ServeOptions, Server, ServerHandle,
};
use hsdag::util::json::Json;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hsdag_fleet_test_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train a small native policy and wrap it as a checkpoint.
fn tiny_checkpoint(train_spec: &str, episodes: usize) -> (Checkpoint, Config) {
    let cfg = Config {
        backend: "native".to_string(),
        hidden: 16,
        update_timestep: 4,
        seed: 5,
        ..Default::default()
    };
    let env = Env::for_workload(Workload::resolve(train_spec).unwrap(), &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    agent.search(&env, episodes).unwrap();
    let ckpt = Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: train_spec.to_string(),
            best_latency: None,
        },
    );
    (ckpt, cfg)
}

/// One in-process shard: a `PlacementService` behind a real TCP server
/// on an ephemeral loopback port.
struct Shard {
    service: Arc<PlacementService>,
    addr: String,
    handle: ServerHandle,
}

fn spawn_shards(n: usize, ckpt: &Checkpoint, cfg: &Config) -> Vec<Shard> {
    (0..n)
        .map(|_| {
            let service = Arc::new(
                PlacementService::new(ckpt.clone(), cfg, ServeOptions::default()).unwrap(),
            );
            let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
            let addr = server.local_addr().to_string();
            let handle = server.spawn(2).unwrap();
            Shard { service, addr, handle }
        })
        .collect()
}

fn shutdown_shards(shards: Vec<Shard>, timeout: Duration) {
    for s in shards {
        client::roundtrip(&s.addr, &protocol::render_shutdown_request(), timeout).unwrap();
        s.handle.join().unwrap();
    }
}

/// Pick specs until every shard owns at least `per_shard` of them — the
/// rendezvous hash decides ownership, so the set adapts to the ports the
/// OS handed out rather than hardcoding an assignment.
fn specs_covering(addrs: &[String], testbed: &str, per_shard: usize) -> Vec<(String, usize)> {
    let mut owned = vec![0usize; addrs.len()];
    let mut picked = Vec::new();
    for n in 4..64 {
        if owned.iter().all(|&c| c >= per_shard) {
            break;
        }
        let spec = format!("seq:{n}");
        let g = Workload::resolve(&spec).unwrap().graph;
        let owner = shard_for(fingerprint(&g, testbed), addrs);
        if owned[owner] < per_shard {
            owned[owner] += 1;
            picked.push((spec, owner));
        }
    }
    assert!(
        owned.iter().all(|&c| c >= per_shard),
        "60 candidate specs did not cover every shard — hash badly skewed?"
    );
    picked
}

#[test]
fn router_partitions_caches_and_fans_out_reload() {
    let (ckpt, cfg) = tiny_checkpoint("layered:3x3:1", 2);
    let dir = tmp_dir("router");
    let ckpt_path = dir.join("fleet.ckpt.json");
    ckpt.save(&ckpt_path).unwrap();

    let timeout = Duration::from_secs(30);
    let shards = spawn_shards(2, &ckpt, &cfg);
    for s in &shards {
        s.service.set_default_checkpoint(&ckpt_path);
    }
    let addrs: Vec<String> = shards.iter().map(|s| s.addr.clone()).collect();
    let router = Router::new(addrs.clone(), timeout).unwrap();
    assert_eq!(router.testbed(), cfg.resolve_testbed().unwrap().id);

    // Each spec routed twice through the router: a cold miss then a
    // cache hit — on the owning shard both times.
    let specs = specs_covering(&addrs, router.testbed(), 1);
    for (spec, owner) in &specs {
        let line =
            protocol::render_place_request(Some(spec.as_str()), None, None, None, None, false);
        for (pass, want_cache) in [("cold", false), ("repeat", true)] {
            let (resp, shut) = router.handle_line(&line);
            assert!(!shut);
            let doc = protocol::parse_response(&resp).unwrap();
            let prov = doc.get("provenance").unwrap().as_str().unwrap();
            assert_eq!(
                prov == "cache",
                want_cache,
                "{spec} {pass} pass (owner shard {owner}): provenance {prov}"
            );
        }
    }

    // The partition property: together the shard caches hold each spec
    // exactly once, and each shard holds exactly what it owns.
    let views: Vec<_> = shards.iter().map(|s| s.service.stats_view()).collect();
    let total: usize = views.iter().map(|v| v.cache_len).sum();
    assert_eq!(total, specs.len(), "fleet caches must hold each spec exactly once");
    for (i, v) in views.iter().enumerate() {
        let owned = specs.iter().filter(|(_, o)| *o == i).count();
        assert_eq!(v.cache_len, owned, "shard {i} cache size");
        assert_eq!(v.cache_hits, owned as u64, "shard {i} cache hits");
    }

    // The router's aggregated stats see the same world.
    let (resp, _) = router.handle_line(&protocol::render_stats_request());
    let doc = protocol::parse_response(&resp).unwrap();
    assert_eq!(doc.get("router").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("fleet_size").unwrap().as_usize(), Some(2));
    // The parallel stats scatter doubles as the health probe: both
    // shards are up, so both report healthy.
    assert_eq!(doc.get("healthy_shards").unwrap().as_usize(), Some(2));
    let routed: Vec<usize> = doc
        .get("routed")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    assert_eq!(routed.iter().sum::<usize>(), 2 * specs.len());
    let shard_stats = doc.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shard_stats.len(), 2);
    for (i, entry) in shard_stats.iter().enumerate() {
        assert_eq!(entry.get("addr").and_then(Json::as_str), Some(addrs[i].as_str()));
        assert_eq!(entry.get("healthy").and_then(Json::as_bool), Some(true));
        let body = entry.get("stats").unwrap();
        assert_eq!(body.get("checkpoint_generation").unwrap().as_usize(), Some(0));
    }

    // Reload fans out: every shard bumps its generation, keeps its cache
    // (same hidden width), and the aggregate response is ok.
    let (resp, _) = router.handle_line(&protocol::render_reload_request(None));
    let doc = protocol::parse_response(&resp).unwrap();
    assert_eq!(doc.get("action").unwrap().as_str(), Some("reload"));
    for entry in doc.get("shards").unwrap().as_arr().unwrap() {
        let body = entry.get("response").unwrap();
        assert_eq!(body.get("generation").unwrap().as_usize(), Some(1));
        assert_eq!(body.get("cache_kept").unwrap().as_bool(), Some(true));
    }
    for (i, s) in shards.iter().enumerate() {
        assert_eq!(s.service.generation(), 1, "shard {i} generation");
        let v = s.service.stats_view();
        assert_eq!(v.reloads, 1);
        let owned = specs.iter().filter(|(_, o)| *o == i).count();
        assert_eq!(v.cache_len, owned, "reload with same hidden must keep the cache");
    }

    // A repeat after reload is still a cache hit (the cache survived).
    let (spec, owner) = &specs[0];
    let line = protocol::render_place_request(Some(spec.as_str()), None, None, None, None, false);
    let (resp, _) = router.handle_line(&line);
    let doc = protocol::parse_response(&resp).unwrap();
    assert_eq!(doc.get("provenance").unwrap().as_str(), Some("cache"), "owner {owner}");

    // Shutdown through the router stops the router only; the shards
    // answer afterwards and are shut down individually.
    let (resp, shut) = router.handle_line(&protocol::render_shutdown_request());
    assert!(shut);
    assert!(protocol::parse_response(&resp).is_ok());
    for s in &shards {
        let resp =
            client::roundtrip(&s.addr, &protocol::render_stats_request(), timeout).unwrap();
        assert!(protocol::parse_response(&resp).is_ok(), "shard must outlive the router");
    }
    shutdown_shards(shards, timeout);
}

#[test]
fn reload_under_concurrent_load_drops_nothing() {
    let (ckpt, cfg) = tiny_checkpoint("layered:3x3:1", 2);
    let dir = tmp_dir("reload_load");
    let ckpt_path = dir.join("live.ckpt.json");
    ckpt.save(&ckpt_path).unwrap();

    let service =
        Arc::new(PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap());
    service.set_default_checkpoint(&ckpt_path);
    let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn(4).unwrap();
    let timeout = Duration::from_secs(30);

    const CLIENTS: usize = 4;
    const REQS: usize = 40;
    const RELOADS: u64 = 3;
    let specs = ["seq:4", "seq:5", "seq:6"];
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for t in 0..CLIENTS {
            let (addr, specs) = (&addr, &specs);
            handles.push(scope.spawn(move || {
                let mut conn = client::Connection::open(addr, timeout).unwrap();
                let tenant = format!("team-{t}");
                for i in 0..REQS {
                    let spec = specs[(t + i) % specs.len()];
                    let line = protocol::render_place_request_for(
                        Some(spec),
                        None,
                        None,
                        None,
                        None,
                        false,
                        false,
                        Some(&tenant),
                    );
                    // Every response must be a success — a dropped or
                    // error response during reload fails the test.
                    let resp = conn.send(&line).unwrap();
                    protocol::parse_response(&resp).unwrap();
                }
            }));
        }
        // Interleave reloads with the traffic.
        for _ in 0..RELOADS {
            std::thread::sleep(Duration::from_millis(30));
            let resp =
                client::roundtrip(&addr, &protocol::render_reload_request(None), timeout)
                    .unwrap();
            protocol::parse_response(&resp).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    let v = service.stats_view();
    assert_eq!(service.generation(), RELOADS);
    assert_eq!(v.reloads, RELOADS);
    assert_eq!(v.errors, 0, "no request may fail during reloads");
    assert!(v.requests >= (CLIENTS * REQS) as u64 + RELOADS);
    assert_eq!(v.checkpoint_generation, RELOADS);
    // Per-tenant accounting: every client thread's label, sorted, with
    // its exact request count.
    let want: Vec<(String, u64)> =
        (0..CLIENTS).map(|t| (format!("team-{t}"), REQS as u64)).collect();
    assert_eq!(v.tenants, want);

    client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    handle.join().unwrap();
}

#[test]
fn saturated_server_sheds_busy_then_recovers() {
    let (ckpt, cfg) = tiny_checkpoint("seq:6", 1);
    let service =
        Arc::new(PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap());
    let mut server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    // One worker, zero queue: a second concurrent connection is over the
    // high-water mark by construction — the shed is deterministic, not a
    // race the test has to win.
    server.set_queue_depth(0);
    let addr = server.local_addr().to_string();
    let handle = server.spawn(1).unwrap();
    let timeout = Duration::from_secs(30);

    // Pin the only worker: complete one exchange so the worker is
    // provably inside this connection's read loop, then keep it open.
    let mut pinned = client::Connection::open(&addr, timeout).unwrap();
    let resp = pinned.send(&protocol::render_stats_request()).unwrap();
    assert!(protocol::parse_response(&resp).is_ok());

    // The surplus connection gets the busy line without sending a byte
    // (admission is at accept time), then EOF.
    let surplus = TcpStream::connect(&addr).unwrap();
    surplus.set_read_timeout(Some(timeout)).unwrap();
    let mut reader = BufReader::new(surplus);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(protocol::is_busy_response(&line), "expected busy shed, got: {line}");
    assert!(protocol::parse_response(&line).is_err(), "busy must be an error response");
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "server must close after busy");

    // Release the worker; the server must serve new connections again.
    drop(pinned);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match client::roundtrip(&addr, &protocol::render_stats_request(), timeout) {
            Ok(resp) if !protocol::is_busy_response(&resp) => {
                let doc = protocol::parse_response(&resp).unwrap();
                assert!(doc.get("busy_rejects").unwrap().as_usize().unwrap() >= 1);
                break;
            }
            _ if Instant::now() > deadline => panic!("server never recovered from shed"),
            _ => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    assert!(service.stats_view().busy_rejects >= 1);

    // Shutdown may race one more busy shed; retry briefly.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let resp =
            client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
        if !protocol::is_busy_response(&resp) {
            break;
        }
        assert!(Instant::now() < deadline, "shutdown kept getting shed");
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.join().unwrap();
}

#[test]
fn clear_cache_over_the_wire() {
    let (ckpt, cfg) = tiny_checkpoint("seq:5", 1);
    let service =
        Arc::new(PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap());
    let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn(2).unwrap();
    let timeout = Duration::from_secs(30);

    let line = protocol::render_place_request(Some("seq:5"), None, None, None, None, false);
    let warm = |label: &str| -> String {
        let resp = client::roundtrip(&addr, &line, timeout).unwrap();
        let doc = protocol::parse_response(&resp).unwrap();
        let prov = doc.get("provenance").unwrap().as_str().unwrap().to_string();
        assert!(doc.get("feasible").unwrap().as_bool() == Some(true), "{label}");
        prov
    };
    assert_ne!(warm("first"), "cache");
    assert_eq!(warm("repeat"), "cache");
    assert_eq!(service.stats_view().cache_len, 1);

    let resp =
        client::roundtrip(&addr, &protocol::render_clear_cache_request(), timeout).unwrap();
    let doc = protocol::parse_response(&resp).unwrap();
    assert_eq!(doc.get("action").unwrap().as_str(), Some("clear-cache"));
    assert_eq!(service.stats_view().cache_len, 0);

    // The next identical request is a fresh inference again.
    assert_ne!(warm("after clear"), "cache");
    assert_eq!(warm("re-repeat"), "cache");

    client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    handle.join().unwrap();
}

#[test]
fn retry_client_backs_off_on_transport_errors_only() {
    // A port that was just bound and released: connecting is refused
    // immediately, so elapsed time is backoff, not network latency.
    let dead_addr = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let stats = protocol::render_stats_request();
    let timeout = Duration::from_secs(2);

    let t0 = Instant::now();
    let err = client::roundtrip_retry(&dead_addr, &stats, timeout, 2).unwrap_err();
    let elapsed = t0.elapsed();
    assert!(format!("{err:#}").contains("after 3 attempt(s)"), "{err:#}");
    // Two backoff sleeps (50 ms + 100 ms) floor the elapsed time.
    assert!(elapsed >= Duration::from_millis(150), "no backoff: {elapsed:?}");

    // retries == 0 is a single attempt.
    let t0 = Instant::now();
    let err = client::roundtrip_retry(&dead_addr, &stats, timeout, 0).unwrap_err();
    assert!(format!("{err:#}").contains("after 1 attempt(s)"), "{err:#}");
    assert!(t0.elapsed() < Duration::from_millis(150));

    // A server-reported failure is returned, not retried: the server
    // sees exactly one request.
    let (ckpt, cfg) = tiny_checkpoint("seq:4", 1);
    let service =
        Arc::new(PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap());
    let server = Server::bind(Arc::clone(&service), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();
    let handle = server.spawn(1).unwrap();
    let bad = "{\"op\":\"place\"}"; // no spec and no graph: a request error
    let resp = client::roundtrip_retry(&addr, bad, timeout, 5).unwrap();
    assert!(protocol::parse_response(&resp).is_err(), "must surface the server error");
    assert_eq!(service.stats_view().requests, 1, "server error must not be retried");

    client::roundtrip(&addr, &protocol::render_shutdown_request(), timeout).unwrap();
    handle.join().unwrap();
}

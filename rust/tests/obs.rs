//! Observability integration tests: the strictly-observational contract.
//!
//! - the metrics registry conserves counts under concurrent writers;
//! - a traced multi-stage served request emits a schema-valid
//!   `hsdag-trace-v1` line whose spans cover the pipeline stages in
//!   order, and the trace id round-trips client → service → response;
//! - the determinism pins: a served request and a short training run are
//!   bit-identical with telemetry (metrics, profiling, tracing) enabled
//!   or disabled — telemetry observes, never steers;
//! - the `metrics` wire command and `stats` stage/histogram fields are
//!   valid documents;
//! - end-to-end through the binary: `train --run-log` emits
//!   `hsdag-run-v1` JSONL without changing the console output, and
//!   `hsdag trace summarize` renders the per-stage table.

use std::path::PathBuf;
use std::process::Command;
use std::sync::{Arc, Mutex};

use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::models::Workload;
use hsdag::obs::metrics;
use hsdag::obs::trace::{self, TraceSink, TRACE_FORMAT};
use hsdag::rl::{Env, HsdagAgent};
use hsdag::serve::{protocol, Checkpoint, CheckpointMeta, PlacementService, ServeOptions};
use hsdag::util::json::Json;

/// Serializes tests that toggle the process-global telemetry switches or
/// assert exact counter deltas (integration tests share one process).
static GLOBAL: Mutex<()> = Mutex::new(());

fn lock_global() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hsdag_obs_test_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Train a small native policy and wrap it as a checkpoint (same recipe
/// as the serve suite; deterministic per seed).
fn tiny_checkpoint(train_spec: &str, episodes: usize) -> (Checkpoint, Config) {
    let cfg = Config {
        backend: "native".to_string(),
        hidden: 16,
        update_timestep: 4,
        seed: 5,
        ..Default::default()
    };
    let env = Env::for_workload(Workload::resolve(train_spec).unwrap(), &cfg).unwrap();
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    agent.search(&env, episodes).unwrap();
    let ckpt = Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: train_spec.to_string(),
            best_latency: None,
        },
    );
    (ckpt, cfg)
}

#[test]
fn counters_conserve_under_concurrent_writers() {
    let _g = lock_global();
    let c = metrics::counter("test.obs.conservation");
    let before = c.get();
    let threads = 8;
    let per_thread = 10_000u64;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                for _ in 0..per_thread {
                    c.inc();
                }
            });
        }
    });
    assert_eq!(c.get(), before + threads * per_thread, "every increment accounted for");

    // Histograms conserve their record count the same way.
    let h = metrics::histogram("test.obs.hist_conservation");
    let base = h.snapshot().count();
    std::thread::scope(|s| {
        for t in 0..threads {
            s.spawn(move || {
                for i in 0..1000u64 {
                    h.record(t * 1000 + i);
                }
            });
        }
    });
    assert_eq!(h.snapshot().count(), base + threads * 1000);
}

#[test]
fn traced_request_emits_ordered_schema_valid_spans() {
    let (ckpt, cfg) = tiny_checkpoint("layered:4x3:2", 2);
    let log_path = tmp_dir("trace").join("trace.jsonl");
    let _ = std::fs::remove_file(&log_path);
    let mut service = PlacementService::new(
        ckpt,
        &cfg,
        ServeOptions { cache_capacity: 8, ..Default::default() },
    )
    .unwrap();
    service.set_trace_sink(Arc::new(TraceSink::open(log_path.to_str().unwrap()).unwrap()));

    // Cold request with a client-supplied trace id, then the cached
    // repeat: two traced requests with very different stage profiles.
    let line = protocol::render_place_request(Some("layered:4x3:2"), None, None, None, None, false);
    let line = protocol::with_trace_id(&line, "00c0ffee00c0ffee").unwrap();
    let (resp, _) = service.handle_line(&line);
    let d1 = Json::parse(&resp).unwrap();
    assert_eq!(d1.get("ok").unwrap().as_bool(), Some(true));
    // The trace id echoes into the response.
    assert_eq!(d1.get("trace").and_then(|t| t.as_str()), Some("00c0ffee00c0ffee"));
    let (resp2, _) = service.handle_line(&line);
    let d2 = Json::parse(&resp2).unwrap();
    assert_eq!(d2.get("provenance").unwrap().as_str(), Some("cache"));

    let text = std::fs::read_to_string(&log_path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "one trace line per request: {text}");

    for raw in &lines {
        let doc = Json::parse(raw).unwrap();
        assert_eq!(doc.get("format").and_then(|f| f.as_str()), Some(TRACE_FORMAT));
        assert_eq!(doc.get("op").and_then(|o| o.as_str()), Some("place"));
        assert_eq!(doc.get("trace").and_then(|t| t.as_str()), Some("00c0ffee00c0ffee"));
        assert!(doc.get("fingerprint").and_then(|f| f.as_str()).is_some());
        let total = doc.get("total_us").and_then(|t| t.as_f64()).unwrap();
        let spans = doc.get("spans").and_then(|s| s.as_arr()).unwrap();
        assert!(!spans.is_empty());
        // Spans are appended in completion order; the serving pipeline is
        // sequential, so start offsets are non-decreasing and inside the
        // request window.
        let mut prev = 0.0;
        for sp in spans {
            let start = sp.get("start_us").and_then(|v| v.as_f64()).unwrap();
            assert!(sp.get("dur_us").and_then(|v| v.as_f64()).is_some());
            assert!(sp.get("stage").and_then(|v| v.as_str()).is_some());
            assert!(start >= prev, "span starts went backwards: {raw}");
            assert!(start <= total, "span starts past the request total: {raw}");
            prev = start;
        }
    }

    let stage_names = |raw: &str| -> Vec<String> {
        Json::parse(raw)
            .unwrap()
            .get("spans")
            .and_then(|s| s.as_arr().map(|a| a.to_vec()))
            .unwrap()
            .iter()
            .map(|sp| sp.get("stage").unwrap().as_str().unwrap().to_string())
            .collect()
    };
    // Cold: the full pipeline ran. Cached repeat: cache, but no rollout.
    let cold = stage_names(lines[0]);
    for want in ["prepare", "cache", "rollout", "select"] {
        assert!(cold.contains(&want.to_string()), "cold trace missing {want}: {cold:?}");
    }
    let cached = stage_names(lines[1]);
    assert!(cached.contains(&"cache".to_string()), "{cached:?}");
    assert!(!cached.contains(&"rollout".to_string()), "{cached:?}");
    assert_eq!(
        Json::parse(lines[1]).unwrap().get("provenance").and_then(|p| p.as_str()),
        Some("cache")
    );
}

#[test]
fn metrics_wire_command_and_stats_stage_fields_are_valid() {
    let _g = lock_global();
    let (ckpt, cfg) = tiny_checkpoint("layered:3x3:1", 2);
    let service = PlacementService::new(ckpt, &cfg, ServeOptions::default()).unwrap();
    let line = protocol::render_place_request(Some("layered:3x3:1"), None, None, None, None, false);
    service.handle_line(&line);
    service.handle_line(&line);

    // `metrics` dumps the registry as a valid hsdag-metrics-v1 document
    // with the serve counters interned by this service.
    let (resp, shut) = service.handle_line(&protocol::render_metrics_request());
    assert!(!shut);
    let doc = Json::parse(&resp).unwrap();
    assert_eq!(doc.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(doc.get("format").and_then(|f| f.as_str()), Some("hsdag-metrics-v1"));
    let counters = match doc.get("counters") {
        Some(Json::Obj(kv)) => kv.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>(),
        other => panic!("counters object, got {other:?}"),
    };
    for want in ["serve.requests", "serve.placements", "serve.cache_hits"] {
        assert!(counters.iter().any(|k| k == want), "missing {want}: {counters:?}");
    }
    assert!(doc.get("histograms").is_some());

    // `stats` carries the histogram buckets and per-stage breakdown.
    let (resp, _) = service.handle_line(&protocol::render_stats_request());
    let st = Json::parse(&resp).unwrap();
    let hist = st.get("service_us_hist").and_then(|h| h.as_arr().map(|a| a.len())).unwrap();
    assert!(hist > 0, "service histogram has nonempty buckets");
    // Stages render as an object keyed by stage name; only stages that
    // actually ran appear (in-process requests never queue).
    let stages = match st.get("stages") {
        Some(Json::Obj(kv)) => kv.clone(),
        other => panic!("stages object, got {other:?}"),
    };
    assert!(!stages.is_empty());
    let names: Vec<&str> = stages.iter().map(|(k, _)| k.as_str()).collect();
    for want in ["prepare", "select"] {
        assert!(names.contains(&want), "missing stage {want}: {names:?}");
    }
    assert!(!names.contains(&"queue"), "in-process requests never queue: {names:?}");
    for (name, sg) in &stages {
        let p50 = sg.get("p50_ms").and_then(|v| v.as_f64()).unwrap();
        let p99 = sg.get("p99_ms").and_then(|v| v.as_f64()).unwrap();
        assert!(p99 >= p50, "{name}: p50 {p50} p99 {p99}");
        assert!(sg.get("count").and_then(|v| v.as_f64()).unwrap() >= 1.0, "{name}");
    }
}

/// The tentpole invariant: telemetry is strictly observational. The same
/// request served with metrics + profiling + tracing all on must produce
/// the same answer (modulo the wall-clock `service_ms` field) as with
/// everything off.
#[test]
fn served_request_identical_with_telemetry_on_and_off() {
    let _g = lock_global();
    let (ckpt, cfg) = tiny_checkpoint("random:24:4", 2);
    let strip_wall = |doc: Json| -> Vec<(String, Json)> {
        match doc {
            Json::Obj(kv) => kv.into_iter().filter(|(k, _)| k != "service_ms").collect(),
            _ => panic!("object response"),
        }
    };
    // Both requests carry the same client trace id so the traced
    // response's `trace` echo matches field-for-field.
    let line = protocol::render_place_request(Some("random:24:4"), None, None, None, None, false);
    let line = protocol::with_trace_id(&line, "feedfacefeedface").unwrap();

    metrics::set_enabled(true);
    metrics::set_profiling(true);
    let log_path = tmp_dir("pin").join("trace.jsonl");
    let mut on = PlacementService::new(
        ckpt.clone(),
        &cfg,
        ServeOptions { cache_capacity: 8, ..Default::default() },
    )
    .unwrap();
    on.set_trace_sink(Arc::new(TraceSink::open(log_path.to_str().unwrap()).unwrap()));
    let resp_on = strip_wall(Json::parse(&on.handle_line(&line).0).unwrap());

    metrics::set_enabled(false);
    metrics::set_profiling(false);
    let off = PlacementService::new(
        ckpt,
        &cfg,
        ServeOptions { cache_capacity: 8, ..Default::default() },
    )
    .unwrap();
    let resp_off = strip_wall(Json::parse(&off.handle_line(&line).0).unwrap());
    metrics::set_enabled(true);

    assert_eq!(resp_on, resp_off, "telemetry changed a served answer");
}

/// Same pin for training: the search trajectory (placements, rewards,
/// losses, entropy) is a pure function of the seed, with or without the
/// metrics registry and kernel profiling recording alongside.
#[test]
fn training_identical_with_telemetry_on_and_off() {
    let _g = lock_global();
    let cfg = Config {
        backend: "native".to_string(),
        hidden: 16,
        update_timestep: 4,
        seed: 11,
        ..Default::default()
    };
    let env = Env::for_workload(Workload::resolve("layered:3x3:1").unwrap(), &cfg).unwrap();

    metrics::set_enabled(true);
    metrics::set_profiling(true);
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let res_on = agent.search(&env, 3).unwrap();

    metrics::set_enabled(false);
    metrics::set_profiling(false);
    let mut agent = HsdagAgent::new(&env, &cfg).unwrap();
    let res_off = agent.search(&env, 3).unwrap();
    metrics::set_enabled(true);

    assert_eq!(res_on.best_actions, res_off.best_actions);
    assert_eq!(res_on.best_latency.to_bits(), res_off.best_latency.to_bits());
    assert_eq!(res_on.curve.len(), res_off.curve.len());
    for (a, b) in res_on.curve.iter().zip(&res_off.curve) {
        assert_eq!(a.mean_reward.to_bits(), b.mean_reward.to_bits(), "episode {}", a.episode);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "episode {}", a.episode);
        assert_eq!(a.entropy.to_bits(), b.entropy.to_bits(), "episode {}", a.episode);
        assert_eq!(a.param_norm.to_bits(), b.param_norm.to_bits(), "episode {}", a.episode);
    }
    // The telemetry itself is live: entropy and param norm were recorded.
    assert!(res_on.curve.iter().any(|p| p.entropy.is_finite()));
    assert!(res_on.curve.iter().any(|p| p.param_norm.is_finite()));
}

#[test]
fn train_run_log_is_schema_valid_and_console_invariant() {
    let bin = env!("CARGO_BIN_EXE_hsdag");
    let dir = tmp_dir("runlog");
    let log = dir.join("run.jsonl");
    let _ = std::fs::remove_file(&log);
    let base_args =
        ["train", "--backend", "native", "--workload", "seq:12", "--episodes", "2", "--seed", "3"];

    let plain = Command::new(bin).args(base_args).output().unwrap();
    assert!(plain.status.success(), "{}", String::from_utf8_lossy(&plain.stderr));
    let logged = Command::new(bin)
        .args(base_args)
        .args(["--run-log", log.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(logged.status.success(), "{}", String::from_utf8_lossy(&logged.stderr));

    // Console learning-curve lines are byte-identical with or without
    // the run log (wall-clock lines excluded).
    let curve_lines = |out: &[u8]| -> Vec<String> {
        String::from_utf8_lossy(out)
            .lines()
            .filter(|l| l.starts_with("  episode"))
            .map(|l| l.to_string())
            .collect()
    };
    let (a, b) = (curve_lines(&plain.stdout), curve_lines(&logged.stdout));
    assert!(!a.is_empty());
    assert_eq!(a, b, "--run-log changed the console output");

    // The log: one hsdag-run-v1 record per curve point, schema-complete.
    let text = std::fs::read_to_string(&log).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), a.len(), "one record per episode line");
    for (i, raw) in lines.iter().enumerate() {
        let doc = Json::parse(raw).unwrap();
        assert_eq!(doc.get("format").and_then(|f| f.as_str()), Some("hsdag-run-v1"));
        assert_eq!(doc.get("episode").and_then(|e| e.as_usize()), Some(i));
        for key in ["best_latency", "mean_reward", "loss", "entropy", "param_norm"] {
            let v = doc.get(key).unwrap_or_else(|| panic!("missing {key}: {raw}"));
            assert!(matches!(v, Json::Num(_) | Json::Null), "{key} not num/null: {raw}");
        }
        assert!(doc.get("mean_reward").unwrap().as_f64().is_some());
    }
}

#[test]
fn trace_summarize_cli_renders_stage_table() {
    let bin = env!("CARGO_BIN_EXE_hsdag");
    let dir = tmp_dir("summarize");
    let log = dir.join("trace.jsonl");
    // Synthesize a small log through the real Trace renderer.
    let sink = TraceSink::open(log.to_str().unwrap()).unwrap();
    for dur in [100u64, 200, 400] {
        let mut t = trace::Trace::new(trace::mint_id(), "place");
        t.span_before_start("queue", dur);
        let s = t.begin();
        t.end("rollout", s);
        sink.write(&t);
    }
    let out =
        Command::new(bin).args(["trace", "summarize", log.to_str().unwrap()]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage"), "{text}");
    assert!(text.contains("queue"), "{text}");
    assert!(text.contains("rollout"), "{text}");
    assert!(text.contains("total"), "{text}");
    assert!(text.contains("3 request(s)"), "{text}");

    // Missing file: a located error, nonzero exit.
    let bad =
        Command::new(bin).args(["trace", "summarize", "/nonexistent.jsonl"]).output().unwrap();
    assert!(!bad.status.success());
    assert!(String::from_utf8_lossy(&bad.stderr).contains("nonexistent"), "named the path");
}

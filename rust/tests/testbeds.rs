//! Testbed-plumbing integration tests: the K-device refactor contract.
//!
//! - `Env::expand` maps working-graph actions onto valid device ids for
//!   every registered testbed (property test over random action vectors);
//! - the default `cpu_gpu` testbed reproduces the pre-refactor
//!   latencies on all three benchmarks: the same placements, simulated
//!   with the pre-refactor devices (`Testbed::paper()` hardware) through
//!   the retained pre-optimization scheduler (`execute_reference`), give
//!   exactly the same numbers — covering both the action-space refactor
//!   and the BinaryHeap scheduler swap at once.

use hsdag::config::Config;
use hsdag::models::Benchmark;
use hsdag::rl::Env;
use hsdag::sim::{execute_reference, Placement, Testbed, CPU, DGPU};
use hsdag::util::prop::{check, PropConfig};
use hsdag::util::Rng;

fn env_on(bench: Benchmark, testbed: &str) -> Env {
    let cfg = Config { testbed: testbed.to_string(), ..Config::default() };
    Env::new(bench, &cfg).unwrap()
}

#[test]
fn expand_maps_actions_to_valid_devices_on_every_testbed() {
    // One env per registered testbed (ResNet keeps this fast); random
    // action vectors must always expand to devices inside the placeable
    // set, covering every original node.
    for tb in Testbed::registered() {
        let env = env_on(Benchmark::ResNet50, &tb.id);
        assert_eq!(env.n_actions(), tb.n_actions(), "{}", tb.id);
        let id = tb.id.clone();
        check(
            &format!("expand-valid-{id}"),
            PropConfig { cases: 24, max_size: 8, ..Default::default() },
            |rng: &mut Rng, _size| {
                let actions: Vec<usize> =
                    (0..env.n_nodes).map(|_| rng.below(env.n_actions())).collect();
                let p = env.expand(&actions).map_err(|e| format!("{e:#}"))?;
                if p.0.len() != env.graph.n() {
                    return Err(format!("{id}: expanded {} of {}", p.0.len(), env.graph.n()));
                }
                for &d in &p.0 {
                    if !env.testbed.placeable.contains(&d) {
                        return Err(format!("{id}: device {d} outside placeable set"));
                    }
                }
                let lat = env.latency(&actions).map_err(|e| format!("{e:#}"))?;
                if !(lat.is_finite() && lat > 0.0) {
                    return Err(format!("{id}: latency {lat}"));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn cpu_gpu_reproduces_pre_refactor_latencies() {
    // Pre-refactor behavior: ACTION_DEVICES = [CPU, DGPU] over
    // Testbed::paper(), simulated by the linear re-scan scheduler. The
    // refactored default path must be bit-identical on all three
    // benchmarks, for single-device and mixed placements alike.
    //
    // Honesty note: `execute_reference` retains the pre-refactor re-scan
    // with ONE canonicalization — exact-equality tie-break instead of the
    // old 1e-15 epsilon tie (see sim::scheduler module docs). Sub-1e-15-s
    // start-time coincidences are the only place the pre-refactor binary
    // could diverge from this pin.
    let legacy_tb = Testbed::paper();
    for b in Benchmark::ALL {
        let env = env_on(b, "cpu_gpu");
        let mut rng = Rng::new(0xB17);
        let mut action_vectors: Vec<Vec<usize>> = vec![
            vec![0; env.n_nodes], // all-CPU (the reference row)
            vec![1; env.n_nodes], // all-dGPU
        ];
        for _ in 0..3 {
            action_vectors.push((0..env.n_nodes).map(|_| rng.below(2)).collect());
        }
        for actions in &action_vectors {
            // Legacy expansion: action index -> [CPU, DGPU].
            let devices: Vec<usize> =
                actions.iter().map(|&a| [CPU, DGPU][a]).collect();
            let legacy_placement = Placement(env.colo.expand_placement(&devices).unwrap());
            let legacy = execute_reference(&env.graph, &legacy_placement, &legacy_tb).makespan;
            let now = env.latency(actions).unwrap();
            assert_eq!(now, legacy, "{}: latency drifted from pre-refactor", b.id());
        }
        // Reward denominator: still the CPU reference latency.
        let legacy_cpu =
            execute_reference(&env.graph, &Placement::all(env.graph.n(), CPU), &legacy_tb)
                .makespan;
        assert_eq!(env.ref_latency, legacy_cpu, "{}: reference drifted", b.id());
    }
}

#[test]
fn best_single_device_latencies_stable_across_testbed_widening() {
    // Widening the action space must not change what the simulator says
    // about the devices shared with the narrow testbed: cpu_gpu and
    // paper3 share hardware, so all-CPU / all-dGPU latencies agree.
    for b in Benchmark::ALL {
        let narrow = env_on(b, "cpu_gpu");
        let wide = env_on(b, "paper3");
        let n_cpu = narrow.latency(&vec![0; narrow.n_nodes]).unwrap();
        let w_cpu = wide.latency(&vec![0; wide.n_nodes]).unwrap();
        assert_eq!(n_cpu, w_cpu, "{}", b.id());
        // dGPU is action 1 on cpu_gpu, action 2 on paper3.
        let n_gpu = narrow.latency(&vec![1; narrow.n_nodes]).unwrap();
        let w_gpu = wide.latency(&vec![2; wide.n_nodes]).unwrap();
        assert_eq!(n_gpu, w_gpu, "{}", b.id());
        assert_eq!(narrow.ref_latency, wide.ref_latency, "{}", b.id());
    }
}

#[test]
fn multi_gpu_sweep_is_monotone_in_sanity() {
    // Not a performance claim, just plumbing: a k-GPU testbed builds an
    // env whose action space is k+1 wide and whose round-robin placement
    // simulates to a finite latency.
    for k in [1, 2, 4] {
        let env = env_on(Benchmark::BertBase, &format!("multi_gpu:{k}"));
        assert_eq!(env.n_actions(), k + 1);
        let rr: Vec<usize> = (0..env.n_nodes).map(|v| v % env.n_actions()).collect();
        let lat = env.latency(&rr).unwrap();
        assert!(lat.is_finite() && lat > 0.0, "k={k}: {lat}");
    }
}

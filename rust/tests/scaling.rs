//! Scale-path integration tests: the whole pipeline (generate -> features
//! -> multi-level coarsen -> env -> policy-sized working graph ->
//! evaluate) on graphs far past the paper benchmarks, in debug mode with
//! a small coarsening budget so `cargo test` stays fast. The 100k tier
//! runs in release via the bench targets and the CI e2e smoke; here we
//! pin the structural invariants the speed claims rest on.

use hsdag::coarsen::{coarsen_to_budget, DEFAULT_COARSEN_BUDGET};
use hsdag::config::Config;
use hsdag::features::{extract, FeatureConfig, FRACTAL_EXACT_THRESHOLD};
use hsdag::models::Workload;
use hsdag::rl::Env;
use hsdag::sim::{execute, IncrementalEvaluator, Placement, Testbed};

#[test]
fn twenty_k_pipeline_end_to_end_with_small_budget() {
    let w = Workload::resolve("random:20000:1").unwrap();
    let g = &w.graph;
    assert_eq!(g.n(), 20_000);
    assert!(g.n() > FRACTAL_EXACT_THRESHOLD, "must exercise the sampled fractal path");

    // Multi-level coarsening drives the working graph under the budget.
    let ml = coarsen_to_budget(g, 512);
    assert!(ml.coarsest().n() <= 512, "coarsest has {} nodes", ml.coarsest().n());
    assert!(ml.n_levels() >= 1);
    // Composed expansion covers every original node.
    let coarse = vec![0usize; ml.n_sets()];
    assert_eq!(ml.expand_placement(&coarse).unwrap().len(), g.n());

    // Feature extraction on the raw 20k graph: sampled fractal, sparse
    // adjacency only — O(n^2) here would hang the suite, not just slow it.
    let feats = extract(g, FeatureConfig::default());
    assert_eq!(feats.x.len(), g.n() * FeatureConfig::dim());
    assert!(feats.x.iter().all(|v| v.is_finite()));

    // Full env construction + one placement evaluation.
    let cfg = Config { coarsen_budget: 512, ..Config::default() };
    let env = Env::for_workload(w, &cfg).unwrap();
    assert!(env.n_nodes <= 512);
    assert_eq!(env.a_norm.numel(), 1, "registry workloads must not hold a dense adjacency");
    let lat = env.latency(&vec![1; env.n_nodes]).unwrap();
    assert!(lat.is_finite() && lat > 0.0);
}

#[test]
fn incremental_evaluator_agrees_with_full_simulation_at_scale() {
    let g = Workload::resolve("random:5000:3").unwrap().graph;
    let tb = Testbed::cpu_gpu();
    let mut actions: Vec<usize> =
        (0..g.n()).map(|v| tb.placeable[v % tb.placeable.len()]).collect();
    let mut eval = IncrementalEvaluator::new(g.clone(), tb.clone());
    let first = eval.evaluate(&actions);
    assert_eq!(first, execute(&g, &Placement(actions.clone()), &tb));
    // A short randomized edit walk, each step checked against the full
    // scheduler (the heavyweight property test lives in sim::scheduler;
    // this pins the behavior at a size it never reaches).
    for step in 0..4usize {
        let v = (step * 1237 + 11) % g.n();
        actions[v] = if actions[v] == tb.placeable[0] { tb.placeable[1] } else { tb.placeable[0] };
        let inc = eval.evaluate(&actions);
        let full = execute(&g, &Placement(actions.clone()), &tb);
        assert_eq!(inc, full, "divergence after edit {step}");
    }
}

#[test]
fn default_budget_keeps_paper_scale_single_level() {
    // The default budget must leave every paper-sized graph exactly as
    // the single co-location pass built it — the scale machinery is
    // invisible until a graph actually needs it.
    let g = Workload::resolve("layered:16x8:3").unwrap().graph;
    let ml = coarsen_to_budget(&g, DEFAULT_COARSEN_BUDGET);
    assert_eq!(ml.n_levels(), 1);
}

//! The placement server: a long-lived, multi-threaded daemon that turns
//! one on-disk policy checkpoint into a placement-as-a-service endpoint.
//!
//! [`PlacementService`] is the transport-free core (the benches and the
//! in-process tests drive it directly); [`Server`] puts it behind a TCP
//! listener with a fixed worker pool speaking the line-delimited
//! [`protocol`]. Per `place` request the service:
//!
//! 1. resolves the graph (registry spec or inline document) and computes
//!    its structural [`fingerprint`] — the cache key;
//! 2. answers from the bounded LRU [`cache`] on a hit (`provenance:
//!    "cache"`), skipping inference entirely; only complete
//!    server-default answers are ever *written* to the cache — a
//!    budget-truncated result or one computed under per-request knob
//!    overrides is returned but not stored, so it can never poison
//!    later unconstrained requests for the same graph;
//! 3. otherwise builds the placement environment and runs policy
//!    inference — one greedy rollout plus a few stochastic ones — under
//!    the per-request latency budget; when the budget is exhausted the
//!    policy stage is skipped or cut short;
//! 4. always evaluates the cheap non-learned candidates (every
//!    single-device deployment plus the capacity-aware memory-greedy) and
//!    serves the fastest *feasible* candidate overall, preferring the
//!    policy on exact ties. The service never returns a placement worse
//!    than the trivial ones it can check in microseconds; `provenance`
//!    reports truthfully whether the policy won (`"policy"`) or a
//!    baseline was served (`"fallback:memory-greedy"`,
//!    `"fallback:single:<device>"`).
//!
//! A `stats` request reports live metrics (qps, cache hit rate, p50/p99
//! service time over a sliding window); a `ctrl: shutdown` message
//! acknowledges, stops the accept loop, drains the workers and joins
//! them — a clean exit, suitable for CI.
//!
//! [`protocol`]: super::protocol
//! [`fingerprint`]: super::fingerprint::fingerprint
//! [`cache`]: super::cache

use std::collections::HashSet;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::cache::LruCache;
use super::checkpoint::Checkpoint;
use super::fingerprint::fingerprint;
use super::protocol::{
    self, PlaceOutcome, PlaceRequest, PlaceSource, Provenance, Request, StatsView,
};
use crate::baselines;
use crate::config::Config;
use crate::models::Workload;
use crate::rl::{Env, HsdagAgent, NativeBackend};
use crate::runtime::ParamStore;
use crate::sim::Placement;
use crate::util::stats;

/// Service-time sliding window for the p50/p99 metrics.
const SERVICE_TIME_WINDOW: usize = 4096;

/// Stochastic rollouts per batched policy pass when a latency budget is
/// set (between chunks the deadline is re-checked; unbounded requests
/// run every rollout in a single pass).
const ROLLOUT_CHUNK: usize = 2;

/// Serving knobs (the `hsdag serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Placement-cache capacity (entries; 0 disables caching).
    pub cache_capacity: usize,
    /// Default per-request policy-inference budget in milliseconds
    /// (None = unbounded); requests may override it.
    pub budget_ms: Option<f64>,
    /// Stochastic policy rollouts on top of the greedy one; requests may
    /// override it.
    pub rollouts: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { cache_capacity: 256, budget_ms: None, rollouts: 4 }
    }
}

/// A complete, server-default answer for one fingerprint.
#[derive(Clone)]
struct CachedPlacement {
    placement: Vec<usize>,
    latency_s: f64,
    ref_latency_s: f64,
    feasible: bool,
}

/// One evaluated non-learned candidate (a single-device deployment or
/// the memory-greedy baseline). These depend only on the graph and the
/// testbed — exactly what the fingerprint hashes — so they are computed
/// once per fingerprint and shared across requests.
#[derive(Clone)]
struct TrivialCandidate {
    makespan: f64,
    feasible: bool,
    placement: Placement,
    name: String,
}

/// What the cache remembers per fingerprint. `answer` is only filled by
/// a complete server-default request (the poisoning rules below), but
/// `trivial` is knob-independent: a budget-truncated or knob-overridden
/// request may still reuse and refresh it.
#[derive(Clone, Default)]
struct CacheEntry {
    answer: Option<CachedPlacement>,
    trivial: Option<Arc<Vec<TrivialCandidate>>>,
}

#[derive(Default)]
struct StatsInner {
    requests: u64,
    placements: u64,
    cache_hits: u64,
    fallbacks: u64,
    errors: u64,
    /// Fresh single-device + memory-greedy evaluation passes (misses of
    /// the per-fingerprint trivial-candidate cache).
    trivial_evals: u64,
    service_ms: Vec<f64>,
    ring_idx: usize,
}

/// The transport-free placement service.
pub struct PlacementService {
    cfg: Config,
    params: ParamStore,
    /// Informational: what the checkpoint says it was trained on.
    trained_on: String,
    device_names: Vec<String>,
    opts: ServeOptions,
    cache: Mutex<LruCache<u64, CacheEntry>>,
    /// Fingerprints with a server-default inference currently running
    /// (single-flight: concurrent identical requests wait for the leader
    /// and answer from the cache instead of duplicating the inference).
    inflight: Mutex<HashSet<u64>>,
    inflight_cv: Condvar,
    stats: Mutex<StatsInner>,
    started: Instant,
}

/// Removes a fingerprint from the in-flight set on scope exit (including
/// the error paths) and wakes every waiter.
struct FlightGuard<'a> {
    svc: &'a PlacementService,
    fp: u64,
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.svc.inflight.lock().unwrap().remove(&self.fp);
        self.svc.inflight_cv.notify_all();
    }
}

impl PlacementService {
    /// Stand the service up from a loaded checkpoint. `cfg` supplies the
    /// testbed (defaulting upstream to the checkpoint's), seed and eval
    /// workers; the checkpoint supplies the parameters and pins the
    /// hidden size. Refuses a checkpoint whose placer width disagrees
    /// with the testbed before any request is served.
    pub fn new(ckpt: Checkpoint, cfg: &Config, opts: ServeOptions) -> Result<PlacementService> {
        let mut cfg = cfg.clone();
        cfg.backend = "native".to_string();
        cfg.hidden = ckpt.meta.hidden;
        // Serving never trains: a 1-step replay buffer keeps per-request
        // agents from allocating a full training window per graph.
        cfg.update_timestep = 1;
        let tb = cfg.resolve_testbed()?;
        ckpt.check_compatible(cfg.hidden, tb.n_actions(), &cfg.testbed)?;
        Ok(PlacementService {
            device_names: tb.devices.iter().map(|d| d.name.clone()).collect(),
            trained_on: ckpt.meta.workload.clone(),
            params: ckpt.store,
            cache: Mutex::new(LruCache::new(opts.cache_capacity)),
            inflight: Mutex::new(HashSet::new()),
            inflight_cv: Condvar::new(),
            stats: Mutex::new(StatsInner::default()),
            started: Instant::now(),
            cfg,
            opts,
        })
    }

    /// The resolved run configuration (testbed id, hidden size, seed).
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// What the checkpoint was trained on (banner text).
    pub fn trained_on(&self) -> &str {
        &self.trained_on
    }

    /// Evaluate the non-learned candidates for one environment: every
    /// single-device deployment plus the capacity-aware memory-greedy.
    fn eval_trivial(env: &Env) -> Vec<TrivialCandidate> {
        let mut out: Vec<TrivialCandidate> = env
            .testbed
            .placeable
            .iter()
            .map(|&d| {
                (
                    Placement::all(env.graph.n(), d),
                    format!("single:{}", env.testbed.devices[d].name),
                )
            })
            .chain(std::iter::once((
                baselines::memory_greedy_placement(&env.graph, &env.testbed),
                "memory-greedy".to_string(),
            )))
            .map(|(p, name)| {
                let rep = env.cost.evaluate(&env.graph, &p, &env.testbed);
                TrivialCandidate {
                    makespan: rep.makespan,
                    feasible: rep.feasible(),
                    placement: p,
                    name,
                }
            })
            .collect();
        out.shrink_to_fit();
        out
    }

    /// One cache probe: the complete answer for `fp` (ready to return as
    /// a `provenance: "cache"` outcome) and/or the reusable
    /// trivial-candidate evaluations.
    #[allow(clippy::type_complexity)]
    fn cache_lookup(
        &self,
        fp: u64,
        fp_hex: &str,
    ) -> (Option<PlaceOutcome>, Option<Arc<Vec<TrivialCandidate>>>) {
        let mut cache = self.cache.lock().unwrap();
        let Some(entry) = cache.get(&fp) else {
            return (None, None);
        };
        let trivial = entry.trivial.clone();
        let answer = entry.answer.as_ref().map(|hit| PlaceOutcome {
            fingerprint: fp_hex.to_string(),
            placement: hit.placement.clone(),
            devices: self.device_names.clone(),
            latency_s: hit.latency_s,
            ref_latency_s: hit.ref_latency_s,
            feasible: hit.feasible,
            provenance: Provenance::Cache,
        });
        (answer, trivial)
    }

    /// Serve one placement request (the cache-or-infer-or-fallback core).
    pub fn handle_place(&self, req: &PlaceRequest) -> Result<PlaceOutcome> {
        let t0 = Instant::now();
        let deadline = req
            .budget_ms
            .or(self.opts.budget_ms)
            .map(|ms| t0 + Duration::from_secs_f64(ms / 1e3));
        let over = |d: &Option<Instant>| d.map(|d| Instant::now() >= d).unwrap_or(false);

        let workload = match &req.source {
            PlaceSource::Spec(s) => Workload::resolve(s)?,
            PlaceSource::Inline(g) => Workload::from_graph(g.clone(), None),
        };
        let fp = fingerprint(&workload.graph, &self.cfg.testbed);
        let fp_hex = format!("{fp:016x}");

        // A request with server-default knobs: its answer may be cached,
        // so concurrent duplicates can single-flight behind one leader.
        // (With caching disabled the leader's answer could never reach
        // the followers, so single-flight would only serialize them.)
        let default_shaped = !req.no_cache
            && req.budget_ms.is_none()
            && req.rollouts.is_none()
            && self.opts.cache_capacity > 0;

        // Cache lookup + single-flight admission. `no_cache` bypasses the
        // cache in both directions, including the trivial-candidate reuse.
        let mut cached_trivial: Option<Arc<Vec<TrivialCandidate>>> = None;
        let mut _flight: Option<FlightGuard<'_>> = None;
        if !req.no_cache {
            loop {
                let (answer, trivial) = self.cache_lookup(fp, &fp_hex);
                cached_trivial = trivial;
                if let Some(hit) = answer {
                    return Ok(hit);
                }
                if !default_shaped {
                    break;
                }
                let mut infl = self.inflight.lock().unwrap();
                if infl.insert(fp) {
                    drop(infl);
                    _flight = Some(FlightGuard { svc: self, fp });
                    // Re-check as leader: a previous leader may have
                    // completed between our miss and the insert; its put
                    // happens-before our successful insert, so this
                    // lookup is guaranteed to see the answer.
                    let (answer, trivial) = self.cache_lookup(fp, &fp_hex);
                    cached_trivial = trivial;
                    if let Some(hit) = answer {
                        return Ok(hit);
                    }
                    break;
                }
                // An identical default-shaped request is mid-inference on
                // another worker: wait for it and re-read the cache (its
                // answer lands there) instead of duplicating the work.
                let _woken = self.inflight_cv.wait(infl).unwrap();
            }
        }

        let env = Env::for_workload(workload, &self.cfg)?;

        // Candidates, policy first (ties between a policy rollout and an
        // identical baseline placement resolve toward the policy).
        let mut candidates: Vec<(f64, bool, Placement, Provenance)> = Vec::new();
        let mut policy_complete = false;
        if !over(&deadline) {
            let backend = NativeBackend::from_snapshot(&env, &self.cfg, &self.params)?;
            let mut agent = HsdagAgent::with_backend(&env, Box::new(backend), &self.cfg)?;
            let n_roll = req.rollouts.unwrap_or(self.opts.rollouts);
            // The greedy rollout plus every stochastic one go through ONE
            // batched policy pass when the request is unbounded (the
            // server-default fast path). Under a deadline, rollouts run
            // in bounded chunks so the budget can still cut the stage
            // short between chunks.
            policy_complete = true;
            let mut remaining = n_roll;
            let mut greedy_done = false;
            loop {
                let chunk = if deadline.is_none() {
                    remaining
                } else {
                    remaining.min(ROLLOUT_CHUNK)
                };
                let outs = agent.rollout_batch(&env, chunk)?;
                for (i, o) in outs.into_iter().enumerate() {
                    if i == 0 && greedy_done {
                        // Later chunks re-run the deterministic greedy
                        // rollout; its candidate is already recorded.
                        continue;
                    }
                    candidates.push((
                        o.det_latency,
                        o.feasible,
                        env.expand(&o.actions)?,
                        Provenance::Policy,
                    ));
                }
                greedy_done = true;
                remaining -= chunk;
                if remaining == 0 {
                    break;
                }
                if over(&deadline) {
                    policy_complete = false;
                    break;
                }
            }
        }
        // The trivial candidates: the service never returns a placement
        // worse than these, and they are the whole answer when the budget
        // was exhausted. They depend only on the fingerprinted structure,
        // so they are computed once per fingerprint and reused from the
        // cache entry afterwards.
        let trivial: Arc<Vec<TrivialCandidate>> = match cached_trivial {
            Some(t) => t,
            None => {
                let t = Arc::new(Self::eval_trivial(&env));
                self.stats.lock().unwrap().trivial_evals += 1;
                if !req.no_cache {
                    let mut cache = self.cache.lock().unwrap();
                    let mut entry = cache.peek(&fp).cloned().unwrap_or_default();
                    entry.trivial = Some(Arc::clone(&t));
                    cache.put(fp, entry);
                }
                t
            }
        };
        for c in trivial.iter() {
            candidates.push((
                c.makespan,
                c.feasible,
                c.placement.clone(),
                Provenance::Fallback(c.name.clone()),
            ));
        }

        // Fastest feasible candidate (fastest overall when nothing is
        // feasible — the response's `feasible: false` says so); strictly
        // better wins, so earlier (policy) candidates take exact ties.
        let any_feasible = candidates.iter().any(|c| c.1);
        let mut best: Option<&(f64, bool, Placement, Provenance)> = None;
        for c in &candidates {
            if any_feasible && !c.1 {
                continue;
            }
            if best.map(|b| c.0 < b.0).unwrap_or(true) {
                best = Some(c);
            }
        }
        let (latency_s, feasible, placement, provenance) =
            best.ok_or_else(|| anyhow!("no placement candidate produced"))?;

        let outcome = PlaceOutcome {
            fingerprint: fp_hex,
            placement: placement.0.clone(),
            devices: self.device_names.clone(),
            latency_s: *latency_s,
            ref_latency_s: env.ref_latency,
            feasible: *feasible,
            provenance: provenance.clone(),
        };
        // Only the server-default answer may enter the cache: a
        // budget-truncated result, or one computed under per-request
        // knob overrides, must never be served to later unconstrained
        // requests for the same graph (cache poisoning).
        let cacheable = !req.no_cache
            && policy_complete
            && req.budget_ms.is_none()
            && req.rollouts.is_none();
        if cacheable {
            let mut cache = self.cache.lock().unwrap();
            let mut entry = cache.peek(&fp).cloned().unwrap_or_default();
            entry.answer = Some(CachedPlacement {
                placement: outcome.placement.clone(),
                latency_s: outcome.latency_s,
                ref_latency_s: outcome.ref_latency_s,
                feasible: outcome.feasible,
            });
            entry.trivial = Some(trivial);
            cache.put(fp, entry);
        }
        Ok(outcome)
    }

    /// Handle one protocol line; returns the response line and whether a
    /// shutdown was requested.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        let t0 = Instant::now();
        match protocol::parse_request(line) {
            Err(e) => {
                let mut s = self.stats.lock().unwrap();
                s.requests += 1;
                s.errors += 1;
                (protocol::render_error_response(None, &format!("{e:#}")), false)
            }
            Ok(Request::Stats) => {
                self.stats.lock().unwrap().requests += 1;
                (protocol::render_stats_response(&self.stats_view()), false)
            }
            Ok(Request::Shutdown) => {
                self.stats.lock().unwrap().requests += 1;
                (protocol::render_ctrl_response("shutdown"), true)
            }
            Ok(Request::Place(req)) => {
                let result = self.handle_place(&req);
                let service_ms = t0.elapsed().as_secs_f64() * 1e3;
                let mut s = self.stats.lock().unwrap();
                s.requests += 1;
                match result {
                    Ok(outcome) => {
                        s.placements += 1;
                        match outcome.provenance {
                            Provenance::Cache => s.cache_hits += 1,
                            Provenance::Fallback(_) => s.fallbacks += 1,
                            Provenance::Policy => {}
                        }
                        if s.service_ms.len() < SERVICE_TIME_WINDOW {
                            s.service_ms.push(service_ms);
                        } else {
                            let i = s.ring_idx;
                            s.service_ms[i] = service_ms;
                            s.ring_idx = (i + 1) % SERVICE_TIME_WINDOW;
                        }
                        (
                            protocol::render_place_response(req.id.as_ref(), &outcome, service_ms),
                            false,
                        )
                    }
                    Err(e) => {
                        s.errors += 1;
                        (
                            protocol::render_error_response(req.id.as_ref(), &format!("{e:#}")),
                            false,
                        )
                    }
                }
            }
        }
    }

    /// Snapshot the live metrics.
    pub fn stats_view(&self) -> StatsView {
        let s = self.stats.lock().unwrap();
        let cache = self.cache.lock().unwrap();
        let uptime_s = self.started.elapsed().as_secs_f64();
        StatsView {
            uptime_s,
            requests: s.requests,
            placements: s.placements,
            cache_hits: s.cache_hits,
            fallbacks: s.fallbacks,
            errors: s.errors,
            trivial_evals: s.trivial_evals,
            cache_len: cache.len(),
            cache_capacity: cache.capacity(),
            qps: s.requests as f64 / uptime_s.max(1e-9),
            cache_hit_rate: s.cache_hits as f64 / (s.placements.max(1)) as f64,
            p50_ms: stats::percentile(&s.service_ms, 50.0),
            p99_ms: stats::percentile(&s.service_ms, 99.0),
        }
    }

    /// Drop every cached placement (benches isolate cold/hit paths).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// TCP front end
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-running server. `addr` may use port 0 for an
/// ephemeral port; [`Server::local_addr`] reports what was bound.
pub struct Server {
    listener: TcpListener,
    service: Arc<PlacementService>,
    addr: SocketAddr,
}

/// Handle to a server running on a background thread (tests, examples).
pub struct ServerHandle {
    pub addr: SocketAddr,
    thread: thread::JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Wait for the server to shut down (a `ctrl: shutdown` request).
    pub fn join(self) -> Result<()> {
        self.thread.join().map_err(|_| anyhow!("server thread panicked"))?
    }
}

impl Server {
    pub fn bind(service: Arc<PlacementService>, addr: &str) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve address '{addr}'"))?;
        let addr = listener.local_addr()?;
        Ok(Server { listener, service, addr })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept and serve until a shutdown request arrives, then drain and
    /// join the `workers`-wide pool. Blocks the calling thread.
    pub fn run(self, workers: usize) -> Result<()> {
        self.listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut pool = Vec::with_capacity(workers.max(1));
        for i in 0..workers.max(1) {
            let rx = Arc::clone(&rx);
            let service = Arc::clone(&self.service);
            let shutdown = Arc::clone(&shutdown);
            pool.push(
                thread::Builder::new()
                    .name(format!("hsdag-serve-{i}"))
                    .spawn(move || worker_loop(&rx, &service, &shutdown))
                    .context("spawning serve worker")?,
            );
        }
        loop {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // A send can only fail once every worker has exited,
                    // which only happens on shutdown.
                    let _ = tx.send(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    shutdown.store(true, Ordering::Relaxed);
                    drop(tx);
                    for t in pool {
                        let _ = t.join();
                    }
                    return Err(e).context("accepting connections");
                }
            }
        }
        drop(tx);
        for t in pool {
            let _ = t.join();
        }
        Ok(())
    }

    /// Run on a background thread; returns once the listener is live.
    pub fn spawn(self, workers: usize) -> Result<ServerHandle> {
        let addr = self.addr;
        let thread = thread::Builder::new()
            .name("hsdag-serve-accept".to_string())
            .spawn(move || self.run(workers))
            .context("spawning server thread")?;
        Ok(ServerHandle { addr, thread })
    }
}

/// One pool worker: pull connections off the shared queue until the
/// channel closes (all senders dropped at shutdown).
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<TcpStream>>,
    service: &PlacementService,
    shutdown: &AtomicBool,
) {
    loop {
        // Holding the lock while blocked in recv is fine: connection
        // *handling* happens after the guard drops, so the pool still
        // serves concurrently; dispatch itself is serial and cheap.
        let stream = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return,
        };
        handle_conn(stream, service, shutdown);
    }
}

/// Serve one connection: line in, line out, until EOF / shutdown. The
/// short read timeout keeps the worker responsive to a shutdown raised
/// elsewhere while this client idles.
fn handle_conn(stream: TcpStream, service: &PlacementService, shutdown: &AtomicBool) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) if buf.is_empty() => return, // clean EOF
            Ok(n) => {
                // n == 0 here means EOF cut a buffered line short (a
                // timeout left partial bytes behind) — still answer it,
                // then return.
                let line = String::from_utf8_lossy(&buf).trim().to_string();
                buf.clear();
                if !line.is_empty() {
                    let (response, shut) = service.handle_line(&line);
                    if writer
                        .write_all(response.as_bytes())
                        .and_then(|_| writer.write_all(b"\n"))
                        .and_then(|_| writer.flush())
                        .is_err()
                    {
                        return;
                    }
                    if shut {
                        shutdown.store(true, Ordering::Relaxed);
                        return;
                    }
                }
                if n == 0 {
                    return;
                }
            }
            // Timeout mid-line: partial bytes stay in `buf`; keep
            // accumulating (and re-check the shutdown flag).
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(_) => return,
        }
    }
}

//! The placement server: a long-lived, multi-threaded daemon that turns
//! one on-disk policy checkpoint into a placement-as-a-service endpoint.
//!
//! [`PlacementService`] is the transport-free core (the benches and the
//! in-process tests drive it directly); [`Server`] puts it behind a TCP
//! listener with a fixed worker pool speaking the line-delimited
//! [`protocol`]. Per `place` request the service:
//!
//! 1. resolves the graph (registry spec or inline document) and computes
//!    its structural [`fingerprint`] — the cache key;
//! 2. answers from the bounded LRU [`cache`] on a hit (`provenance:
//!    "cache"`), skipping inference entirely; only complete
//!    server-default answers are ever *written* to the cache — a
//!    budget-truncated result or one computed under per-request knob
//!    overrides is returned but not stored, so it can never poison
//!    later unconstrained requests for the same graph;
//! 3. otherwise builds the placement environment and runs policy
//!    inference — one greedy rollout plus a few stochastic ones — under
//!    the per-request latency budget; when the budget is exhausted the
//!    policy stage is skipped or cut short;
//! 4. always evaluates the cheap non-learned candidates (every
//!    single-device deployment plus the capacity-aware memory-greedy) and
//!    serves the fastest *feasible* candidate overall, preferring the
//!    policy on exact ties. The service never returns a placement worse
//!    than the trivial ones it can check in microseconds; `provenance`
//!    reports truthfully whether the policy won (`"policy"`) or a
//!    baseline was served (`"fallback:memory-greedy"`,
//!    `"fallback:single:<device>"`).
//!
//! A `stats` request reports live metrics (qps, cache hit rate, p50/p99
//! service time over a sliding window); a `ctrl: shutdown` message
//! acknowledges, stops the accept loop, drains the workers and joins
//! them — a clean exit, suitable for CI.
//!
//! [`protocol`]: super::protocol
//! [`fingerprint`]: super::fingerprint::fingerprint
//! [`cache`]: super::cache

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::cache::LruCache;
use super::checkpoint::Checkpoint;
use super::fingerprint::fingerprint;
use super::protocol::{
    self, PlaceOutcome, PlaceRequest, PlaceSource, Provenance, Request, StatsView,
};
use crate::baselines;
use crate::config::Config;
use crate::models::Workload;
use crate::rl::{Env, HsdagAgent, NativeBackend};
use crate::runtime::ParamStore;
use crate::sim::Placement;
use crate::util::stats;

/// Service-time sliding window for the p50/p99 metrics.
const SERVICE_TIME_WINDOW: usize = 4096;

/// Serving knobs (the `hsdag serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Placement-cache capacity (entries; 0 disables caching).
    pub cache_capacity: usize,
    /// Default per-request policy-inference budget in milliseconds
    /// (None = unbounded); requests may override it.
    pub budget_ms: Option<f64>,
    /// Stochastic policy rollouts on top of the greedy one; requests may
    /// override it.
    pub rollouts: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { cache_capacity: 256, budget_ms: None, rollouts: 4 }
    }
}

/// What the cache remembers per fingerprint.
#[derive(Clone)]
struct CachedPlacement {
    placement: Vec<usize>,
    latency_s: f64,
    ref_latency_s: f64,
    feasible: bool,
}

#[derive(Default)]
struct StatsInner {
    requests: u64,
    placements: u64,
    cache_hits: u64,
    fallbacks: u64,
    errors: u64,
    service_ms: Vec<f64>,
    ring_idx: usize,
}

/// The transport-free placement service.
pub struct PlacementService {
    cfg: Config,
    params: ParamStore,
    /// Informational: what the checkpoint says it was trained on.
    trained_on: String,
    device_names: Vec<String>,
    opts: ServeOptions,
    cache: Mutex<LruCache<u64, CachedPlacement>>,
    stats: Mutex<StatsInner>,
    started: Instant,
}

impl PlacementService {
    /// Stand the service up from a loaded checkpoint. `cfg` supplies the
    /// testbed (defaulting upstream to the checkpoint's), seed and eval
    /// workers; the checkpoint supplies the parameters and pins the
    /// hidden size. Refuses a checkpoint whose placer width disagrees
    /// with the testbed before any request is served.
    pub fn new(ckpt: Checkpoint, cfg: &Config, opts: ServeOptions) -> Result<PlacementService> {
        let mut cfg = cfg.clone();
        cfg.backend = "native".to_string();
        cfg.hidden = ckpt.meta.hidden;
        // Serving never trains: a 1-step replay buffer keeps per-request
        // agents from allocating a full training window per graph.
        cfg.update_timestep = 1;
        let tb = cfg.resolve_testbed()?;
        ckpt.check_compatible(cfg.hidden, tb.n_actions(), &cfg.testbed)?;
        Ok(PlacementService {
            device_names: tb.devices.iter().map(|d| d.name.clone()).collect(),
            trained_on: ckpt.meta.workload.clone(),
            params: ckpt.store,
            cache: Mutex::new(LruCache::new(opts.cache_capacity)),
            stats: Mutex::new(StatsInner::default()),
            started: Instant::now(),
            cfg,
            opts,
        })
    }

    /// The resolved run configuration (testbed id, hidden size, seed).
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// What the checkpoint was trained on (banner text).
    pub fn trained_on(&self) -> &str {
        &self.trained_on
    }

    /// Serve one placement request (the cache-or-infer-or-fallback core).
    pub fn handle_place(&self, req: &PlaceRequest) -> Result<PlaceOutcome> {
        let t0 = Instant::now();
        let deadline = req
            .budget_ms
            .or(self.opts.budget_ms)
            .map(|ms| t0 + Duration::from_secs_f64(ms / 1e3));
        let over = |d: &Option<Instant>| d.map(|d| Instant::now() >= d).unwrap_or(false);

        let workload = match &req.source {
            PlaceSource::Spec(s) => Workload::resolve(s)?,
            PlaceSource::Inline(g) => Workload::from_graph(g.clone(), None),
        };
        let fp = fingerprint(&workload.graph, &self.cfg.testbed);
        let fp_hex = format!("{fp:016x}");

        if !req.no_cache {
            let mut cache = self.cache.lock().unwrap();
            if let Some(hit) = cache.get(&fp) {
                return Ok(PlaceOutcome {
                    fingerprint: fp_hex,
                    placement: hit.placement.clone(),
                    devices: self.device_names.clone(),
                    latency_s: hit.latency_s,
                    ref_latency_s: hit.ref_latency_s,
                    feasible: hit.feasible,
                    provenance: Provenance::Cache,
                });
            }
        }

        let env = Env::for_workload(workload, &self.cfg)?;

        // Candidates, policy first (ties between a policy rollout and an
        // identical baseline placement resolve toward the policy).
        let mut candidates: Vec<(f64, bool, Placement, Provenance)> = Vec::new();
        let mut policy_complete = false;
        if !over(&deadline) {
            let backend = NativeBackend::from_snapshot(&env, &self.cfg, &self.params)?;
            let mut agent = HsdagAgent::with_backend(&env, Box::new(backend), &self.cfg)?;
            agent.reset_episode();
            let o = agent.step(&env, false)?;
            candidates.push((o.det_latency, o.feasible, env.expand(&o.actions)?, Provenance::Policy));
            policy_complete = true;
            for _ in 0..req.rollouts.unwrap_or(self.opts.rollouts) {
                if over(&deadline) {
                    policy_complete = false;
                    break;
                }
                let o = agent.step(&env, true)?;
                candidates.push((
                    o.det_latency,
                    o.feasible,
                    env.expand(&o.actions)?,
                    Provenance::Policy,
                ));
            }
        }
        // The trivial candidates are microseconds of simulator time: the
        // service never returns a placement worse than these, and they
        // are the whole answer when the budget was exhausted.
        let mut trivial: Vec<(Placement, String)> = env
            .testbed
            .placeable
            .iter()
            .map(|&d| {
                (
                    Placement::all(env.graph.n(), d),
                    format!("single:{}", env.testbed.devices[d].name),
                )
            })
            .collect();
        trivial.push((
            baselines::memory_greedy_placement(&env.graph, &env.testbed),
            "memory-greedy".to_string(),
        ));
        for (p, name) in trivial {
            let rep = env.cost.evaluate(&env.graph, &p, &env.testbed);
            candidates.push((rep.makespan, rep.feasible(), p, Provenance::Fallback(name)));
        }

        // Fastest feasible candidate (fastest overall when nothing is
        // feasible — the response's `feasible: false` says so); strictly
        // better wins, so earlier (policy) candidates take exact ties.
        let any_feasible = candidates.iter().any(|c| c.1);
        let mut best: Option<&(f64, bool, Placement, Provenance)> = None;
        for c in &candidates {
            if any_feasible && !c.1 {
                continue;
            }
            if best.map(|b| c.0 < b.0).unwrap_or(true) {
                best = Some(c);
            }
        }
        let (latency_s, feasible, placement, provenance) =
            best.ok_or_else(|| anyhow!("no placement candidate produced"))?;

        let outcome = PlaceOutcome {
            fingerprint: fp_hex,
            placement: placement.0.clone(),
            devices: self.device_names.clone(),
            latency_s: *latency_s,
            ref_latency_s: env.ref_latency,
            feasible: *feasible,
            provenance: provenance.clone(),
        };
        // Only the server-default answer may enter the cache: a
        // budget-truncated result, or one computed under per-request
        // knob overrides, must never be served to later unconstrained
        // requests for the same graph (cache poisoning).
        let cacheable = !req.no_cache
            && policy_complete
            && req.budget_ms.is_none()
            && req.rollouts.is_none();
        if cacheable {
            self.cache.lock().unwrap().put(
                fp,
                CachedPlacement {
                    placement: outcome.placement.clone(),
                    latency_s: outcome.latency_s,
                    ref_latency_s: outcome.ref_latency_s,
                    feasible: outcome.feasible,
                },
            );
        }
        Ok(outcome)
    }

    /// Handle one protocol line; returns the response line and whether a
    /// shutdown was requested.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        let t0 = Instant::now();
        match protocol::parse_request(line) {
            Err(e) => {
                let mut s = self.stats.lock().unwrap();
                s.requests += 1;
                s.errors += 1;
                (protocol::render_error_response(None, &format!("{e:#}")), false)
            }
            Ok(Request::Stats) => {
                self.stats.lock().unwrap().requests += 1;
                (protocol::render_stats_response(&self.stats_view()), false)
            }
            Ok(Request::Shutdown) => {
                self.stats.lock().unwrap().requests += 1;
                (protocol::render_ctrl_response("shutdown"), true)
            }
            Ok(Request::Place(req)) => {
                let result = self.handle_place(&req);
                let service_ms = t0.elapsed().as_secs_f64() * 1e3;
                let mut s = self.stats.lock().unwrap();
                s.requests += 1;
                match result {
                    Ok(outcome) => {
                        s.placements += 1;
                        match outcome.provenance {
                            Provenance::Cache => s.cache_hits += 1,
                            Provenance::Fallback(_) => s.fallbacks += 1,
                            Provenance::Policy => {}
                        }
                        if s.service_ms.len() < SERVICE_TIME_WINDOW {
                            s.service_ms.push(service_ms);
                        } else {
                            let i = s.ring_idx;
                            s.service_ms[i] = service_ms;
                            s.ring_idx = (i + 1) % SERVICE_TIME_WINDOW;
                        }
                        (
                            protocol::render_place_response(req.id.as_ref(), &outcome, service_ms),
                            false,
                        )
                    }
                    Err(e) => {
                        s.errors += 1;
                        (
                            protocol::render_error_response(req.id.as_ref(), &format!("{e:#}")),
                            false,
                        )
                    }
                }
            }
        }
    }

    /// Snapshot the live metrics.
    pub fn stats_view(&self) -> StatsView {
        let s = self.stats.lock().unwrap();
        let cache = self.cache.lock().unwrap();
        let uptime_s = self.started.elapsed().as_secs_f64();
        StatsView {
            uptime_s,
            requests: s.requests,
            placements: s.placements,
            cache_hits: s.cache_hits,
            fallbacks: s.fallbacks,
            errors: s.errors,
            cache_len: cache.len(),
            cache_capacity: cache.capacity(),
            qps: s.requests as f64 / uptime_s.max(1e-9),
            cache_hit_rate: s.cache_hits as f64 / (s.placements.max(1)) as f64,
            p50_ms: stats::percentile(&s.service_ms, 50.0),
            p99_ms: stats::percentile(&s.service_ms, 99.0),
        }
    }

    /// Drop every cached placement (benches isolate cold/hit paths).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }
}

// ---------------------------------------------------------------------------
// TCP front end
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-running server. `addr` may use port 0 for an
/// ephemeral port; [`Server::local_addr`] reports what was bound.
pub struct Server {
    listener: TcpListener,
    service: Arc<PlacementService>,
    addr: SocketAddr,
}

/// Handle to a server running on a background thread (tests, examples).
pub struct ServerHandle {
    pub addr: SocketAddr,
    thread: thread::JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Wait for the server to shut down (a `ctrl: shutdown` request).
    pub fn join(self) -> Result<()> {
        self.thread.join().map_err(|_| anyhow!("server thread panicked"))?
    }
}

impl Server {
    pub fn bind(service: Arc<PlacementService>, addr: &str) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve address '{addr}'"))?;
        let addr = listener.local_addr()?;
        Ok(Server { listener, service, addr })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept and serve until a shutdown request arrives, then drain and
    /// join the `workers`-wide pool. Blocks the calling thread.
    pub fn run(self, workers: usize) -> Result<()> {
        self.listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut pool = Vec::with_capacity(workers.max(1));
        for i in 0..workers.max(1) {
            let rx = Arc::clone(&rx);
            let service = Arc::clone(&self.service);
            let shutdown = Arc::clone(&shutdown);
            pool.push(
                thread::Builder::new()
                    .name(format!("hsdag-serve-{i}"))
                    .spawn(move || worker_loop(&rx, &service, &shutdown))
                    .context("spawning serve worker")?,
            );
        }
        loop {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    // A send can only fail once every worker has exited,
                    // which only happens on shutdown.
                    let _ = tx.send(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    shutdown.store(true, Ordering::Relaxed);
                    drop(tx);
                    for t in pool {
                        let _ = t.join();
                    }
                    return Err(e).context("accepting connections");
                }
            }
        }
        drop(tx);
        for t in pool {
            let _ = t.join();
        }
        Ok(())
    }

    /// Run on a background thread; returns once the listener is live.
    pub fn spawn(self, workers: usize) -> Result<ServerHandle> {
        let addr = self.addr;
        let thread = thread::Builder::new()
            .name("hsdag-serve-accept".to_string())
            .spawn(move || self.run(workers))
            .context("spawning server thread")?;
        Ok(ServerHandle { addr, thread })
    }
}

/// One pool worker: pull connections off the shared queue until the
/// channel closes (all senders dropped at shutdown).
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<TcpStream>>,
    service: &PlacementService,
    shutdown: &AtomicBool,
) {
    loop {
        // Holding the lock while blocked in recv is fine: connection
        // *handling* happens after the guard drops, so the pool still
        // serves concurrently; dispatch itself is serial and cheap.
        let stream = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return,
        };
        handle_conn(stream, service, shutdown);
    }
}

/// Serve one connection: line in, line out, until EOF / shutdown. The
/// short read timeout keeps the worker responsive to a shutdown raised
/// elsewhere while this client idles.
fn handle_conn(stream: TcpStream, service: &PlacementService, shutdown: &AtomicBool) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) if buf.is_empty() => return, // clean EOF
            Ok(n) => {
                // n == 0 here means EOF cut a buffered line short (a
                // timeout left partial bytes behind) — still answer it,
                // then return.
                let line = String::from_utf8_lossy(&buf).trim().to_string();
                buf.clear();
                if !line.is_empty() {
                    let (response, shut) = service.handle_line(&line);
                    if writer
                        .write_all(response.as_bytes())
                        .and_then(|_| writer.write_all(b"\n"))
                        .and_then(|_| writer.flush())
                        .is_err()
                    {
                        return;
                    }
                    if shut {
                        shutdown.store(true, Ordering::Relaxed);
                        return;
                    }
                }
                if n == 0 {
                    return;
                }
            }
            // Timeout mid-line: partial bytes stay in `buf`; keep
            // accumulating (and re-check the shutdown flag).
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(_) => return,
        }
    }
}

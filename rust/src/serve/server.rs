//! The placement server: a long-lived, multi-threaded daemon that turns
//! one on-disk policy checkpoint into a placement-as-a-service endpoint.
//!
//! [`PlacementService`] is the transport-free core (the benches and the
//! in-process tests drive it directly); [`Server`] puts it behind a TCP
//! listener with a fixed worker pool speaking the line-delimited
//! [`protocol`]. Per `place` request the service:
//!
//! 1. resolves the graph (registry spec or inline document) and computes
//!    its structural [`fingerprint`] — the cache key;
//! 2. answers from the bounded LRU [`cache`] on a hit (`provenance:
//!    "cache"`), skipping inference entirely; only complete
//!    server-default answers are ever *written* to the cache — a
//!    budget-truncated result or one computed under per-request knob
//!    overrides is returned but not stored, so it can never poison
//!    later unconstrained requests for the same graph;
//! 3. otherwise builds the placement environment and runs policy
//!    inference — one greedy rollout plus a few stochastic ones — under
//!    the per-request latency budget; when the budget is exhausted the
//!    policy stage is skipped or cut short. Each rollout batch simulates
//!    its placements through one batched `Env::report_many` call, so the
//!    process-global worker pool (`--workers`) spreads the evaluations
//!    without changing a single bit of the answer. A `fast_math` request
//!    opts this one inference into the lane kernels; such answers never
//!    enter or leave the placement cache;
//! 4. always evaluates the cheap non-learned candidates (every
//!    single-device deployment plus the capacity-aware memory-greedy) and
//!    serves the fastest *feasible* candidate overall, preferring the
//!    policy on exact ties. The service never returns a placement worse
//!    than the trivial ones it can check in microseconds; `provenance`
//!    reports truthfully whether the policy won (`"policy"`) or a
//!    baseline was served (`"fallback:memory-greedy"`,
//!    `"fallback:single:<device>"`).
//!
//! A `stats` request reports live metrics (qps, cache hit rate, p50/p99
//! service time, the service-time histogram buckets, a per-stage
//! latency breakdown, per-tenant request counts, the live checkpoint
//! generation); a `metrics` request dumps the process-wide
//! [`obs::metrics`](crate::obs::metrics) registry; a `ctrl: shutdown`
//! message acknowledges, stops the accept loop, drains the workers and
//! joins them — a clean exit, suitable for CI.
//!
//! ## Observability
//!
//! Service and stage timings land in log₂-bucketed histograms
//! ([`LogHist`]) under the stats mutex — O(1) per record, O(buckets)
//! per quantile, so a `stats` call never clones or sorts a sample
//! window while holding the lock. The same events increment the global
//! metrics registry (sharded relaxed atomics, no lock at all). With a
//! [`TraceSink`] attached (`--trace-log`), each `place` request emits
//! one `hsdag-trace-v1` JSONL line with per-stage spans ([`STAGES`]:
//! queue wait, workload/env preparation, cache lookup + single-flight,
//! policy rollouts, trivial-candidate simulation, final selection),
//! keyed by the request's trace id (client/router-supplied via the
//! wire `trace` field, else minted here). All of it is strictly
//! observational: `tests/obs.rs` pins that placements are bit-identical
//! with telemetry on or off.
//!
//! ## Hot reload
//!
//! The policy lives behind an RCU-style swap: requests clone an
//! `Arc<PolicySnapshot>` out of a mutex at admission and never touch the
//! shared pointer again, so a `ctrl: reload` (or SIGHUP, see
//! [`sighup_flag`]) can load + pre-flight a new `hsdag-params-v1`
//! checkpoint *outside* any lock, then swap the `Arc` in a critical
//! section that is one pointer move long. In-flight requests finish on
//! the snapshot they started with; nothing blocks, nothing drops. The
//! `checkpoint_generation` counter bumps per successful swap and `stats`
//! reports it (and the new `trained_on`) truthfully. The placement cache
//! is *kept* across a reload when the new checkpoint has the same
//! architecture (hidden width — cached answers are simulator-verified
//! placements, still valid under any policy) and *flushed* when the
//! architecture changed; `ctrl: clear-cache` forces a flush either way.
//!
//! ## Admission control
//!
//! The accept loop feeds workers through a *bounded* queue
//! ([`Server::set_queue_depth`], default [`DEFAULT_QUEUE_DEPTH`]; depth
//! 0 admits a connection only when a worker is idle right now). Past
//! the high-water mark a new
//! connection is answered with one fast `{"ok": false, "busy": true}`
//! line and closed — overload degrades into explicit shed load (counted
//! in `stats.busy_rejects`) instead of unbounded queueing and p99
//! collapse.
//!
//! [`protocol`]: super::protocol
//! [`fingerprint`]: super::fingerprint::fingerprint
//! [`cache`]: super::cache

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::TrySendError;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::cache::LruCache;
use super::checkpoint::Checkpoint;
use super::fingerprint::fingerprint;
use super::protocol::{
    self, PlaceOutcome, PlaceRequest, PlaceSource, Provenance, Request, StatsView,
};
use crate::baselines;
use crate::config::Config;
use crate::models::Workload;
use crate::obs::metrics::{self, LogHist};
use crate::obs::trace::{self, Trace, TraceSink};
use crate::rl::{Env, HsdagAgent, NativeBackend};
use crate::runtime::ParamStore;
use crate::sim::Placement;
use crate::util::json::Json;

/// Stochastic rollouts per batched policy pass when a latency budget is
/// set (between chunks the deadline is re-checked; unbounded requests
/// run every rollout in a single pass).
const ROLLOUT_CHUNK: usize = 2;

/// Default admission-control high-water mark (pending connections).
pub const DEFAULT_QUEUE_DEPTH: usize = 64;

/// Instrumented stages of the `place` pipeline, in pipeline order:
/// admission-queue wait, workload/env preparation, cache lookup (incl.
/// single-flight wait), policy rollout batches, trivial-candidate
/// simulation, and fastest-feasible selection. These are the trace span
/// names and the keys of the `stats` per-stage breakdown.
pub const STAGES: [&str; N_STAGES] = ["queue", "prepare", "cache", "rollout", "simulate", "select"];
pub const N_STAGES: usize = 6;
const S_QUEUE: usize = 0;
const S_PREPARE: usize = 1;
const S_CACHE: usize = 2;
const S_ROLLOUT: usize = 3;
const S_SIMULATE: usize = 4;
const S_SELECT: usize = 5;

/// Close one instrumented stage: accumulate its duration into the
/// per-request stage table and append a span to the trace (if one is
/// being collected). Purely observational — never branches the request.
fn note_stage(
    stage_us: &mut [u64; N_STAGES],
    trace: &mut Option<Trace>,
    idx: usize,
    started: Instant,
) {
    stage_us[idx] += started.elapsed().as_micros() as u64;
    if let Some(t) = trace {
        t.end(STAGES[idx], started);
    }
}

/// Front-end context handed to [`LineHandler::handle_line_ctx`] —
/// what only the transport layer can know about a request.
#[derive(Debug, Default, Clone, Copy)]
pub struct RequestCtx {
    /// Microseconds the connection waited in the admission queue before
    /// a worker picked it up. Applies to the connection's first line
    /// (pipelined followers were never queue-blocked); 0 when the
    /// handler is driven in-process.
    pub queue_us: u64,
}

/// Anything that answers protocol lines — the TCP [`Server`] front end
/// is generic over this, so one accept-loop/worker-pool/admission
/// implementation fronts both a [`PlacementService`] shard and a
/// [`Router`](super::router::Router).
pub trait LineHandler: Send + Sync {
    /// Handle one protocol line; returns the response line and whether
    /// the handler's own shutdown was requested.
    fn handle_line(&self, line: &str) -> (String, bool);

    /// [`LineHandler::handle_line`] plus front-end context (queue
    /// wait). The TCP front end calls this; the default ignores the
    /// context, so simple handlers only implement `handle_line`.
    fn handle_line_ctx(&self, line: &str, ctx: &RequestCtx) -> (String, bool) {
        let _ = ctx;
        self.handle_line(line)
    }

    /// Called by the front end when it sheds a connection past the
    /// admission high-water mark (stats hooks).
    fn note_busy(&self) {}
}

/// Serving knobs (the `hsdag serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Placement-cache capacity (entries; 0 disables caching).
    pub cache_capacity: usize,
    /// Default per-request policy-inference budget in milliseconds
    /// (None = unbounded); requests may override it.
    pub budget_ms: Option<f64>,
    /// Stochastic policy rollouts on top of the greedy one; requests may
    /// override it.
    pub rollouts: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { cache_capacity: 256, budget_ms: None, rollouts: 4 }
    }
}

/// A complete, server-default answer for one fingerprint.
#[derive(Clone)]
struct CachedPlacement {
    placement: Vec<usize>,
    latency_s: f64,
    ref_latency_s: f64,
    feasible: bool,
}

/// One evaluated non-learned candidate (a single-device deployment or
/// the memory-greedy baseline). These depend only on the graph and the
/// testbed — exactly what the fingerprint hashes — so they are computed
/// once per fingerprint and shared across requests.
#[derive(Clone)]
struct TrivialCandidate {
    makespan: f64,
    feasible: bool,
    placement: Placement,
    name: String,
}

/// What the cache remembers per fingerprint. `answer` is only filled by
/// a complete server-default request (the poisoning rules below), but
/// `trivial` is knob-independent: a budget-truncated or knob-overridden
/// request may still reuse and refresh it.
#[derive(Clone, Default)]
struct CacheEntry {
    answer: Option<CachedPlacement>,
    trivial: Option<Arc<Vec<TrivialCandidate>>>,
}

struct StatsInner {
    requests: u64,
    placements: u64,
    cache_hits: u64,
    fallbacks: u64,
    errors: u64,
    /// Fresh single-device + memory-greedy evaluation passes (misses of
    /// the per-fingerprint trivial-candidate cache).
    trivial_evals: u64,
    /// Successful checkpoint swaps since boot.
    reloads: u64,
    /// Connections shed by admission control (not counted in `requests`:
    /// a shed connection never reached a worker).
    busy_rejects: u64,
    /// Place requests per self-reported tenant label.
    tenants: HashMap<String, u64>,
    /// Service-time histogram: O(1) record, O(buckets) quantile, so a
    /// `stats` call never sorts a sample window under this mutex.
    service_hist: LogHist,
    /// Per-stage latency histograms, indexed like [`STAGES`].
    stage_hists: [LogHist; N_STAGES],
}

impl Default for StatsInner {
    fn default() -> Self {
        StatsInner {
            requests: 0,
            placements: 0,
            cache_hits: 0,
            fallbacks: 0,
            errors: 0,
            trivial_evals: 0,
            reloads: 0,
            busy_rejects: 0,
            tenants: HashMap::new(),
            service_hist: LogHist::new(),
            stage_hists: std::array::from_fn(|_| LogHist::new()),
        }
    }
}

/// Interned registry handles for the serve hot path: resolved once at
/// service construction, each event afterwards is a single relaxed
/// atomic increment (no name lookup, no lock).
struct ServeMetrics {
    requests: &'static metrics::Counter,
    placements: &'static metrics::Counter,
    cache_hits: &'static metrics::Counter,
    fallbacks: &'static metrics::Counter,
    errors: &'static metrics::Counter,
    busy_rejects: &'static metrics::Counter,
    service_us: &'static metrics::Histogram,
    queue_us: &'static metrics::Histogram,
}

impl ServeMetrics {
    fn intern() -> ServeMetrics {
        ServeMetrics {
            requests: metrics::counter("serve.requests"),
            placements: metrics::counter("serve.placements"),
            cache_hits: metrics::counter("serve.cache_hits"),
            fallbacks: metrics::counter("serve.fallbacks"),
            errors: metrics::counter("serve.errors"),
            busy_rejects: metrics::counter("serve.busy_rejects"),
            service_us: metrics::histogram("serve.service_us"),
            queue_us: metrics::histogram("serve.queue_us"),
        }
    }
}

/// One immutable generation of the policy: the parameters plus the
/// config they were validated under (the checkpoint pins `hidden`, so
/// the config can differ across generations). Requests clone the `Arc`
/// once at admission and never look back — a reload swapping the
/// service-level pointer cannot stall or corrupt an in-flight request.
struct PolicySnapshot {
    params: ParamStore,
    cfg: Config,
    /// Informational: what the checkpoint says it was trained on.
    trained_on: String,
    /// 0 at boot, +1 per successful [`PlacementService::reload`].
    generation: u64,
}

/// The transport-free placement service.
pub struct PlacementService {
    /// Boot-time config: testbed/seed/backend are fixed for the process
    /// lifetime (a reload refuses to change testbed); `hidden` here is
    /// the boot checkpoint's and may be superseded by the live snapshot.
    cfg: Config,
    /// The live policy, RCU-style: lock, clone the `Arc`, unlock.
    policy: Mutex<Arc<PolicySnapshot>>,
    /// Where `reload(None)` (the bare `ctrl: reload` / SIGHUP path)
    /// re-reads the checkpoint from.
    default_ckpt: Mutex<Option<PathBuf>>,
    device_names: Vec<String>,
    opts: ServeOptions,
    cache: Mutex<LruCache<u64, CacheEntry>>,
    /// Fingerprints with a server-default inference currently running
    /// (single-flight: concurrent identical requests wait for the leader
    /// and answer from the cache instead of duplicating the inference).
    inflight: Mutex<HashSet<u64>>,
    inflight_cv: Condvar,
    stats: Mutex<StatsInner>,
    metrics: ServeMetrics,
    /// When set (`--trace-log`), every `place` request appends one
    /// `hsdag-trace-v1` JSONL line here.
    trace_sink: Option<Arc<TraceSink>>,
    started: Instant,
}

/// Removes a fingerprint from the in-flight set on scope exit (including
/// the error paths) and wakes every waiter.
struct FlightGuard<'a> {
    svc: &'a PlacementService,
    fp: u64,
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        self.svc.inflight.lock().unwrap().remove(&self.fp);
        self.svc.inflight_cv.notify_all();
    }
}

impl PlacementService {
    /// Stand the service up from a loaded checkpoint. `cfg` supplies the
    /// testbed (defaulting upstream to the checkpoint's), seed and eval
    /// workers; the checkpoint supplies the parameters and pins the
    /// hidden size. Refuses a checkpoint whose placer width disagrees
    /// with the testbed before any request is served.
    pub fn new(ckpt: Checkpoint, cfg: &Config, opts: ServeOptions) -> Result<PlacementService> {
        let mut cfg = cfg.clone();
        cfg.backend = "native".to_string();
        cfg.hidden = ckpt.meta.hidden;
        // Serving never trains: a 1-step replay buffer keeps per-request
        // agents from allocating a full training window per graph.
        cfg.update_timestep = 1;
        let tb = cfg.resolve_testbed()?;
        ckpt.check_compatible(cfg.hidden, tb.n_actions(), &cfg.testbed)?;
        let snapshot = PolicySnapshot {
            params: ckpt.store,
            cfg: cfg.clone(),
            trained_on: ckpt.meta.workload.clone(),
            generation: 0,
        };
        Ok(PlacementService {
            device_names: tb.devices.iter().map(|d| d.name.clone()).collect(),
            policy: Mutex::new(Arc::new(snapshot)),
            default_ckpt: Mutex::new(None),
            cache: Mutex::new(LruCache::new(opts.cache_capacity)),
            inflight: Mutex::new(HashSet::new()),
            inflight_cv: Condvar::new(),
            stats: Mutex::new(StatsInner::default()),
            metrics: ServeMetrics::intern(),
            trace_sink: None,
            started: Instant::now(),
            cfg,
            opts,
        })
    }

    /// The boot-time run configuration (testbed id, hidden size, seed).
    /// After a reload the live snapshot's config is authoritative for
    /// `hidden`; testbed and seed never change.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// What the live checkpoint was trained on (banner text; tracks
    /// reloads).
    pub fn trained_on(&self) -> String {
        self.policy.lock().unwrap().trained_on.clone()
    }

    /// The live checkpoint generation (0 at boot, +1 per reload).
    pub fn generation(&self) -> u64 {
        self.policy.lock().unwrap().generation
    }

    /// Register the checkpoint path a bare `ctrl: reload` (or SIGHUP)
    /// re-reads; `hsdag serve` points this at its `--load` flag so the
    /// atomically-replace-then-reload runbook needs no argument.
    pub fn set_default_checkpoint(&self, path: &Path) {
        *self.default_ckpt.lock().unwrap() = Some(path.to_path_buf());
    }

    /// Attach a `hsdag-trace-v1` JSONL sink (`--trace-log`); call before
    /// the service is shared. With no sink, a request still gets spans
    /// collected (and its trace id echoed) when it carries a `trace`
    /// field — they are just not written anywhere.
    pub fn set_trace_sink(&mut self, sink: Arc<TraceSink>) {
        self.trace_sink = Some(sink);
    }

    /// Load, validate, pre-flight and atomically swap in a new
    /// checkpoint; in-flight requests finish on the snapshot they
    /// already hold. Returns `(generation, cache_kept, trained_on)`.
    ///
    /// Everything expensive — disk read, shape checks, a smoke rollout —
    /// happens *before* the policy lock is taken; the critical section
    /// is one `Arc` assignment. A checkpoint for a different testbed is
    /// refused (that is a redeploy, not a reload). The placement cache
    /// is kept when the architecture (hidden width) is unchanged —
    /// cached answers are simulator-verified placements, valid
    /// regardless of which policy found them — and flushed otherwise.
    pub fn reload(&self, path: Option<&Path>) -> Result<(u64, bool, String)> {
        let path = match path {
            Some(p) => p.to_path_buf(),
            None => self.default_ckpt.lock().unwrap().clone().ok_or_else(|| {
                anyhow!(
                    "reload: no checkpoint given and no default path registered \
                     (pass ctrl.checkpoint, or start serve with --load)"
                )
            })?,
        };
        let ckpt = Checkpoint::load(&path)
            .with_context(|| format!("reloading checkpoint '{}'", path.display()))?;
        let tb = self.cfg.resolve_testbed()?;
        // The checkpoint's own hidden width is the candidate config's:
        // architecture may change across a reload (the cache is flushed
        // then); the action space and testbed must not.
        ckpt.check_compatible(ckpt.meta.hidden, tb.n_actions(), &self.cfg.testbed)?;
        let mut cfg = self.cfg.clone();
        cfg.hidden = ckpt.meta.hidden;
        // Pre-flight: stand a full agent up on a tiny graph and run the
        // greedy rollout. This catches parameter-store problems the
        // shape header checks cannot (e.g. a feature-dim mismatch that
        // only surfaces when the backend wires the layers together),
        // while the old snapshot keeps serving.
        let smoke = Workload::resolve("seq:4")?;
        let env = Env::for_workload(smoke, &cfg)?;
        let backend = NativeBackend::from_snapshot(&env, &cfg, &ckpt.store)?;
        let mut agent = HsdagAgent::with_backend(&env, Box::new(backend), &cfg)?;
        agent
            .rollout_batch(&env, 0)
            .context("reload pre-flight rollout failed; keeping the old checkpoint")?;
        let trained_on = ckpt.meta.workload.clone();
        let (generation, cache_kept) = {
            let mut slot = self.policy.lock().unwrap();
            let generation = slot.generation + 1;
            let cache_kept = cfg.hidden == slot.cfg.hidden;
            *slot = Arc::new(PolicySnapshot {
                params: ckpt.store,
                cfg,
                trained_on: trained_on.clone(),
                generation,
            });
            (generation, cache_kept)
        };
        if !cache_kept {
            self.clear_cache();
        }
        self.stats.lock().unwrap().reloads += 1;
        crate::log_debug!(
            "reload: generation {generation}, cache_kept {cache_kept}, trained_on {trained_on}"
        );
        Ok((generation, cache_kept, trained_on))
    }

    /// Evaluate the non-learned candidates for one environment: every
    /// single-device deployment plus the capacity-aware memory-greedy.
    fn eval_trivial(env: &Env) -> Vec<TrivialCandidate> {
        let mut out: Vec<TrivialCandidate> = env
            .testbed
            .placeable
            .iter()
            .map(|&d| {
                (
                    Placement::all(env.graph.n(), d),
                    format!("single:{}", env.testbed.devices[d].name),
                )
            })
            .chain(std::iter::once((
                baselines::memory_greedy_placement(&env.graph, &env.testbed),
                "memory-greedy".to_string(),
            )))
            .map(|(p, name)| {
                let rep = env.cost.evaluate(&env.graph, &p, &env.testbed);
                TrivialCandidate {
                    makespan: rep.makespan,
                    feasible: rep.feasible(),
                    placement: p,
                    name,
                }
            })
            .collect();
        out.shrink_to_fit();
        out
    }

    /// One cache probe: the complete answer for `fp` (ready to return as
    /// a `provenance: "cache"` outcome) and/or the reusable
    /// trivial-candidate evaluations.
    #[allow(clippy::type_complexity)]
    fn cache_lookup(
        &self,
        fp: u64,
        fp_hex: &str,
    ) -> (Option<PlaceOutcome>, Option<Arc<Vec<TrivialCandidate>>>) {
        let mut cache = self.cache.lock().unwrap();
        let Some(entry) = cache.get(&fp) else {
            return (None, None);
        };
        let trivial = entry.trivial.clone();
        let answer = entry.answer.as_ref().map(|hit| PlaceOutcome {
            fingerprint: fp_hex.to_string(),
            placement: hit.placement.clone(),
            devices: self.device_names.clone(),
            latency_s: hit.latency_s,
            ref_latency_s: hit.ref_latency_s,
            feasible: hit.feasible,
            provenance: Provenance::Cache,
        });
        (answer, trivial)
    }

    /// Serve one placement request (the cache-or-infer-or-fallback core).
    pub fn handle_place(&self, req: &PlaceRequest) -> Result<PlaceOutcome> {
        self.place_traced(req, &mut [0; N_STAGES], &mut None)
    }

    /// [`PlacementService::handle_place`] with stage instrumentation:
    /// accumulates per-stage microseconds into `stage_us` and appends
    /// spans to `trace` when one is being collected. The instrumentation
    /// is strictly observational — identical placements with or without
    /// it (pinned by `tests/obs.rs`).
    fn place_traced(
        &self,
        req: &PlaceRequest,
        stage_us: &mut [u64; N_STAGES],
        trace: &mut Option<Trace>,
    ) -> Result<PlaceOutcome> {
        let t0 = Instant::now();
        // RCU read side: one lock + Arc clone, then this request runs to
        // completion on `snap` no matter how many reloads land meanwhile.
        let snap: Arc<PolicySnapshot> = self.policy.lock().unwrap().clone();
        let deadline = req
            .budget_ms
            .or(self.opts.budget_ms)
            .map(|ms| t0 + Duration::from_secs_f64(ms / 1e3));
        let over = |d: &Option<Instant>| d.map(|d| Instant::now() >= d).unwrap_or(false);

        let t_prep = Instant::now();
        let workload = match &req.source {
            PlaceSource::Spec(s) => Workload::resolve(s)?,
            PlaceSource::Inline(g) => Workload::from_graph(g.clone(), None),
        };
        let fp = fingerprint(&workload.graph, &snap.cfg.testbed);
        let fp_hex = format!("{fp:016x}");
        note_stage(stage_us, trace, S_PREPARE, t_prep);

        // A request with server-default knobs: its answer may be cached,
        // so concurrent duplicates can single-flight behind one leader.
        // (With caching disabled the leader's answer could never reach
        // the followers, so single-flight would only serialize them.)
        let default_shaped = !req.no_cache
            && !req.fast_math
            && req.budget_ms.is_none()
            && req.rollouts.is_none()
            && self.opts.cache_capacity > 0;

        // Cache lookup + single-flight admission. `no_cache` bypasses the
        // cache in both directions, including the trivial-candidate reuse.
        // A `fast_math` request never answers from the cache (the caller
        // asked for the lane kernels, not a stored exact-kernel answer)
        // but may still reuse the policy-independent trivial evaluations.
        let mut cached_trivial: Option<Arc<Vec<TrivialCandidate>>> = None;
        let mut _flight: Option<FlightGuard<'_>> = None;
        if !req.no_cache {
            // The cache stage covers the probe(s) AND any single-flight
            // wait behind a leader — exactly the time a duplicate
            // request spends not computing.
            let t_cache = Instant::now();
            loop {
                let (answer, trivial) = self.cache_lookup(fp, &fp_hex);
                cached_trivial = trivial;
                if let Some(hit) = answer {
                    if !req.fast_math {
                        note_stage(stage_us, trace, S_CACHE, t_cache);
                        return Ok(hit);
                    }
                }
                if !default_shaped {
                    break;
                }
                let mut infl = self.inflight.lock().unwrap();
                if infl.insert(fp) {
                    drop(infl);
                    _flight = Some(FlightGuard { svc: self, fp });
                    // Re-check as leader: a previous leader may have
                    // completed between our miss and the insert; its put
                    // happens-before our successful insert, so this
                    // lookup is guaranteed to see the answer.
                    let (answer, trivial) = self.cache_lookup(fp, &fp_hex);
                    cached_trivial = trivial;
                    if let Some(hit) = answer {
                        note_stage(stage_us, trace, S_CACHE, t_cache);
                        return Ok(hit);
                    }
                    break;
                }
                // An identical default-shaped request is mid-inference on
                // another worker: wait for it and re-read the cache (its
                // answer lands there) instead of duplicating the work.
                let _woken = self.inflight_cv.wait(infl).unwrap();
            }
            note_stage(stage_us, trace, S_CACHE, t_cache);
        }

        let t_env = Instant::now();
        let env = Env::for_workload(workload, &snap.cfg)?;
        note_stage(stage_us, trace, S_PREPARE, t_env);

        // Candidates, policy first (ties between a policy rollout and an
        // identical baseline placement resolve toward the policy).
        let mut candidates: Vec<(f64, bool, Placement, Provenance)> = Vec::new();
        let mut policy_complete = false;
        if !over(&deadline) {
            let t_roll = Instant::now();
            let mut backend = NativeBackend::from_snapshot(&env, &snap.cfg, &snap.params)?;
            if req.fast_math {
                // Per-request opt-in: the lane kernels run for this
                // inference only; the snapshot itself is untouched.
                backend.policy_mut().set_fast_math(true);
            }
            let mut agent = HsdagAgent::with_backend(&env, Box::new(backend), &snap.cfg)?;
            let n_roll = req.rollouts.unwrap_or(self.opts.rollouts);
            // The greedy rollout plus every stochastic one go through ONE
            // batched policy pass when the request is unbounded (the
            // server-default fast path). Under a deadline, rollouts run
            // in bounded chunks so the budget can still cut the stage
            // short between chunks.
            policy_complete = true;
            let mut remaining = n_roll;
            let mut greedy_done = false;
            loop {
                let chunk = if deadline.is_none() {
                    remaining
                } else {
                    remaining.min(ROLLOUT_CHUNK)
                };
                let outs = agent.rollout_batch(&env, chunk)?;
                for (i, o) in outs.into_iter().enumerate() {
                    if i == 0 && greedy_done {
                        // Later chunks re-run the deterministic greedy
                        // rollout; its candidate is already recorded.
                        continue;
                    }
                    candidates.push((
                        o.det_latency,
                        o.feasible,
                        env.expand(&o.actions)?,
                        Provenance::Policy,
                    ));
                }
                greedy_done = true;
                remaining -= chunk;
                if remaining == 0 {
                    break;
                }
                if over(&deadline) {
                    policy_complete = false;
                    break;
                }
            }
            note_stage(stage_us, trace, S_ROLLOUT, t_roll);
        }
        // The trivial candidates: the service never returns a placement
        // worse than these, and they are the whole answer when the budget
        // was exhausted. They depend only on the fingerprinted structure,
        // so they are computed once per fingerprint and reused from the
        // cache entry afterwards.
        let trivial: Arc<Vec<TrivialCandidate>> = match cached_trivial {
            Some(t) => t,
            None => {
                let t_sim = Instant::now();
                let t = Arc::new(Self::eval_trivial(&env));
                note_stage(stage_us, trace, S_SIMULATE, t_sim);
                self.stats.lock().unwrap().trivial_evals += 1;
                if !req.no_cache {
                    let mut cache = self.cache.lock().unwrap();
                    let mut entry = cache.peek(&fp).cloned().unwrap_or_default();
                    entry.trivial = Some(Arc::clone(&t));
                    cache.put(fp, entry);
                }
                t
            }
        };
        for c in trivial.iter() {
            candidates.push((
                c.makespan,
                c.feasible,
                c.placement.clone(),
                Provenance::Fallback(c.name.clone()),
            ));
        }

        // Fastest feasible candidate (fastest overall when nothing is
        // feasible — the response's `feasible: false` says so); strictly
        // better wins, so earlier (policy) candidates take exact ties.
        let t_sel = Instant::now();
        let any_feasible = candidates.iter().any(|c| c.1);
        let mut best: Option<&(f64, bool, Placement, Provenance)> = None;
        for c in &candidates {
            if any_feasible && !c.1 {
                continue;
            }
            if best.map(|b| c.0 < b.0).unwrap_or(true) {
                best = Some(c);
            }
        }
        let (latency_s, feasible, placement, provenance) =
            best.ok_or_else(|| anyhow!("no placement candidate produced"))?;
        note_stage(stage_us, trace, S_SELECT, t_sel);

        let outcome = PlaceOutcome {
            fingerprint: fp_hex,
            placement: placement.0.clone(),
            devices: self.device_names.clone(),
            latency_s: *latency_s,
            ref_latency_s: env.ref_latency,
            feasible: *feasible,
            provenance: provenance.clone(),
        };
        // Only the server-default answer may enter the cache: a
        // budget-truncated result, or one computed under per-request
        // knob overrides, must never be served to later unconstrained
        // requests for the same graph (cache poisoning). A checkpoint
        // swap mid-inference also voids cacheability — the reload may
        // just have flushed the cache, and an old-generation answer must
        // not repopulate it behind the new policy's back. A fast-math
        // answer is likewise never stored: its logits came from the
        // reassociated lane kernels, and the cache serves only the
        // bit-reproducible default path. (The trivial candidates above
        // are exempt: they are policy-independent.)
        let cacheable = !req.no_cache
            && !req.fast_math
            && policy_complete
            && req.budget_ms.is_none()
            && req.rollouts.is_none()
            && self.policy.lock().unwrap().generation == snap.generation;
        if cacheable {
            let mut cache = self.cache.lock().unwrap();
            let mut entry = cache.peek(&fp).cloned().unwrap_or_default();
            entry.answer = Some(CachedPlacement {
                placement: outcome.placement.clone(),
                latency_s: outcome.latency_s,
                ref_latency_s: outcome.ref_latency_s,
                feasible: outcome.feasible,
            });
            entry.trivial = Some(trivial);
            cache.put(fp, entry);
        }
        Ok(outcome)
    }

    /// Handle one protocol line; returns the response line and whether a
    /// shutdown was requested.
    pub fn handle_line(&self, line: &str) -> (String, bool) {
        self.handle_line_ctx(line, &RequestCtx::default())
    }

    /// [`PlacementService::handle_line`] with front-end context: the
    /// admission-queue wait becomes the request's `queue` stage.
    pub fn handle_line_ctx(&self, line: &str, ctx: &RequestCtx) -> (String, bool) {
        let t0 = Instant::now();
        match protocol::parse_request(line) {
            Err(e) => {
                {
                    let mut s = self.stats.lock().unwrap();
                    s.requests += 1;
                    s.errors += 1;
                }
                self.metrics.requests.inc();
                self.metrics.errors.inc();
                (protocol::render_error_response(None, &format!("{e:#}")), false)
            }
            Ok(Request::Stats) => {
                self.stats.lock().unwrap().requests += 1;
                self.metrics.requests.inc();
                (protocol::render_stats_response(&self.stats_view()), false)
            }
            Ok(Request::Metrics) => {
                self.stats.lock().unwrap().requests += 1;
                self.metrics.requests.inc();
                (protocol::render_metrics_response(), false)
            }
            Ok(Request::Shutdown) => {
                self.stats.lock().unwrap().requests += 1;
                self.metrics.requests.inc();
                (protocol::render_ctrl_response("shutdown"), true)
            }
            Ok(Request::Reload(path)) => {
                self.stats.lock().unwrap().requests += 1;
                self.metrics.requests.inc();
                match self.reload(path.as_deref().map(Path::new)) {
                    Ok((generation, cache_kept, trained_on)) => (
                        protocol::render_reload_response(generation, cache_kept, &trained_on),
                        false,
                    ),
                    Err(e) => {
                        // The old checkpoint keeps serving; the caller
                        // learns why the swap did not happen.
                        self.stats.lock().unwrap().errors += 1;
                        self.metrics.errors.inc();
                        (protocol::render_error_response(None, &format!("{e:#}")), false)
                    }
                }
            }
            Ok(Request::ClearCache) => {
                self.stats.lock().unwrap().requests += 1;
                self.metrics.requests.inc();
                self.clear_cache();
                (protocol::render_ctrl_response("clear-cache"), false)
            }
            Ok(Request::Place(req)) => {
                // A trace is collected when a sink is attached or the
                // request carries its own id (a router minted one);
                // otherwise the instrumentation costs only the stage
                // Instant reads.
                let mut trace: Option<Trace> =
                    if self.trace_sink.is_some() || req.trace.is_some() {
                        let id =
                            req.trace.clone().unwrap_or_else(trace::mint_id);
                        Some(Trace::new(id, "place"))
                    } else {
                        None
                    };
                let mut stage_us = [0u64; N_STAGES];
                stage_us[S_QUEUE] = ctx.queue_us;
                if ctx.queue_us > 0 {
                    if let Some(t) = &mut trace {
                        t.span_before_start(STAGES[S_QUEUE], ctx.queue_us);
                    }
                }
                let result = self.place_traced(&req, &mut stage_us, &mut trace);
                let service_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.metrics.requests.inc();
                self.metrics.service_us.record((service_ms * 1e3) as u64);
                if ctx.queue_us > 0 {
                    self.metrics.queue_us.record(ctx.queue_us);
                }
                let trace_id = trace.as_ref().map(|t| t.id().to_string());
                let mut s = self.stats.lock().unwrap();
                s.requests += 1;
                if let Some(tenant) = &req.tenant {
                    *s.tenants.entry(tenant.clone()).or_insert(0) += 1;
                }
                match result {
                    Ok(outcome) => {
                        s.placements += 1;
                        self.metrics.placements.inc();
                        match outcome.provenance {
                            Provenance::Cache => {
                                s.cache_hits += 1;
                                self.metrics.cache_hits.inc();
                            }
                            Provenance::Fallback(_) => {
                                s.fallbacks += 1;
                                self.metrics.fallbacks.inc();
                            }
                            Provenance::Policy => {}
                        }
                        s.service_hist.record_ms(service_ms);
                        for (i, &us) in stage_us.iter().enumerate() {
                            if us > 0 {
                                s.stage_hists[i].record_us(us);
                            }
                        }
                        drop(s);
                        if let Some(t) = &mut trace {
                            t.field("fingerprint", Json::Str(outcome.fingerprint.clone()));
                            t.field("provenance", Json::Str(outcome.provenance.label()));
                            if let Some(sink) = &self.trace_sink {
                                sink.write(t);
                            }
                        }
                        (
                            protocol::render_place_response(
                                req.id.as_ref(),
                                &outcome,
                                service_ms,
                                trace_id.as_deref(),
                            ),
                            false,
                        )
                    }
                    Err(e) => {
                        s.errors += 1;
                        self.metrics.errors.inc();
                        drop(s);
                        if let Some(t) = &mut trace {
                            t.field("error", Json::Str(format!("{e:#}")));
                            if let Some(sink) = &self.trace_sink {
                                sink.write(t);
                            }
                        }
                        (
                            protocol::render_error_response(req.id.as_ref(), &format!("{e:#}")),
                            false,
                        )
                    }
                }
            }
        }
    }

    /// Snapshot the live metrics. The three locks are taken one at a
    /// time (never nested) so this can never deadlock against a
    /// concurrent reload or place.
    pub fn stats_view(&self) -> StatsView {
        let (checkpoint_generation, trained_on) = {
            let p = self.policy.lock().unwrap();
            (p.generation, p.trained_on.clone())
        };
        let (cache_len, cache_capacity) = {
            let c = self.cache.lock().unwrap();
            (c.len(), c.capacity())
        };
        let s = self.stats.lock().unwrap();
        let uptime_s = self.started.elapsed().as_secs_f64();
        let mut tenants: Vec<(String, u64)> =
            s.tenants.iter().map(|(k, v)| (k.clone(), *v)).collect();
        tenants.sort();
        StatsView {
            uptime_s,
            requests: s.requests,
            placements: s.placements,
            cache_hits: s.cache_hits,
            fallbacks: s.fallbacks,
            errors: s.errors,
            trivial_evals: s.trivial_evals,
            reloads: s.reloads,
            busy_rejects: s.busy_rejects,
            cache_len,
            cache_capacity,
            qps: s.requests as f64 / uptime_s.max(1e-9),
            cache_hit_rate: s.cache_hits as f64 / (s.placements.max(1)) as f64,
            // Quantiles come straight off the log₂ histogram: no clone,
            // no sort, O(buckets) while holding the stats mutex.
            p50_ms: s.service_hist.quantile_ms(50.0),
            p99_ms: s.service_hist.quantile_ms(99.0),
            service_hist: s.service_hist.snapshot().nonzero(),
            stages: STAGES
                .iter()
                .zip(s.stage_hists.iter())
                .filter(|(_, h)| h.count() > 0)
                .map(|(&name, h)| protocol::StageStat {
                    name,
                    count: h.count(),
                    p50_ms: h.quantile_ms(50.0),
                    p99_ms: h.quantile_ms(99.0),
                })
                .collect(),
            testbed: self.cfg.testbed.clone(),
            checkpoint_generation,
            trained_on,
            tenants,
        }
    }

    /// Drop every cached placement (benches isolate cold/hit paths; the
    /// `ctrl: clear-cache` escape hatch after a reload that should have
    /// flushed).
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }
}

impl LineHandler for PlacementService {
    fn handle_line(&self, line: &str) -> (String, bool) {
        PlacementService::handle_line(self, line)
    }

    fn handle_line_ctx(&self, line: &str, ctx: &RequestCtx) -> (String, bool) {
        PlacementService::handle_line_ctx(self, line, ctx)
    }

    fn note_busy(&self) {
        self.stats.lock().unwrap().busy_rejects += 1;
        self.metrics.busy_rejects.inc();
    }
}

// ---------------------------------------------------------------------------
// TCP front end
// ---------------------------------------------------------------------------

/// A bound-but-not-yet-running server. `addr` may use port 0 for an
/// ephemeral port; [`Server::local_addr`] reports what was bound. The
/// front end is generic over [`LineHandler`]: the same accept loop,
/// worker pool and admission control serve both a [`PlacementService`]
/// shard and a [`Router`](super::router::Router).
pub struct Server {
    listener: TcpListener,
    handler: Arc<dyn LineHandler>,
    addr: SocketAddr,
    queue_depth: usize,
}

/// Handle to a server running on a background thread (tests, examples).
pub struct ServerHandle {
    pub addr: SocketAddr,
    thread: thread::JoinHandle<Result<()>>,
}

impl ServerHandle {
    /// Wait for the server to shut down (a `ctrl: shutdown` request).
    pub fn join(self) -> Result<()> {
        self.thread.join().map_err(|_| anyhow!("server thread panicked"))?
    }
}

impl Server {
    pub fn bind(handler: Arc<dyn LineHandler>, addr: &str) -> Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve address '{addr}'"))?;
        let addr = listener.local_addr()?;
        Ok(Server { listener, handler, addr, queue_depth: DEFAULT_QUEUE_DEPTH })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Admission-control high-water mark: accepted connections that may
    /// wait for a worker. Depth 0 is a rendezvous — a connection is
    /// admitted only if a worker is idle at that instant; anything past
    /// the mark gets one `busy` line and a close.
    pub fn set_queue_depth(&mut self, depth: usize) {
        self.queue_depth = depth;
    }

    /// Accept and serve until a shutdown request arrives, then drain and
    /// join the `workers`-wide pool. Blocks the calling thread.
    pub fn run(self, workers: usize) -> Result<()> {
        self.listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        // The bounded hand-off IS the admission queue: `try_send` either
        // parks the connection within the high-water mark (or straight
        // into an idle worker's `recv`) or fails fast, in which case the
        // client gets an explicit `busy` line instead of silently
        // joining an unbounded backlog. The enqueue Instant rides along
        // so the worker can report the queue wait as the request's
        // `queue` stage.
        let (tx, rx) = mpsc::sync_channel::<(Instant, TcpStream)>(self.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let mut pool = Vec::with_capacity(workers.max(1));
        for i in 0..workers.max(1) {
            let rx = Arc::clone(&rx);
            let handler = Arc::clone(&self.handler);
            let shutdown = Arc::clone(&shutdown);
            pool.push(
                thread::Builder::new()
                    .name(format!("hsdag-serve-{i}"))
                    .spawn(move || worker_loop(&rx, &*handler, &shutdown))
                    .context("spawning serve worker")?,
            );
        }
        loop {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => match tx.try_send((Instant::now(), stream)) {
                    Ok(()) => {}
                    Err(TrySendError::Full((_, stream))) => {
                        shed_busy(stream, self.queue_depth);
                        self.handler.note_busy();
                    }
                    // Workers only exit once the senders drop, which
                    // only happens on shutdown; drop the connection and
                    // let the flag check above end the loop.
                    Err(TrySendError::Disconnected(_)) => {}
                },
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(10));
                }
                Err(e) => {
                    shutdown.store(true, Ordering::Relaxed);
                    drop(tx);
                    for t in pool {
                        let _ = t.join();
                    }
                    return Err(e).context("accepting connections");
                }
            }
        }
        drop(tx);
        for t in pool {
            let _ = t.join();
        }
        Ok(())
    }

    /// Run on a background thread; returns once the listener is live.
    pub fn spawn(self, workers: usize) -> Result<ServerHandle> {
        let addr = self.addr;
        let thread = thread::Builder::new()
            .name("hsdag-serve-accept".to_string())
            .spawn(move || self.run(workers))
            .context("spawning server thread")?;
        Ok(ServerHandle { addr, thread })
    }
}

/// Shed one over-capacity connection: a single fast `busy` line, then
/// close. Runs on the accept thread, so it must never block long — the
/// write timeout bounds a pathological client.
fn shed_busy(mut stream: TcpStream, queue_depth: usize) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let line = protocol::render_busy_response(queue_depth);
    let _ = stream
        .write_all(line.as_bytes())
        .and_then(|_| stream.write_all(b"\n"))
        .and_then(|_| stream.flush());
}

/// One pool worker: pull connections off the shared queue until the
/// channel closes (all senders dropped at shutdown).
fn worker_loop(
    rx: &Mutex<mpsc::Receiver<(Instant, TcpStream)>>,
    handler: &dyn LineHandler,
    shutdown: &AtomicBool,
) {
    loop {
        // Holding the lock while blocked in recv is fine: connection
        // *handling* happens after the guard drops, so the pool still
        // serves concurrently; dispatch itself is serial and cheap.
        let (enqueued, stream) = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return,
        };
        let queue_us = enqueued.elapsed().as_micros() as u64;
        handle_conn(stream, handler, shutdown, queue_us);
    }
}

/// Serve one connection: line in, line out, until EOF / shutdown. The
/// short read timeout keeps the worker responsive to a shutdown raised
/// elsewhere while this client idles. `queue_us` is the admission-queue
/// wait, attributed to the connection's first request only (later
/// pipelined lines were never queue-blocked).
fn handle_conn(
    stream: TcpStream,
    handler: &dyn LineHandler,
    shutdown: &AtomicBool,
    queue_us: u64,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut first_line = true;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        match reader.read_until(b'\n', &mut buf) {
            Ok(0) if buf.is_empty() => return, // clean EOF
            Ok(n) => {
                // n == 0 here means EOF cut a buffered line short (a
                // timeout left partial bytes behind) — still answer it,
                // then return.
                let line = String::from_utf8_lossy(&buf).trim().to_string();
                buf.clear();
                if !line.is_empty() {
                    let ctx = RequestCtx { queue_us: if first_line { queue_us } else { 0 } };
                    first_line = false;
                    let (response, shut) = handler.handle_line_ctx(&line, &ctx);
                    if writer
                        .write_all(response.as_bytes())
                        .and_then(|_| writer.write_all(b"\n"))
                        .and_then(|_| writer.flush())
                        .is_err()
                    {
                        return;
                    }
                    if shut {
                        shutdown.store(true, Ordering::Relaxed);
                        return;
                    }
                }
                if n == 0 {
                    return;
                }
            }
            // Timeout mid-line: partial bytes stay in `buf`; keep
            // accumulating (and re-check the shutdown flag).
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => continue,
            Err(_) => return,
        }
    }
}

// ---------------------------------------------------------------------------
// SIGHUP → reload latch
// ---------------------------------------------------------------------------

#[cfg(unix)]
static SIGHUP_FLAG: AtomicBool = AtomicBool::new(false);

/// The handler itself only flips an atomic — the only thing that is
/// async-signal-safe here. A watcher thread (see `hsdag serve`) polls
/// the flag and performs the actual [`PlacementService::reload`].
#[cfg(unix)]
extern "C" fn sighup_latch(_signum: i32) {
    SIGHUP_FLAG.store(true, Ordering::Relaxed);
}

/// Install (once) a SIGHUP handler that latches into a process-wide
/// flag, and return the flag; the caller polls it and swaps it back to
/// `false` before reloading. Returns `None` on platforms without POSIX
/// signals. Declared against the C library directly — the crate has no
/// libc dependency.
pub fn sighup_flag() -> Option<&'static AtomicBool> {
    #[cfg(unix)]
    {
        use std::sync::Once;
        const SIGHUP: i32 = 1;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        static INSTALL: Once = Once::new();
        INSTALL.call_once(|| unsafe {
            signal(SIGHUP, sighup_latch);
        });
        Some(&SIGHUP_FLAG)
    }
    #[cfg(not(unix))]
    {
        None
    }
}

//! The placement service subsystem: everything between a *trained*
//! policy and a *deployed* one.
//!
//! The paper's framework ends at training — the learned HSDAG policy
//! lives and dies with its process. This layer makes the policy a
//! persistent, reusable artifact (GDP / Placeto's "train once, place
//! many" regime) and puts it behind a long-lived daemon:
//!
//! - [`checkpoint`] — the `hsdag-params-v1` on-disk format: the full
//!   `ParamStore` (params + Adam state) plus deployment metadata, with
//!   layout validation on load. Written by `train --save` /
//!   `generalize --save`, consumed by every `--load` path.
//! - [`fingerprint`] — deterministic structural hashes over graph
//!   topology, op identity, shapes and the testbed id; node *names* are
//!   excluded, so the same model re-traced under different layer paths
//!   keys identically.
//! - [`cache`] — a bounded LRU keyed by fingerprint: a repeat graph is
//!   answered without touching the policy at all.
//! - [`protocol`] — the line-delimited JSON wire format (`place`,
//!   `stats`, `ctrl` requests) spoken over TCP.
//! - [`server`] — the `hsdag serve` daemon: a worker pool over a TCP
//!   listener, per-request latency budgets with baseline fallback, live
//!   metrics and graceful shutdown.
//! - [`client`] — the `hsdag request` plumbing (one line in, one line
//!   out), shared by the CLI, the serving example, the loadgen bench and
//!   the loopback tests.

pub mod cache;
pub mod checkpoint;
pub mod client;
pub mod fingerprint;
pub mod protocol;
pub mod server;

pub use cache::LruCache;
pub use checkpoint::{Checkpoint, CheckpointMeta};
pub use fingerprint::{fingerprint, fingerprint_delta, fingerprint_hex, FingerprintState};
pub use protocol::{PlaceOutcome, Provenance, Request, StatsView};
pub use server::{PlacementService, ServeOptions, Server, ServerHandle};

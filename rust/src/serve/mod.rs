//! The placement service subsystem: everything between a *trained*
//! policy and a *deployed* one.
//!
//! The paper's framework ends at training — the learned HSDAG policy
//! lives and dies with its process. This layer makes the policy a
//! persistent, reusable artifact (GDP / Placeto's "train once, place
//! many" regime) and puts it behind a long-lived daemon:
//!
//! - [`checkpoint`] — the `hsdag-params-v1` on-disk format: the full
//!   `ParamStore` (params + Adam state) plus deployment metadata, with
//!   layout validation on load. Written by `train --save` /
//!   `generalize --save`, consumed by every `--load` path.
//! - [`fingerprint`] — deterministic structural hashes over graph
//!   topology, op identity, shapes and the testbed id; node *names* are
//!   excluded, so the same model re-traced under different layer paths
//!   keys identically.
//! - [`cache`] — a bounded LRU keyed by fingerprint: a repeat graph is
//!   answered without touching the policy at all.
//! - [`protocol`] — the line-delimited JSON wire format (`place`,
//!   `stats`, `ctrl` requests) spoken over TCP.
//! - [`server`] — the `hsdag serve` daemon: a worker pool over a TCP
//!   listener with bounded admission (explicit `busy` shed past the
//!   high-water mark), per-request latency budgets with baseline
//!   fallback, RCU-style zero-downtime checkpoint reload
//!   (`ctrl: reload` / SIGHUP), live metrics and graceful shutdown.
//! - [`router`] — the fleet tier: `hsdag route` consistent-hashes
//!   requests by fingerprint across N shard daemons (rendezvous
//!   hashing, [`shard_for`]) so the shards' caches partition the
//!   keyspace instead of duplicating it.
//! - [`client`] — the `hsdag request` plumbing (one line in, one line
//!   out, optional bounded retry with backoff + jitter), shared by the
//!   CLI, the serving example, the loadgen bench and the loopback
//!   tests.

pub mod cache;
pub mod checkpoint;
pub mod client;
pub mod fingerprint;
pub mod protocol;
pub mod router;
pub mod server;

pub use cache::LruCache;
pub use checkpoint::{Checkpoint, CheckpointMeta};
pub use fingerprint::{fingerprint, fingerprint_delta, fingerprint_hex, FingerprintState};
pub use protocol::{PlaceOutcome, Provenance, Request, StatsView};
pub use router::{discover_testbed, shard_for, Router};
pub use server::{
    sighup_flag, LineHandler, PlacementService, ServeOptions, Server, ServerHandle,
    DEFAULT_QUEUE_DEPTH,
};

//! Wire protocol of the placement server: one JSON document per line,
//! both directions, over TCP.
//!
//! Requests (`op` selects the handler):
//!
//! ```json
//! {"op": "place", "workload": "resnet"}
//! {"op": "place", "graph": {"format": "hsdag-graph-v1", ...},
//!  "id": 7, "budget_ms": 5.0, "rollouts": 8, "no_cache": true,
//!  "tenant": "team-a", "trace": "7c9e1f20aa314d56"}
//! {"op": "stats"}
//! {"op": "metrics"}
//! {"op": "ctrl", "action": "shutdown"}
//! {"op": "ctrl", "action": "reload", "checkpoint": "/path/new.ckpt.json"}
//! {"op": "ctrl", "action": "clear-cache"}
//! ```
//!
//! A `place` request names its graph exactly one way: `workload` (a
//! registry spec resolved server-side, see [`crate::models::Workload`])
//! or `graph` (an inline `hsdag-graph-v1` document). Optional fields:
//! `id` (any JSON value, echoed verbatim into the response), `budget_ms`
//! (per-request policy-inference budget overriding the server default),
//! `rollouts` (stochastic policy rollouts on top of the greedy one),
//! `no_cache` (bypass the placement cache in both directions),
//! `fast_math` (run the policy with the opt-in lane kernels; such
//! answers never touch the cache), `tenant` (a caller label counted
//! per tenant in `stats`) and `trace` (a request-trace id, minted by
//! the client or the router and echoed in the response; a shard with
//! `--trace-log` writes a `hsdag-trace-v1` span line under this id —
//! see [`crate::obs::trace`]).
//!
//! `metrics` dumps the process-wide [`crate::obs::metrics`] registry
//! (counters, gauges, log-bucketed histograms) as a `hsdag-metrics-v1`
//! document wrapped in the usual `ok`/`op` envelope.
//!
//! `ctrl: reload` hot-swaps the served checkpoint with zero downtime
//! (`checkpoint` optional — it defaults to the path the daemon was
//! started with); `ctrl: clear-cache` drops every cached placement
//! (operationally: after a reload that kept the cache by mistake). A
//! shard at capacity sheds load with a fast, recognizable
//! `{"ok": false, "busy": true, ...}` line instead of queueing
//! unboundedly — see [`render_busy_response`].
//!
//! Responses always carry `ok`; placements report the structural
//! fingerprint, the placement (device id per original graph node), the
//! device names, predicted/reference latency, the speedup vs the
//! testbed's reference device, feasibility, the `provenance` of the
//! served placement (`policy`, `cache`, or `fallback:<name>` — see the
//! server docs for the semantics) and the service time:
//!
//! ```json
//! {"ok": true, "op": "place", "id": 7, "fingerprint": "91b0c3...",
//!  "provenance": "policy", "feasible": true, "latency_s": 0.0123,
//!  "ref_latency_s": 0.0456, "speedup_pct": 73.0,
//!  "placement": [0, 1, 1], "devices": ["Xeon-8358 CPU", "A5000 dGPU"],
//!  "service_ms": 2.31}
//! {"ok": false, "error": "unknown workload 'warehouse'"}
//! ```

use anyhow::{anyhow, bail, Result};

use crate::graph::{json as graph_json, CompGraph};
use crate::util::json::Json;

/// A parsed request line.
pub enum Request {
    Place(PlaceRequest),
    Stats,
    /// Dump the process-wide metrics registry.
    Metrics,
    Shutdown,
    /// Hot-reload the served checkpoint (optional explicit path; `None`
    /// re-reads the path the daemon was started with).
    Reload(Option<String>),
    /// Drop every cached placement.
    ClearCache,
}

/// The graph a `place` request wants placed.
pub enum PlaceSource {
    /// Registry spec, resolved server-side.
    Spec(String),
    /// Inline `hsdag-graph-v1` graph (already parsed and validated).
    Inline(CompGraph),
}

pub struct PlaceRequest {
    pub source: PlaceSource,
    /// Echoed verbatim into the response.
    pub id: Option<Json>,
    pub budget_ms: Option<f64>,
    pub rollouts: Option<usize>,
    pub no_cache: bool,
    /// Run the policy with the opt-in fast-math lane kernels
    /// (tolerance-equal, not bit-equal, to the default kernels).
    /// Fast-math answers never enter or leave the placement cache.
    pub fast_math: bool,
    /// Caller label for the per-tenant request counters in `stats`.
    pub tenant: Option<String>,
    /// Request-trace id (client- or router-minted), echoed in the
    /// response and stamped onto `hsdag-trace-v1` span lines. Purely
    /// observational: it never influences placement or caching.
    pub trace: Option<String>,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let doc = Json::parse(line.trim()).map_err(|e| anyhow!("invalid request JSON: {e}"))?;
    let op = doc
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing string \"op\" (place | stats | metrics | ctrl)"))?;
    match op {
        "stats" => Ok(Request::Stats),
        "metrics" => Ok(Request::Metrics),
        "ctrl" => match doc.get("action").and_then(Json::as_str) {
            Some("shutdown") => Ok(Request::Shutdown),
            Some("reload") => {
                let ckpt = match doc.get("checkpoint") {
                    None => None,
                    Some(v) => Some(
                        v.as_str()
                            .ok_or_else(|| anyhow!("\"checkpoint\" must be a string path"))?
                            .to_string(),
                    ),
                };
                Ok(Request::Reload(ckpt))
            }
            Some("clear-cache") => Ok(Request::ClearCache),
            Some(other) => {
                bail!("unknown ctrl action '{other}' (known: shutdown | reload | clear-cache)")
            }
            None => bail!("ctrl request needs a string \"action\""),
        },
        "place" => {
            let spec = doc.get("workload").and_then(Json::as_str);
            let inline = doc.get("graph");
            let source = match (spec, inline) {
                (Some(s), None) => PlaceSource::Spec(s.to_string()),
                (None, Some(v)) => PlaceSource::Inline(
                    graph_json::from_value(v).map_err(|e| anyhow!("inline graph: {e:#}"))?,
                ),
                (Some(_), Some(_)) => bail!("give \"workload\" or \"graph\", not both"),
                (None, None) => bail!("place request needs \"workload\" or \"graph\""),
            };
            let budget_ms = match doc.get("budget_ms") {
                None => None,
                Some(v) => Some(
                    v.as_f64()
                        .filter(|b| b.is_finite() && *b >= 0.0)
                        .ok_or_else(|| anyhow!("\"budget_ms\" must be a non-negative number"))?,
                ),
            };
            let rollouts = match doc.get("rollouts") {
                None => None,
                Some(v) => Some(
                    v.as_usize().ok_or_else(|| anyhow!("\"rollouts\" must be an integer"))?,
                ),
            };
            let no_cache = match doc.get("no_cache") {
                None => false,
                Some(v) => {
                    v.as_bool().ok_or_else(|| anyhow!("\"no_cache\" must be a boolean"))?
                }
            };
            let fast_math = match doc.get("fast_math") {
                None => false,
                Some(v) => {
                    v.as_bool().ok_or_else(|| anyhow!("\"fast_math\" must be a boolean"))?
                }
            };
            let tenant = match doc.get("tenant") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("\"tenant\" must be a string"))?
                        .to_string(),
                ),
            };
            let trace = match doc.get("trace") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("\"trace\" must be a string id"))?
                        .to_string(),
                ),
            };
            Ok(Request::Place(PlaceRequest {
                source,
                id: doc.get("id").cloned(),
                budget_ms,
                rollouts,
                no_cache,
                fast_math,
                tenant,
                trace,
            }))
        }
        other => bail!("unknown op '{other}' (known: place | stats | metrics | ctrl)"),
    }
}

// ---------------------------------------------------------------------------
// Request builders (the `hsdag request` client and the tests use these so
// every writer emits the exact grammar `parse_request` accepts).
// ---------------------------------------------------------------------------

/// Render a `place` request line for a registry spec or an inline graph.
pub fn render_place_request(
    workload: Option<&str>,
    graph: Option<&CompGraph>,
    id: Option<&Json>,
    budget_ms: Option<f64>,
    rollouts: Option<usize>,
    no_cache: bool,
) -> String {
    render_place_request_for(workload, graph, id, budget_ms, rollouts, no_cache, false, None)
}

/// [`render_place_request`] with the opt-in knobs: `fast_math` (lane
/// kernels, uncached) and a tenant label for the per-tenant request
/// counters.
#[allow(clippy::too_many_arguments)]
pub fn render_place_request_for(
    workload: Option<&str>,
    graph: Option<&CompGraph>,
    id: Option<&Json>,
    budget_ms: Option<f64>,
    rollouts: Option<usize>,
    no_cache: bool,
    fast_math: bool,
    tenant: Option<&str>,
) -> String {
    let mut fields = vec![("op".to_string(), Json::Str("place".to_string()))];
    if let Some(v) = id {
        fields.push(("id".to_string(), v.clone()));
    }
    if let Some(s) = workload {
        fields.push(("workload".to_string(), Json::Str(s.to_string())));
    }
    if let Some(g) = graph {
        fields.push(("graph".to_string(), graph_json::to_value(g)));
    }
    if let Some(b) = budget_ms {
        fields.push(("budget_ms".to_string(), Json::Num(b)));
    }
    if let Some(r) = rollouts {
        fields.push(("rollouts".to_string(), Json::Num(r as f64)));
    }
    if no_cache {
        fields.push(("no_cache".to_string(), Json::Bool(true)));
    }
    if fast_math {
        fields.push(("fast_math".to_string(), Json::Bool(true)));
    }
    if let Some(t) = tenant {
        fields.push(("tenant".to_string(), Json::Str(t.to_string())));
    }
    Json::Obj(fields).to_string_compact()
}

/// Return `line` with its `trace` field set to `id` (replacing any
/// existing one). The router uses this to mint-and-propagate trace ids
/// without re-rendering the request from its parsed form — every other
/// field passes through byte-for-byte.
pub fn with_trace_id(line: &str, id: &str) -> Result<String> {
    match Json::parse(line.trim()).map_err(|e| anyhow!("invalid request JSON: {e}"))? {
        Json::Obj(mut fields) => {
            fields.retain(|(k, _)| k != "trace");
            fields.push(("trace".to_string(), Json::Str(id.to_string())));
            Ok(Json::Obj(fields).to_string_compact())
        }
        _ => bail!("request line is not a JSON object"),
    }
}

pub fn render_stats_request() -> String {
    Json::Obj(vec![("op".to_string(), Json::Str("stats".to_string()))]).to_string_compact()
}

/// Render a `metrics` request line (dump the registry).
pub fn render_metrics_request() -> String {
    Json::Obj(vec![("op".to_string(), Json::Str("metrics".to_string()))]).to_string_compact()
}

pub fn render_shutdown_request() -> String {
    Json::Obj(vec![
        ("op".to_string(), Json::Str("ctrl".to_string())),
        ("action".to_string(), Json::Str("shutdown".to_string())),
    ])
    .to_string_compact()
}

/// Render a `ctrl: reload` request (`checkpoint` optional — the daemon
/// falls back to the path it was started with).
pub fn render_reload_request(checkpoint: Option<&str>) -> String {
    let mut fields = vec![
        ("op".to_string(), Json::Str("ctrl".to_string())),
        ("action".to_string(), Json::Str("reload".to_string())),
    ];
    if let Some(p) = checkpoint {
        fields.push(("checkpoint".to_string(), Json::Str(p.to_string())));
    }
    Json::Obj(fields).to_string_compact()
}

pub fn render_clear_cache_request() -> String {
    Json::Obj(vec![
        ("op".to_string(), Json::Str("ctrl".to_string())),
        ("action".to_string(), Json::Str("clear-cache".to_string())),
    ])
    .to_string_compact()
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// Where a served placement came from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Provenance {
    /// Fresh policy inference won the candidate comparison.
    Policy,
    /// Answered from the LRU placement cache, no inference run.
    Cache,
    /// A non-learned candidate was served: the latency budget was
    /// exhausted, no policy rollout was feasible, or a baseline beat
    /// every rollout. The string names the winner (`memory-greedy`,
    /// `single:<device>`).
    Fallback(String),
}

impl Provenance {
    pub fn label(&self) -> String {
        match self {
            Provenance::Policy => "policy".to_string(),
            Provenance::Cache => "cache".to_string(),
            Provenance::Fallback(name) => format!("fallback:{name}"),
        }
    }
}

/// One served placement, ready to render.
#[derive(Debug, Clone)]
pub struct PlaceOutcome {
    /// Structural fingerprint (hex) — the cache key.
    pub fingerprint: String,
    /// Device id per original graph node.
    pub placement: Vec<usize>,
    /// Testbed device names, indexed by device id.
    pub devices: Vec<String>,
    /// Predicted (simulated, deterministic) latency of the placement.
    pub latency_s: f64,
    /// Latency of the testbed's reference device (speedup denominator).
    pub ref_latency_s: f64,
    pub feasible: bool,
    pub provenance: Provenance,
}

impl PlaceOutcome {
    pub fn speedup_pct(&self) -> f64 {
        100.0 * (1.0 - self.latency_s / self.ref_latency_s)
    }
}

/// Render a `place` response line. `trace` echoes the request's trace
/// id (present exactly when the request was traced) so callers can
/// correlate responses with `hsdag-trace-v1` span lines.
pub fn render_place_response(
    id: Option<&Json>,
    o: &PlaceOutcome,
    service_ms: f64,
    trace: Option<&str>,
) -> String {
    let mut fields = vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("place".to_string())),
    ];
    if let Some(v) = id {
        fields.push(("id".to_string(), v.clone()));
    }
    if let Some(t) = trace {
        fields.push(("trace".to_string(), Json::Str(t.to_string())));
    }
    fields.extend([
        ("fingerprint".to_string(), Json::Str(o.fingerprint.clone())),
        ("provenance".to_string(), Json::Str(o.provenance.label())),
        ("feasible".to_string(), Json::Bool(o.feasible)),
        ("latency_s".to_string(), Json::Num(o.latency_s)),
        ("ref_latency_s".to_string(), Json::Num(o.ref_latency_s)),
        ("speedup_pct".to_string(), Json::Num(o.speedup_pct())),
        (
            "placement".to_string(),
            Json::Arr(o.placement.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
        (
            "devices".to_string(),
            Json::Arr(o.devices.iter().map(|d| Json::Str(d.clone())).collect()),
        ),
        ("service_ms".to_string(), Json::Num(service_ms)),
    ]);
    Json::Obj(fields).to_string_compact()
}

/// Live service metrics, as reported by a `stats` response.
#[derive(Debug, Clone)]
pub struct StatsView {
    pub uptime_s: f64,
    pub requests: u64,
    pub placements: u64,
    pub cache_hits: u64,
    pub fallbacks: u64,
    pub errors: u64,
    /// Fresh trivial-candidate evaluation passes (single-device +
    /// memory-greedy); repeats for a known fingerprint reuse the cached
    /// evaluations instead.
    pub trivial_evals: u64,
    pub cache_len: usize,
    pub cache_capacity: usize,
    pub qps: f64,
    pub cache_hit_rate: f64,
    /// Service-time quantiles, estimated from the log-bucketed
    /// histogram (microsecond buckets — no sample window is kept or
    /// sorted; see `obs::metrics::LogHist`).
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// The service-time histogram itself: non-empty `(lo_us, hi_us,
    /// count)` buckets, inclusive bounds.
    pub service_hist: Vec<(u64, u64, u64)>,
    /// Per-stage latency breakdown of the place pipeline (queue wait,
    /// cache lookup, policy rollouts, trivial simulation, selection).
    pub stages: Vec<StageStat>,
    /// Testbed id the shard serves (routers and sharded clients discover
    /// it here so their fingerprints agree with the shard's).
    pub testbed: String,
    /// Monotonic generation of the live checkpoint: 0 at startup, +1 per
    /// successful `ctrl: reload` / SIGHUP swap.
    pub checkpoint_generation: u64,
    /// What the *live* checkpoint says it was trained on (tracks
    /// reloads truthfully).
    pub trained_on: String,
    /// Successful hot reloads since startup.
    pub reloads: u64,
    /// Connections shed with a `busy` response past the admission
    /// high-water mark.
    pub busy_rejects: u64,
    /// Per-tenant `place` request counts (requests carrying a `tenant`
    /// label), sorted by tenant name.
    pub tenants: Vec<(String, u64)>,
}

/// One pipeline stage's latency aggregate in a `stats` response.
#[derive(Debug, Clone)]
pub struct StageStat {
    pub name: &'static str,
    pub count: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Render the `metrics` response: the whole `hsdag-metrics-v1` registry
/// dump wrapped in the protocol's `ok`/`op` envelope.
pub fn render_metrics_response() -> String {
    let mut fields = vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("metrics".to_string())),
    ];
    if let Json::Obj(body) = crate::obs::metrics::registry_json() {
        fields.extend(body);
    }
    Json::Obj(fields).to_string_compact()
}

pub fn render_stats_response(s: &StatsView) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("stats".to_string())),
        ("uptime_s".to_string(), Json::Num(s.uptime_s)),
        ("requests".to_string(), Json::Num(s.requests as f64)),
        ("placements".to_string(), Json::Num(s.placements as f64)),
        ("cache_hits".to_string(), Json::Num(s.cache_hits as f64)),
        ("fallbacks".to_string(), Json::Num(s.fallbacks as f64)),
        ("errors".to_string(), Json::Num(s.errors as f64)),
        ("trivial_evals".to_string(), Json::Num(s.trivial_evals as f64)),
        ("cache_len".to_string(), Json::Num(s.cache_len as f64)),
        ("cache_capacity".to_string(), Json::Num(s.cache_capacity as f64)),
        ("qps".to_string(), Json::Num(s.qps)),
        ("cache_hit_rate".to_string(), Json::Num(s.cache_hit_rate)),
        ("p50_ms".to_string(), Json::Num(s.p50_ms)),
        ("p99_ms".to_string(), Json::Num(s.p99_ms)),
        (
            "service_us_hist".to_string(),
            Json::Arr(
                s.service_hist
                    .iter()
                    .map(|&(lo, hi, c)| {
                        Json::Arr(vec![
                            Json::Num(lo as f64),
                            Json::Num(hi.min(1 << 62) as f64),
                            Json::Num(c as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "stages".to_string(),
            Json::Obj(
                s.stages
                    .iter()
                    .map(|st| {
                        (
                            st.name.to_string(),
                            Json::Obj(vec![
                                ("count".to_string(), Json::Num(st.count as f64)),
                                ("p50_ms".to_string(), Json::Num(st.p50_ms)),
                                ("p99_ms".to_string(), Json::Num(st.p99_ms)),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        ("testbed".to_string(), Json::Str(s.testbed.clone())),
        (
            "checkpoint_generation".to_string(),
            Json::Num(s.checkpoint_generation as f64),
        ),
        ("trained_on".to_string(), Json::Str(s.trained_on.clone())),
        ("reloads".to_string(), Json::Num(s.reloads as f64)),
        ("busy_rejects".to_string(), Json::Num(s.busy_rejects as f64)),
        (
            "tenants".to_string(),
            Json::Obj(
                s.tenants
                    .iter()
                    .map(|(name, count)| (name.clone(), Json::Num(*count as f64)))
                    .collect(),
            ),
        ),
    ])
    .to_string_compact()
}

/// Render the acknowledgment of a successful `ctrl: reload`: the new
/// generation, whether the placement cache survived the swap, and what
/// the new checkpoint was trained on.
pub fn render_reload_response(generation: u64, cache_kept: bool, trained_on: &str) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("ctrl".to_string())),
        ("action".to_string(), Json::Str("reload".to_string())),
        ("generation".to_string(), Json::Num(generation as f64)),
        ("cache_kept".to_string(), Json::Bool(cache_kept)),
        ("trained_on".to_string(), Json::Str(trained_on.to_string())),
    ])
    .to_string_compact()
}

/// Render the shed-load response a shard writes past its admission
/// high-water mark. The `busy` marker distinguishes explicit
/// backpressure (retryable) from request errors (not retryable).
pub fn render_busy_response(pending: usize) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(false)),
        ("busy".to_string(), Json::Bool(true)),
        (
            "error".to_string(),
            Json::Str(format!(
                "busy: shard at capacity ({pending} pending connections); retry with backoff"
            )),
        ),
    ])
    .to_string_compact()
}

/// Does a response line report explicit shed load (`busy: true`)?
pub fn is_busy_response(line: &str) -> bool {
    Json::parse(line.trim())
        .ok()
        .and_then(|doc| doc.get("busy").and_then(Json::as_bool))
        .unwrap_or(false)
}

/// Render the acknowledgment of a `ctrl` request.
pub fn render_ctrl_response(action: &str) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(true)),
        ("op".to_string(), Json::Str("ctrl".to_string())),
        ("action".to_string(), Json::Str(action.to_string())),
    ])
    .to_string_compact()
}

/// Render an error response line.
pub fn render_error_response(id: Option<&Json>, message: &str) -> String {
    let mut fields = vec![("ok".to_string(), Json::Bool(false))];
    if let Some(v) = id {
        fields.push(("id".to_string(), v.clone()));
    }
    fields.push(("error".to_string(), Json::Str(message.to_string())));
    Json::Obj(fields).to_string_compact()
}

/// Parse a response line, erroring when the server reported a failure
/// (the `hsdag request` client's exit-status contract).
pub fn parse_response(line: &str) -> Result<Json> {
    let doc = Json::parse(line.trim()).map_err(|e| anyhow!("invalid response JSON: {e}"))?;
    match doc.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(doc),
        Some(false) => bail!(
            "server error: {}",
            doc.get("error").and_then(Json::as_str).unwrap_or("(no message)")
        ),
        None => bail!("malformed response (no \"ok\" field): {line}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Workload;

    #[test]
    fn place_request_roundtrip_spec_and_inline() {
        let line = render_place_request(Some("seq:8"), None, None, None, None, false);
        match parse_request(&line).unwrap() {
            Request::Place(p) => {
                assert!(matches!(p.source, PlaceSource::Spec(ref s) if s == "seq:8"));
                assert!(p.id.is_none() && p.budget_ms.is_none() && !p.no_cache);
            }
            _ => panic!("wrong op"),
        }
        let g = Workload::resolve("layered:3x2:1").unwrap().graph;
        let id = Json::Num(7.0);
        let line = render_place_request_for(
            None,
            Some(&g),
            Some(&id),
            Some(2.5),
            Some(8),
            true,
            true,
            Some("team-a"),
        );
        match parse_request(&line).unwrap() {
            Request::Place(p) => {
                match p.source {
                    PlaceSource::Inline(h) => {
                        assert_eq!(h.n(), g.n());
                        assert_eq!(h.edges, g.edges);
                    }
                    PlaceSource::Spec(_) => panic!("expected inline graph"),
                }
                assert_eq!(p.id, Some(Json::Num(7.0)));
                assert_eq!(p.budget_ms, Some(2.5));
                assert_eq!(p.rollouts, Some(8));
                assert!(p.no_cache);
                assert!(p.fast_math);
                assert_eq!(p.tenant.as_deref(), Some("team-a"));
            }
            _ => panic!("wrong op"),
        }
        // fast_math defaults off and rejects non-boolean values.
        let plain = render_place_request(Some("seq:8"), None, None, None, None, false);
        match parse_request(&plain).unwrap() {
            Request::Place(p) => assert!(!p.fast_math),
            _ => panic!("wrong op"),
        }
    }

    #[test]
    fn stats_and_shutdown_roundtrip() {
        assert!(matches!(parse_request(&render_stats_request()).unwrap(), Request::Stats));
        assert!(matches!(parse_request(&render_shutdown_request()).unwrap(), Request::Shutdown));
        assert!(matches!(parse_request(&render_metrics_request()).unwrap(), Request::Metrics));
    }

    #[test]
    fn trace_id_parses_and_injects() {
        // A trace id parses out of a place request...
        let line = r#"{"op": "place", "workload": "seq:8", "trace": "abc123"}"#;
        match parse_request(line).unwrap() {
            Request::Place(p) => assert_eq!(p.trace.as_deref(), Some("abc123")),
            _ => panic!("wrong op"),
        }
        // ...defaults to None...
        let plain = render_place_request(Some("seq:8"), None, None, None, None, false);
        match parse_request(&plain).unwrap() {
            Request::Place(p) => assert!(p.trace.is_none()),
            _ => panic!("wrong op"),
        }
        // ...and a non-string id is a parse error.
        let err = parse_request(r#"{"op": "place", "workload": "a", "trace": 7}"#).unwrap_err();
        assert!(format!("{err:#}").contains("trace"), "{err:#}");
        // Injection adds the field without disturbing the others, and
        // replaces an existing id rather than duplicating the key.
        let traced = with_trace_id(&plain, "deadbeef01234567").unwrap();
        match parse_request(&traced).unwrap() {
            Request::Place(p) => {
                assert_eq!(p.trace.as_deref(), Some("deadbeef01234567"));
                assert!(matches!(p.source, PlaceSource::Spec(ref s) if s == "seq:8"));
            }
            _ => panic!("wrong op"),
        }
        let retraced = with_trace_id(&traced, "ffff").unwrap();
        match parse_request(&retraced).unwrap() {
            Request::Place(p) => assert_eq!(p.trace.as_deref(), Some("ffff")),
            _ => panic!("wrong op"),
        }
        assert!(with_trace_id("[1,2]", "x").is_err());
    }

    #[test]
    fn metrics_response_is_valid_document() {
        crate::obs::metrics::counter("test.protocol.metric").inc();
        let line = render_metrics_response();
        let doc = parse_response(&line).unwrap();
        assert_eq!(doc.get("op").and_then(Json::as_str), Some("metrics"));
        assert_eq!(doc.get("format").and_then(Json::as_str), Some("hsdag-metrics-v1"));
        assert!(matches!(doc.get("counters"), Some(Json::Obj(_))));
        assert!(matches!(doc.get("histograms"), Some(Json::Obj(_))));
    }

    #[test]
    fn reload_and_clear_cache_roundtrip() {
        // Reload with the daemon's default checkpoint path...
        match parse_request(&render_reload_request(None)).unwrap() {
            Request::Reload(None) => {}
            _ => panic!("wrong op"),
        }
        // ...and with an explicit one.
        match parse_request(&render_reload_request(Some("/tmp/new.ckpt.json"))).unwrap() {
            Request::Reload(Some(p)) => assert_eq!(p, "/tmp/new.ckpt.json"),
            _ => panic!("wrong op"),
        }
        assert!(matches!(
            parse_request(&render_clear_cache_request()).unwrap(),
            Request::ClearCache
        ));
        // A non-string checkpoint is a parse error, not a silent default.
        let err = parse_request(r#"{"op": "ctrl", "action": "reload", "checkpoint": 3}"#)
            .unwrap_err();
        assert!(format!("{err:#}").contains("checkpoint"), "{err:#}");
    }

    #[test]
    fn reload_response_reports_generation_and_cache_policy() {
        let line = render_reload_response(3, true, "generalize:seq:48");
        let doc = parse_response(&line).unwrap();
        assert_eq!(doc.get("action").unwrap().as_str(), Some("reload"));
        assert_eq!(doc.get("generation").unwrap().as_usize(), Some(3));
        assert_eq!(doc.get("cache_kept").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("trained_on").unwrap().as_str(), Some("generalize:seq:48"));
    }

    #[test]
    fn busy_responses_are_errors_and_recognizable() {
        let line = render_busy_response(64);
        // An error for the exit-status contract...
        let msg = format!("{:#}", parse_response(&line).unwrap_err());
        assert!(msg.contains("busy"), "{msg}");
        // ...but distinguishable from request errors, so clients know the
        // load was shed (retryable) rather than the request being wrong.
        assert!(is_busy_response(&line));
        assert!(!is_busy_response(&render_error_response(None, "unknown workload")));
        assert!(!is_busy_response("not json"));
    }

    #[test]
    fn malformed_requests_error_with_a_message() {
        for (line, needle) in [
            ("not json", "invalid request"),
            (r#"{"op": "fly"}"#, "unknown op"),
            (r#"{"workload": "seq:8"}"#, "missing string \"op\""),
            (r#"{"op": "place"}"#, "needs \"workload\" or \"graph\""),
            (r#"{"op": "place", "workload": "a", "graph": {}}"#, "not both"),
            (r#"{"op": "place", "graph": {"format": "wrong"}}"#, "inline graph"),
            (r#"{"op": "place", "workload": "a", "budget_ms": -1}"#, "budget_ms"),
            (r#"{"op": "place", "workload": "a", "no_cache": 1}"#, "no_cache"),
            (r#"{"op": "place", "workload": "a", "fast_math": 1}"#, "fast_math"),
            (r#"{"op": "place", "workload": "a", "tenant": 7}"#, "tenant"),
            (r#"{"op": "ctrl", "action": "reboot"}"#, "unknown ctrl action"),
            (r#"{"op": "ctrl"}"#, "needs a string"),
        ] {
            let err = parse_request(line).expect_err(line);
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{line}: {msg}");
        }
    }

    #[test]
    fn responses_render_and_parse() {
        let o = PlaceOutcome {
            fingerprint: "00ff00ff00ff00ff".to_string(),
            placement: vec![0, 1, 1],
            devices: vec!["CPU".to_string(), "GPU".to_string()],
            latency_s: 0.01,
            ref_latency_s: 0.04,
            feasible: true,
            provenance: Provenance::Cache,
        };
        let id = Json::Str("req-1".to_string());
        let line = render_place_response(Some(&id), &o, 1.5, None);
        let doc = parse_response(&line).unwrap();
        assert_eq!(doc.get("provenance").unwrap().as_str(), Some("cache"));
        assert_eq!(doc.get("id").unwrap().as_str(), Some("req-1"));
        assert!(doc.get("trace").is_none());
        // A traced request's id is echoed back.
        let traced = render_place_response(None, &o, 1.5, Some("abc123"));
        let doc = parse_response(&traced).unwrap();
        assert_eq!(doc.get("trace").unwrap().as_str(), Some("abc123"));
        assert_eq!(doc.get("latency_s").unwrap().as_f64(), Some(0.01));
        assert!((doc.get("speedup_pct").unwrap().as_f64().unwrap() - 75.0).abs() < 1e-9);
        assert_eq!(doc.get("placement").unwrap().as_arr().unwrap().len(), 3);
        // Error responses fail parse_response with the server's message.
        let err_line = render_error_response(None, "boom");
        let msg = format!("{:#}", parse_response(&err_line).unwrap_err());
        assert!(msg.contains("boom"), "{msg}");
        // Provenance labels.
        assert_eq!(Provenance::Policy.label(), "policy");
        assert_eq!(Provenance::Fallback("memory-greedy".to_string()).label(), "fallback:memory-greedy");
    }
}

//! On-disk policy checkpoints: the `hsdag-params-v1` JSON format.
//!
//! A checkpoint is the full learning state of one HSDAG policy — every
//! parameter tensor plus its Adam moments and the step counter — together
//! with the metadata needed to refuse a mismatched deployment *before*
//! any tensor math runs: the hidden size, the feature width, the
//! action-space width and the testbed id the policy was trained against.
//! The layout is graph-independent (see [`crate::rl::PolicyBackend`]),
//! so a checkpoint trained on one workload serves placements for any
//! graph on a layout-compatible testbed.
//!
//! ```json
//! {
//!   "format": "hsdag-params-v1",
//!   "hidden": 128, "feature_dim": 69, "actions": 2,
//!   "testbed": "cpu_gpu", "workload": "resnet50",
//!   "best_latency": 0.01234,
//!   "step": 40,
//!   "tensors": [
//!     {"name": "trans_w0", "dims": [69, 128],
//!      "data": [...], "m": [...], "v": [...]},
//!     ...
//!   ]
//! }
//! ```
//!
//! Serialization goes through the hand-rolled [`crate::util::json`]
//! layer (no serde offline). Scalars are written with rust's
//! shortest-round-trip float formatting: every f32 survives the
//! f32 → f64 → text → f64 → f32 trip bit-identically, which the
//! `tests/serve.rs` round-trip test pins. Loading validates the format
//! tag, the per-tensor dims/data/moment alignment (via
//! [`ParamStore::from_parts`]) and the metadata's consistency with the
//! tensors themselves, and every failure is a located error message —
//! a truncated or hand-mangled checkpoint never panics the loader.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{ParamStore, Tensor};
use crate::util::json::Json;

/// Format tag written into (and required from) every checkpoint.
pub const FORMAT_TAG: &str = "hsdag-params-v1";

/// Deployment metadata stored next to the tensors.
#[derive(Debug, Clone)]
pub struct CheckpointMeta {
    /// Policy hidden width (loaders adopt it — `hidden` is not a CLI
    /// flag, the checkpoint is the source of truth).
    pub hidden: usize,
    /// Node-feature width the first transform layer was built for.
    pub feature_dim: usize,
    /// Action-space width of the placer head (testbed placement targets).
    pub actions: usize,
    /// Testbed registry id the policy was trained on.
    pub testbed: String,
    /// Workload spec(s) the policy was trained on (informational).
    pub workload: String,
    /// Best deterministic latency observed during training, if tracked.
    pub best_latency: Option<f64>,
}

/// A loaded (or about-to-be-saved) checkpoint.
#[derive(Clone)]
pub struct Checkpoint {
    pub meta: CheckpointMeta,
    pub store: ParamStore,
}

impl Checkpoint {
    pub fn new(store: ParamStore, meta: CheckpointMeta) -> Checkpoint {
        Checkpoint { meta, store }
    }

    /// Render the v1 JSON document (pretty: one scalar array per line,
    /// so checkpoints diff sanely under version control).
    pub fn to_json(&self) -> String {
        let f32s = |xs: &[f32]| Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect());
        let tensors: Vec<Json> = (0..self.store.n())
            .map(|i| {
                Json::Obj(vec![
                    ("name".to_string(), Json::Str(self.store.names[i].clone())),
                    (
                        "dims".to_string(),
                        Json::Arr(
                            self.store.params[i].dims().iter().map(|&d| Json::Num(d as f64)).collect(),
                        ),
                    ),
                    ("data".to_string(), f32s(self.store.params[i].as_f32())),
                    ("m".to_string(), f32s(self.store.m[i].as_f32())),
                    ("v".to_string(), f32s(self.store.v[i].as_f32())),
                ])
            })
            .collect();
        let mut fields = vec![
            ("format".to_string(), Json::Str(FORMAT_TAG.to_string())),
            ("hidden".to_string(), Json::Num(self.meta.hidden as f64)),
            ("feature_dim".to_string(), Json::Num(self.meta.feature_dim as f64)),
            ("actions".to_string(), Json::Num(self.meta.actions as f64)),
            ("testbed".to_string(), Json::Str(self.meta.testbed.clone())),
            ("workload".to_string(), Json::Str(self.meta.workload.clone())),
        ];
        if let Some(l) = self.meta.best_latency {
            fields.push(("best_latency".to_string(), Json::Num(l)));
        }
        fields.push(("step".to_string(), Json::Num(self.store.step as f64)));
        fields.push(("tensors".to_string(), Json::Arr(tensors)));
        Json::Obj(fields).to_string_pretty()
    }

    /// Parse and validate a v1 document.
    pub fn parse(text: &str) -> Result<Checkpoint> {
        let doc = Json::parse(text).map_err(|e| anyhow!("invalid checkpoint JSON: {e}"))?;
        match doc.get("format").and_then(Json::as_str) {
            Some(FORMAT_TAG) => {}
            Some(other) => bail!("unsupported checkpoint format '{other}' (want '{FORMAT_TAG}')"),
            None => bail!("missing \"format\" field (want '{FORMAT_TAG}')"),
        }
        let field_usize = |key: &str| -> Result<usize> {
            doc.get(key)
                .and_then(Json::as_usize)
                .filter(|&x| x >= 1)
                .ok_or_else(|| anyhow!("missing or invalid \"{key}\" (want a positive integer)"))
        };
        let meta = CheckpointMeta {
            hidden: field_usize("hidden")?,
            feature_dim: field_usize("feature_dim")?,
            actions: field_usize("actions")?,
            testbed: doc
                .get("testbed")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing string \"testbed\""))?
                .to_string(),
            workload: doc.get("workload").and_then(Json::as_str).unwrap_or("?").to_string(),
            best_latency: doc.get("best_latency").and_then(Json::as_f64),
        };
        let step = doc
            .get("step")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("missing numeric \"step\""))?;
        let tensors = doc
            .get("tensors")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing \"tensors\" array"))?;
        if tensors.is_empty() {
            bail!("checkpoint has no tensors");
        }

        let mut params = Vec::with_capacity(tensors.len());
        let mut m = Vec::with_capacity(tensors.len());
        let mut v = Vec::with_capacity(tensors.len());
        let mut names = Vec::with_capacity(tensors.len());
        for (i, t) in tensors.iter().enumerate() {
            let name = t
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensors[{i}]: missing string \"name\""))?
                .to_string();
            let dims_json = t
                .get("dims")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensors[{i}] '{name}': missing \"dims\" array"))?;
            let mut dims = Vec::with_capacity(dims_json.len());
            for (di, d) in dims_json.iter().enumerate() {
                dims.push(d.as_usize().filter(|&x| x >= 1).ok_or_else(|| {
                    anyhow!("tensors[{i}] '{name}': dims[{di}] is not a positive integer")
                })?);
            }
            let numel = dims.iter().product::<usize>().max(1);
            let plane = |key: &str| -> Result<Tensor> {
                let arr = t
                    .get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("tensors[{i}] '{name}': missing \"{key}\" array"))?;
                if arr.len() != numel {
                    bail!(
                        "tensors[{i}] '{name}': \"{key}\" holds {} scalars but dims {:?} \
                         want {numel} (truncated checkpoint?)",
                        arr.len(),
                        dims
                    );
                }
                let mut data = Vec::with_capacity(numel);
                for (k, x) in arr.iter().enumerate() {
                    let x = x
                        .as_f64()
                        .ok_or_else(|| anyhow!("tensors[{i}] '{name}': {key}[{k}] not a number"))?;
                    let x32 = x as f32;
                    if !x32.is_finite() {
                        bail!("tensors[{i}] '{name}': {key}[{k}] = {x} out of f32 range");
                    }
                    data.push(x32);
                }
                Ok(Tensor::f32(&dims, data))
            };
            params.push(plane("data")?);
            m.push(plane("m")?);
            v.push(plane("v")?);
            names.push(name);
        }
        let store = ParamStore::from_parts(params, m, v, step as f32, names)?;
        let ckpt = Checkpoint { meta, store };
        ckpt.self_check()?;
        Ok(ckpt)
    }

    /// Metadata must agree with the tensors it travels with (the HSDAG
    /// layout names are stable across both backends — see
    /// `ParamStore::init_hsdag` / `hsdag_param_spec`): a checkpoint whose
    /// header promises one shape while its tensors carry another is
    /// corrupt, not merely incompatible.
    fn self_check(&self) -> Result<()> {
        for (name, want) in [
            ("trans_w0", vec![self.meta.feature_dim, self.meta.hidden]),
            ("place_w1", vec![self.meta.hidden, self.meta.actions]),
        ] {
            if let Some(i) = self.store.names.iter().position(|n| n == name) {
                let got = self.store.params[i].dims();
                if got != want.as_slice() {
                    bail!(
                        "checkpoint metadata (hidden {}, feature_dim {}, actions {}) \
                         disagrees with tensor '{name}' dims {:?} (want {:?})",
                        self.meta.hidden,
                        self.meta.feature_dim,
                        self.meta.actions,
                        got,
                        want
                    );
                }
            }
        }
        Ok(())
    }

    /// Pre-flight a deployment: does this checkpoint fit a run at
    /// `hidden` / `actions` on `testbed_id`? The error names both sides
    /// (the classic failure is serving a 2-device checkpoint on a wider
    /// `--testbed`).
    pub fn check_compatible(&self, hidden: usize, actions: usize, testbed_id: &str) -> Result<()> {
        if self.meta.hidden != hidden {
            bail!(
                "checkpoint was trained at hidden {}, this run wants hidden {hidden}",
                self.meta.hidden
            );
        }
        if self.meta.actions != actions {
            bail!(
                "checkpoint places onto {} targets (trained on testbed '{}'), but testbed \
                 '{testbed_id}' exposes {actions} — pick a testbed of matching width or \
                 retrain with --testbed {testbed_id}",
                self.meta.actions,
                self.meta.testbed
            );
        }
        Ok(())
    }

    /// Write atomically-ish: temp file in the same directory, then
    /// rename, so a crash mid-write never leaves a torn checkpoint at
    /// `path` (best-so-far saves overwrite it repeatedly).
    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json())
            .with_context(|| format!("writing checkpoint '{}'", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming checkpoint into '{}'", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint '{}'", path.display()))?;
        Self::parse(&text).with_context(|| format!("checkpoint '{}'", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(11);
        let mut store = ParamStore::init_hsdag(9, 8, 3, &mut rng);
        store.step = 7.0;
        // Non-trivial moments so the round-trip covers all three planes.
        store.m[0].as_f32_mut()[0] = 0.125;
        store.v[2].as_f32_mut()[1] = 3.5e-7;
        Checkpoint::new(
            store,
            CheckpointMeta {
                hidden: 8,
                feature_dim: 9,
                actions: 3,
                testbed: "paper3".to_string(),
                workload: "layered:4x3".to_string(),
                best_latency: Some(0.0125),
            },
        )
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let ckpt = sample();
        let text = ckpt.to_json();
        let back = Checkpoint::parse(&text).unwrap();
        assert_eq!(back.meta.hidden, 8);
        assert_eq!(back.meta.actions, 3);
        assert_eq!(back.meta.testbed, "paper3");
        assert_eq!(back.meta.workload, "layered:4x3");
        assert_eq!(back.meta.best_latency, Some(0.0125));
        assert_eq!(back.store.step, 7.0);
        assert_eq!(back.store.names, ckpt.store.names);
        for i in 0..ckpt.store.n() {
            assert_eq!(back.store.params[i].dims(), ckpt.store.params[i].dims());
            assert_eq!(back.store.params[i].as_f32(), ckpt.store.params[i].as_f32(), "params {i}");
            assert_eq!(back.store.m[i].as_f32(), ckpt.store.m[i].as_f32(), "m {i}");
            assert_eq!(back.store.v[i].as_f32(), ckpt.store.v[i].as_f32(), "v {i}");
        }
    }

    #[test]
    fn corrupt_documents_error_with_a_message() {
        let good = sample().to_json();
        // Truncation at any midpoint is a parse error, never a panic.
        for frac in [4, 2] {
            let cut = &good[..good.len() / frac];
            assert!(Checkpoint::parse(cut).is_err(), "truncated at 1/{frac} parsed");
        }
        // Wrong format tag.
        let wrong = good.replace(FORMAT_TAG, "hsdag-params-v9");
        let msg = format!("{:#}", Checkpoint::parse(&wrong).unwrap_err());
        assert!(msg.contains("hsdag-params-v9"), "{msg}");
        // A dims/data mismatch is caught with the tensor named.
        let mangled = good.replace("\"dims\": [9, 8]", "\"dims\": [9, 4]");
        let msg = format!("{:#}", Checkpoint::parse(&mangled).unwrap_err());
        assert!(msg.contains("trans_w0"), "{msg}");
        // Metadata that disagrees with the tensors is corrupt.
        let lied = good.replace("\"actions\": 3", "\"actions\": 2");
        let msg = format!("{:#}", Checkpoint::parse(&lied).unwrap_err());
        assert!(msg.contains("disagrees"), "{msg}");
        assert!(Checkpoint::parse("").is_err());
        assert!(Checkpoint::parse("{}").is_err());
    }

    #[test]
    fn compatibility_preflight_names_both_sides() {
        let ckpt = sample();
        ckpt.check_compatible(8, 3, "paper3").unwrap();
        let msg = format!("{:#}", ckpt.check_compatible(8, 2, "cpu_gpu").unwrap_err());
        assert!(msg.contains("paper3") && msg.contains("cpu_gpu"), "{msg}");
        let msg = format!("{:#}", ckpt.check_compatible(128, 3, "paper3").unwrap_err());
        assert!(msg.contains("hidden"), "{msg}");
    }

    #[test]
    fn save_and_load_via_tempfile() {
        let dir = std::env::temp_dir().join("hsdag_checkpoint_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.json");
        let ckpt = sample();
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.store.params[0].as_f32(), ckpt.store.params[0].as_f32());
        // Load errors carry the path.
        let missing = Checkpoint::load(&dir.join("nope.json")).unwrap_err();
        assert!(format!("{missing:#}").contains("nope.json"));
    }
}

//! Structural graph fingerprints: the placement-cache key.
//!
//! Two placement requests may serve the *same* graph under different node
//! names (every tracing frontend generates its own layer paths), and the
//! *same* graph on two testbeds is two different placement problems. The
//! fingerprint therefore hashes exactly what the policy and the simulator
//! can observe, and nothing else:
//!
//! - topology: node count plus the sorted edge list (node ids are dense
//!   and meaningful — they index the feature matrix — so no further
//!   canonicalization is needed, and *renaming* nodes never changes the
//!   hash);
//! - per-node op identity: the feature one-hot slot (built-in kind index,
//!   or the hash bucket of a custom kind label — what the policy sees)
//!   AND the cost class (what the simulator sees; two custom labels can
//!   share a feature bucket yet cost differently);
//! - per-node output shape and cost attrs (taps / reduce_dim / groups);
//! - the testbed id.
//!
//! The hash is 64-bit FNV-1a over an unambiguous byte encoding (every
//! variable-length run is length-prefixed), so it is deterministic across
//! processes, platforms and runs — a checkpoint-serving daemon restarted
//! tomorrow computes the same keys it computed today.

use crate::graph::CompGraph;

/// 64-bit FNV-1a running hash.
struct Fnv(u64);

impl Fnv {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new() -> Fnv {
        Fnv(Self::OFFSET)
    }

    fn bytes(&mut self, data: &[u8]) {
        for &b in data {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    /// Length-prefixed string (two strings can never collide by
    /// concatenation ambiguity).
    fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.bytes(s.as_bytes());
    }
}

/// Deterministic structural fingerprint of (graph, testbed) — see the
/// module docs for exactly what is (and is not) hashed.
pub fn fingerprint(g: &CompGraph, testbed_id: &str) -> u64 {
    let mut h = Fnv::new();
    h.str("hsdag-fp-v1");
    h.str(testbed_id);
    h.usize(g.n());
    for node in &g.nodes {
        h.usize(node.feature_slot());
        h.usize(node.kind.index());
        h.usize(node.output_shape.len());
        for &d in &node.output_shape {
            h.usize(d);
        }
        h.usize(node.attrs.taps);
        h.usize(node.attrs.reduce_dim);
        h.usize(node.attrs.groups);
    }
    // Edge order is a construction artifact, not structure: hash sorted.
    let mut edges = g.edges.clone();
    edges.sort_unstable();
    h.usize(edges.len());
    for (s, t) in edges {
        h.usize(s);
        h.usize(t);
    }
    h.0
}

/// The fingerprint rendered the way the wire protocol reports it
/// (16 lowercase hex digits).
pub fn fingerprint_hex(g: &CompGraph, testbed_id: &str) -> String {
    format!("{:016x}", fingerprint(g, testbed_id))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpAttrs, OpKind, OpNode};
    use crate::models::Workload;

    fn base() -> CompGraph {
        let mut g = CompGraph::new("base");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 8]));
        let a = g.add_node(OpNode::new("a", OpKind::Relu, vec![1, 8]));
        let b = g.add_node(
            OpNode::new("b", OpKind::MatMul, vec![1, 8])
                .with_attrs(OpAttrs { taps: 1, reduce_dim: 8, groups: 1 }),
        );
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 8]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        g
    }

    #[test]
    fn deterministic_across_builds_and_resolves() {
        assert_eq!(fingerprint(&base(), "cpu_gpu"), fingerprint(&base(), "cpu_gpu"));
        let w1 = Workload::resolve("layered:4x3:2").unwrap();
        let w2 = Workload::resolve("layered:4x3:2").unwrap();
        assert_eq!(fingerprint(&w1.graph, "cpu_gpu"), fingerprint(&w2.graph, "cpu_gpu"));
        let hex = fingerprint_hex(&w1.graph, "cpu_gpu");
        assert_eq!(hex.len(), 16);
        assert_eq!(u64::from_str_radix(&hex, 16).unwrap(), fingerprint(&w1.graph, "cpu_gpu"));
    }

    #[test]
    fn node_renaming_does_not_change_the_hash() {
        let g = base();
        let mut renamed = g.clone();
        for (i, node) in renamed.nodes.iter_mut().enumerate() {
            node.name = format!("totally_different_{i}");
        }
        assert_eq!(fingerprint(&g, "cpu_gpu"), fingerprint(&renamed, "cpu_gpu"));
    }

    #[test]
    fn edge_order_is_canonicalized() {
        let g = base();
        let mut reordered = g.clone();
        reordered.edges.reverse();
        assert_eq!(fingerprint(&g, "cpu_gpu"), fingerprint(&reordered, "cpu_gpu"));
    }

    #[test]
    fn structure_kind_shape_and_testbed_all_flip_the_hash() {
        let g = base();
        let fp = fingerprint(&g, "cpu_gpu");

        // Edge flip: rewire a -> out into b's slot. (Mutating the edge
        // list alone is fine — adjacency is not hashed.)
        let mut edge_flip = g.clone();
        edge_flip.edges[2] = (2, 1);
        // Kind change.
        let mut kind_change = g.clone();
        kind_change.nodes[1].kind = OpKind::Sigmoid;
        // Custom label: feature slot moves even though the cost class
        // stays.
        let mut label_change = g.clone();
        label_change.nodes[1] = label_change.nodes[1].clone().with_custom_kind("FusedGate");
        // Shape change.
        let mut shape_change = g.clone();
        shape_change.nodes[2].output_shape = vec![1, 16];
        // Attr change.
        let mut attr_change = g.clone();
        attr_change.nodes[2].attrs.reduce_dim = 4;

        let variants = [
            fingerprint(&edge_flip, "cpu_gpu"),
            fingerprint(&kind_change, "cpu_gpu"),
            fingerprint(&label_change, "cpu_gpu"),
            fingerprint(&shape_change, "cpu_gpu"),
            fingerprint(&attr_change, "cpu_gpu"),
            fingerprint(&g, "paper3"),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(*v, fp, "variant {i} collided with the base graph");
        }
        // And the variants are pairwise distinct among themselves.
        for i in 0..variants.len() {
            for j in i + 1..variants.len() {
                assert_ne!(variants[i], variants[j], "variants {i} and {j} collided");
            }
        }
    }
}

//! Structural graph fingerprints: the placement-cache key.
//!
//! Two placement requests may serve the *same* graph under different node
//! names (every tracing frontend generates its own layer paths), and the
//! *same* graph on two testbeds is two different placement problems. The
//! fingerprint therefore hashes exactly what the policy and the simulator
//! can observe, and nothing else:
//!
//! - topology: node count plus the sorted edge list (node ids are dense
//!   and meaningful — they index the feature matrix — so no further
//!   canonicalization is needed, and *renaming* nodes never changes the
//!   hash);
//! - per-node op identity: the feature one-hot slot (built-in kind index,
//!   or the hash bucket of a custom kind label — what the policy sees)
//!   AND the cost class (what the simulator sees; two custom labels can
//!   share a feature bucket yet cost differently);
//! - per-node output shape and cost attrs (taps / reduce_dim / groups);
//! - the testbed id.
//!
//! The hash is 64-bit FNV-1a over an unambiguous byte encoding (every
//! variable-length run is length-prefixed), so it is deterministic across
//! processes, platforms and runs — a checkpoint-serving daemon restarted
//! tomorrow computes the same keys it computed today.

use crate::graph::CompGraph;

/// 64-bit FNV-1a running hash.
struct Fnv(u64);

impl Fnv {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new() -> Fnv {
        Fnv(Self::OFFSET)
    }

    fn bytes(&mut self, data: &[u8]) {
        for &b in data {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    fn usize(&mut self, x: usize) {
        self.u64(x as u64);
    }

    /// Length-prefixed string (two strings can never collide by
    /// concatenation ambiguity).
    fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.bytes(s.as_bytes());
    }
}

/// Deterministic structural fingerprint of (graph, testbed) — see the
/// module docs for exactly what is (and is not) hashed.
pub fn fingerprint(g: &CompGraph, testbed_id: &str) -> u64 {
    let mut h = Fnv::new();
    h.str("hsdag-fp-v1");
    h.str(testbed_id);
    h.usize(g.n());
    for node in &g.nodes {
        h.usize(node.feature_slot());
        h.usize(node.kind.index());
        h.usize(node.output_shape.len());
        for &d in &node.output_shape {
            h.usize(d);
        }
        h.usize(node.attrs.taps);
        h.usize(node.attrs.reduce_dim);
        h.usize(node.attrs.groups);
    }
    // Edge order is a construction artifact, not structure: hash sorted.
    let mut edges = g.edges.clone();
    edges.sort_unstable();
    h.usize(edges.len());
    for (s, t) in edges {
        h.usize(s);
        h.usize(t);
    }
    h.0
}

/// The fingerprint rendered the way the wire protocol reports it
/// (16 lowercase hex digits).
pub fn fingerprint_hex(g: &CompGraph, testbed_id: &str) -> String {
    format!("{:016x}", fingerprint(g, testbed_id))
}

/// Position-mix for combining per-node subhashes order-independently:
/// the combined value is a wrapping *sum* of `mix(id, subhash)` terms, so
/// updating one node is a subtract-old / add-new in O(1) instead of a
/// full O(n + m) re-hash. The mix binds each subhash to its node id so
/// swapping two nodes' contents changes the sum.
fn mix(id: usize, subhash: u64) -> u64 {
    let x = subhash ^ (id as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    (x ^ (x >> 31)).wrapping_mul(0xBF58_476D_1CE4_E5B9)
}

/// Everything the fingerprint observes about one node: op identity,
/// shape, cost attrs, and its *out-edge list* (sorted — adjacency push
/// order is a construction artifact). In-edges are deliberately absent:
/// every edge is covered exactly once, by its source's subhash, so the
/// dirty set for an edge edit is just the source node.
fn node_subhash(g: &CompGraph, v: usize) -> u64 {
    let node = &g.nodes[v];
    let mut h = Fnv::new();
    h.usize(node.feature_slot());
    h.usize(node.kind.index());
    h.usize(node.output_shape.len());
    for &d in &node.output_shape {
        h.usize(d);
    }
    h.usize(node.attrs.taps);
    h.usize(node.attrs.reduce_dim);
    h.usize(node.attrs.groups);
    let mut outs = g.out_neighbors(v).to_vec();
    outs.sort_unstable();
    h.usize(outs.len());
    for t in outs {
        h.usize(t);
    }
    h.0
}

/// Incrementally maintainable structural fingerprint ("hsdag-fpd-v1").
///
/// The serve daemon re-keys its placement cache on every request; for a
/// 100k-node graph where an editing frontend touched three nodes, a full
/// `fingerprint` walk is 100k node hashes plus an O(m log m) edge sort
/// per request. `FingerprintState` holds one subhash per node and a
/// running order-independent combination; [`fingerprint_delta`] re-hashes
/// only the dirty nodes (plus any appended ones) and patches the
/// combination in O(|dirty| + out-degree) — bit-identical, by
/// construction and by differential test, to rebuilding the state from
/// scratch with [`FingerprintState::full`].
///
/// This is a *separate* hash family from the wire-protocol
/// `fingerprint` ("hsdag-fp-v1"), which stays byte-for-byte stable for
/// existing caches; both discriminate exactly the same observations.
///
/// Supported edits: node field mutations (kind / shape / attrs), edge
/// insertions (dirty = the source node), and node appends (ids are dense
/// and append-only — the state grows to match the graph). Deletions are
/// not modeled; graphs here only grow.
pub struct FingerprintState {
    /// FNV over (version tag, testbed id) — fixed for the state's life.
    header: u64,
    node_hash: Vec<u64>,
    /// Wrapping sum of `mix(v, node_hash[v])` over all nodes.
    sum: u64,
}

impl FingerprintState {
    /// Build the state from scratch in O(n + m).
    pub fn full(g: &CompGraph, testbed_id: &str) -> FingerprintState {
        let mut h = Fnv::new();
        h.str("hsdag-fpd-v1");
        h.str(testbed_id);
        let header = h.0;
        let node_hash: Vec<u64> = (0..g.n()).map(|v| node_subhash(g, v)).collect();
        let sum = node_hash
            .iter()
            .enumerate()
            .fold(0u64, |acc, (v, &nh)| acc.wrapping_add(mix(v, nh)));
        FingerprintState { header, node_hash, sum }
    }

    /// The current fingerprint value. O(1).
    pub fn value(&self) -> u64 {
        let mut h = Fnv::new();
        h.u64(self.header);
        h.usize(self.node_hash.len());
        h.u64(self.sum);
        h.0
    }

    /// Number of nodes the state currently covers.
    pub fn n(&self) -> usize {
        self.node_hash.len()
    }

    /// Re-hash exactly the `dirty` nodes against the current graph and
    /// patch the combined value; appended nodes (ids at or past the old
    /// length) are picked up automatically. Listing a node twice is
    /// harmless (the second update is a no-op). Returns the new value.
    pub fn apply_delta(&mut self, g: &CompGraph, dirty: &[usize]) -> u64 {
        // Appended nodes are always dirty: they had no subhash before.
        let old_len = self.node_hash.len();
        for v in old_len..g.n() {
            let nh = node_subhash(g, v);
            self.node_hash.push(nh);
            self.sum = self.sum.wrapping_add(mix(v, nh));
        }
        for &v in dirty {
            assert!(v < g.n(), "dirty node {v} out of range");
            if v >= old_len {
                continue; // freshly appended: already hashed above
            }
            let nh = node_subhash(g, v);
            let old = std::mem::replace(&mut self.node_hash[v], nh);
            self.sum = self.sum.wrapping_sub(mix(v, old)).wrapping_add(mix(v, nh));
        }
        self.value()
    }
}

/// Free-function form of the incremental update: patch `state` for the
/// given dirty nodes and return the new fingerprint value.
pub fn fingerprint_delta(state: &mut FingerprintState, g: &CompGraph, dirty: &[usize]) -> u64 {
    state.apply_delta(g, dirty)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpAttrs, OpKind, OpNode};
    use crate::models::Workload;

    fn base() -> CompGraph {
        let mut g = CompGraph::new("base");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 8]));
        let a = g.add_node(OpNode::new("a", OpKind::Relu, vec![1, 8]));
        let b = g.add_node(
            OpNode::new("b", OpKind::MatMul, vec![1, 8])
                .with_attrs(OpAttrs { taps: 1, reduce_dim: 8, groups: 1 }),
        );
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 8]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        g
    }

    #[test]
    fn deterministic_across_builds_and_resolves() {
        assert_eq!(fingerprint(&base(), "cpu_gpu"), fingerprint(&base(), "cpu_gpu"));
        let w1 = Workload::resolve("layered:4x3:2").unwrap();
        let w2 = Workload::resolve("layered:4x3:2").unwrap();
        assert_eq!(fingerprint(&w1.graph, "cpu_gpu"), fingerprint(&w2.graph, "cpu_gpu"));
        let hex = fingerprint_hex(&w1.graph, "cpu_gpu");
        assert_eq!(hex.len(), 16);
        assert_eq!(u64::from_str_radix(&hex, 16).unwrap(), fingerprint(&w1.graph, "cpu_gpu"));
    }

    #[test]
    fn node_renaming_does_not_change_the_hash() {
        let g = base();
        let mut renamed = g.clone();
        for (i, node) in renamed.nodes.iter_mut().enumerate() {
            node.name = format!("totally_different_{i}");
        }
        assert_eq!(fingerprint(&g, "cpu_gpu"), fingerprint(&renamed, "cpu_gpu"));
    }

    #[test]
    fn edge_order_is_canonicalized() {
        let g = base();
        let mut reordered = g.clone();
        reordered.edges.reverse();
        assert_eq!(fingerprint(&g, "cpu_gpu"), fingerprint(&reordered, "cpu_gpu"));
    }

    #[test]
    fn structure_kind_shape_and_testbed_all_flip_the_hash() {
        let g = base();
        let fp = fingerprint(&g, "cpu_gpu");

        // Edge flip: rewire a -> out into b's slot. (Mutating the edge
        // list alone is fine — adjacency is not hashed.)
        let mut edge_flip = g.clone();
        edge_flip.edges[2] = (2, 1);
        // Kind change.
        let mut kind_change = g.clone();
        kind_change.nodes[1].kind = OpKind::Sigmoid;
        // Custom label: feature slot moves even though the cost class
        // stays.
        let mut label_change = g.clone();
        label_change.nodes[1] = label_change.nodes[1].clone().with_custom_kind("FusedGate");
        // Shape change.
        let mut shape_change = g.clone();
        shape_change.nodes[2].output_shape = vec![1, 16];
        // Attr change.
        let mut attr_change = g.clone();
        attr_change.nodes[2].attrs.reduce_dim = 4;

        let variants = [
            fingerprint(&edge_flip, "cpu_gpu"),
            fingerprint(&kind_change, "cpu_gpu"),
            fingerprint(&label_change, "cpu_gpu"),
            fingerprint(&shape_change, "cpu_gpu"),
            fingerprint(&attr_change, "cpu_gpu"),
            fingerprint(&g, "paper3"),
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(*v, fp, "variant {i} collided with the base graph");
        }
        // And the variants are pairwise distinct among themselves.
        for i in 0..variants.len() {
            for j in i + 1..variants.len() {
                assert_ne!(variants[i], variants[j], "variants {i} and {j} collided");
            }
        }
    }

    #[test]
    fn delta_state_discriminates_like_the_full_fingerprint() {
        let g = base();
        let fp = FingerprintState::full(&g, "cpu_gpu").value();
        let mut kind_change = g.clone();
        kind_change.nodes[1].kind = OpKind::Sigmoid;
        let mut shape_change = g.clone();
        shape_change.nodes[2].output_shape = vec![1, 16];
        for (label, variant) in [
            ("kind", FingerprintState::full(&kind_change, "cpu_gpu").value()),
            ("shape", FingerprintState::full(&shape_change, "cpu_gpu").value()),
            ("testbed", FingerprintState::full(&g, "paper3").value()),
        ] {
            assert_ne!(variant, fp, "{label} variant collided with the base graph");
        }
        // Renaming still never changes the hash.
        let mut renamed = g.clone();
        for (i, node) in renamed.nodes.iter_mut().enumerate() {
            node.name = format!("other_{i}");
        }
        assert_eq!(FingerprintState::full(&renamed, "cpu_gpu").value(), fp);
    }

    /// The tentpole differential test: a long randomized edit sequence
    /// (field mutations, edge inserts, node appends) where after every
    /// edit the incrementally patched state must equal a from-scratch
    /// rebuild, bit for bit.
    #[test]
    fn delta_matches_full_recompute_on_randomized_edit_sequences() {
        use crate::util::Rng;
        for case in 0..12u64 {
            let mut rng = Rng::new(0xF19E_0001 ^ case.wrapping_mul(0x9E37_79B9));
            let w = Workload::resolve(&format!("layered:6x4:{case}")).unwrap();
            let mut g = w.graph;
            let mut state = FingerprintState::full(&g, "cpu_gpu");
            assert_eq!(state.value(), FingerprintState::full(&g, "cpu_gpu").value());
            for _ in 0..30 {
                let mut dirty: Vec<usize> = Vec::new();
                match rng.below(4) {
                    0 => {
                        // Mutate a node's cost attrs / shape.
                        let v = rng.below(g.n());
                        g.nodes[v].attrs.taps = rng.below(5);
                        g.nodes[v].output_shape = vec![1, 1 + rng.below(64)];
                        dirty.push(v);
                    }
                    1 => {
                        // Insert a forward edge src -> dst (src < dst keeps
                        // it acyclic); only the source is dirty.
                        let src = rng.below(g.n() - 1);
                        let dst = src + 1 + rng.below(g.n() - src - 1);
                        g.add_edge(src, dst);
                        dirty.push(src);
                    }
                    2 => {
                        // Append a node and wire an existing node into it.
                        let src = rng.below(g.n());
                        let v = g.add_node(OpNode::new("appended", OpKind::Relu, vec![1, 4]));
                        g.add_edge(src, v);
                        dirty.push(src);
                        // `v` itself is picked up by the append path.
                    }
                    _ => {
                        // Relabel a node: must NOT change the hash, and an
                        // empty dirty set must keep the state consistent.
                        let v = rng.below(g.n());
                        g.nodes[v].name.push('x');
                    }
                }
                let patched = state.apply_delta(&g, &dirty);
                let rebuilt = FingerprintState::full(&g, "cpu_gpu");
                assert_eq!(
                    patched,
                    rebuilt.value(),
                    "case {case}: delta diverged from full recompute (n={})",
                    g.n()
                );
                assert_eq!(state.n(), g.n());
            }
        }
    }

    #[test]
    fn fingerprint_delta_free_function_and_duplicate_dirty_entries() {
        let mut g = base();
        let mut state = FingerprintState::full(&g, "cpu_gpu");
        g.nodes[2].attrs.groups = 7;
        // Same node listed twice: second update is a no-op.
        let v = fingerprint_delta(&mut state, &g, &[2, 2]);
        assert_eq!(v, FingerprintState::full(&g, "cpu_gpu").value());
        assert_eq!(v, state.value());
    }
}

//! The fleet tier: consistent-hash routing of placement requests across
//! N shard daemons.
//!
//! One `hsdag serve` process duplicates every LRU cache line N times
//! when deployed as N independent daemons behind a dumb load balancer.
//! The router instead partitions the *fingerprint space*: each `place`
//! request is forwarded to the shard that rendezvous-hashing
//! ([`shard_for`]) assigns its structural fingerprint, so each shard's
//! placement cache and single-flight table own a disjoint slice of the
//! keyspace and aggregate cache capacity scales with fleet size.
//!
//! Properties the tests pin:
//!
//! - **Determinism**: [`shard_for`] is a pure function of the
//!   fingerprint and the shard *address strings* — no RNG, no state.
//!   The router, the sharded client (`hsdag request --shards ...`) and
//!   any future implementation agree on every fingerprint's owner by
//!   construction, and golden values keep the function from drifting.
//! - **Permutation invariance**: scoring is per-address
//!   (highest-random-weight), so reordering `--shards` never reshuffles
//!   the keyspace, and adding a shard only moves the ~1/N of keys that
//!   now score highest on the newcomer.
//! - **Fingerprint agreement**: fingerprints hash the testbed id, so
//!   the router discovers the fleet's testbed from a shard's `stats`
//!   response at startup ([`Router::new`]) instead of trusting its own
//!   config — a router pointed at a fleet serving a different testbed
//!   would otherwise compute different keys than the shards themselves.
//!
//! The router speaks the same line protocol as a shard and plugs into
//! the same TCP front end ([`Server`](super::server::Server)) via
//! [`LineHandler`]: `place` is routed, `stats` fans out and aggregates
//! (plus the router's own routing counters and a per-shard health
//! verdict), `ctrl: reload` / `ctrl: clear-cache` fan out to every
//! shard, and `ctrl: shutdown` stops the *router only* — shards are
//! independent processes with their own lifecycles. Shard `busy`
//! responses pass through verbatim, so backpressure reaches the client
//! that caused it.
//!
//! Fan-out ops scatter over the worker pool ([`pool::map_indexed`]):
//! each shard owns its own connection pool (disjoint mutexes), so the
//! scatter is lock-safe and a fleet `stats` costs the *slowest* shard's
//! round-trip instead of the sum of all of them.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::client::{roundtrip, Connection};
use super::fingerprint::fingerprint;
use super::protocol::{self, PlaceRequest, PlaceSource, Request};
use super::server::{LineHandler, RequestCtx};
use crate::models::Workload;
use crate::obs::metrics;
use crate::obs::trace::{self, Trace, TraceSink};
use crate::util::json::Json;
use crate::util::pool;

/// 64-bit FNV-1a over a byte string (the shard-address hash half of the
/// rendezvous score). Kept private-and-duplicated rather than shared
/// with the fingerprint module on purpose: the two hash families must
/// be able to evolve independently without silently re-keying the other.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The splitmix64 finalizer: a cheap, well-mixed bijection on u64.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Rendezvous (highest-random-weight) hashing: the owning shard of a
/// fingerprint is the one whose `(address, fingerprint)` pair scores
/// highest. Pure and deterministic — every caller that knows the shard
/// addresses agrees on the owner, whatever order the addresses came in.
/// Exact-score ties (vanishingly rare) break toward the lexically
/// smallest address so even they are permutation-invariant.
///
/// Returns an index into `shards`.
///
/// # Panics
/// When `shards` is empty — an empty fleet cannot own anything.
pub fn shard_for(fp: u64, shards: &[String]) -> usize {
    assert!(!shards.is_empty(), "shard_for: empty shard list");
    let mut best = 0usize;
    let mut best_score = 0u64;
    for (i, addr) in shards.iter().enumerate() {
        let score = splitmix64(fnv1a(addr.as_bytes()) ^ fp);
        if i == 0
            || score > best_score
            || (score == best_score && shards[i] < shards[best])
        {
            best = i;
            best_score = score;
        }
    }
    best
}

/// Ask the fleet which testbed it serves: query each shard's `stats`
/// until one answers, then verify every *other* reachable shard agrees
/// (fingerprints hash the testbed id, so a mixed-testbed fleet would
/// partition the keyspace incoherently). Errors when no shard is
/// reachable or two shards disagree.
pub fn discover_testbed(shards: &[String], timeout: Duration) -> Result<String> {
    let req = protocol::render_stats_request();
    let mut found: Option<(String, String)> = None; // (testbed, source addr)
    let mut last_err: Option<anyhow::Error> = None;
    for addr in shards {
        match roundtrip(addr, &req, timeout) {
            Err(e) => last_err = Some(e),
            Ok(line) => {
                let doc = protocol::parse_response(&line)
                    .with_context(|| format!("stats from shard {addr}"))?;
                let tb = doc
                    .get("testbed")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("shard {addr} reports no testbed in stats"))?
                    .to_string();
                match &found {
                    None => found = Some((tb, addr.clone())),
                    Some((seen, seen_addr)) if *seen != tb => bail!(
                        "fleet testbed mismatch: shard {seen_addr} serves '{seen}' \
                         but shard {addr} serves '{tb}'"
                    ),
                    Some(_) => {}
                }
            }
        }
    }
    match found {
        Some((tb, _)) => Ok(tb),
        None => Err(last_err
            .unwrap_or_else(|| anyhow!("no shards given"))
            .context("discovering the fleet testbed (is any shard up?)")),
    }
}

#[derive(Default)]
struct RouterInner {
    /// Lines handled by the router (any op).
    requests: u64,
    /// `place` requests forwarded, per shard index.
    routed: Vec<u64>,
    /// Requests the router failed (parse errors, unreachable shard).
    errors: u64,
    /// `busy` responses passed through from saturated shards.
    shard_busy: u64,
    /// Connections the router's *own* admission control shed.
    busy_rejects: u64,
}

/// Interned registry handles for the routing hot path (see
/// `obs::metrics`; resolved once at router construction).
struct RouterMetrics {
    requests: &'static metrics::Counter,
    errors: &'static metrics::Counter,
    shard_busy: &'static metrics::Counter,
    forward_us: &'static metrics::Histogram,
}

impl RouterMetrics {
    fn intern() -> RouterMetrics {
        RouterMetrics {
            requests: metrics::counter("router.requests"),
            errors: metrics::counter("router.errors"),
            shard_busy: metrics::counter("router.shard_busy"),
            forward_us: metrics::histogram("router.forward_us"),
        }
    }
}

/// A routing front end over a fixed shard list. See the module docs for
/// the semantics of each op.
pub struct Router {
    shards: Vec<String>,
    testbed: String,
    timeout: Duration,
    /// Idle pipelined connections per shard, reused across requests so
    /// steady-state routing costs no TCP handshakes.
    pools: Vec<Mutex<Vec<Connection>>>,
    stats: Mutex<RouterInner>,
    metrics: RouterMetrics,
    /// When set (`--trace-log` on the router), each routed `place`
    /// request gets a trace id minted here (unless the client sent one),
    /// propagated to the owning shard on the wire, and a router-side
    /// `hsdag-trace-v1` line (fingerprint + forward spans) appended.
    trace_sink: Option<Arc<TraceSink>>,
}

impl Router {
    /// Stand the router up: requires at least one shard address and at
    /// least one *reachable* shard (to discover the fleet's testbed id,
    /// without which fingerprints — the routing keys — cannot be
    /// computed).
    pub fn new(shards: Vec<String>, timeout: Duration) -> Result<Router> {
        if shards.is_empty() {
            bail!("router needs at least one shard address (--shards a,b,...)");
        }
        let testbed = discover_testbed(&shards, timeout)?;
        let pools = shards.iter().map(|_| Mutex::new(Vec::new())).collect();
        let stats = Mutex::new(RouterInner { routed: vec![0; shards.len()], ..Default::default() });
        Ok(Router {
            shards,
            testbed,
            timeout,
            pools,
            stats,
            metrics: RouterMetrics::intern(),
            trace_sink: None,
        })
    }

    /// Attach a `hsdag-trace-v1` JSONL sink; call before the router is
    /// shared. Also turns on trace-id minting for routed requests.
    pub fn set_trace_sink(&mut self, sink: Arc<TraceSink>) {
        self.trace_sink = Some(sink);
    }

    /// The testbed id discovered from the fleet.
    pub fn testbed(&self) -> &str {
        &self.testbed
    }

    /// The shard list, in the order routing indices refer to it.
    pub fn shards(&self) -> &[String] {
        &self.shards
    }

    /// Forward one line to a shard, reusing a pooled connection when one
    /// is idle. A stale pooled connection (shard restarted, idle close)
    /// gets exactly one fresh-connection retry — safe because every
    /// protocol op is idempotent on the shard side. The connection is
    /// returned to the pool unless the shard shed it with `busy` (the
    /// shard closes after a busy line).
    fn forward(&self, shard: usize, line: &str) -> Result<String> {
        let addr = &self.shards[shard];
        if let Some(mut conn) = self.pools[shard].lock().unwrap().pop() {
            if let Ok(resp) = conn.send(line) {
                if !protocol::is_busy_response(&resp) {
                    self.pools[shard].lock().unwrap().push(conn);
                }
                return Ok(resp);
            }
            // Stale: fall through to a fresh connection.
        }
        let mut conn = Connection::open(addr, self.timeout)
            .with_context(|| format!("router: connecting shard {shard} at {addr}"))?;
        let resp = conn
            .send(line)
            .with_context(|| format!("router: forwarding to shard {shard} at {addr}"))?;
        if !protocol::is_busy_response(&resp) {
            self.pools[shard].lock().unwrap().push(conn);
        }
        Ok(resp)
    }

    /// Send one line to every shard; each entry is the shard's response
    /// or the transport error that prevented one, in shard order. The
    /// scatter runs on the worker pool (`--workers`): every shard's
    /// connection pool is its own mutex, so concurrent forwards never
    /// contend, and the fan-out completes in the slowest shard's
    /// round-trip rather than the sum over the fleet.
    fn fan_out(&self, line: &str) -> Vec<Result<String>> {
        pool::map_indexed(self.shards.len(), 0, |i| self.forward(i, line))
    }

    /// Route a `place` request: fingerprint the graph the same way the
    /// owning shard will, pick the owner, forward the line, and pass the
    /// shard's response through verbatim. Without a trace sink the
    /// *original* line is forwarded byte-for-byte (the shard re-parses
    /// it; the router never rewrites requests); with one, the single
    /// rewrite the router is allowed is injecting the trace id it
    /// minted, so the shard's trace line and the router's share an id.
    fn route_place(&self, line: &str, req: &PlaceRequest) -> Result<String> {
        let mut rtrace: Option<Trace> = self.trace_sink.as_ref().map(|_| {
            Trace::new(req.trace.clone().unwrap_or_else(trace::mint_id), "route")
        });
        let t_fp = Instant::now();
        let fp = match &req.source {
            PlaceSource::Spec(s) => {
                let w = Workload::resolve(s)?;
                fingerprint(&w.graph, &self.testbed)
            }
            PlaceSource::Inline(g) => fingerprint(g, &self.testbed),
        };
        let shard = shard_for(fp, &self.shards);
        if let Some(t) = &mut rtrace {
            t.end("fingerprint", t_fp);
        }
        // Propagate the minted id on the wire; a malformed-but-parsed
        // line (impossible today) falls back to verbatim forwarding
        // rather than failing the request over telemetry. Untraced
        // requests forward the original `line` with no rewrite and no
        // allocation.
        let injected: Option<String> = match (&rtrace, &req.trace) {
            (Some(t), None) => protocol::with_trace_id(line, t.id()).ok(),
            _ => None,
        };
        let t_fwd = Instant::now();
        let fwd = self.forward(shard, injected.as_deref().unwrap_or(line));
        if let Some(t) = &mut rtrace {
            t.end("forward", t_fwd);
            t.field("shard", Json::Num(shard as f64));
            t.field("addr", Json::Str(self.shards[shard].clone()));
        }
        self.metrics.forward_us.record(t_fwd.elapsed().as_micros() as u64);
        let resp = match fwd {
            Ok(r) => r,
            Err(e) => {
                if let (Some(t), Some(sink)) = (&mut rtrace, &self.trace_sink) {
                    t.field("error", Json::Str(format!("{e:#}")));
                    sink.write(t);
                }
                return Err(e);
            }
        };
        if let (Some(t), Some(sink)) = (&mut rtrace, &self.trace_sink) {
            sink.write(t);
        }
        let mut s = self.stats.lock().unwrap();
        s.routed[shard] += 1;
        if protocol::is_busy_response(&resp) {
            s.shard_busy += 1;
            self.metrics.shard_busy.inc();
        }
        Ok(resp)
    }

    /// The aggregated `metrics` response: the router's own registry dump
    /// plus each shard's (or the error that replaced it), mirroring the
    /// fleet `stats` shape.
    fn render_fleet_metrics(&self) -> String {
        let per_shard = self.fan_out(&protocol::render_metrics_request());
        let shards_json: Vec<Json> = per_shard
            .iter()
            .zip(&self.shards)
            .map(|(resp, addr)| {
                let body = match resp {
                    Ok(l) => Json::parse(l).unwrap_or(Json::Null),
                    Err(e) => Json::Obj(vec![
                        ("ok".to_string(), Json::Bool(false)),
                        ("error".to_string(), Json::Str(format!("{e:#}"))),
                    ]),
                };
                Json::Obj(vec![
                    ("addr".to_string(), Json::Str(addr.clone())),
                    ("metrics".to_string(), body),
                ])
            })
            .collect();
        let mut doc = match Json::parse(&protocol::render_metrics_response()) {
            Ok(Json::Obj(fields)) => fields,
            _ => vec![
                ("ok".to_string(), Json::Bool(true)),
                ("op".to_string(), Json::Str("metrics".to_string())),
            ],
        };
        doc.push(("router".to_string(), Json::Bool(true)));
        doc.push(("shards".to_string(), Json::Arr(shards_json)));
        Json::Obj(doc).to_string_compact()
    }

    /// The aggregated `stats` response: the router's own counters plus
    /// each shard's full stats document (or the error that replaced it).
    /// Doubling as the fleet health probe, each shard entry carries a
    /// `healthy` verdict — true iff the shard answered a well-formed
    /// `ok: true` stats line — and the top level counts `healthy_shards`
    /// so one parallel round-trip tells the operator who is up.
    fn render_fleet_stats(&self) -> String {
        let per_shard = self.fan_out(&protocol::render_stats_request());
        let s = self.stats.lock().unwrap();
        let mut healthy_shards = 0usize;
        let shards_json: Vec<Json> = per_shard
            .iter()
            .zip(&self.shards)
            .map(|(resp, addr)| {
                let body = match resp {
                    Ok(line) => Json::parse(line).unwrap_or_else(|e| {
                        Json::Obj(vec![
                            ("ok".to_string(), Json::Bool(false)),
                            ("error".to_string(), Json::Str(format!("bad stats JSON: {e}"))),
                        ])
                    }),
                    Err(e) => Json::Obj(vec![
                        ("ok".to_string(), Json::Bool(false)),
                        ("error".to_string(), Json::Str(format!("{e:#}"))),
                    ]),
                };
                let healthy = body.get("ok").and_then(Json::as_bool) == Some(true);
                healthy_shards += healthy as usize;
                Json::Obj(vec![
                    ("addr".to_string(), Json::Str(addr.clone())),
                    ("healthy".to_string(), Json::Bool(healthy)),
                    ("stats".to_string(), body),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("ok".to_string(), Json::Bool(true)),
            ("op".to_string(), Json::Str("stats".to_string())),
            ("router".to_string(), Json::Bool(true)),
            ("fleet_size".to_string(), Json::Num(self.shards.len() as f64)),
            ("healthy_shards".to_string(), Json::Num(healthy_shards as f64)),
            ("testbed".to_string(), Json::Str(self.testbed.clone())),
            ("requests".to_string(), Json::Num(s.requests as f64)),
            (
                "routed".to_string(),
                Json::Arr(s.routed.iter().map(|&n| Json::Num(n as f64)).collect()),
            ),
            ("errors".to_string(), Json::Num(s.errors as f64)),
            ("shard_busy".to_string(), Json::Num(s.shard_busy as f64)),
            ("busy_rejects".to_string(), Json::Num(s.busy_rejects as f64)),
            ("shards".to_string(), Json::Arr(shards_json)),
        ])
        .to_string_compact()
    }

    /// Fan a `ctrl` line out to every shard and aggregate: overall `ok`
    /// iff every shard acknowledged, with each shard's raw response
    /// embedded for the operator.
    fn render_fleet_ctrl(&self, action: &str, line: &str) -> String {
        let per_shard = self.fan_out(line);
        let mut all_ok = true;
        let shards_json: Vec<Json> = per_shard
            .iter()
            .zip(&self.shards)
            .map(|(resp, addr)| {
                let body = match resp {
                    Ok(l) => {
                        let doc = Json::parse(l).unwrap_or(Json::Null);
                        if doc.get("ok").and_then(Json::as_bool) != Some(true) {
                            all_ok = false;
                        }
                        doc
                    }
                    Err(e) => {
                        all_ok = false;
                        Json::Obj(vec![
                            ("ok".to_string(), Json::Bool(false)),
                            ("error".to_string(), Json::Str(format!("{e:#}"))),
                        ])
                    }
                };
                Json::Obj(vec![
                    ("addr".to_string(), Json::Str(addr.clone())),
                    ("response".to_string(), body),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("ok".to_string(), Json::Bool(all_ok)),
            ("op".to_string(), Json::Str("ctrl".to_string())),
            ("action".to_string(), Json::Str(action.to_string())),
            ("router".to_string(), Json::Bool(true)),
            ("shards".to_string(), Json::Arr(shards_json)),
        ])
        .to_string_compact()
    }
}

impl LineHandler for Router {
    fn handle_line(&self, line: &str) -> (String, bool) {
        self.handle_line_ctx(line, &RequestCtx::default())
    }

    fn handle_line_ctx(&self, line: &str, _ctx: &RequestCtx) -> (String, bool) {
        self.stats.lock().unwrap().requests += 1;
        self.metrics.requests.inc();
        match protocol::parse_request(line) {
            Err(e) => {
                self.stats.lock().unwrap().errors += 1;
                self.metrics.errors.inc();
                (protocol::render_error_response(None, &format!("{e:#}")), false)
            }
            Ok(Request::Place(req)) => match self.route_place(line, &req) {
                Ok(resp) => (resp, false),
                Err(e) => {
                    self.stats.lock().unwrap().errors += 1;
                    self.metrics.errors.inc();
                    (
                        protocol::render_error_response(req.id.as_ref(), &format!("{e:#}")),
                        false,
                    )
                }
            },
            Ok(Request::Stats) => (self.render_fleet_stats(), false),
            Ok(Request::Metrics) => (self.render_fleet_metrics(), false),
            Ok(Request::Reload(_)) => (self.render_fleet_ctrl("reload", line), false),
            Ok(Request::ClearCache) => (self.render_fleet_ctrl("clear-cache", line), false),
            // Shutdown stops the router only: shards are independent
            // processes, shut down individually (or left up for the
            // next router).
            Ok(Request::Shutdown) => (protocol::render_ctrl_response("shutdown"), true),
        }
    }

    fn note_busy(&self) {
        self.stats.lock().unwrap().busy_rejects += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 7481 + i)).collect()
    }

    #[test]
    fn shard_for_is_deterministic_and_permutation_invariant() {
        let shards = addrs(4);
        let mut rev = shards.clone();
        rev.reverse();
        for fp in (0..2000u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)) {
            let a = &shards[shard_for(fp, &shards)];
            let b = &rev[shard_for(fp, &rev)];
            assert_eq!(a, b, "fp {fp:016x} moved when the shard list was permuted");
        }
    }

    /// Golden values: these pin the exact hash function. If this test
    /// breaks, router and client deployments of different builds would
    /// disagree on key ownership — never change the function without a
    /// fleet-wide flag day.
    #[test]
    fn shard_for_golden_values() {
        // Frozen once from the implementation. If this test breaks,
        // router and client deployments of different builds would
        // disagree on key ownership — never change the hash function
        // without a fleet-wide flag day.
        let shards = addrs(3);
        let got: Vec<usize> = (0..16u64)
            .map(|i| shard_for(i.wrapping_mul(0x0101_0101_0101_0101), &shards))
            .collect();
        assert_eq!(got, vec![0, 1, 0, 1, 1, 2, 1, 0, 0, 2, 1, 0, 0, 1, 2, 0]);
        // The underlying primitives are pinned too, which pins shard_for
        // transitively for ANY address list, not just this one.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"127.0.0.1:7481"), 0xb46a_69e9_5e9e_1b8c);
        assert_eq!(splitmix64(0), 0xe220_a839_7b1d_cdaf);
        assert_eq!(splitmix64(1), 0x910a_2dec_8902_5cc1);
        assert_eq!(splitmix64(0xdead_beef), 0x4adf_b90f_68c9_eb9b);
    }

    #[test]
    fn shard_for_spreads_keys_and_is_stable_under_growth() {
        let shards = addrs(4);
        let mut counts = vec![0usize; shards.len()];
        let fps: Vec<u64> = (0..4000u64).map(|i| splitmix64(i)).collect();
        for &fp in &fps {
            counts[shard_for(fp, &shards)] += 1;
        }
        // Spread: no shard owns more than half or less than a twentieth
        // of a uniform keyspace across 4 shards (expected share 25%).
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 200 && c < 2000, "shard {i} owns {c}/4000 keys");
        }
        // Minimal disruption: adding a 5th shard only moves keys that
        // now belong to it — every key that stays on an old shard stays
        // on the SAME old shard.
        let mut grown = shards.clone();
        grown.push("127.0.0.1:7485".to_string());
        let mut moved = 0usize;
        for &fp in &fps {
            let old = shard_for(fp, &shards);
            let new = shard_for(fp, &grown);
            if grown[new] == "127.0.0.1:7485" {
                moved += 1;
            } else {
                assert_eq!(shards[old], grown[new], "fp {fp:016x} moved between old shards");
            }
        }
        // The newcomer takes roughly 1/5; certainly not 0 and not half.
        assert!(moved > 400 && moved < 2000, "new shard took {moved}/4000 keys");
    }

    #[test]
    fn shard_for_single_shard_owns_everything() {
        let one = vec!["10.0.0.1:7000".to_string()];
        for fp in [0u64, 1, u64::MAX, 0xdead_beef] {
            assert_eq!(shard_for(fp, &one), 0);
        }
    }
}

//! Bounded LRU placement cache.
//!
//! The serving daemon keys this by graph [`fingerprint`] so a repeat
//! request skips workload resolution, env construction and policy
//! inference entirely — the dominant cost of a request. The
//! implementation is a classic O(1) LRU (hash map into an index-linked
//! slab ordered most- to least-recently used); it is single-threaded on
//! purpose and sits behind a `Mutex` in the server, whose critical
//! sections are a handful of pointer updates.
//!
//! `capacity == 0` is a valid configuration meaning "caching disabled":
//! every `get` misses and every `put` is dropped.
//!
//! [`fingerprint`]: super::fingerprint::fingerprint

use std::collections::HashMap;
use std::hash::Hash;

const NONE: usize = usize::MAX;

struct Slot<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// A bounded most-recently-used cache.
pub struct LruCache<K: Eq + Hash + Clone, V> {
    map: HashMap<K, usize>,
    slots: Vec<Slot<K, V>>,
    free: Vec<usize>,
    /// Most-recently-used slot (NONE when empty).
    head: usize,
    /// Least-recently-used slot (NONE when empty).
    tail: usize,
    capacity: usize,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(capacity: usize) -> LruCache<K, V> {
        LruCache {
            map: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NONE,
            tail: NONE,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Unlink slot `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NONE {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NONE {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    /// Link slot `i` at the head (most recently used).
    fn link_front(&mut self, i: usize) {
        self.slots[i].prev = NONE;
        self.slots[i].next = self.head;
        if self.head != NONE {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NONE {
            self.tail = i;
        }
    }

    /// Look up `key`, marking it most recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let i = *self.map.get(key)?;
        if i != self.head {
            self.unlink(i);
            self.link_front(i);
        }
        Some(&self.slots[i].value)
    }

    /// Look up without touching recency (stats endpoints, tests).
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|&i| &self.slots[i].value)
    }

    /// Insert (or refresh) `key`, evicting the least-recently-used entry
    /// on overflow. Returns the evicted (key, value), if any.
    pub fn put(&mut self, key: K, value: V) -> Option<(K, V)> {
        if self.capacity == 0 {
            return None;
        }
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = value;
            if i != self.head {
                self.unlink(i);
                self.link_front(i);
            }
            return None;
        }
        let evicted = if self.map.len() == self.capacity {
            let lru = self.tail;
            self.unlink(lru);
            self.map.remove(&self.slots[lru].key);
            self.free.push(lru);
            // Move the dead payload out by swapping in the new one below.
            Some(lru)
        } else {
            None
        };
        let i = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot { key: key.clone(), value, prev: NONE, next: NONE });
                let i = self.slots.len() - 1;
                self.map.insert(key, i);
                self.link_front(i);
                return None;
            }
        };
        let old = std::mem::replace(
            &mut self.slots[i],
            Slot { key: key.clone(), value, prev: NONE, next: NONE },
        );
        self.map.insert(key, i);
        self.link_front(i);
        evicted.map(|_| (old.key, old.value))
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.slots.clear();
        self.free.clear();
        self.head = NONE;
        self.tail = NONE;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Keys from MRU to LRU, by walking the recency list.
    fn order(c: &LruCache<u64, u64>) -> Vec<u64> {
        let mut out = Vec::new();
        let mut i = c.head;
        while i != NONE {
            out.push(c.slots[i].key);
            i = c.slots[i].next;
        }
        assert_eq!(out.len(), c.len(), "list and map disagree");
        out
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(3);
        for k in [1u64, 2, 3] {
            assert!(c.put(k, k * 10).is_none());
        }
        assert_eq!(order(&c), vec![3, 2, 1]);
        // Touch 1 -> 2 becomes LRU and falls out on the next insert.
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(order(&c), vec![1, 3, 2]);
        let evicted = c.put(4, 40).unwrap();
        assert_eq!(evicted, (2, 20));
        assert_eq!(c.len(), 3);
        assert!(c.peek(&2).is_none());
        assert_eq!(order(&c), vec![4, 1, 3]);
    }

    #[test]
    fn put_refreshes_value_and_recency() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        assert!(c.put(1, 11).is_none(), "refresh is not an eviction");
        assert_eq!(order(&c), vec![1, 2]);
        assert_eq!(c.get(&1), Some(&11));
        // 2 is now LRU.
        assert_eq!(c.put(3, 30).unwrap().0, 2);
    }

    #[test]
    fn capacity_edges() {
        // capacity 0: caching disabled.
        let mut c: LruCache<u64, u64> = LruCache::new(0);
        assert!(c.put(1, 10).is_none());
        assert!(c.get(&1).is_none());
        assert_eq!(c.len(), 0);
        // capacity 1: every distinct insert evicts the previous entry.
        let mut c = LruCache::new(1);
        assert!(c.put(1, 10).is_none());
        assert_eq!(c.put(2, 20).unwrap(), (1, 10));
        assert_eq!(c.get(&2), Some(&20));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn clear_resets_everything() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        c.clear();
        assert!(c.is_empty());
        assert!(c.get(&1).is_none());
        c.put(3, 30);
        assert_eq!(order(&c), vec![3]);
    }

    /// The deployment shape: many server workers hammering one
    /// `Mutex<LruCache>`. The cache itself is single-threaded; what this
    /// pins down is that the *server's usage pattern* (peek + put + len
    /// under one lock hold, gets under another) maintains every
    /// invariant no matter how threads interleave: capacity is never
    /// exceeded, values stay bound to their keys, insertions are
    /// conserved (fresh inserts == evictions + final occupancy), and the
    /// recency list still orders correctly afterwards.
    #[test]
    fn concurrent_hammer_keeps_invariants() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Mutex;

        const CAPACITY: usize = 16;
        const THREADS: u64 = 8;
        const ITERS: u64 = 2000;
        const KEYSPACE: u64 = 48;

        let cache: Mutex<LruCache<u64, u64>> = Mutex::new(LruCache::new(CAPACITY));
        let hits = AtomicUsize::new(0);
        let misses = AtomicUsize::new(0);
        let puts = AtomicUsize::new(0);
        let fresh_inserts = AtomicUsize::new(0);
        let evictions = AtomicUsize::new(0);

        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let (cache, hits, misses, puts, fresh_inserts, evictions) =
                    (&cache, &hits, &misses, &puts, &fresh_inserts, &evictions);
                scope.spawn(move || {
                    // Deterministic per-thread op stream (different per
                    // thread so the interleaving, not the ops, varies).
                    let mut x = 0x9E37_79B9u64.wrapping_mul(t + 1);
                    for _ in 0..ITERS {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                        let k = (x >> 33) % KEYSPACE;
                        let mut c = cache.lock().unwrap();
                        if x & 1 == 0 {
                            match c.get(&k) {
                                Some(v) => {
                                    assert_eq!(*v, k * 10, "value bound to wrong key");
                                    hits.fetch_add(1, Ordering::Relaxed);
                                }
                                None => {
                                    misses.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        } else {
                            let was_present = c.peek(&k).is_some();
                            let evicted = c.put(k, k * 10);
                            puts.fetch_add(1, Ordering::Relaxed);
                            if !was_present {
                                fresh_inserts.fetch_add(1, Ordering::Relaxed);
                            }
                            if let Some((ek, ev)) = evicted {
                                assert!(!was_present, "refresh must never evict");
                                assert_eq!(ev, ek * 10, "evicted value bound to wrong key");
                                evictions.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        assert!(c.len() <= CAPACITY, "capacity exceeded");
                    }
                });
            }
        });

        let c = cache.lock().unwrap();
        assert_eq!(c.len(), CAPACITY, "keyspace >> capacity, cache must be full");
        // Conservation: every key that entered either fell out or is here.
        assert_eq!(
            fresh_inserts.load(Ordering::Relaxed),
            evictions.load(Ordering::Relaxed) + c.len(),
            "insertions not conserved"
        );
        // Every op landed in exactly one counter bucket.
        assert_eq!(
            hits.load(Ordering::Relaxed)
                + misses.load(Ordering::Relaxed)
                + puts.load(Ordering::Relaxed),
            (THREADS * ITERS) as usize,
            "op counters inconsistent"
        );
        assert!(fresh_inserts.load(Ordering::Relaxed) <= puts.load(Ordering::Relaxed));
        // The recency list survived the interleaving: it walks exactly
        // the mapped keys (checked by `order`) and eviction order still
        // behaves deterministically from here on.
        drop(c);
        let mut c = cache.lock().unwrap();
        let keys = order(&c);
        assert_eq!(keys.len(), CAPACITY);
        let lru = *keys.last().unwrap();
        let mru = keys[0];
        let (ek, _) = c.put(u64::MAX, 0).expect("full cache must evict");
        assert_eq!(ek, lru, "post-hammer eviction must take the list tail");
        assert!(c.peek(&mru).is_some(), "MRU entry must survive");
    }

    #[test]
    fn churn_keeps_invariants() {
        // Deterministic mixed get/put churn; `order` checks list/map
        // agreement at every step.
        let mut c = LruCache::new(8);
        let mut model: Vec<u64> = Vec::new(); // MRU -> LRU reference model
        for i in 0..500u64 {
            let k = (i * 7 + i / 3) % 20;
            if i % 3 == 0 {
                if c.get(&k).is_some() {
                    model.retain(|&x| x != k);
                    model.insert(0, k);
                }
            } else {
                let evicted = c.put(k, k);
                if let Some(pos) = model.iter().position(|&x| x == k) {
                    model.remove(pos);
                    assert!(evicted.is_none());
                } else if model.len() == 8 {
                    let lru = model.pop().unwrap();
                    assert_eq!(evicted.unwrap().0, lru);
                } else {
                    assert!(evicted.is_none());
                }
                model.insert(0, k);
            }
            assert_eq!(order(&c), model, "step {i}");
        }
    }
}

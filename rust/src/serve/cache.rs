//! Bounded LRU placement cache.
//!
//! The serving daemon keys this by graph [`fingerprint`] so a repeat
//! request skips workload resolution, env construction and policy
//! inference entirely — the dominant cost of a request. The
//! implementation is a classic O(1) LRU (hash map into an index-linked
//! slab ordered most- to least-recently used); it is single-threaded on
//! purpose and sits behind a `Mutex` in the server, whose critical
//! sections are a handful of pointer updates.
//!
//! `capacity == 0` is a valid configuration meaning "caching disabled":
//! every `get` misses and every `put` is dropped.
//!
//! [`fingerprint`]: super::fingerprint::fingerprint

use std::collections::HashMap;
use std::hash::Hash;

const NONE: usize = usize::MAX;

struct Slot<K, V> {
    key: K,
    value: V,
    prev: usize,
    next: usize,
}

/// A bounded most-recently-used cache.
pub struct LruCache<K: Eq + Hash + Clone, V> {
    map: HashMap<K, usize>,
    slots: Vec<Slot<K, V>>,
    free: Vec<usize>,
    /// Most-recently-used slot (NONE when empty).
    head: usize,
    /// Least-recently-used slot (NONE when empty).
    tail: usize,
    capacity: usize,
}

impl<K: Eq + Hash + Clone, V> LruCache<K, V> {
    pub fn new(capacity: usize) -> LruCache<K, V> {
        LruCache {
            map: HashMap::with_capacity(capacity),
            slots: Vec::with_capacity(capacity),
            free: Vec::new(),
            head: NONE,
            tail: NONE,
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Unlink slot `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NONE {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NONE {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    /// Link slot `i` at the head (most recently used).
    fn link_front(&mut self, i: usize) {
        self.slots[i].prev = NONE;
        self.slots[i].next = self.head;
        if self.head != NONE {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NONE {
            self.tail = i;
        }
    }

    /// Look up `key`, marking it most recently used on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let i = *self.map.get(key)?;
        if i != self.head {
            self.unlink(i);
            self.link_front(i);
        }
        Some(&self.slots[i].value)
    }

    /// Look up without touching recency (stats endpoints, tests).
    pub fn peek(&self, key: &K) -> Option<&V> {
        self.map.get(key).map(|&i| &self.slots[i].value)
    }

    /// Insert (or refresh) `key`, evicting the least-recently-used entry
    /// on overflow. Returns the evicted (key, value), if any.
    pub fn put(&mut self, key: K, value: V) -> Option<(K, V)> {
        if self.capacity == 0 {
            return None;
        }
        if let Some(&i) = self.map.get(&key) {
            self.slots[i].value = value;
            if i != self.head {
                self.unlink(i);
                self.link_front(i);
            }
            return None;
        }
        let evicted = if self.map.len() == self.capacity {
            let lru = self.tail;
            self.unlink(lru);
            self.map.remove(&self.slots[lru].key);
            self.free.push(lru);
            // Move the dead payload out by swapping in the new one below.
            Some(lru)
        } else {
            None
        };
        let i = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(Slot { key: key.clone(), value, prev: NONE, next: NONE });
                let i = self.slots.len() - 1;
                self.map.insert(key, i);
                self.link_front(i);
                return None;
            }
        };
        let old = std::mem::replace(
            &mut self.slots[i],
            Slot { key: key.clone(), value, prev: NONE, next: NONE },
        );
        self.map.insert(key, i);
        self.link_front(i);
        evicted.map(|_| (old.key, old.value))
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.slots.clear();
        self.free.clear();
        self.head = NONE;
        self.tail = NONE;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Keys from MRU to LRU, by walking the recency list.
    fn order(c: &LruCache<u64, u64>) -> Vec<u64> {
        let mut out = Vec::new();
        let mut i = c.head;
        while i != NONE {
            out.push(c.slots[i].key);
            i = c.slots[i].next;
        }
        assert_eq!(out.len(), c.len(), "list and map disagree");
        out
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = LruCache::new(3);
        for k in [1u64, 2, 3] {
            assert!(c.put(k, k * 10).is_none());
        }
        assert_eq!(order(&c), vec![3, 2, 1]);
        // Touch 1 -> 2 becomes LRU and falls out on the next insert.
        assert_eq!(c.get(&1), Some(&10));
        assert_eq!(order(&c), vec![1, 3, 2]);
        let evicted = c.put(4, 40).unwrap();
        assert_eq!(evicted, (2, 20));
        assert_eq!(c.len(), 3);
        assert!(c.peek(&2).is_none());
        assert_eq!(order(&c), vec![4, 1, 3]);
    }

    #[test]
    fn put_refreshes_value_and_recency() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        assert!(c.put(1, 11).is_none(), "refresh is not an eviction");
        assert_eq!(order(&c), vec![1, 2]);
        assert_eq!(c.get(&1), Some(&11));
        // 2 is now LRU.
        assert_eq!(c.put(3, 30).unwrap().0, 2);
    }

    #[test]
    fn capacity_edges() {
        // capacity 0: caching disabled.
        let mut c: LruCache<u64, u64> = LruCache::new(0);
        assert!(c.put(1, 10).is_none());
        assert!(c.get(&1).is_none());
        assert_eq!(c.len(), 0);
        // capacity 1: every distinct insert evicts the previous entry.
        let mut c = LruCache::new(1);
        assert!(c.put(1, 10).is_none());
        assert_eq!(c.put(2, 20).unwrap(), (1, 10));
        assert_eq!(c.get(&2), Some(&20));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn clear_resets_everything() {
        let mut c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        c.clear();
        assert!(c.is_empty());
        assert!(c.get(&1).is_none());
        c.put(3, 30);
        assert_eq!(order(&c), vec![3]);
    }

    #[test]
    fn churn_keeps_invariants() {
        // Deterministic mixed get/put churn; `order` checks list/map
        // agreement at every step.
        let mut c = LruCache::new(8);
        let mut model: Vec<u64> = Vec::new(); // MRU -> LRU reference model
        for i in 0..500u64 {
            let k = (i * 7 + i / 3) % 20;
            if i % 3 == 0 {
                if c.get(&k).is_some() {
                    model.retain(|&x| x != k);
                    model.insert(0, k);
                }
            } else {
                let evicted = c.put(k, k);
                if let Some(pos) = model.iter().position(|&x| x == k) {
                    model.remove(pos);
                    assert!(evicted.is_none());
                } else if model.len() == 8 {
                    let lru = model.pop().unwrap();
                    assert_eq!(evicted.unwrap().0, lru);
                } else {
                    assert!(evicted.is_none());
                }
                model.insert(0, k);
            }
            assert_eq!(order(&c), model, "step {i}");
        }
    }
}

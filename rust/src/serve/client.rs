//! Client-side plumbing for the placement server: one request line in,
//! one response line out, over a fresh TCP connection.
//!
//! This is what `hsdag request` (and the serving example, the loadgen
//! bench, and the loopback tests) use — one code path for every writer
//! of the wire protocol. Connections are intentionally per-request:
//! the protocol is stateless, a placement response is several orders of
//! magnitude more expensive than a TCP handshake on loopback, and a
//! crashed client can never wedge a worker. The server side does accept
//! pipelined requests on one connection; [`Connection`] exposes that
//! for the loadgen.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Send one request line, wait for the one response line.
pub fn roundtrip(addr: &str, request_line: &str, timeout: Duration) -> Result<String> {
    let mut conn = Connection::open(addr, timeout)?;
    conn.send(request_line)
}

/// A pipelined connection: many request/response exchanges, one stream.
pub struct Connection {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Connection {
    pub fn open(addr: &str, timeout: Duration) -> Result<Connection> {
        let sockaddr: SocketAddr = addr
            .parse()
            .with_context(|| format!("bad server address '{addr}' (want IP:PORT)"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)
            .with_context(|| format!("connecting to hsdag server at {addr}"))?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Connection { reader: BufReader::new(stream), writer })
    }

    /// One exchange: write `request_line`, read the response line.
    pub fn send(&mut self, request_line: &str) -> Result<String> {
        self.writer.write_all(request_line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .context("reading response from hsdag server")?;
        if n == 0 {
            bail!("server closed the connection without responding");
        }
        Ok(line.trim_end().to_string())
    }
}

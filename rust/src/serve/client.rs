//! Client-side plumbing for the placement server: one request line in,
//! one response line out, over a fresh TCP connection.
//!
//! This is what `hsdag request` (and the serving example, the loadgen
//! bench, and the loopback tests) use — one code path for every writer
//! of the wire protocol. Connections are intentionally per-request:
//! the protocol is stateless, a placement response is several orders of
//! magnitude more expensive than a TCP handshake on loopback, and a
//! crashed client can never wedge a worker. The server side does accept
//! pipelined requests on one connection; [`Connection`] exposes that
//! for the loadgen.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Send one request line, wait for the one response line.
pub fn roundtrip(addr: &str, request_line: &str, timeout: Duration) -> Result<String> {
    let mut conn = Connection::open(addr, timeout)?;
    conn.send(request_line)
}

/// [`roundtrip`] with up to `retries` extra attempts on failure, backing
/// off exponentially (50 ms doubling, capped at 2 s) with jitter so N
/// clients retrying a briefly-down shard don't re-stampede it in sync.
///
/// Only *transport* failures reach the retry path — every `Err` out of
/// [`roundtrip`] is a connect/IO error by construction, while a
/// server-reported failure (`"ok": false`, including `busy` shed-load
/// lines) comes back as `Ok(line)` and is never retried here; the
/// caller's response parsing keeps its exit-status contract. With
/// `retries == 0` this is exactly [`roundtrip`].
pub fn roundtrip_retry(
    addr: &str,
    request_line: &str,
    timeout: Duration,
    retries: usize,
) -> Result<String> {
    let mut delay = Duration::from_millis(50);
    for attempt in 0..=retries {
        match roundtrip(addr, request_line, timeout) {
            Ok(resp) => return Ok(resp),
            Err(e) if attempt == retries => {
                return Err(e).with_context(|| {
                    format!("request failed after {} attempt(s)", retries + 1)
                });
            }
            Err(_) => {
                std::thread::sleep(delay + jitter(delay / 2, addr, attempt));
                delay = (delay * 2).min(Duration::from_secs(2));
            }
        }
    }
    unreachable!("the attempt loop always returns");
}

/// Up-to-`cap` pseudo-random jitter, seeded from the clock, the target
/// address and the attempt number (no RNG dependency; splitmix64 over
/// the seed is plenty for de-synchronizing retry stampedes).
fn jitter(cap: Duration, addr: &str, attempt: usize) -> Duration {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.subsec_nanos() as u64)
        .unwrap_or(0);
    let mut x = nanos ^ ((attempt as u64) << 32);
    for b in addr.as_bytes() {
        x = x.rotate_left(8) ^ (*b as u64);
    }
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let cap_ns = cap.as_nanos() as u64;
    if cap_ns == 0 {
        return Duration::ZERO;
    }
    Duration::from_nanos(z % cap_ns)
}

/// A pipelined connection: many request/response exchanges, one stream.
pub struct Connection {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Connection {
    pub fn open(addr: &str, timeout: Duration) -> Result<Connection> {
        let sockaddr: SocketAddr = addr
            .parse()
            .with_context(|| format!("bad server address '{addr}' (want IP:PORT)"))?;
        let stream = TcpStream::connect_timeout(&sockaddr, timeout)
            .with_context(|| format!("connecting to hsdag server at {addr}"))?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        stream.set_nodelay(true)?;
        let writer = stream.try_clone()?;
        Ok(Connection { reader: BufReader::new(stream), writer })
    }

    /// One exchange: write `request_line`, read the response line.
    pub fn send(&mut self, request_line: &str) -> Result<String> {
        self.writer.write_all(request_line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .context("reading response from hsdag server")?;
        if n == 0 {
            bail!("server closed the connection without responding");
        }
        Ok(line.trim_end().to_string())
    }
}

//! Co-location coarsening heuristic (Appendix G).
//!
//! For each vertex v_i in topological order: if v_j is the sole child of
//! v_i and v_i is the sole parent of v_j, they join the same co-location
//! set C_s. The coarsened graph CG has one node per co-location set; the
//! set's operation kind is the member whose kind index equals the rounded
//! mean of member kind indices ("the operation type of each co-location
//! set determined by the mean of the operation types", Appendix G), its
//! output shape/attrs come from the set's terminal member (the tensor that
//! actually crosses the set boundary), and its FLOPs are the members' sum.
//!
//! In addition to the paper's rule we fold `Constant` producers into their
//! consumer's set: OpenVINO never schedules a weight on a different device
//! from its op, and folding removes placement-rule violations by
//! construction (§2.2 "co-locating heuristics eliminate certain execution
//! failures").

use anyhow::{ensure, Result};

use crate::graph::{CompGraph, OpKind, OpNode};

/// Result of the co-location pass.
#[derive(Debug, Clone)]
pub struct Coarsening {
    /// Co-location set id for every original node.
    pub set_of: Vec<usize>,
    /// Number of sets (== coarse graph node count).
    pub n_sets: usize,
    /// The coarsened graph.
    pub coarse: CompGraph,
    /// For each set, the member ids in the original graph.
    pub members: Vec<Vec<usize>>,
}

impl Coarsening {
    /// Expand a placement over coarse nodes to a placement over original
    /// nodes. Errors (instead of panicking) when the placement length
    /// doesn't match the set count — the failure mode of pairing a
    /// placement with the wrong (e.g. user-supplied) graph.
    pub fn expand_placement(&self, coarse_placement: &[usize]) -> Result<Vec<usize>> {
        ensure!(
            coarse_placement.len() == self.n_sets,
            "placement covers {} co-location sets but the graph has {}",
            coarse_placement.len(),
            self.n_sets
        );
        Ok(self.set_of.iter().map(|&s| coarse_placement[s]).collect())
    }
}

/// Apply the Appendix-G co-location heuristic to `g`.
pub fn colocate(g: &CompGraph) -> Coarsening {
    let n = g.n();
    // Union-find over original nodes.
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        let mut r = x;
        while parent[r] != r {
            r = parent[r];
        }
        // Path compression.
        let mut c = x;
        while parent[c] != r {
            let nxt = parent[c];
            parent[c] = r;
            c = nxt;
        }
        r
    }

    // 1. Fold constants into their (unique) consumer.
    for v in 0..n {
        if g.nodes[v].kind == OpKind::Constant && g.out_degree(v) >= 1 {
            let c = g.out_neighbors(v)[0];
            let (rv, rc) = (find(&mut parent, v), find(&mut parent, c));
            if rv != rc {
                parent[rv] = rc;
            }
        }
    }

    // 2. The paper's rule, in topological order. Constant edges are
    // ignored when counting parents (the weight is already folded in).
    let order = g.topo_order().expect("DAG");
    for &vi in &order {
        if g.nodes[vi].kind == OpKind::Constant {
            continue;
        }
        let children: Vec<usize> = g.out_neighbors(vi).to_vec();
        if children.len() != 1 {
            continue;
        }
        let vj = children[0];
        let real_parents: Vec<usize> = g
            .in_neighbors(vj)
            .iter()
            .copied()
            .filter(|&p| g.nodes[p].kind != OpKind::Constant)
            .collect();
        if real_parents.len() == 1 && real_parents[0] == vi {
            let (ri, rj) = (find(&mut parent, vi), find(&mut parent, vj));
            if ri != rj {
                parent[ri] = rj;
            }
        }
    }

    // Dense set ids in topological order of each set's first member.
    let mut set_of = vec![usize::MAX; n];
    let mut members: Vec<Vec<usize>> = Vec::new();
    for &v in &order {
        let r = find(&mut parent, v);
        if set_of[r] == usize::MAX {
            set_of[r] = members.len();
            members.push(Vec::new());
        }
        set_of[v] = set_of[r];
        members[set_of[v]].push(v);
    }
    let n_sets = members.len();

    // Build the coarse graph.
    let mut coarse = CompGraph::new(format!("{}_coarse", g.name));
    for (s, mem) in members.iter().enumerate() {
        // Mean-of-kind-indices rule for the set's kind.
        let mean_idx = mem.iter().map(|&v| g.nodes[v].kind.index()).sum::<usize>() as f64
            / mem.len() as f64;
        let kind = OpKind::ALL[(mean_idx.round() as usize).min(OpKind::COUNT - 1)];
        // Terminal member: last in topo order within the set.
        let term = *mem.last().unwrap();
        let mut node = OpNode::new(
            format!("set{s}_{}", g.nodes[term].name),
            kind,
            g.nodes[term].output_shape.clone(),
        );
        node.attrs = g.nodes[term].attrs;
        // A set whose members all carry the same custom kind label keeps
        // it (typically a singleton from a loaded workload), so the
        // hashed one-hot slot survives coarsening; mixed sets fall back
        // to the mean-kind rule above.
        if mem.iter().all(|&v| g.nodes[v].custom_kind == g.nodes[term].custom_kind) {
            node.custom_kind = g.nodes[term].custom_kind.clone();
        }
        coarse.add_node(node);
    }
    for &(a, b) in &g.edges {
        let (sa, sb) = (set_of[a], set_of[b]);
        if sa != sb {
            coarse.add_edge(sa, sb);
        }
    }

    Coarsening { set_of, n_sets, coarse, members }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpNode};
    use crate::models::Benchmark;
    use crate::util::prop::{check, PropConfig};

    fn chain(n: usize) -> CompGraph {
        let mut g = CompGraph::new("chain");
        let mut prev = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 4]));
        for i in 0..n {
            let v = g.add_node(OpNode::new(format!("r{i}"), OpKind::Relu, vec![1, 4]));
            g.add_edge(prev, v);
            prev = v;
        }
        let out = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 4]));
        g.add_edge(prev, out);
        g
    }

    #[test]
    fn pure_chain_collapses_to_one_set() {
        let c = colocate(&chain(10));
        assert_eq!(c.n_sets, 1);
        assert_eq!(c.coarse.n(), 1);
        assert_eq!(c.coarse.m(), 0);
    }

    #[test]
    fn diamond_keeps_branches_separate() {
        let mut g = CompGraph::new("d");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1]));
        let a = g.add_node(OpNode::new("a", OpKind::Relu, vec![1]));
        let b = g.add_node(OpNode::new("b", OpKind::Sigmoid, vec![1]));
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        let c = colocate(&g);
        // in has 2 children (no merge); a,b each have sole child `out`, but
        // out has 2 parents -> no merge anywhere.
        assert_eq!(c.n_sets, 4);
    }

    #[test]
    fn constants_fold_into_consumer() {
        let mut g = CompGraph::new("c");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1]));
        let w = g.add_node(OpNode::new("w", OpKind::Constant, vec![1]));
        let m = g.add_node(OpNode::new("mm", OpKind::MatMul, vec![1]));
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1]));
        g.add_edge(i, m);
        g.add_edge(w, m);
        g.add_edge(m, o);
        let c = colocate(&g);
        assert_eq!(c.set_of[w], c.set_of[m], "weight folded into its consumer");
    }

    #[test]
    fn expand_placement_roundtrip() {
        let c = colocate(&chain(5));
        let p = c.expand_placement(&vec![1; c.n_sets]).unwrap();
        assert!(p.iter().all(|&d| d == 1));
        assert_eq!(p.len(), 7);
    }

    #[test]
    fn expand_placement_length_mismatch_is_an_error() {
        let c = colocate(&chain(5));
        let err = c.expand_placement(&vec![0; c.n_sets + 3]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("co-location sets"), "{msg}");
        assert!(c.expand_placement(&[]).is_err());
    }

    #[test]
    fn benchmarks_coarsen_substantially() {
        for b in Benchmark::ALL {
            let g = b.build();
            let c = colocate(&g);
            assert!(
                c.n_sets * 2 < g.n(),
                "{}: {} sets from {} nodes",
                b.id(),
                c.n_sets,
                g.n()
            );
            assert!(c.coarse.is_dag(), "{}: coarse graph must stay a DAG", b.id());
        }
    }

    #[test]
    fn colocate_covers_every_node_exactly_once_prop() {
        // The co-location sets are a partition: every original node lands
        // in exactly one member list, and `set_of` agrees with it.
        check(
            "coarsen-partition",
            PropConfig { cases: 48, max_size: 100, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let c = colocate(&g);
                let mut count = vec![0usize; g.n()];
                for mem in &c.members {
                    for &v in mem {
                        count[v] += 1;
                    }
                }
                if let Some(v) = count.iter().position(|&k| k != 1) {
                    return Err(format!("node {v} covered {} times", count[v]));
                }
                if c.set_of.len() != g.n() {
                    return Err(format!("set_of len {} != {}", c.set_of.len(), g.n()));
                }
                if c.n_sets != c.members.len() || c.coarse.n() != c.n_sets {
                    return Err("set count / coarse node count mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn expand_placement_roundtrips_group_actions_prop() {
        // Expanding a per-group action vector assigns every original node
        // exactly its group's action: members of one set always share a
        // device, and nothing else leaks in.
        check(
            "coarsen-expand-roundtrip",
            PropConfig { cases: 48, max_size: 100, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 4);
                let c = colocate(&g);
                let k = 2 + rng.below(4);
                let actions: Vec<usize> = (0..c.n_sets).map(|_| rng.below(k)).collect();
                let p = c.expand_placement(&actions).map_err(|e| format!("{e:#}"))?;
                if p.len() != g.n() {
                    return Err(format!("expanded {} of {} nodes", p.len(), g.n()));
                }
                for v in 0..g.n() {
                    if p[v] != actions[c.set_of[v]] {
                        return Err(format!(
                            "node {v}: device {} != group action {}",
                            p[v],
                            actions[c.set_of[v]]
                        ));
                    }
                }
                for (s, mem) in c.members.iter().enumerate() {
                    if mem.iter().any(|&v| p[v] != p[mem[0]]) {
                        return Err(format!("set {s} split across devices"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn coarse_graph_is_dag_prop() {
        check("coarsen-dag", PropConfig { cases: 48, max_size: 100, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 3);
            let c = colocate(&g);
            if !c.coarse.is_dag() {
                return Err("coarse graph has a cycle".into());
            }
            if c.set_of.iter().any(|&s| s >= c.n_sets) {
                return Err("set id out of range".into());
            }
            // Every set non-empty and members consistent.
            for (s, mem) in c.members.iter().enumerate() {
                if mem.is_empty() {
                    return Err(format!("empty set {s}"));
                }
                for &v in mem {
                    if c.set_of[v] != s {
                        return Err("member/set mismatch".into());
                    }
                }
            }
            Ok(())
        });
    }
}

//! Co-location coarsening heuristic (Appendix G).
//!
//! For each vertex v_i in topological order: if v_j is the sole child of
//! v_i and v_i is the sole parent of v_j, they join the same co-location
//! set C_s. The coarsened graph CG has one node per co-location set; the
//! set's operation kind is the member whose kind index equals the rounded
//! mean of member kind indices ("the operation type of each co-location
//! set determined by the mean of the operation types", Appendix G), its
//! output shape/attrs come from the set's terminal member (the tensor that
//! actually crosses the set boundary), and its FLOPs are the members' sum.
//!
//! In addition to the paper's rule we fold `Constant` producers into their
//! consumer's set: OpenVINO never schedules a weight on a different device
//! from its op, and folding removes placement-rule violations by
//! construction (§2.2 "co-locating heuristics eliminate certain execution
//! failures").
//!
//! **Multi-level coarsening** ([`coarsen_to_budget`]): one co-location
//! pass rarely shrinks a 100k-node graph below what the policy can
//! afford, so levels stack — each level re-runs co-location on the
//! previous coarse graph and, when that stalls, a *layer-matching* pass
//! pairs nodes within one longest-path depth layer. Same-layer merges
//! can never create a cycle: every edge strictly increases the layer, so
//! any coarse edge between layer-homogeneous sets strictly increases the
//! layer too. Placements over the coarsest graph expand back down via
//! [`MultiLevel::expand_placement`] (composition of per-level
//! expansions) and refine greedily per level via
//! [`MultiLevel::refine_placement`]; [`MultiLevel::flatten`] collapses
//! the stack to a single [`Coarsening`] so downstream consumers (the
//! RL env, the serve daemon) stay single-level-shaped.

use anyhow::{ensure, Result};

use crate::graph::{CompGraph, OpKind, OpNode};
use crate::sim::{DeviceId, IncrementalEvaluator, Testbed};

/// Result of the co-location pass.
#[derive(Debug, Clone)]
pub struct Coarsening {
    /// Co-location set id for every original node.
    pub set_of: Vec<usize>,
    /// Number of sets (== coarse graph node count).
    pub n_sets: usize,
    /// The coarsened graph.
    pub coarse: CompGraph,
    /// For each set, the member ids in the original graph.
    pub members: Vec<Vec<usize>>,
}

impl Coarsening {
    /// Expand a placement over coarse nodes to a placement over original
    /// nodes. Errors (instead of panicking) when the placement length
    /// doesn't match the set count — the failure mode of pairing a
    /// placement with the wrong (e.g. user-supplied) graph.
    pub fn expand_placement(&self, coarse_placement: &[usize]) -> Result<Vec<usize>> {
        ensure!(
            coarse_placement.len() == self.n_sets,
            "placement covers {} co-location sets but the graph has {}",
            coarse_placement.len(),
            self.n_sets
        );
        Ok(self.set_of.iter().map(|&s| coarse_placement[s]).collect())
    }
}

/// Union-find root with path compression (shared by the coarsening
/// passes and the set assembly).
fn find(parent: &mut [usize], x: usize) -> usize {
    let mut r = x;
    while parent[r] != r {
        r = parent[r];
    }
    let mut c = x;
    while parent[c] != r {
        let nxt = parent[c];
        parent[c] = r;
        c = nxt;
    }
    r
}

/// Apply the Appendix-G co-location heuristic to `g`.
pub fn colocate(g: &CompGraph) -> Coarsening {
    let n = g.n();
    // Union-find over original nodes.
    let mut parent: Vec<usize> = (0..n).collect();

    // 1. Fold constants into their (unique) consumer.
    for v in 0..n {
        if g.nodes[v].kind == OpKind::Constant && g.out_degree(v) >= 1 {
            let c = g.out_neighbors(v)[0];
            let (rv, rc) = (find(&mut parent, v), find(&mut parent, c));
            if rv != rc {
                parent[rv] = rc;
            }
        }
    }

    // 2. The paper's rule, in topological order. Constant edges are
    // ignored when counting parents (the weight is already folded in).
    let order = g.topo_order().expect("DAG");
    for &vi in &order {
        if g.nodes[vi].kind == OpKind::Constant {
            continue;
        }
        let children: Vec<usize> = g.out_neighbors(vi).to_vec();
        if children.len() != 1 {
            continue;
        }
        let vj = children[0];
        let real_parents: Vec<usize> = g
            .in_neighbors(vj)
            .iter()
            .copied()
            .filter(|&p| g.nodes[p].kind != OpKind::Constant)
            .collect();
        if real_parents.len() == 1 && real_parents[0] == vi {
            let (ri, rj) = (find(&mut parent, vi), find(&mut parent, vj));
            if ri != rj {
                parent[ri] = rj;
            }
        }
    }

    assemble(g, parent, &order)
}

/// Turn a union-find `parent` forest over `g`'s nodes into a
/// [`Coarsening`]: dense set ids in topological order of each set's
/// first member, coarse nodes under the mean-kind/terminal-member rules,
/// deduplicated coarse edges.
fn assemble(g: &CompGraph, mut parent: Vec<usize>, order: &[usize]) -> Coarsening {
    let n = g.n();
    let mut set_of = vec![usize::MAX; n];
    let mut members: Vec<Vec<usize>> = Vec::new();
    for &v in order {
        let r = find(&mut parent, v);
        if set_of[r] == usize::MAX {
            set_of[r] = members.len();
            members.push(Vec::new());
        }
        set_of[v] = set_of[r];
        members[set_of[v]].push(v);
    }
    let n_sets = members.len();

    // Build the coarse graph.
    let mut coarse = CompGraph::new(format!("{}_coarse", g.name));
    for (s, mem) in members.iter().enumerate() {
        // Mean-of-kind-indices rule for the set's kind.
        let mean_idx = mem.iter().map(|&v| g.nodes[v].kind.index()).sum::<usize>() as f64
            / mem.len() as f64;
        let kind = OpKind::ALL[(mean_idx.round() as usize).min(OpKind::COUNT - 1)];
        // Terminal member: last in topo order within the set.
        let term = *mem.last().unwrap();
        let mut node = OpNode::new(
            format!("set{s}_{}", g.nodes[term].name),
            kind,
            g.nodes[term].output_shape.clone(),
        );
        node.attrs = g.nodes[term].attrs;
        // A set whose members all carry the same custom kind label keeps
        // it (typically a singleton from a loaded workload), so the
        // hashed one-hot slot survives coarsening; mixed sets fall back
        // to the mean-kind rule above.
        if mem.iter().all(|&v| g.nodes[v].custom_kind == g.nodes[term].custom_kind) {
            node.custom_kind = g.nodes[term].custom_kind.clone();
        }
        coarse.add_node(node);
    }
    for &(a, b) in &g.edges {
        let (sa, sb) = (set_of[a], set_of[b]);
        if sa != sb {
            coarse.add_edge(sa, sb);
        }
    }

    Coarsening { set_of, n_sets, coarse, members }
}

/// Layer-matching coarsening pass: pair nodes within one longest-path
/// depth layer (preferring siblings — nodes sharing their first
/// in-neighbor). Cycle-safe by construction: every edge strictly
/// increases the layer, so no directed path connects two same-layer
/// nodes, and every coarse edge between layer-homogeneous sets still
/// strictly increases the layer.
fn colocate_layers(g: &CompGraph) -> Coarsening {
    let n = g.n();
    let order = g.topo_order().expect("DAG");
    let mut layer = vec![0usize; n];
    for &v in &order {
        for &w in g.out_neighbors(v) {
            layer[w] = layer[w].max(layer[v] + 1);
        }
    }
    let max_layer = layer.iter().copied().max().unwrap_or(0);
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); max_layer + 1];
    for v in 0..n {
        buckets[layer[v]].push(v);
    }
    let mut parent: Vec<usize> = (0..n).collect();
    for bucket in buckets.iter_mut() {
        bucket.sort_by_key(|&v| (g.in_neighbors(v).first().copied().unwrap_or(v), v));
        for pair in bucket.chunks(2) {
            if let [a, b] = *pair {
                parent[b] = a;
            }
        }
    }
    assemble(g, parent, &order)
}

/// Default working-graph budget for multi-level coarsening
/// (`Config::coarsen_budget`, `--coarsen-budget`). Paper-scale
/// benchmarks (≤ ~1k nodes) stay single-level under it.
pub const DEFAULT_COARSEN_BUDGET: usize = 8192;

/// A stack of coarsening levels: `levels[0]` coarsens the original
/// graph, `levels[i]` coarsens `levels[i-1].coarse`. The policy places
/// the coarsest graph; placements expand back down level by level.
#[derive(Debug, Clone)]
pub struct MultiLevel {
    pub levels: Vec<Coarsening>,
}

impl MultiLevel {
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// The coarsest (policy-facing) graph.
    pub fn coarsest(&self) -> &CompGraph {
        &self.levels.last().expect("at least one level").coarse
    }

    /// Group count of the coarsest level.
    pub fn n_sets(&self) -> usize {
        self.levels.last().expect("at least one level").n_sets
    }

    /// Expand a coarsest-level placement to original nodes by composing
    /// every level's expansion top-down.
    pub fn expand_placement(&self, coarse_placement: &[usize]) -> Result<Vec<usize>> {
        let mut p = coarse_placement.to_vec();
        for lvl in self.levels.iter().rev() {
            p = lvl.expand_placement(&p)?;
        }
        Ok(p)
    }

    /// Collapse the stack to one [`Coarsening`] mapping original nodes
    /// straight to coarsest sets, so single-level consumers (the RL env,
    /// serving) need no code changes. A one-level stack flattens to
    /// exactly that level.
    pub fn flatten(&self) -> Coarsening {
        if self.levels.len() == 1 {
            return self.levels[0].clone();
        }
        let mut set_of = self.levels[0].set_of.clone();
        for lvl in &self.levels[1..] {
            for s in set_of.iter_mut() {
                *s = lvl.set_of[*s];
            }
        }
        let last = self.levels.last().expect("at least one level");
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); last.n_sets];
        for (v, &s) in set_of.iter().enumerate() {
            members[s].push(v);
        }
        Coarsening { set_of, n_sets: last.n_sets, coarse: last.coarse.clone(), members }
    }

    /// Greedy V-cycle refinement: walk levels coarsest → finest; at each
    /// level with at most `cap` groups, sweep the groups once, moving
    /// each group to the device (out of `devices`) that minimizes the
    /// makespan of the *fully expanded* placement on the original graph
    /// — evaluated incrementally, so each trial only re-simulates from
    /// the first affected event. Infeasible (OOM) candidates never win
    /// over feasible ones. The result is never worse than the plain
    /// expansion of `coarse_actions`.
    pub fn refine_placement(
        &self,
        g: &CompGraph,
        tb: &Testbed,
        coarse_actions: &[usize],
        devices: &[DeviceId],
        cap: usize,
    ) -> Result<Vec<usize>> {
        ensure!(!devices.is_empty(), "refinement needs at least one candidate device");
        let mut eval = IncrementalEvaluator::new(g.clone(), tb.clone());
        // Per-level group placement, starting at the coarsest level.
        let mut p = coarse_actions.to_vec();
        for (k, lvl) in self.levels.iter().enumerate().rev() {
            ensure!(
                p.len() == lvl.n_sets,
                "level {k} placement covers {} groups, want {}",
                p.len(),
                lvl.n_sets
            );
            if lvl.n_sets <= cap {
                let expand_full = |pk: &[usize]| -> Result<Vec<usize>> {
                    let mut q = pk.to_vec();
                    for l in self.levels[..=k].iter().rev() {
                        q = l.expand_placement(&q)?;
                    }
                    Ok(q)
                };
                let base = expand_full(&p)?;
                let r = eval.evaluate(&base);
                let (mut best_mk, mut best_ok) = (r.makespan, r.feasible());
                for s in 0..lvl.n_sets {
                    for &d in devices {
                        if d == p[s] {
                            continue;
                        }
                        let prev = p[s];
                        p[s] = d;
                        let full = expand_full(&p)?;
                        let r = eval.evaluate(&full);
                        let better = if r.feasible() {
                            !best_ok || r.makespan < best_mk
                        } else {
                            false
                        };
                        if better {
                            best_mk = r.makespan;
                            best_ok = true;
                        } else {
                            p[s] = prev;
                        }
                    }
                }
            }
            // Descend one level: group placement over the next-finer set.
            p = lvl.expand_placement(&p)?;
        }
        Ok(p)
    }
}

/// Recursively coarsen `g` until the working graph fits `budget` nodes
/// (or no pass makes progress — the budget is best-effort on adversarial
/// layerings). Each round tries a fresh co-location pass first (merging
/// exposes new chains), then falls back to the layer-matching pass.
pub fn coarsen_to_budget(g: &CompGraph, budget: usize) -> MultiLevel {
    let budget = budget.max(1);
    let mut levels = vec![colocate(g)];
    loop {
        let next = {
            let top = &levels.last().expect("seeded").coarse;
            let n = top.n();
            if n <= budget || levels.len() >= 64 {
                break;
            }
            let c = colocate(top);
            let c = if c.n_sets < n { c } else { colocate_layers(top) };
            if c.n_sets >= n {
                break; // no progress possible
            }
            c
        };
        levels.push(next);
    }
    MultiLevel { levels }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpNode};
    use crate::models::Benchmark;
    use crate::util::prop::{check, PropConfig};

    fn chain(n: usize) -> CompGraph {
        let mut g = CompGraph::new("chain");
        let mut prev = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 4]));
        for i in 0..n {
            let v = g.add_node(OpNode::new(format!("r{i}"), OpKind::Relu, vec![1, 4]));
            g.add_edge(prev, v);
            prev = v;
        }
        let out = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 4]));
        g.add_edge(prev, out);
        g
    }

    #[test]
    fn pure_chain_collapses_to_one_set() {
        let c = colocate(&chain(10));
        assert_eq!(c.n_sets, 1);
        assert_eq!(c.coarse.n(), 1);
        assert_eq!(c.coarse.m(), 0);
    }

    #[test]
    fn diamond_keeps_branches_separate() {
        let mut g = CompGraph::new("d");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1]));
        let a = g.add_node(OpNode::new("a", OpKind::Relu, vec![1]));
        let b = g.add_node(OpNode::new("b", OpKind::Sigmoid, vec![1]));
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        let c = colocate(&g);
        // in has 2 children (no merge); a,b each have sole child `out`, but
        // out has 2 parents -> no merge anywhere.
        assert_eq!(c.n_sets, 4);
    }

    #[test]
    fn constants_fold_into_consumer() {
        let mut g = CompGraph::new("c");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1]));
        let w = g.add_node(OpNode::new("w", OpKind::Constant, vec![1]));
        let m = g.add_node(OpNode::new("mm", OpKind::MatMul, vec![1]));
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1]));
        g.add_edge(i, m);
        g.add_edge(w, m);
        g.add_edge(m, o);
        let c = colocate(&g);
        assert_eq!(c.set_of[w], c.set_of[m], "weight folded into its consumer");
    }

    #[test]
    fn expand_placement_roundtrip() {
        let c = colocate(&chain(5));
        let p = c.expand_placement(&vec![1; c.n_sets]).unwrap();
        assert!(p.iter().all(|&d| d == 1));
        assert_eq!(p.len(), 7);
    }

    #[test]
    fn expand_placement_length_mismatch_is_an_error() {
        let c = colocate(&chain(5));
        let err = c.expand_placement(&vec![0; c.n_sets + 3]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("co-location sets"), "{msg}");
        assert!(c.expand_placement(&[]).is_err());
    }

    #[test]
    fn benchmarks_coarsen_substantially() {
        for b in Benchmark::ALL {
            let g = b.build();
            let c = colocate(&g);
            assert!(
                c.n_sets * 2 < g.n(),
                "{}: {} sets from {} nodes",
                b.id(),
                c.n_sets,
                g.n()
            );
            assert!(c.coarse.is_dag(), "{}: coarse graph must stay a DAG", b.id());
        }
    }

    #[test]
    fn colocate_covers_every_node_exactly_once_prop() {
        // The co-location sets are a partition: every original node lands
        // in exactly one member list, and `set_of` agrees with it.
        check(
            "coarsen-partition",
            PropConfig { cases: 48, max_size: 100, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let c = colocate(&g);
                let mut count = vec![0usize; g.n()];
                for mem in &c.members {
                    for &v in mem {
                        count[v] += 1;
                    }
                }
                if let Some(v) = count.iter().position(|&k| k != 1) {
                    return Err(format!("node {v} covered {} times", count[v]));
                }
                if c.set_of.len() != g.n() {
                    return Err(format!("set_of len {} != {}", c.set_of.len(), g.n()));
                }
                if c.n_sets != c.members.len() || c.coarse.n() != c.n_sets {
                    return Err("set count / coarse node count mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn expand_placement_roundtrips_group_actions_prop() {
        // Expanding a per-group action vector assigns every original node
        // exactly its group's action: members of one set always share a
        // device, and nothing else leaks in.
        check(
            "coarsen-expand-roundtrip",
            PropConfig { cases: 48, max_size: 100, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 4);
                let c = colocate(&g);
                let k = 2 + rng.below(4);
                let actions: Vec<usize> = (0..c.n_sets).map(|_| rng.below(k)).collect();
                let p = c.expand_placement(&actions).map_err(|e| format!("{e:#}"))?;
                if p.len() != g.n() {
                    return Err(format!("expanded {} of {} nodes", p.len(), g.n()));
                }
                for v in 0..g.n() {
                    if p[v] != actions[c.set_of[v]] {
                        return Err(format!(
                            "node {v}: device {} != group action {}",
                            p[v],
                            actions[c.set_of[v]]
                        ));
                    }
                }
                for (s, mem) in c.members.iter().enumerate() {
                    if mem.iter().any(|&v| p[v] != p[mem[0]]) {
                        return Err(format!("set {s} split across devices"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn multi_level_hits_budget_on_wide_graphs() {
        let g = crate::models::synth::layered(48, 24, 7);
        let ml = coarsen_to_budget(&g, 64);
        assert!(ml.n_levels() > 1, "wide graph should need several levels");
        assert!(ml.coarsest().n() <= 64, "coarsest has {} nodes", ml.coarsest().n());
        for lvl in &ml.levels {
            assert!(lvl.coarse.is_dag());
        }
    }

    #[test]
    fn flatten_of_single_level_is_colocate() {
        let g = Benchmark::ResNet50.build();
        let ml = coarsen_to_budget(&g, DEFAULT_COARSEN_BUDGET);
        assert_eq!(ml.n_levels(), 1, "paper-scale graphs stay single-level");
        let flat = ml.flatten();
        let c = colocate(&g);
        assert_eq!(flat.set_of, c.set_of);
        assert_eq!(flat.n_sets, c.n_sets);
        assert_eq!(flat.coarse.n(), c.coarse.n());
        assert_eq!(flat.coarse.edges, c.coarse.edges);
    }

    #[test]
    fn multi_level_invariants_per_level_prop() {
        // At EVERY level: the sets are an exact cover of that level's
        // input graph and the coarse graph is a DAG; composed expansion
        // agrees with the flattened expansion node for node.
        check(
            "coarsen-multilevel",
            PropConfig { cases: 32, max_size: 120, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let budget = 1 + rng.below(16);
                let ml = coarsen_to_budget(&g, budget);
                let mut n_in = g.n();
                for (k, lvl) in ml.levels.iter().enumerate() {
                    if lvl.set_of.len() != n_in {
                        return Err(format!("level {k}: set_of len {}", lvl.set_of.len()));
                    }
                    let mut count = vec![0usize; n_in];
                    for mem in &lvl.members {
                        if mem.is_empty() {
                            return Err(format!("level {k}: empty set"));
                        }
                        for &v in mem {
                            count[v] += 1;
                        }
                    }
                    if count.iter().any(|&c| c != 1) {
                        return Err(format!("level {k}: not an exact cover"));
                    }
                    if !lvl.coarse.is_dag() {
                        return Err(format!("level {k}: coarse graph not a DAG"));
                    }
                    if lvl.coarse.n() != lvl.n_sets {
                        return Err(format!("level {k}: coarse n != n_sets"));
                    }
                    n_in = lvl.n_sets;
                }
                // Composed vs flattened expansion.
                let k_dev = 2 + rng.below(3);
                let actions: Vec<usize> = (0..ml.n_sets()).map(|_| rng.below(k_dev)).collect();
                let composed = ml.expand_placement(&actions).map_err(|e| format!("{e:#}"))?;
                let flat = ml.flatten();
                let direct = flat.expand_placement(&actions).map_err(|e| format!("{e:#}"))?;
                if composed != direct {
                    return Err("composed expansion != flattened expansion".into());
                }
                if composed.len() != g.n() {
                    return Err("expansion misses nodes".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn refine_never_worse_than_plain_expansion() {
        use crate::sim::{execute, Placement, Testbed};
        let tb = Testbed::paper();
        check(
            "coarsen-refine",
            PropConfig { cases: 12, max_size: 70, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let ml = coarsen_to_budget(&g, 8);
                let actions: Vec<usize> =
                    (0..ml.n_sets()).map(|_| tb.placeable[rng.below(tb.placeable.len())]).collect();
                let base = ml.expand_placement(&actions).map_err(|e| format!("{e:#}"))?;
                let refined = ml
                    .refine_placement(&g, &tb, &actions, &tb.placeable, 64)
                    .map_err(|e| format!("{e:#}"))?;
                let mk_base = execute(&g, &Placement(base), &tb).makespan;
                let mk_ref = execute(&g, &Placement(refined), &tb).makespan;
                if mk_ref > mk_base {
                    return Err(format!("refinement regressed: {mk_ref} > {mk_base}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn coarse_graph_is_dag_prop() {
        check("coarsen-dag", PropConfig { cases: 48, max_size: 100, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 3);
            let c = colocate(&g);
            if !c.coarse.is_dag() {
                return Err("coarse graph has a cycle".into());
            }
            if c.set_of.iter().any(|&s| s >= c.n_sets) {
                return Err("set id out of range".into());
            }
            // Every set non-empty and members consistent.
            for (s, mem) in c.members.iter().enumerate() {
                if mem.is_empty() {
                    return Err(format!("empty set {s}"));
                }
                for &v in mem {
                    if c.set_of[v] != s {
                        return Err("member/set mismatch".into());
                    }
                }
            }
            Ok(())
        });
    }
}

//! # HSDAG — structure-aware learned device placement
//!
//! A rust + JAX + Pallas reproduction of *"A Structure-Aware Framework for
//! Learning Device Placements on Computation Graphs"* (NeurIPS 2024).
//!
//! The crate owns the computation-graph substrate, feature extraction,
//! graph-parsing partitioner, heterogeneous execution simulator, the
//! REINFORCE search loop, the baselines, and the experiment harness that
//! regenerates every table and figure of the paper. What gets placed is
//! open-world: the [`models::Workload`] registry resolves `--workload`
//! specs (paper benchmarks, `file:` graphs in the JSON/DOT formats of
//! [`graph`], parametric synthetic generators), and the
//! [`harness::generalize`] harness trains one policy across a workload
//! suite and zero-shot evaluates held-out graphs. Neural compute runs
//! behind the [`rl::PolicyBackend`] trait with two interchangeable
//! implementations:
//!
//! - **native** (default) — pure-rust f32 kernels ([`runtime::nn`]); the
//!   whole pipeline, *including end-to-end HSDAG training*, runs with no
//!   artifacts, no python and no external dependencies;
//! - **pjrt** — AOT-compiled JAX/Pallas policies (HLO text from
//!   `make artifacts`) executed through the PJRT [`runtime::Engine`], the
//!   paper-faithful path.
//!
//! `--backend {native,pjrt,auto}` selects one; `auto` picks pjrt exactly
//! when `artifacts/` holds compiled artifacts. See DESIGN.md for the
//! system inventory.
//!
//! Trained policies outlive their process through the [`serve`]
//! subsystem: `hsdag-params-v1` checkpoints (`--save` / `--load`),
//! structural graph fingerprints, an LRU placement cache, and the
//! multi-threaded `hsdag serve` daemon with its `hsdag request` client.

pub mod baselines;
pub mod coarsen;
pub mod cli;
pub mod config;
pub mod features;
pub mod graph;
pub mod harness;
pub mod models;
pub mod obs;
pub mod parsing;
pub mod rl;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;

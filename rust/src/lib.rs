//! # HSDAG — structure-aware learned device placement
//!
//! A rust + JAX + Pallas reproduction of *"A Structure-Aware Framework for
//! Learning Device Placements on Computation Graphs"* (NeurIPS 2024).
//!
//! The crate is the Layer-3 coordinator: it owns the computation-graph
//! substrate, feature extraction, graph-parsing partitioner, heterogeneous
//! execution simulator, PJRT runtime (loading AOT-compiled JAX/Pallas
//! policies from `artifacts/`), the REINFORCE search loop, the baselines,
//! and the experiment harness that regenerates every table and figure of
//! the paper. See DESIGN.md for the system inventory.

pub mod baselines;
pub mod coarsen;
pub mod cli;
pub mod config;
pub mod features;
pub mod graph;
pub mod harness;
pub mod models;
pub mod parsing;
pub mod rl;
pub mod runtime;
pub mod sim;
pub mod util;

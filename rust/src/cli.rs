//! Hand-rolled CLI (the offline crate set has no clap).
//!
//! `hsdag <command> [--flag value]...` — see `usage()` for the command
//! list. Flags are parsed into a key/value map; each command pulls what it
//! needs and falls back to the Table 6 defaults.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::features::FeatureConfig;
use crate::models::Workload;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Cli {
    pub command: String,
    pub flags: HashMap<String, String>,
    /// Positional arguments after the command. Only commands that opt in
    /// (`trace`, with its `summarize <log>` sub-shape) accept any;
    /// everywhere else a bare word is still a parse error.
    pub args: Vec<String>,
}

pub fn usage() -> &'static str {
    "hsdag — structure-aware learned device placement (NeurIPS'24 reproduction)

USAGE: hsdag <command> [--flag value]...

COMMANDS
  table1                 graph statistics (Table 1)
  table2                 baseline comparison (Table 2)     [--episodes N]
  table3                 feature ablations (Table 3)       [--episodes N]
  table4                 BERT downstream drift (Table 4)
  table5                 search runtime (Table 5)          [--episodes N]
  figure2                partition DOT dumps (Figure 2)    [--out-dir D] [--episodes N]
  train                  run one HSDAG search              [--workload W] [--episodes N]
                                                           [--save CKPT] [--load CKPT]
  place                  evaluate a fixed placement        [--workload W] [--method M]
                         (or a loaded policy's)            [--load CKPT] [--dump-dot F]
                                                           [--refine-cap N]
  generalize             train one policy on a workload    [--train A,B,..] [--eval C,D,..]
                         suite, zero-shot eval held-out    [--episodes N] [--rollouts N]
                                                           [--save CKPT]
                                                           [--eval-only --load CKPT]
  serve                  placement shard over a trained    --load CKPT [--addr IP:PORT]
                         checkpoint (see README \"Serving\") [--serve-workers N] [--queue-depth N]
                         SIGHUP or ctrl:reload hot-swaps   [--cache-capacity N] [--budget-ms X]
                         the checkpoint with zero downtime [--rollouts N]
  route                  consistent-hash router over N     --shards A,B,.. [--addr IP:PORT]
                         shards (see README \"Fleet\")       [--serve-workers N] [--queue-depth N]
                                                           [--timeout-s X]
  request                client for a server / router      [--addr IP:PORT] [--workload W]
                                                           [--graph F] [--id X] [--budget-ms X]
                                                           [--rollouts N] [--no-cache]
                                                           [--tenant T] [--retries N]
                                                           [--shards A,B,..] (client-side routing)
                                                           [--stats] [--shutdown]
                                                           [--reload [--checkpoint CKPT]]
                                                           [--clear-cache]
  export                 write a workload as v1 JSON       [--workload W] [--out F]
  graph-stats            validate + describe workloads     [--workload W]
  trace summarize LOG    per-stage p50/p95/p99 latency table from an
                         hsdag-trace-v1 JSONL log (--trace-log output)
  config                 print the Table 6 hyper-parameters

COMMON FLAGS
  --workload SPEC                   what to place (default resnet). Registry specs:
                                    inception | resnet | bert   (paper benchmarks)
                                    file:<path>{.json|.dot}     (on-disk graph)
                                    seq:<n> | layered:<d>x<w>[:<seed>]
                                    transformer:<layers>:<heads> | random:<n>[:<seed>]
  --bench B                         legacy alias for --workload
  --testbed ID                      device set: cpu_gpu | paper3 | cpu_gpu_tight | multi_gpu:<k>[:<mem_gb>]
                                    (default cpu_gpu — the paper's 2-way CPU/dGPU setup;
                                    cpu_gpu_tight / :<mem_gb> bound device memory)
  --backend native|pjrt|auto        policy backend (default auto: pjrt when the artifacts
                                    directory holds compiled *.hlo.txt artifacts, else the
                                    pure-rust native kernels — training needs no artifacts
                                    on the native backend)
  --episodes N                      RL search episodes (default 30)
  --seed N                          RNG seed (default 0)
  --oom-penalty X                   reward for infeasible (OOM) placements during search (default 0)
  --workers N                       threads for every data-parallel path: batched placement
                                    evaluation, the native policy kernels, rollout fan-out and
                                    the router scatter (default 0 = one per core; results are
                                    bit-identical at any worker count)
  --fast-math                       opt-in reassociated 8-wide lane kernels in the native policy
                                    (faster, deterministic, but only tolerance-equal to the
                                    default bit-reproducible kernels)
  --artifacts DIR                   artifacts directory (default artifacts)
  --no-baseline                     disable the EMA reward baseline (paper-literal Eq. 14)
  --no-shape | --no-node-id | --no-structural   feature ablations
  --coarsen-budget N                working-graph node budget for multi-level coarsening
                                    (default 8192; see README \"Scaling\")
  --exact-fractal                   pin exact per-node fractal dimensions (disables the
                                    sampled landmark estimator on large graphs)
  --out-dir DIR                     output directory (default results)
  --save PATH                       write an hsdag-params-v1 policy checkpoint (train /
                                    generalize: on best-so-far / per round, and at exit)
  --load PATH                       read a checkpoint (place / generalize --eval-only / serve,
                                    or train — warm-start fine-tuning); layout or testbed-width
                                    mismatches are clear errors

OBSERVABILITY (see README \"Observability\")
  --log-level L                     stderr verbosity: off | error | warn | info | debug
                                    (default info; the HSDAG_LOG env var sets the same knob,
                                    the flag wins). User-facing banners/tables are unaffected.
  --profile                         opt-in kernel/pool profiling counters (per-kernel calls,
                                    wall ns, flops; worker busy time) in the metrics registry
  --trace-log PATH                  serve / route: append one hsdag-trace-v1 JSONL line per
                                    place request (per-stage spans; summarize with
                                    `hsdag trace summarize PATH`)
  --run-log PATH                    train: append one hsdag-run-v1 JSONL record per episode
                                    (reward / loss / entropy / param-norm)
  --trace-id X                      request: tag the place request with a trace id, echoed in
                                    the response and in server-side trace lines
  --metrics                         request: dump the server's metrics registry
                                    (hsdag-metrics-v1; a router aggregates the fleet's)
"
}

/// Parse `args` (excluding argv[0]).
pub fn parse(args: &[String]) -> Result<Cli> {
    if args.is_empty() {
        bail!("no command given\n\n{}", usage());
    }
    let command = args[0].clone();
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 1;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            // Boolean flags take no value; everything else takes one.
            let boolean = matches!(
                key,
                "no-baseline"
                    | "no-shape"
                    | "no-node-id"
                    | "no-structural"
                    | "exact-fractal"
                    | "fast-math"
                    | "help"
                    | "eval-only"
                    | "stats"
                    | "shutdown"
                    | "no-cache"
                    | "reload"
                    | "clear-cache"
                    | "metrics"
                    | "profile"
            );
            if boolean {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                if i + 1 >= args.len() {
                    bail!("flag --{key} needs a value");
                }
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            }
        } else if command == "trace" {
            // `hsdag trace summarize <log>` — the one command with a
            // positional sub-shape.
            positional.push(a.clone());
            i += 1;
        } else {
            bail!("unexpected argument '{a}'\n\n{}", usage());
        }
    }
    Ok(Cli { command, flags, args: positional })
}

impl Cli {
    pub fn usize_flag(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Comma-separated list flag (empty entries dropped).
    pub fn str_list_flag(&self, key: &str, default: &str) -> Vec<String> {
        self.str_flag(key, default)
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }

    /// Resolve `--workload` (falling back to its legacy `--bench` alias,
    /// default resnet) through the workload registry.
    pub fn workload(&self) -> Result<Workload> {
        let spec = self
            .flags
            .get("workload")
            .or_else(|| self.flags.get("bench"))
            .cloned()
            .unwrap_or_else(|| "resnet".to_string());
        Workload::resolve(&spec)
    }

    /// Assemble the run Config from flags.
    pub fn config(&self) -> Result<Config> {
        let cfg = Config {
            seed: self.usize_flag("seed", 0)? as u64,
            artifacts_dir: self.str_flag("artifacts", "artifacts"),
            max_episodes: self.usize_flag("episodes", 30)?,
            testbed: self.str_flag("testbed", "cpu_gpu"),
            backend: self.str_flag("backend", "auto"),
            oom_penalty: self.f64_flag("oom-penalty", 0.0)?,
            workers: self.usize_flag("workers", 0)?,
            fast_math: self.flags.contains_key("fast-math"),
            use_baseline: !self.flags.contains_key("no-baseline"),
            coarsen_budget: self
                .usize_flag("coarsen-budget", crate::coarsen::DEFAULT_COARSEN_BUDGET)?
                .max(1),
            features: FeatureConfig {
                no_shape: self.flags.contains_key("no-shape"),
                no_node_id: self.flags.contains_key("no-node-id"),
                no_structural: self.flags.contains_key("no-structural"),
                exact_fractal: self.flags.contains_key("exact-fractal"),
            },
            log_level: self.str_flag("log-level", "info"),
            profile: self.flags.contains_key("profile"),
            ..Config::default()
        };
        // Fail fast on typos (the registry / backend errors name the
        // known ids).
        cfg.resolve_testbed()?;
        crate::rl::backend::BackendKind::resolve(&cfg.backend, &cfg.artifacts_dir)?;
        if crate::obs::log::Level::parse(&cfg.log_level).is_none() {
            bail!("unknown --log-level '{}' (off | error | warn | info | debug)", cfg.log_level);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Benchmark;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let c = parse(&argv("train --bench bert --episodes 5 --no-baseline")).unwrap();
        assert_eq!(c.command, "train");
        assert_eq!(c.workload().unwrap().bench, Some(Benchmark::BertBase));
        assert_eq!(c.usize_flag("episodes", 30).unwrap(), 5);
        let cfg = c.config().unwrap();
        assert!(!cfg.use_baseline);
        assert_eq!(cfg.max_episodes, 5);
    }

    #[test]
    fn rejects_missing_value() {
        assert!(parse(&argv("train --episodes")).is_err());
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(parse(&argv("train boom")).is_err());
    }

    #[test]
    fn defaults_apply() {
        let c = parse(&argv("table2")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.seed, 0);
        assert!(cfg.use_baseline);
        assert_eq!(cfg.testbed, "cpu_gpu");
        assert_eq!(c.workload().unwrap().bench, Some(Benchmark::ResNet50));
    }

    #[test]
    fn ablation_flags_set_features() {
        let c = parse(&argv("train --no-shape")).unwrap();
        assert!(c.config().unwrap().features.no_shape);
    }

    #[test]
    fn testbed_flag_selects_device_set() {
        let c = parse(&argv("train --testbed paper3")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.testbed, "paper3");
        assert_eq!(cfg.num_devices(), 3);

        let c = parse(&argv("train --testbed multi_gpu:4")).unwrap();
        assert_eq!(c.config().unwrap().num_devices(), 5);
    }

    #[test]
    fn memory_flags_parse() {
        let args = argv("train --testbed cpu_gpu_tight --oom-penalty 0.25 --workers 4");
        let cfg = parse(&args).unwrap().config().unwrap();
        assert_eq!(cfg.testbed, "cpu_gpu_tight");
        assert_eq!(cfg.oom_penalty, 0.25);
        assert_eq!(cfg.workers, 4);
        // (main() installs the flag as the process-global pool knob;
        // config() stays side-effect-free so parallel tests don't race.)
        // Memory-capped multi-GPU ids resolve through the same flag.
        let c = parse(&argv("train --testbed multi_gpu:2:8")).unwrap();
        assert_eq!(c.config().unwrap().num_devices(), 3);
        // Defaults: penalty 0, auto workers, exact kernels.
        let c = parse(&argv("table2")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.oom_penalty, 0.0);
        assert_eq!(cfg.workers, 0);
        assert!(!cfg.fast_math);
        // --fast-math is a boolean flag.
        let c = parse(&argv("train --fast-math --workers 2")).unwrap();
        let cfg = c.config().unwrap();
        assert!(cfg.fast_math);
        assert_eq!(cfg.workers, 2);
        // Malformed values are errors, not silent defaults.
        assert!(parse(&argv("train --oom-penalty x")).unwrap().config().is_err());
    }

    #[test]
    fn scaling_flags_parse() {
        let c = parse(&argv("train --coarsen-budget 512 --exact-fractal")).unwrap();
        let cfg = c.config().unwrap();
        assert_eq!(cfg.coarsen_budget, 512);
        assert!(cfg.features.exact_fractal);
        // Defaults: the multi-level budget, sampled fractal auto mode.
        let cfg = parse(&argv("train")).unwrap().config().unwrap();
        assert_eq!(cfg.coarsen_budget, crate::coarsen::DEFAULT_COARSEN_BUDGET);
        assert!(!cfg.features.exact_fractal);
        // A zero budget is clamped, not a panic in the coarsener.
        let cfg = parse(&argv("train --coarsen-budget 0")).unwrap().config().unwrap();
        assert_eq!(cfg.coarsen_budget, 1);
        assert!(parse(&argv("train --coarsen-budget x")).unwrap().config().is_err());
    }

    #[test]
    fn workload_flag_resolves_through_registry() {
        // Registry spec.
        let c = parse(&argv("train --workload layered:4x3")).unwrap();
        let w = c.workload().unwrap();
        assert!(w.bench.is_none());
        assert_eq!(w.graph.n(), 4 * 3 + 2);
        // Paper benchmark by alias, via --workload or legacy --bench.
        let c = parse(&argv("train --workload bert")).unwrap();
        assert_eq!(c.workload().unwrap().bench, Some(Benchmark::BertBase));
        let c = parse(&argv("train --bench bert")).unwrap();
        assert_eq!(c.workload().unwrap().bench, Some(Benchmark::BertBase));
        // --workload wins over --bench; default stays resnet.
        let c = parse(&argv("train --bench bert --workload seq:4")).unwrap();
        assert!(c.workload().unwrap().bench.is_none());
        let c = parse(&argv("train")).unwrap();
        assert_eq!(c.workload().unwrap().bench, Some(Benchmark::ResNet50));
        // Unknown specs name the registry.
        let err = parse(&argv("train --workload warehouse")).unwrap().workload();
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("known workload sources"), "{msg}");
    }

    #[test]
    fn list_flags_split_on_commas() {
        let c = parse(&argv("generalize --train seq:8,layered:3x2, --eval random:12:1")).unwrap();
        assert_eq!(c.str_list_flag("train", ""), vec!["seq:8", "layered:3x2"]);
        assert_eq!(c.str_list_flag("eval", ""), vec!["random:12:1"]);
        assert_eq!(c.str_list_flag("missing", "a,b"), vec!["a", "b"]);
        assert!(c.str_list_flag("missing2", "").is_empty());
    }

    #[test]
    fn serve_and_request_flags_parse() {
        let c = parse(&argv(
            "serve --load ckpt.json --addr 127.0.0.1:0 --serve-workers 2 --cache-capacity 64",
        ))
        .unwrap();
        assert_eq!(c.command, "serve");
        assert_eq!(c.str_flag("load", ""), "ckpt.json");
        assert_eq!(c.usize_flag("serve-workers", 4).unwrap(), 2);
        assert_eq!(c.usize_flag("cache-capacity", 256).unwrap(), 64);
        // request's boolean flags take no value.
        let c = parse(&argv("request --addr 127.0.0.1:7477 --stats")).unwrap();
        assert!(c.flags.contains_key("stats"));
        let c = parse(&argv("request --workload seq:8 --no-cache --shutdown")).unwrap();
        assert!(c.flags.contains_key("no-cache") && c.flags.contains_key("shutdown"));
        let c = parse(&argv("generalize --eval-only --load g.json")).unwrap();
        assert!(c.flags.contains_key("eval-only"));
    }

    #[test]
    fn fleet_flags_parse() {
        // route takes a shard list; --queue-depth is a valued flag.
        let c = parse(&argv("route --shards 127.0.0.1:7481,127.0.0.1:7482 --queue-depth 8")).unwrap();
        assert_eq!(c.command, "route");
        assert_eq!(
            c.str_list_flag("shards", ""),
            vec!["127.0.0.1:7481", "127.0.0.1:7482"]
        );
        assert_eq!(c.usize_flag("queue-depth", 64).unwrap(), 8);
        // reload / clear-cache are boolean; --checkpoint and --tenant
        // and --retries take values.
        let c = parse(&argv("request --addr 127.0.0.1:7477 --reload --checkpoint new.json")).unwrap();
        assert!(c.flags.contains_key("reload"));
        assert_eq!(c.str_flag("checkpoint", ""), "new.json");
        let c = parse(&argv("request --clear-cache --addr 127.0.0.1:7477")).unwrap();
        assert!(c.flags.contains_key("clear-cache"));
        let c = parse(&argv(
            "request --workload seq:8 --tenant team-a --retries 3 --shards a:1,b:2",
        ))
        .unwrap();
        assert_eq!(c.str_flag("tenant", ""), "team-a");
        assert_eq!(c.usize_flag("retries", 0).unwrap(), 3);
        assert_eq!(c.str_list_flag("shards", ""), vec!["a:1", "b:2"]);
    }

    #[test]
    fn observability_flags_parse() {
        let c = parse(&argv(
            "serve --load ckpt.json --trace-log t.jsonl --log-level debug --profile",
        ))
        .unwrap();
        assert_eq!(c.str_flag("trace-log", ""), "t.jsonl");
        let cfg = c.config().unwrap();
        assert_eq!(cfg.log_level, "debug");
        assert!(cfg.profile);
        // --metrics is boolean; --trace-id and --run-log take values.
        let c = parse(&argv("request --addr 127.0.0.1:7477 --metrics")).unwrap();
        assert!(c.flags.contains_key("metrics"));
        let c = parse(&argv("request --workload seq:8 --trace-id abc")).unwrap();
        assert_eq!(c.str_flag("trace-id", ""), "abc");
        let c = parse(&argv("train --run-log run.jsonl")).unwrap();
        assert_eq!(c.str_flag("run-log", ""), "run.jsonl");
        // `trace` takes positional args; every other command still
        // rejects bare words (pinned by rejects_positional_garbage too).
        let c = parse(&argv("trace summarize run.jsonl")).unwrap();
        assert_eq!(c.args, vec!["summarize", "run.jsonl"]);
        assert!(parse(&argv("train boom")).is_err());
        // A bad level fails at config time, naming the choices.
        let err = parse(&argv("train --log-level loud")).unwrap().config();
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("loud") && msg.contains("debug"), "{msg}");
        // Defaults: info level, profiling off.
        let cfg = parse(&argv("table2")).unwrap().config().unwrap();
        assert_eq!(cfg.log_level, "info");
        assert!(!cfg.profile);
    }

    #[test]
    fn backend_flag_parses_and_rejects_typos() {
        let c = parse(&argv("train --backend native")).unwrap();
        assert_eq!(c.config().unwrap().backend, "native");
        let c = parse(&argv("train --backend pjrt")).unwrap();
        assert_eq!(c.config().unwrap().backend, "pjrt");
        // Default is auto.
        let c = parse(&argv("train")).unwrap();
        assert_eq!(c.config().unwrap().backend, "auto");
        // Typos fail fast with the known values in the message.
        let err = parse(&argv("train --backend tpu")).unwrap().config();
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("tpu") && msg.contains("native"), "{msg}");
    }

    #[test]
    fn unknown_testbed_rejected_early() {
        let c = parse(&argv("train --testbed warehouse")).unwrap();
        let err = c.config();
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("warehouse") && msg.contains("multi_gpu"), "{msg}");
    }
}

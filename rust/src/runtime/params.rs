//! Parameter store: host-side policy parameters + Adam state, kept in the
//! exact order of the artifact spec so train-step round-trips are
//! positional.
//!
//! The train artifacts take (params..., m..., v..., step, ...) and return
//! (params'..., m'..., v'..., step', loss); `apply_train_outputs` writes
//! the returned literals straight back into the store.

use anyhow::{bail, Result};

use super::spec::{ArtifactSpec, DType};
use super::tensor::{glorot_init, Tensor};
use crate::util::Rng;

/// Policy parameters + optimizer state. `Clone` snapshots the whole
/// learning state — params, Adam moments and step — which is how one
/// policy hops between per-workload backends in the generalization
/// harness.
#[derive(Clone)]
pub struct ParamStore {
    /// Learnable tensors, spec order.
    pub params: Vec<Tensor>,
    /// Adam first / second moments, aligned with `params`.
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Adam step counter (float32 scalar, as the artifact expects).
    pub step: f32,
    /// Names, for diagnostics.
    pub names: Vec<String>,
}

impl ParamStore {
    /// Initialize from the *train* spec of a policy: the first n inputs up
    /// to the one named `m_<first>` are the learnable parameters.
    pub fn init_from_spec(spec: &ArtifactSpec, rng: &mut Rng) -> Result<ParamStore> {
        let mut n_params = 0;
        for inp in &spec.inputs {
            if inp.name.starts_with("m_") {
                break;
            }
            n_params += 1;
        }
        if n_params == 0 || n_params == spec.inputs.len() {
            bail!("{}: could not locate the m_* optimizer block", spec.fn_name);
        }
        let mut params = Vec::with_capacity(n_params);
        let mut names = Vec::with_capacity(n_params);
        for inp in &spec.inputs[..n_params] {
            if inp.dtype != DType::F32 {
                bail!("param '{}' is not f32", inp.name);
            }
            params.push(glorot_init(&inp.dims, rng));
            names.push(inp.name.clone());
        }
        let m = params.iter().map(|p| Tensor::zeros(DType::F32, p.dims())).collect();
        let v = params.iter().map(|p| Tensor::zeros(DType::F32, p.dims())).collect();
        Ok(ParamStore { params, m, v, step: 0.0, names })
    }

    /// Initialize the HSDAG parameter set for the native backend: same
    /// tensors, order and names as `python/compile/model.py`'s
    /// `hsdag_param_spec` (Glorot-uniform weights, zero biases), so the
    /// two backends share one layout.
    pub fn init_hsdag(d: usize, h: usize, nd: usize, rng: &mut Rng) -> ParamStore {
        let spec: [(&str, Vec<usize>); 16] = [
            ("trans_w0", vec![d, h]),
            ("trans_b0", vec![h]),
            ("trans_w1", vec![h, h]),
            ("trans_b1", vec![h]),
            ("gcn_w0", vec![h, h]),
            ("gcn_b0", vec![h]),
            ("gcn_w1", vec![h, h]),
            ("gcn_b1", vec![h]),
            ("edge_w0", vec![h, h]),
            ("edge_b0", vec![h]),
            ("edge_w1", vec![h, 1]),
            ("edge_b1", vec![1]),
            ("place_w0", vec![h, h]),
            ("place_b0", vec![h]),
            ("place_w1", vec![h, nd]),
            ("place_b1", vec![nd]),
        ];
        let mut params = Vec::with_capacity(spec.len());
        let mut names = Vec::with_capacity(spec.len());
        for (name, dims) in spec {
            params.push(glorot_init(&dims, rng));
            names.push(name.to_string());
        }
        let m = params.iter().map(|p: &Tensor| Tensor::zeros(DType::F32, p.dims())).collect();
        let v = params.iter().map(|p: &Tensor| Tensor::zeros(DType::F32, p.dims())).collect();
        ParamStore { params, m, v, step: 0.0, names }
    }

    /// Reassemble a store from deserialized parts (the on-disk checkpoint
    /// path), validating the alignment invariants the rest of the store
    /// relies on: one m/v moment tensor per parameter with identical
    /// dims, everything f32, a finite non-negative step counter.
    pub fn from_parts(
        params: Vec<Tensor>,
        m: Vec<Tensor>,
        v: Vec<Tensor>,
        step: f32,
        names: Vec<String>,
    ) -> Result<ParamStore> {
        if params.is_empty() {
            bail!("parameter store has no tensors");
        }
        if m.len() != params.len() || v.len() != params.len() || names.len() != params.len() {
            bail!(
                "misaligned store: {} params, {} m, {} v, {} names",
                params.len(),
                m.len(),
                v.len(),
                names.len()
            );
        }
        for i in 0..params.len() {
            if params[i].dtype() != DType::F32
                || m[i].dtype() != DType::F32
                || v[i].dtype() != DType::F32
            {
                bail!("tensor '{}' is not f32", names[i]);
            }
            if m[i].dims() != params[i].dims() || v[i].dims() != params[i].dims() {
                bail!(
                    "tensor '{}': moment dims {:?}/{:?} do not match param dims {:?}",
                    names[i],
                    m[i].dims(),
                    v[i].dims(),
                    params[i].dims()
                );
            }
        }
        if !step.is_finite() || step < 0.0 {
            bail!("bad Adam step counter {step}");
        }
        Ok(ParamStore { params, m, v, step, names })
    }

    /// One Adam step over per-parameter gradients (aligned with `params`),
    /// matching the artifact train-step's update rule bit-for-bit in
    /// structure: bias-corrected moments, float32 step counter.
    pub fn adam_step(&mut self, grads: &[Vec<f32>], lr: f64, b1: f64, b2: f64, eps: f64) {
        assert_eq!(grads.len(), self.params.len(), "one gradient per parameter");
        self.step += 1.0;
        let step = self.step as f64;
        let bc1 = 1.0 - b1.powf(step);
        let bc2 = 1.0 - b2.powf(step);
        for i in 0..self.params.len() {
            let p = self.params[i].as_f32_mut();
            let m = self.m[i].as_f32_mut();
            let v = self.v[i].as_f32_mut();
            assert_eq!(grads[i].len(), p.len(), "gradient {i} shape mismatch");
            for k in 0..p.len() {
                let g = grads[i][k] as f64;
                let mk = b1 * m[k] as f64 + (1.0 - b1) * g;
                let vk = b2 * v[k] as f64 + (1.0 - b2) * g * g;
                m[k] = mk as f32;
                v[k] = vk as f32;
                let mhat = mk / bc1;
                let vhat = vk / bc2;
                p[k] = (p[k] as f64 - lr * mhat / (vhat.sqrt() + eps)) as f32;
            }
        }
    }

    pub fn n(&self) -> usize {
        self.params.len()
    }

    /// Total learnable scalar count.
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// L2 norm over all learnable scalars (f64 accumulation). Telemetry
    /// for the training run log's `param_norm` column.
    pub fn l2_norm(&self) -> f64 {
        let mut ss = 0.0f64;
        for p in &self.params {
            for &x in p.as_f32() {
                ss += x as f64 * x as f64;
            }
        }
        ss.sqrt()
    }

    /// Assemble the (params..., m..., v..., step) prefix of a train call.
    pub fn train_prefix(&self) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(3 * self.n() + 1);
        out.extend(self.params.iter().cloned());
        out.extend(self.m.iter().cloned());
        out.extend(self.v.iter().cloned());
        out.push(Tensor::scalar_f32(self.step));
        out
    }

    /// Write back the (params'..., m'..., v'..., step', loss) outputs of a
    /// train call. Returns the loss.
    pub fn apply_train_outputs(&mut self, outs: &[xla::Literal]) -> Result<f32> {
        let n = self.n();
        if outs.len() != 3 * n + 2 {
            bail!("train returned {} outputs, expected {}", outs.len(), 3 * n + 2);
        }
        for i in 0..n {
            self.params[i] =
                Tensor::from_literal(&outs[i], DType::F32, &self.params[i].dims().to_vec())?;
            self.m[i] =
                Tensor::from_literal(&outs[n + i], DType::F32, &self.m[i].dims().to_vec())?;
            self.v[i] =
                Tensor::from_literal(&outs[2 * n + i], DType::F32, &self.v[i].dims().to_vec())?;
        }
        self.step = outs[3 * n].to_vec::<f32>()?[0];
        let loss = outs[3 * n + 1].to_vec::<f32>()?[0];
        if !loss.is_finite() {
            bail!("non-finite training loss {loss}");
        }
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::spec::ArtifactSpec;

    const SPEC: &str = "\
fn toy_train
bench toy v=128 e=128 t=4
in w0 f32 4,8
in b0 f32 8
in m_w0 f32 4,8
in m_b0 f32 8
in v_w0 f32 4,8
in v_b0 f32 8
in step f32 scalar
in x f32 128,4
out w0
out b0
out m_w0
out m_b0
out v_w0
out v_b0
out step
out loss
";

    #[test]
    fn init_locates_param_block() {
        let spec = ArtifactSpec::parse(SPEC).unwrap();
        let mut rng = Rng::new(3);
        let ps = ParamStore::init_from_spec(&spec, &mut rng).unwrap();
        assert_eq!(ps.n(), 2);
        assert_eq!(ps.names, vec!["w0", "b0"]);
        assert_eq!(ps.n_scalars(), 32 + 8);
        // Weights random, biases zero.
        assert!(ps.params[0].as_f32().iter().any(|&x| x != 0.0));
        assert!(ps.params[1].as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn train_prefix_layout() {
        let spec = ArtifactSpec::parse(SPEC).unwrap();
        let mut rng = Rng::new(4);
        let ps = ParamStore::init_from_spec(&spec, &mut rng).unwrap();
        let prefix = ps.train_prefix();
        assert_eq!(prefix.len(), 7);
        assert_eq!(prefix[6].numel(), 1);
        // Moments zeroed.
        assert!(prefix[2].as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn init_hsdag_matches_python_spec_layout() {
        let mut rng = Rng::new(6);
        let ps = ParamStore::init_hsdag(69, 128, 2, &mut rng);
        assert_eq!(ps.n(), 16);
        assert_eq!(ps.names[0], "trans_w0");
        assert_eq!(ps.names[10], "edge_w1");
        assert_eq!(ps.names[15], "place_b1");
        assert_eq!(ps.params[0].dims(), &[69, 128]);
        assert_eq!(ps.params[10].dims(), &[128, 1]);
        assert_eq!(ps.params[14].dims(), &[128, 2]);
        // Weights random, biases zero, moments zero.
        assert!(ps.params[0].as_f32().iter().any(|&x| x != 0.0));
        assert!(ps.params[1].as_f32().iter().all(|&x| x == 0.0));
        assert!(ps.m[0].as_f32().iter().all(|&x| x == 0.0));
        // Deterministic per seed.
        let mut rng2 = Rng::new(6);
        let ps2 = ParamStore::init_hsdag(69, 128, 2, &mut rng2);
        assert_eq!(ps.params[0].as_f32(), ps2.params[0].as_f32());
    }

    #[test]
    fn adam_step_moves_against_gradient() {
        let mut rng = Rng::new(7);
        let mut ps = ParamStore::init_hsdag(4, 4, 2, &mut rng);
        let before = ps.params[0].as_f32()[0];
        let mut grads: Vec<Vec<f32>> =
            ps.params.iter().map(|p| vec![0.0; p.numel()]).collect();
        grads[0][0] = 1.0; // positive gradient -> parameter must decrease
        ps.adam_step(&grads, 1e-2, 0.9, 0.999, 1e-8);
        assert_eq!(ps.step, 1.0);
        let after = ps.params[0].as_f32()[0];
        assert!(after < before, "{before} -> {after}");
        // First step with bias correction moves by ~lr.
        assert!((before - after - 1e-2).abs() < 1e-3, "{}", before - after);
        // Untouched entries stay put.
        assert_eq!(ps.params[2].as_f32(), {
            let mut rng2 = Rng::new(7);
            let ps2 = ParamStore::init_hsdag(4, 4, 2, &mut rng2);
            ps2.params[2].as_f32().to_vec()
        }
        .as_slice());
    }

    #[test]
    fn from_parts_validates_alignment() {
        let mut rng = Rng::new(8);
        let ps = ParamStore::init_hsdag(4, 4, 2, &mut rng);
        let ok = ParamStore::from_parts(
            ps.params.clone(),
            ps.m.clone(),
            ps.v.clone(),
            3.0,
            ps.names.clone(),
        )
        .unwrap();
        assert_eq!(ok.step, 3.0);
        assert_eq!(ok.n(), ps.n());
        // Dropped moment tensor.
        let err = ParamStore::from_parts(
            ps.params.clone(),
            ps.m[..ps.n() - 1].to_vec(),
            ps.v.clone(),
            0.0,
            ps.names.clone(),
        );
        assert!(format!("{:#}", err.unwrap_err()).contains("misaligned"));
        // Moment dims diverge from the param's.
        let mut bad_m = ps.m.clone();
        bad_m[0] = Tensor::zeros(DType::F32, &[2, 2]);
        let err =
            ParamStore::from_parts(ps.params.clone(), bad_m, ps.v.clone(), 0.0, ps.names.clone());
        assert!(format!("{:#}", err.unwrap_err()).contains("moment dims"));
        // Negative / non-finite step counters are rejected.
        assert!(ParamStore::from_parts(
            ps.params.clone(),
            ps.m.clone(),
            ps.v.clone(),
            -1.0,
            ps.names.clone()
        )
        .is_err());
    }

    #[test]
    fn l2_norm_sums_all_tensors() {
        let mut ps = ParamStore {
            params: vec![Tensor::f32(&[2], vec![3.0, 0.0]), Tensor::f32(&[1], vec![4.0])],
            m: vec![Tensor::zeros(DType::F32, &[2]), Tensor::zeros(DType::F32, &[1])],
            v: vec![Tensor::zeros(DType::F32, &[2]), Tensor::zeros(DType::F32, &[1])],
            step: 0.0,
            names: vec!["a".into(), "b".into()],
        };
        assert!((ps.l2_norm() - 5.0).abs() < 1e-12);
        ps.params[0].as_f32_mut()[1] = 12.0;
        assert!((ps.l2_norm() - 13.0).abs() < 1e-12);
    }

    #[test]
    fn apply_rejects_wrong_arity() {
        let spec = ArtifactSpec::parse(SPEC).unwrap();
        let mut rng = Rng::new(5);
        let mut ps = ParamStore::init_from_spec(&spec, &mut rng).unwrap();
        assert!(ps.apply_train_outputs(&[]).is_err());
    }
}

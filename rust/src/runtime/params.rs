//! Parameter store: host-side policy parameters + Adam state, kept in the
//! exact order of the artifact spec so train-step round-trips are
//! positional.
//!
//! The train artifacts take (params..., m..., v..., step, ...) and return
//! (params'..., m'..., v'..., step', loss); `apply_train_outputs` writes
//! the returned literals straight back into the store.

use anyhow::{bail, Result};

use super::spec::{ArtifactSpec, DType};
use super::tensor::{glorot_init, Tensor};
use crate::util::Rng;

/// Policy parameters + optimizer state.
pub struct ParamStore {
    /// Learnable tensors, spec order.
    pub params: Vec<Tensor>,
    /// Adam first / second moments, aligned with `params`.
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Adam step counter (float32 scalar, as the artifact expects).
    pub step: f32,
    /// Names, for diagnostics.
    pub names: Vec<String>,
}

impl ParamStore {
    /// Initialize from the *train* spec of a policy: the first n inputs up
    /// to the one named `m_<first>` are the learnable parameters.
    pub fn init_from_spec(spec: &ArtifactSpec, rng: &mut Rng) -> Result<ParamStore> {
        let mut n_params = 0;
        for inp in &spec.inputs {
            if inp.name.starts_with("m_") {
                break;
            }
            n_params += 1;
        }
        if n_params == 0 || n_params == spec.inputs.len() {
            bail!("{}: could not locate the m_* optimizer block", spec.fn_name);
        }
        let mut params = Vec::with_capacity(n_params);
        let mut names = Vec::with_capacity(n_params);
        for inp in &spec.inputs[..n_params] {
            if inp.dtype != DType::F32 {
                bail!("param '{}' is not f32", inp.name);
            }
            params.push(glorot_init(&inp.dims, rng));
            names.push(inp.name.clone());
        }
        let m = params.iter().map(|p| Tensor::zeros(DType::F32, p.dims())).collect();
        let v = params.iter().map(|p| Tensor::zeros(DType::F32, p.dims())).collect();
        Ok(ParamStore { params, m, v, step: 0.0, names })
    }

    pub fn n(&self) -> usize {
        self.params.len()
    }

    /// Total learnable scalar count.
    pub fn n_scalars(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Assemble the (params..., m..., v..., step) prefix of a train call.
    pub fn train_prefix(&self) -> Vec<Tensor> {
        let mut out = Vec::with_capacity(3 * self.n() + 1);
        out.extend(self.params.iter().cloned());
        out.extend(self.m.iter().cloned());
        out.extend(self.v.iter().cloned());
        out.push(Tensor::scalar_f32(self.step));
        out
    }

    /// Write back the (params'..., m'..., v'..., step', loss) outputs of a
    /// train call. Returns the loss.
    pub fn apply_train_outputs(&mut self, outs: &[xla::Literal]) -> Result<f32> {
        let n = self.n();
        if outs.len() != 3 * n + 2 {
            bail!("train returned {} outputs, expected {}", outs.len(), 3 * n + 2);
        }
        for i in 0..n {
            self.params[i] =
                Tensor::from_literal(&outs[i], DType::F32, &self.params[i].dims().to_vec())?;
            self.m[i] =
                Tensor::from_literal(&outs[n + i], DType::F32, &self.m[i].dims().to_vec())?;
            self.v[i] =
                Tensor::from_literal(&outs[2 * n + i], DType::F32, &self.v[i].dims().to_vec())?;
        }
        self.step = outs[3 * n].to_vec::<f32>()?[0];
        let loss = outs[3 * n + 1].to_vec::<f32>()?[0];
        if !loss.is_finite() {
            bail!("non-finite training loss {loss}");
        }
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::spec::ArtifactSpec;

    const SPEC: &str = "\
fn toy_train
bench toy v=128 e=128 t=4
in w0 f32 4,8
in b0 f32 8
in m_w0 f32 4,8
in m_b0 f32 8
in v_w0 f32 4,8
in v_b0 f32 8
in step f32 scalar
in x f32 128,4
out w0
out b0
out m_w0
out m_b0
out v_w0
out v_b0
out step
out loss
";

    #[test]
    fn init_locates_param_block() {
        let spec = ArtifactSpec::parse(SPEC).unwrap();
        let mut rng = Rng::new(3);
        let ps = ParamStore::init_from_spec(&spec, &mut rng).unwrap();
        assert_eq!(ps.n(), 2);
        assert_eq!(ps.names, vec!["w0", "b0"]);
        assert_eq!(ps.n_scalars(), 32 + 8);
        // Weights random, biases zero.
        assert!(ps.params[0].as_f32().iter().any(|&x| x != 0.0));
        assert!(ps.params[1].as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn train_prefix_layout() {
        let spec = ArtifactSpec::parse(SPEC).unwrap();
        let mut rng = Rng::new(4);
        let ps = ParamStore::init_from_spec(&spec, &mut rng).unwrap();
        let prefix = ps.train_prefix();
        assert_eq!(prefix.len(), 7);
        assert_eq!(prefix[6].numel(), 1);
        // Moments zeroed.
        assert!(prefix[2].as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn apply_rejects_wrong_arity() {
        let spec = ArtifactSpec::parse(SPEC).unwrap();
        let mut rng = Rng::new(5);
        let mut ps = ParamStore::init_from_spec(&spec, &mut rng).unwrap();
        assert!(ps.apply_train_outputs(&[]).is_err());
    }
}

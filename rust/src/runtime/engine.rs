//! PJRT engine: loads AOT HLO-text artifacts, compiles them once on the
//! CPU client, and executes them from the search loop.
//!
//! Pattern follows /opt/xla-example/load_hlo/: HLO *text* in (the
//! xla_extension 0.5.1 proto parser reassigns jax's 64-bit instruction
//! ids), `return_tuple=True` out, so every execution returns one tuple
//! literal we decompose.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::spec::ArtifactSpec;
use super::tensor::Tensor;

/// One compiled artifact.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; validates against the spec and returns
    /// the decomposed output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, spec wants {}",
                self.spec.fn_name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            t.check_against(s).with_context(|| self.spec.fn_name.clone())?;
            literals.push(t.to_literal()?);
        }
        self.run_literals(&literals)
    }

    /// Execute with pre-built literals (hot path: lets the caller reuse
    /// buffers that don't change between steps).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute with borrowed literals — the zero-copy hot path: constant
    /// tensors (features, adjacency, cached parameters) are converted to
    /// literals once and reused across every step (see EXPERIMENTS.md
    /// §Perf for the before/after).
    pub fn run_refs(&self, literals: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if literals.len() != self.spec.inputs.len() {
            bail!(
                "{}: {} inputs given, spec wants {}",
                self.spec.fn_name,
                literals.len(),
                self.spec.inputs.len()
            );
        }
        let result = self.exe.execute::<&xla::Literal>(literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// Loads and caches compiled artifacts for one benchmark+policy family.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, Executable>,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!(
                "artifacts directory '{}' missing — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(Engine { client: xla::PjRtClient::cpu()?, dir, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and memoize) the artifact `<name>.hlo.txt` + `.spec.txt`.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let hlo = self.dir.join(format!("{name}.hlo.txt"));
            let spec_path = self.dir.join(format!("{name}.spec.txt"));
            let spec_text = std::fs::read_to_string(&spec_path)
                .with_context(|| format!("reading {}", spec_path.display()))?;
            let spec = ArtifactSpec::parse(&spec_text)
                .with_context(|| format!("parsing {}", spec_path.display()))?;
            let proto = xla::HloModuleProto::from_text_file(
                hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), Executable { spec, exe });
        }
        Ok(&self.cache[name])
    }
}

#[cfg(test)]
mod tests {
    //! Engine integration tests live in rust/tests/runtime_integration.rs
    //! (they need built artifacts); here we only check error paths that
    //! don't require a PJRT client.
    use super::*;

    #[test]
    fn missing_dir_is_an_error() {
        let e = Engine::cpu("/nonexistent/artifacts");
        assert!(e.is_err());
        let msg = format!("{:#}", e.err().unwrap());
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}

//! Policy compute runtime, two flavors behind one parameter layout:
//!
//! - **PJRT** (`engine`): load AOT-compiled policy artifacts (HLO text
//!   from `make artifacts`) and execute them on an XLA client — the
//!   paper-faithful JAX/Pallas path.
//! - **Native** (`nn`): small pure-rust f32 kernels implementing the same
//!   HSDAG model (GCN encoder, GPN edge scorer, placer head, Eq. 14
//!   REINFORCE + Adam) with no artifacts and no external dependencies —
//!   the default whenever `artifacts/` is absent.
//!
//! `params` owns the shared parameter-store layout (spec order, Adam
//! state); `spec`/`tensor` are the artifact-side contracts. The backend
//! selection itself lives in `rl::backend`.

pub mod engine;
pub mod nn;
pub mod params;
pub mod spec;
pub mod tensor;

pub use engine::{Engine, Executable};
pub use nn::{NativeBatch, NativePolicy};
pub use params::ParamStore;
pub use spec::{ArtifactSpec, DType, InputSpec};
pub use tensor::Tensor;

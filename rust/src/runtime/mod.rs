//! PJRT runtime: load AOT-compiled policy artifacts (HLO text) and execute
//! them from the rust search loop. Python never runs here — `make
//! artifacts` is the only python invocation in the whole system.

pub mod engine;
pub mod params;
pub mod spec;
pub mod tensor;

pub use engine::{Engine, Executable};
pub use params::ParamStore;
pub use spec::{ArtifactSpec, DType, InputSpec};
pub use tensor::Tensor;

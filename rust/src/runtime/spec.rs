//! Artifact spec parser.
//!
//! `python -m compile.aot` emits a `<name>.spec.txt` beside every
//! `<name>.hlo.txt` describing the flat input signature (name, dtype,
//! shape per line) and output names. The runtime parses these to assemble
//! input literals in the right order and to verify the shape contract
//! between the rust graph pipeline and the AOT'd policies at load time.

use anyhow::{anyhow, bail, Context, Result};

/// Element type of a tensor in the artifact signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

/// One input slot of an artifact.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub dtype: DType,
    /// Empty = scalar.
    pub dims: Vec<usize>,
}

impl InputSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

/// Parsed `.spec.txt`.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub fn_name: String,
    pub bench: String,
    /// Padded nodes / edges the artifact was lowered at.
    pub v: usize,
    pub e: usize,
    /// Buffered steps T for train artifacts.
    pub t: usize,
    /// Placement targets (action-space width) the policy head was lowered
    /// at; 0 when the spec predates the field (treated as 2 downstream).
    pub nd: usize,
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    /// Parse the spec text format emitted by `aot.write_spec`.
    pub fn parse(text: &str) -> Result<ArtifactSpec> {
        let mut fn_name = String::new();
        let mut bench = String::new();
        let (mut v, mut e, mut t, mut nd) = (0usize, 0usize, 0usize, 0usize);
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let tag = parts.next().unwrap();
            let ctx = || format!("spec line {}: '{line}'", ln + 1);
            match tag {
                "fn" => fn_name = parts.next().with_context(ctx)?.to_string(),
                "bench" => {
                    bench = parts.next().with_context(ctx)?.to_string();
                    for kv in parts {
                        let (k, val) = kv.split_once('=').with_context(ctx)?;
                        let val: usize = val.parse().with_context(ctx)?;
                        match k {
                            "v" => v = val,
                            "e" => e = val,
                            "t" => t = val,
                            "nd" => nd = val,
                            _ => {}
                        }
                    }
                }
                "in" => {
                    let name = parts.next().with_context(ctx)?.to_string();
                    let dtype = DType::parse(parts.next().with_context(ctx)?)?;
                    let dimstr = parts.next().with_context(ctx)?;
                    let dims = if dimstr == "scalar" {
                        vec![]
                    } else {
                        dimstr
                            .split(',')
                            .map(|d| d.parse::<usize>().map_err(|e| anyhow!("{e}")))
                            .collect::<Result<Vec<_>>>()
                            .with_context(ctx)?
                    };
                    inputs.push(InputSpec { name, dtype, dims });
                }
                "out" => outputs.push(parts.next().with_context(ctx)?.to_string()),
                _ => bail!("unknown spec tag '{tag}' at line {}", ln + 1),
            }
        }
        if fn_name.is_empty() || inputs.is_empty() {
            bail!("incomplete spec (fn='{fn_name}', {} inputs)", inputs.len());
        }
        Ok(ArtifactSpec { fn_name, bench, v, e, t, nd, inputs, outputs })
    }

    /// Index of the input named `name`.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|i| i.name == name)
    }

    /// Action-space width for testbed compatibility checks: specs
    /// predating the `nd` field (nd=0) were all lowered at 2 devices.
    pub fn nd_or_legacy(&self) -> usize {
        if self.nd == 0 {
            2
        } else {
            self.nd
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# hsdag artifact spec v1
fn resnet50_hsdag_fwd
bench resnet50 v=512 e=512 d=69 h=128 nd=2 t=20
in trans_w0 f32 69,128
in trans_b0 f32 128
in x0 f32 512,69
in edge_src i32 512
in step f32 scalar
out z
out scores
";

    #[test]
    fn parses_sample() {
        let s = ArtifactSpec::parse(SAMPLE).unwrap();
        assert_eq!(s.fn_name, "resnet50_hsdag_fwd");
        assert_eq!(s.bench, "resnet50");
        assert_eq!((s.v, s.e, s.t), (512, 512, 20));
        assert_eq!(s.nd, 2);
        assert_eq!(s.inputs.len(), 5);
        assert_eq!(s.inputs[0].dims, vec![69, 128]);
        assert_eq!(s.inputs[3].dtype, DType::I32);
        assert_eq!(s.inputs[4].dims, Vec::<usize>::new());
        assert_eq!(s.inputs[4].numel(), 1);
        assert_eq!(s.outputs, vec!["z", "scores"]);
    }

    #[test]
    fn input_index_lookup() {
        let s = ArtifactSpec::parse(SAMPLE).unwrap();
        assert_eq!(s.input_index("x0"), Some(2));
        assert_eq!(s.input_index("nope"), None);
    }

    #[test]
    fn nd_defaults_to_zero_for_legacy_specs() {
        let s = ArtifactSpec::parse("fn f\nbench b v=4 e=4 t=1\nin a f32 4\nout y\n").unwrap();
        assert_eq!(s.nd, 0);
    }

    #[test]
    fn rejects_bad_dtype() {
        assert!(ArtifactSpec::parse("fn f\nin a f64 3\nout y\n").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(ArtifactSpec::parse("# nothing\n").is_err());
    }
}

//! Native f32 tensor kernels: the building blocks of the pure-rust policy
//! backend (`NativePolicy`), mirroring the math of the AOT'd JAX/Pallas
//! kernels in `python/compile/kernels/` — dense matmuls, the GCN
//! message-passing aggregation over the DAG's normalized adjacency (kept
//! sparse as CSR instead of the artifacts' dense `[V, V]` matrix),
//! segment mean-pooling, softmax/log-prob, and the transpose products the
//! hand-written backward passes need.
//!
//! ## Kernel discipline (PR 6)
//!
//! The hot kernels are written so LLVM autovectorizes them while staying
//! **bit-identical** to the straightforward scalar loops they replaced:
//!
//! - Dense matmuls are branch-free (no `if aik == 0.0 { continue }` in
//!   the inner loop — that branch defeats SIMD on dense hidden layers)
//!   and unroll the reduction dimension in panels of 4 with *chained*
//!   separately-rounded adds, so every output element accumulates its
//!   terms in exactly the reference order. Skipping a `±0.0 * b` term vs
//!   adding it changes nothing for finite `b` (the accumulator can never
//!   hold `-0.0` under round-to-nearest), which is why the dense kernels
//!   are differential-tested bit-for-bit against the legacy sparse-skip
//!   loops.
//! - The sparsity skip survives only in the dedicated
//!   [`matmul_sparse_rows`] / [`matmul_at_b_acc_sparse`] entry points,
//!   used where rows genuinely are mostly zero: the one-hot input-feature
//!   layer.
//! - Message passing is a fused CSR kernel ([`aggregate_bias_relu_into`])
//!   that walks the edge list **once per layer** and applies bias + ReLU
//!   in the same pass over each output row, instead of three sweeps over
//!   `[n, h]`. CSR rows preserve the COO entry order, so accumulation
//!   per output element is unchanged.
//! - `_into` variants write into caller-owned buffers; the allocating
//!   wrappers remain for tests and one-shot callers. `NativePolicy` feeds
//!   them from a reusable [`policy::Scratch`] arena.
//!
//! ## Threading model (PR 9)
//!
//! The dense matmuls and the CSR aggregation fan their *output rows* out
//! across the shared scoped worker pool (`crate::util::pool`) in
//! contiguous disjoint bands. Each row's accumulation runs the serial
//! loop verbatim, so results are **bit-identical at any worker count** —
//! including the backward `A^T·B` product, which partitions its *output*
//! rows (not the input batch) precisely so no cross-thread reduction
//! ever reassociates a sum (see [`matmul_at_b_acc_workers`]). Every
//! parallel kernel has a `*_workers` entry point (0 = the global
//! `--workers` knob); the plain names pick serial vs pool automatically
//! by a flop-count threshold, which is a pure throughput decision — both
//! sides of the threshold produce the same bits.
//!
//! The opt-in `--fast-math` variants ([`matmul_into_fast`],
//! [`matmul_a_bt_into_fast`], [`dot_fast`]) trade that guarantee for
//! wider lanes: `chunks_exact(8)` panels whose partial sums combine as a
//! balanced tree. Still deterministic for a fixed input — but a
//! *different* (fixed) rounding order than the default kernels, so
//! outputs agree to relative tolerance, not bitwise. Nothing reaches
//! them unless the flag is set.
//!
//! Everything here is deterministic, row-major and unpadded: the native
//! backend works at the *real* working-graph sizes, not the artifacts'
//! static padded capacities.

pub mod policy;

pub use policy::{NativeBatch, NativePolicy};

use std::sync::OnceLock;

use crate::obs::metrics::{self, KernelStats};
use crate::util::pool;

/// Reduction-dimension unroll of the dense matmul kernels. Chained adds
/// keep per-element accumulation order identical to the scalar loop; the
/// panel exists to amortize the `c` row read/write and give LLVM four
/// independent multiply streams per SIMD lane.
const K_UNROLL: usize = 4;

/// Minimum multiply-accumulate count before a kernel's default entry
/// point fans its output rows across the worker pool: below this the
/// scoped spawn/join overhead (tens of microseconds) exceeds the
/// arithmetic. Purely a throughput decision — the banded path is
/// bit-identical to serial, so the threshold can never change a result.
const PAR_MIN_WORK: usize = 1 << 17;

/// Worker request for a kernel's default entry point: the pool (0 = the
/// global `--workers` knob) above the work threshold, inline below it.
fn par_workers(work: usize) -> usize {
    if work >= PAR_MIN_WORK {
        0
    } else {
        1
    }
}

/// C[m,n] = A[m,k] @ B[k,n] (row-major), dense path: branch-free and
/// autovectorization-friendly. Bit-identical to the scalar
/// i→k→j accumulation (and to [`matmul_sparse_rows`]) for finite inputs.
/// Large shapes fan output rows across the worker pool — same bits
/// either way.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    matmul_into_workers(a, b, m, k, n, c, par_workers(m * k * n));
}

/// [`matmul_into`] with an explicit worker count (0 = the global
/// `--workers` knob). Output rows split into contiguous disjoint bands;
/// each row runs the serial accumulation verbatim, so the result is
/// bit-identical at any worker count.
pub fn matmul_into_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    // Opt-in profiling (`--profile`): one relaxed load when off. Strictly
    // observational — the computation below never sees the guard.
    static STATS: OnceLock<&'static KernelStats> = OnceLock::new();
    let _t = metrics::profile(&STATS, "kernel.matmul", 2 * (m * k * n) as u64);
    pool::for_each_row_band(c, m, n, workers, |row0, band| {
        for (r, crow) in band.chunks_exact_mut(n).enumerate() {
            let i = row0 + r;
            let arow = &a[i * k..(i + 1) * k];
            crow.fill(0.0);
            let mut kk = 0;
            while kk + K_UNROLL <= k {
                let (a0, a1, a2, a3) = (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                let b0 = &b[kk * n..(kk + 1) * n];
                let b1 = &b[(kk + 1) * n..(kk + 2) * n];
                let b2 = &b[(kk + 2) * n..(kk + 3) * n];
                let b3 = &b[(kk + 3) * n..(kk + 4) * n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    // Four separately-rounded adds, in ascending-k order —
                    // the exact accumulation order of the reference loop.
                    let mut acc = *cj;
                    acc += a0 * b0[j];
                    acc += a1 * b1[j];
                    acc += a2 * b2[j];
                    acc += a3 * b3[j];
                    *cj = acc;
                }
                kk += K_UNROLL;
            }
            for kt in kk..k {
                let aik = arow[kt];
                let brow = &b[kt * n..(kt + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += aik * bj;
                }
            }
        }
    });
}

/// Allocating wrapper around [`matmul_into`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    matmul_into(a, b, m, k, n, &mut c);
    c
}

/// C[m,n] = A[m,k] @ B[k,n] for A with mostly-zero rows (the one-hot
/// input-feature layer). This is the legacy kernel with the sparsity
/// skip: the branch loses badly on dense hidden activations but wins on
/// X⁰, whose rows are a handful of one-hot slots. Bit-identical to the
/// dense path for finite `b`.
pub fn matmul_sparse_rows(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
}

/// C[k,n] += A[m,k]^T @ B[m,n] — the weight-gradient product, accumulated
/// into `c` so per-step gradients sum across a buffered batch. Dense
/// path: branch-free saxpy rows (activations after the input layer are
/// not sparse enough to pay for a branch).
pub fn matmul_at_b_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    matmul_at_b_acc_workers(a, b, m, k, n, c, par_workers(m * k * n));
}

/// [`matmul_at_b_acc`] with an explicit worker count (0 = the global
/// `--workers` knob).
///
/// The accumulation-order argument: gradient accumulation can NOT be
/// parallelized by splitting the `m` input rows and reducing per-thread
/// partial sums afterwards — partials start from 0.0 while the serial
/// kernel folds each term straight into the live accumulator, and f32
/// addition is not associative, so any reduce-after scheme drifts from
/// the reference bitwise. Instead the *output* rows `kk` are
/// partitioned: each band walks all `m` input rows in the reference
/// order and touches only its own rows of `c`, so every element sees the
/// exact serial add sequence (ascending `i`, seeded with the incoming
/// accumulator) — bit-identical at any worker count, paid for by each
/// band streaming all of `a` and `b`.
pub fn matmul_at_b_acc_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    if n == 0 {
        return;
    }
    static STATS: OnceLock<&'static KernelStats> = OnceLock::new();
    let _t = metrics::profile(&STATS, "kernel.matmul_at_b", 2 * (m * k * n) as u64);
    pool::for_each_row_band(c, k, n, workers, |k0, band| {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (lk, crow) in band.chunks_exact_mut(n).enumerate() {
                let aik = arow[k0 + lk];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += aik * bj;
                }
            }
        }
    });
}

/// [`matmul_at_b_acc`] with the sparsity skip, for genuinely sparse `a`
/// (the X⁰ input features in the TRANS_W0 gradient). Bit-identical to
/// the dense variant for finite `b`.
pub fn matmul_at_b_acc_sparse(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
}

/// C[m,k] = A[m,n] @ B[k,n]^T — the activation-gradient product
/// (`dX = dY @ W^T` with row-major W). Four output columns per pass
/// share one streaming read of the `a` row (independent dot chains);
/// each dot keeps the reference left-to-right order. Large shapes fan
/// output rows across the worker pool — same bits either way.
pub fn matmul_a_bt_into(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    matmul_a_bt_into_workers(a, b, m, n, k, c, par_workers(m * n * k));
}

/// [`matmul_a_bt_into`] with an explicit worker count (0 = the global
/// `--workers` knob). Output rows split into contiguous disjoint bands;
/// every dot keeps its serial reduction order, so the result is
/// bit-identical at any worker count.
pub fn matmul_a_bt_into_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    if k == 0 {
        return;
    }
    static STATS: OnceLock<&'static KernelStats> = OnceLock::new();
    let _t = metrics::profile(&STATS, "kernel.matmul_a_bt", 2 * (m * n * k) as u64);
    pool::for_each_row_band(c, m, k, workers, |row0, band| {
        for (r, crow) in band.chunks_exact_mut(k).enumerate() {
            let i = row0 + r;
            let arow = &a[i * n..(i + 1) * n];
            let mut kk = 0;
            while kk + K_UNROLL <= k {
                let b0 = &b[kk * n..(kk + 1) * n];
                let b1 = &b[(kk + 1) * n..(kk + 2) * n];
                let b2 = &b[(kk + 2) * n..(kk + 3) * n];
                let b3 = &b[(kk + 3) * n..(kk + 4) * n];
                let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
                for (j, &aj) in arow.iter().enumerate() {
                    s0 += aj * b0[j];
                    s1 += aj * b1[j];
                    s2 += aj * b2[j];
                    s3 += aj * b3[j];
                }
                crow[kk] = s0;
                crow[kk + 1] = s1;
                crow[kk + 2] = s2;
                crow[kk + 3] = s3;
                kk += K_UNROLL;
            }
            for kt in kk..k {
                let brow = &b[kt * n..(kt + 1) * n];
                crow[kt] = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
            }
        }
    });
}

/// Allocating wrapper around [`matmul_a_bt_into`].
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * k];
    matmul_a_bt_into(a, b, m, n, k, &mut c);
    c
}

/// Fast-math lane width: panel partial sums combine as a balanced tree
/// instead of one serial add chain.
const FAST_LANES: usize = 8;

/// C[m,n] = A[m,k] @ B[k,n], `--fast-math` variant: walks k in panels of
/// [`FAST_LANES`] and folds each panel's eight products into the
/// accumulator through a balanced tree sum. The shorter dependency
/// chains let LLVM keep more multiply streams in flight than the
/// chained-add default — at the price of a *different* (but fixed and
/// deterministic) rounding order than [`matmul_into`], so outputs agree
/// to relative tolerance, not bitwise. Only reachable behind the opt-in
/// flag.
pub fn matmul_into_fast(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    matmul_into_fast_workers(a, b, m, k, n, c, par_workers(m * k * n));
}

/// [`matmul_into_fast`] with an explicit worker count. Banding is the
/// same disjoint-output-row scheme as the exact kernels, so for a fixed
/// input the fast path is itself bit-identical at any worker count.
pub fn matmul_into_fast_workers(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    workers: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if n == 0 {
        return;
    }
    pool::for_each_row_band(c, m, n, workers, |row0, band| {
        for (r, crow) in band.chunks_exact_mut(n).enumerate() {
            let i = row0 + r;
            let arow = &a[i * k..(i + 1) * k];
            crow.fill(0.0);
            let mut kk = 0;
            while kk + FAST_LANES <= k {
                let al = &arow[kk..kk + FAST_LANES];
                let brows: [&[f32]; FAST_LANES] =
                    std::array::from_fn(|l| &b[(kk + l) * n..(kk + l + 1) * n]);
                for (j, cj) in crow.iter_mut().enumerate() {
                    let p: [f32; FAST_LANES] = std::array::from_fn(|l| al[l] * brows[l][j]);
                    *cj += ((p[0] + p[1]) + (p[2] + p[3])) + ((p[4] + p[5]) + (p[6] + p[7]));
                }
                kk += FAST_LANES;
            }
            for kt in kk..k {
                let aik = arow[kt];
                let brow = &b[kt * n..(kt + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow) {
                    *cj += aik * bj;
                }
            }
        }
    });
}

/// Reassociated 8-lane dot product — the `--fast-math` reduction: eight
/// independent accumulators over `chunks_exact(8)` panels, combined as a
/// balanced tree, serial tail. Deterministic, but a different rounding
/// order than the left-to-right reference sum.
pub fn dot_fast(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let xc = x.chunks_exact(FAST_LANES);
    let yc = y.chunks_exact(FAST_LANES);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    let mut acc = [0f32; FAST_LANES];
    for (xs, ys) in xc.zip(yc) {
        for l in 0..FAST_LANES {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut tail = 0f32;
    for (xv, yv) in xr.iter().zip(yr) {
        tail += xv * yv;
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// C[m,k] = A[m,n] @ B[k,n]^T, `--fast-math` variant: every output
/// element is a [`dot_fast`] lane reduction. Same banded row ownership
/// as the exact kernel; tolerance-equal (not bitwise) to
/// [`matmul_a_bt_into`].
pub fn matmul_a_bt_into_fast(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    if k == 0 {
        return;
    }
    pool::for_each_row_band(c, m, k, par_workers(m * n * k), |row0, band| {
        for (r, crow) in band.chunks_exact_mut(k).enumerate() {
            let arow = &a[(row0 + r) * n..(row0 + r + 1) * n];
            for (kk, cj) in crow.iter_mut().enumerate() {
                *cj = dot_fast(arow, &b[kk * n..(kk + 1) * n]);
            }
        }
    });
}

/// x[r, :] += bias for every row.
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(bias.len(), cols);
    for r in 0..rows {
        for (xi, bi) in x[r * cols..(r + 1) * cols].iter_mut().zip(bias) {
            *xi += bi;
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero `dx` wherever the forward activation was zero
/// (`act` is the *post*-ReLU output).
pub fn relu_bwd(dx: &mut [f32], act: &[f32]) {
    debug_assert_eq!(dx.len(), act.len());
    for (d, &a) in dx.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// out[c] += sum over rows of x[r, c] (bias gradients).
pub fn colsum_acc(x: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), cols);
    for r in 0..rows {
        for (o, xi) in out.iter_mut().zip(&x[r * cols..(r + 1) * cols]) {
            *o += xi;
        }
    }
}

/// Message-passing aggregation over a sparse operator in COO form:
/// out[i, :] += w * x[j, :] for every (i, j, w). With the symmetric
/// normalized adjacency this is Â @ X — and, Â being symmetric, its own
/// transpose, so forward and backward use the same call. Kept as the
/// reference implementation; the hot path runs the CSR kernels below.
pub fn aggregate(coo: &[(u32, u32, f32)], x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * cols);
    let mut out = vec![0f32; rows * cols];
    for &(i, j, w) in coo {
        let (i, j) = (i as usize, j as usize);
        let src = &x[j * cols..(j + 1) * cols];
        let dst = &mut out[i * cols..(i + 1) * cols];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += w * s;
        }
    }
    out
}

/// The normalized adjacency in CSR form: rows grouped by destination
/// node, entries within a row in the *original COO order* (a stable
/// counting sort), so per-element accumulation order — and therefore
/// every bit of the output — matches the COO walk exactly.
#[derive(Debug, Clone)]
pub struct Csr {
    /// `rows + 1` offsets into `col`/`w`.
    row_ptr: Vec<u32>,
    col: Vec<u32>,
    w: Vec<f32>,
    rows: usize,
}

impl Csr {
    pub fn from_coo(rows: usize, coo: &[(u32, u32, f32)]) -> Csr {
        let mut row_ptr = vec![0u32; rows + 1];
        for &(i, _, _) in coo {
            row_ptr[i as usize + 1] += 1;
        }
        for r in 0..rows {
            row_ptr[r + 1] += row_ptr[r];
        }
        let mut next: Vec<u32> = row_ptr[..rows].to_vec();
        let mut col = vec![0u32; coo.len()];
        let mut w = vec![0f32; coo.len()];
        for &(i, j, wij) in coo {
            let slot = next[i as usize] as usize;
            col[slot] = j;
            w[slot] = wij;
            next[i as usize] += 1;
        }
        Csr { row_ptr, col, w, rows }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn nnz(&self) -> usize {
        self.col.len()
    }

    /// Heap bytes held by the operator — the scaling benches report this
    /// as a peak-RSS proxy alongside throughput.
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col.len() * 4 + self.w.len() * 4
    }

    /// Materialize as a dense row-major `[rows, cols]` matrix. Test and
    /// diagnostics helper only — the hot paths never call this.
    pub fn to_dense(&self, cols: usize) -> Vec<f32> {
        let mut a = vec![0f32; self.rows * cols];
        for i in 0..self.rows {
            for e in self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize {
                a[i * cols + self.col[e] as usize] += self.w[e];
            }
        }
        a
    }
}

/// out = Â @ x over the CSR operator (overwrites `out`). One pass over
/// the edge list; each output row accumulates in cache instead of
/// scattering writes across the matrix. Large operators fan output rows
/// across the worker pool — same bits either way (CSR rows are
/// independent gathers).
pub fn aggregate_into(csr: &Csr, x: &[f32], cols: usize, out: &mut [f32]) {
    aggregate_into_workers(csr, x, cols, out, par_workers(csr.nnz() * cols));
}

/// [`aggregate_into`] with an explicit worker count (0 = the global
/// `--workers` knob). Each output row reads (never writes) the shared
/// `x`, so banding is race-free and bit-identical at any worker count.
pub fn aggregate_into_workers(csr: &Csr, x: &[f32], cols: usize, out: &mut [f32], workers: usize) {
    debug_assert_eq!(x.len(), csr.rows * cols);
    debug_assert_eq!(out.len(), csr.rows * cols);
    if cols == 0 {
        return;
    }
    pool::for_each_row_band(out, csr.rows, cols, workers, |row0, band| {
        for (r, dst) in band.chunks_exact_mut(cols).enumerate() {
            let i = row0 + r;
            dst.fill(0.0);
            for e in csr.row_ptr[i] as usize..csr.row_ptr[i + 1] as usize {
                let w = csr.w[e];
                let src = &x[csr.col[e] as usize * cols..(csr.col[e] as usize + 1) * cols];
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    });
}

/// The fused GCN layer pass: out = relu(Â @ x + bias), walking the edge
/// list once and finishing each output row (bias add + ReLU) while it is
/// still hot, instead of three separate sweeps over `[n, h]`.
/// Bit-identical to `aggregate` → `add_bias` → `relu`. Large operators
/// fan output rows across the worker pool — same bits either way.
pub fn aggregate_bias_relu_into(csr: &Csr, x: &[f32], bias: &[f32], cols: usize, out: &mut [f32]) {
    aggregate_bias_relu_into_workers(csr, x, bias, cols, out, par_workers(csr.nnz() * cols));
}

/// [`aggregate_bias_relu_into`] with an explicit worker count (0 = the
/// global `--workers` knob). Same disjoint-output-row scheme as
/// [`aggregate_into_workers`]: bit-identical at any worker count.
pub fn aggregate_bias_relu_into_workers(
    csr: &Csr,
    x: &[f32],
    bias: &[f32],
    cols: usize,
    out: &mut [f32],
    workers: usize,
) {
    debug_assert_eq!(bias.len(), cols);
    debug_assert_eq!(x.len(), csr.rows * cols);
    debug_assert_eq!(out.len(), csr.rows * cols);
    if cols == 0 {
        return;
    }
    static STATS: OnceLock<&'static KernelStats> = OnceLock::new();
    let _t = metrics::profile(&STATS, "kernel.aggregate", 2 * (csr.nnz() * cols) as u64);
    pool::for_each_row_band(out, csr.rows, cols, workers, |row0, band| {
        for (r, dst) in band.chunks_exact_mut(cols).enumerate() {
            let i = row0 + r;
            dst.fill(0.0);
            for e in csr.row_ptr[i] as usize..csr.row_ptr[i + 1] as usize {
                let w = csr.w[e];
                let src = &x[csr.col[e] as usize * cols..(csr.col[e] as usize + 1) * cols];
                for (o, s) in dst.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
            for (o, bi) in dst.iter_mut().zip(bias) {
                *o += bi;
                if *o < 0.0 {
                    *o = 0.0;
                }
            }
        }
    });
}

/// Build the symmetric-normalized adjacency with self-loops (Eq. 6) as a
/// COO list over the *undirected* support of A + I — the sparse twin of
/// `features::normalized_adjacency` (duplicate edges deduplicate, exactly
/// like the dense construction).
pub fn normalized_adjacency_coo(n: usize, edges: &[(usize, usize)]) -> Vec<(u32, u32, f32)> {
    let mut und = std::collections::HashSet::new();
    for &(s, t) in edges {
        if s != t {
            und.insert((s.min(t), s.max(t)));
        }
    }
    let mut deg = vec![1f32; n]; // self-loop
    for &(a, b) in &und {
        deg[a] += 1.0;
        deg[b] += 1.0;
    }
    let dinv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
    let mut coo = Vec::with_capacity(n + 2 * und.len());
    for (v, di) in dinv.iter().enumerate() {
        coo.push((v as u32, v as u32, di * di));
    }
    let mut pairs: Vec<(usize, usize)> = und.into_iter().collect();
    pairs.sort_unstable(); // deterministic accumulation order
    for (a, b) in pairs {
        let w = dinv[a] * dinv[b];
        coo.push((a as u32, b as u32, w));
        coo.push((b as u32, a as u32, w));
    }
    coo
}

/// Â in CSR form straight from the edge list — the sparse hot path used
/// by the native policy and the serving pipeline. The dense
/// `features::normalized_adjacency` remains only as the small-graph
/// differential-test reference.
pub fn normalized_adjacency_csr(n: usize, edges: &[(usize, usize)]) -> Csr {
    Csr::from_coo(n, &normalized_adjacency_coo(n, edges))
}

/// Mean-pool rows of `z` into `slots` segments by id (the segment_mean of
/// Alg. 1), writing into caller buffers (`pooled` is `[slots, cols]`,
/// `counts` is `[slots]`). Empty segments pool to zero.
pub fn segment_mean_into(
    z: &[f32],
    ids: &[i32],
    rows: usize,
    cols: usize,
    slots: usize,
    pooled: &mut [f32],
    counts: &mut [f32],
) {
    debug_assert_eq!(z.len(), rows * cols);
    debug_assert_eq!(ids.len(), rows);
    debug_assert_eq!(pooled.len(), slots * cols);
    debug_assert_eq!(counts.len(), slots);
    pooled.fill(0.0);
    counts.fill(0.0);
    for (r, &id) in ids.iter().enumerate() {
        let c = id as usize;
        counts[c] += 1.0;
        let src = &z[r * cols..(r + 1) * cols];
        let dst = &mut pooled[c * cols..(c + 1) * cols];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += s;
        }
    }
    for (c, &cnt) in counts.iter().enumerate() {
        if cnt > 1.0 {
            for v in pooled[c * cols..(c + 1) * cols].iter_mut() {
                *v /= cnt;
            }
        }
    }
}

/// Allocating wrapper around [`segment_mean_into`]; returns
/// (pooled `[slots, cols]`, counts `[slots]`).
pub fn segment_mean(
    z: &[f32],
    ids: &[i32],
    rows: usize,
    cols: usize,
    slots: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut pooled = vec![0f32; slots * cols];
    let mut counts = vec![0f32; slots];
    segment_mean_into(z, ids, rows, cols, slots, &mut pooled, &mut counts);
    (pooled, counts)
}

/// Numerically-stable log-softmax of one row, into a caller buffer.
pub fn log_softmax_into(row: &[f32], out: &mut [f32]) {
    debug_assert_eq!(row.len(), out.len());
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = row.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln() as f32;
    for (o, &x) in out.iter_mut().zip(row) {
        *o = x - mx - lse;
    }
}

/// Numerically-stable log-softmax of one row.
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; row.len()];
    log_softmax_into(row, &mut out);
    out
}

/// Logistic sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x as f64).exp() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The pre-PR6 scalar kernels, kept verbatim as differential-test
    /// references: the blocked/branch-free kernels must reproduce these
    /// bit-for-bit on every shape, including sparse inputs.
    mod reference {
        pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
            let mut c = vec![0f32; m * n];
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        *cj += aik * bj;
                    }
                }
            }
            c
        }

        pub fn matmul_at_b_acc(
            a: &[f32],
            b: &[f32],
            m: usize,
            k: usize,
            n: usize,
            c: &mut [f32],
        ) {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let brow = &b[i * n..(i + 1) * n];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let crow = &mut c[kk * n..(kk + 1) * n];
                    for (cj, bj) in crow.iter_mut().zip(brow) {
                        *cj += aik * bj;
                    }
                }
            }
        }

        pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
            let mut c = vec![0f32; m * k];
            for i in 0..m {
                let arow = &a[i * n..(i + 1) * n];
                let crow = &mut c[i * k..(i + 1) * k];
                for (kk, cj) in crow.iter_mut().enumerate() {
                    let brow = &b[kk * n..(kk + 1) * n];
                    *cj = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
                }
            }
            c
        }
    }

    /// Random values with a controllable zero fraction (the sparse-skip
    /// equivalence must hold exactly where the old kernel skipped).
    fn random_mat(rng: &mut Rng, len: usize, zero_frac: f64) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.next_f64() < zero_frac {
                    0.0
                } else {
                    rng.next_f32() * 2.0 - 1.0
                }
            })
            .collect()
    }

    #[test]
    fn matmul_small() {
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn profiling_is_observationally_invisible() {
        // With --profile on, kernels record call/ns/flops counters but
        // produce bit-identical outputs; with it off, no counts accrue.
        let _g = metrics::lock_test_guard();
        let mut rng = Rng::new(11);
        let a = random_mat(&mut rng, 8 * 5, 0.0);
        let b = random_mat(&mut rng, 5 * 7, 0.0);
        let off = matmul(&a, &b, 8, 5, 7);
        let calls = metrics::counter("kernel.matmul.calls");
        let flops = metrics::counter("kernel.matmul.flops");
        let (c0, f0) = (calls.get(), flops.get());
        metrics::set_profiling(true);
        let on = matmul(&a, &b, 8, 5, 7);
        metrics::set_profiling(false);
        assert_eq!(off, on);
        assert!(calls.get() >= c0 + 1);
        assert!(flops.get() >= f0 + 2 * 8 * 5 * 7);
    }

    #[test]
    fn dense_kernels_match_legacy_skip_kernels_bitwise() {
        // Odd / non-multiple-of-unroll shapes, with and without zeros:
        // the blocked branch-free kernels must be bit-identical to the
        // legacy scalar loops (satellite: skip removal is observationally
        // invisible).
        let mut rng = Rng::new(42);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (2, 3, 2),
            (5, 7, 3),
            (4, 4, 4),
            (7, 13, 11),
            (9, 16, 8),
            (16, 17, 19),
            (33, 46, 32),
        ] {
            for &zf in &[0.0, 0.3, 0.9] {
                let a = random_mat(&mut rng, m * k, zf);
                let b = random_mat(&mut rng, k * n, 0.0);
                // matmul: dense vs legacy vs sparse entry point.
                let want = reference::matmul(&a, &b, m, k, n);
                let got = matmul(&a, &b, m, k, n);
                assert_eq!(got, want, "matmul {m}x{k}x{n} zf={zf}");
                let mut sp = vec![0f32; m * n];
                matmul_sparse_rows(&a, &b, m, k, n, &mut sp);
                assert_eq!(sp, want, "matmul_sparse_rows {m}x{k}x{n} zf={zf}");
                // A^T B accumulation, seeded with a non-zero accumulator.
                let seed = random_mat(&mut rng, k * n, 0.0);
                let bb = random_mat(&mut rng, m * n, 0.0);
                let mut want_acc = seed.clone();
                reference::matmul_at_b_acc(&a, &bb, m, k, n, &mut want_acc);
                let mut got_acc = seed.clone();
                matmul_at_b_acc(&a, &bb, m, k, n, &mut got_acc);
                assert_eq!(got_acc, want_acc, "at_b_acc {m}x{k}x{n} zf={zf}");
                let mut got_sp = seed.clone();
                matmul_at_b_acc_sparse(&a, &bb, m, k, n, &mut got_sp);
                assert_eq!(got_sp, want_acc, "at_b_acc_sparse {m}x{k}x{n} zf={zf}");
                // A B^T (bb is [m,n], seed is [k,n]).
                let want_bt = reference::matmul_a_bt(&bb, &seed, m, n, k);
                let got_bt = matmul_a_bt(&bb, &seed, m, n, k);
                assert_eq!(got_bt, want_bt, "a_bt {m}x{n}x{k}");
            }
        }
    }

    #[test]
    fn worker_counts_are_bit_identical() {
        // The tentpole guarantee: every parallel kernel entry point at
        // workers ∈ {0 (auto), 2, 4} must reproduce workers=1 bitwise —
        // banding moves rows between threads, never terms within a sum.
        let mut rng = Rng::new(1234);
        for &(m, k, n) in
            &[(1usize, 1usize, 1usize), (5, 7, 3), (16, 17, 19), (33, 46, 32), (67, 31, 29)]
        {
            let a = random_mat(&mut rng, m * k, 0.2);
            let b = random_mat(&mut rng, k * n, 0.0);
            let mut want = vec![0f32; m * n];
            matmul_into_workers(&a, &b, m, k, n, &mut want, 1);
            // a2 doubles as the [m,n] operand of A^T·B and A·B^T below.
            let a2 = random_mat(&mut rng, m * n, 0.0);
            let b2 = random_mat(&mut rng, k * n, 0.0);
            let mut want_bt = vec![0f32; m * k];
            matmul_a_bt_into_workers(&a2, &b2, m, n, k, &mut want_bt, 1);
            let seed = random_mat(&mut rng, k * n, 0.0);
            let mut want_acc = seed.clone();
            matmul_at_b_acc_workers(&a, &a2, m, k, n, &mut want_acc, 1);
            for workers in [0usize, 2, 4] {
                let mut got = vec![9f32; m * n];
                matmul_into_workers(&a, &b, m, k, n, &mut got, workers);
                assert_eq!(got, want, "matmul {m}x{k}x{n} workers={workers}");
                let mut got_bt = vec![9f32; m * k];
                matmul_a_bt_into_workers(&a2, &b2, m, n, k, &mut got_bt, workers);
                assert_eq!(got_bt, want_bt, "a_bt {m}x{n}x{k} workers={workers}");
                // The documented partition-by-output-rows scheme must
                // preserve the serial order even seeded mid-accumulation.
                let mut got_acc = seed.clone();
                matmul_at_b_acc_workers(&a, &a2, m, k, n, &mut got_acc, workers);
                assert_eq!(got_acc, want_acc, "at_b_acc {m}x{k}x{n} workers={workers}");
            }
        }
    }

    #[test]
    fn csr_aggregation_bit_identical_across_worker_counts() {
        use crate::graph::CompGraph;
        let mut rng = Rng::new(4321);
        let g = CompGraph::random(&mut rng, 57, 23);
        let coo = normalized_adjacency_coo(g.n(), &g.edges);
        let csr = Csr::from_coo(g.n(), &coo);
        for cols in [1usize, 5, 16] {
            let x = random_mat(&mut rng, g.n() * cols, 0.1);
            let bias = random_mat(&mut rng, cols, 0.0);
            let mut want = vec![0f32; g.n() * cols];
            aggregate_into_workers(&csr, &x, cols, &mut want, 1);
            let mut want_fused = vec![0f32; g.n() * cols];
            aggregate_bias_relu_into_workers(&csr, &x, &bias, cols, &mut want_fused, 1);
            for workers in [0usize, 2, 4] {
                let mut got = vec![7f32; g.n() * cols];
                aggregate_into_workers(&csr, &x, cols, &mut got, workers);
                assert_eq!(got, want, "aggregate cols={cols} workers={workers}");
                let mut gotf = vec![7f32; g.n() * cols];
                aggregate_bias_relu_into_workers(&csr, &x, &bias, cols, &mut gotf, workers);
                assert_eq!(gotf, want_fused, "fused cols={cols} workers={workers}");
            }
        }
    }

    #[test]
    fn default_entry_points_route_large_shapes_through_the_pool() {
        // Above the work threshold the plain names take the banded path;
        // the auto-dispatch relies on that path matching serial bitwise.
        let mut rng = Rng::new(888);
        let (m, k, n) = (128usize, 64usize, 64usize);
        assert!(m * k * n >= PAR_MIN_WORK, "shape must clear the threshold");
        let a = random_mat(&mut rng, m * k, 0.0);
        let b = random_mat(&mut rng, k * n, 0.0);
        let mut want = vec![0f32; m * n];
        matmul_into_workers(&a, &b, m, k, n, &mut want, 1);
        let mut got = vec![0f32; m * n];
        matmul_into(&a, &b, m, k, n, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn fast_math_agrees_to_tolerance_and_is_worker_invariant() {
        // Fast math reassociates sums, so it only promises tolerance
        // against the exact kernels — but it is still a *fixed* order:
        // within the fast path, worker counts must agree bitwise.
        let mut rng = Rng::new(2468);
        for &(m, k, n) in &[(3usize, 8usize, 5usize), (7, 13, 11), (16, 32, 19), (33, 46, 32)] {
            let a = random_mat(&mut rng, m * k, 0.0);
            let b = random_mat(&mut rng, k * n, 0.0);
            let exact = matmul(&a, &b, m, k, n);
            let mut fast = vec![0f32; m * n];
            matmul_into_fast(&a, &b, m, k, n, &mut fast);
            for (i, (&e, &f)) in exact.iter().zip(&fast).enumerate() {
                let tol = 1e-4 * (1.0 + e.abs());
                assert!((e - f).abs() <= tol, "matmul_fast {m}x{k}x{n} [{i}]: {e} vs {f}");
            }
            for workers in [2usize, 4] {
                let mut fw = vec![0f32; m * n];
                matmul_into_fast_workers(&a, &b, m, k, n, &mut fw, workers);
                assert_eq!(fw, fast, "fast path must be worker-invariant (w={workers})");
            }
            // A·B^T fast vs exact (a2 [m,n], b2 [k,n]).
            let a2 = random_mat(&mut rng, m * n, 0.0);
            let b2 = random_mat(&mut rng, k * n, 0.0);
            let exact_bt = matmul_a_bt(&a2, &b2, m, n, k);
            let mut fast_bt = vec![0f32; m * k];
            matmul_a_bt_into_fast(&a2, &b2, m, n, k, &mut fast_bt);
            for (i, (&e, &f)) in exact_bt.iter().zip(&fast_bt).enumerate() {
                let tol = 1e-4 * (1.0 + e.abs());
                assert!((e - f).abs() <= tol, "a_bt_fast {m}x{n}x{k} [{i}]: {e} vs {f}");
            }
        }
    }

    #[test]
    fn dot_fast_matches_reference_sum_to_tolerance() {
        let mut rng = Rng::new(1357);
        for len in [0usize, 1, 7, 8, 9, 16, 37, 200] {
            let x = random_mat(&mut rng, len, 0.0);
            let y = random_mat(&mut rng, len, 0.0);
            let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            let got = dot_fast(&x, &y);
            assert!((want - got).abs() <= 1e-4 * (1.0 + want.abs()), "len={len}: {want} vs {got}");
            // Deterministic: same inputs, same bits, every call.
            assert_eq!(got.to_bits(), dot_fast(&x, &y).to_bits());
        }
    }

    #[test]
    fn transpose_products_agree_with_matmul() {
        // A^T B via matmul_at_b_acc == matmul of the explicit transpose.
        let a = [1., 2., 3., 4., 5., 6.]; // [3,2]
        let b = [1., 0., 2., 1., 0., 3.]; // [3,2]
        let at = [1., 3., 5., 2., 4., 6.]; // [2,3]
        let mut c = vec![0f32; 4];
        matmul_at_b_acc(&a, &b, 3, 2, 2, &mut c);
        assert_eq!(c, matmul(&at, &b, 2, 3, 2));
        // A B^T via matmul_a_bt == matmul with the explicit transpose.
        let bt = [1., 2., 0., 0., 1., 3.]; // [2,3]
        assert_eq!(matmul_a_bt(&a, &b, 3, 2, 3), matmul(&a, &bt, 3, 2, 3));
    }

    #[test]
    fn bias_relu_and_backward() {
        let mut x = vec![-1.0, 0.5, 2.0, -0.25];
        add_bias(&mut x, &[0.25, -0.25], 2, 2);
        assert_eq!(x, vec![-0.75, 0.25, 2.25, -0.5]);
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.25, 2.25, 0.0]);
        let mut dx = vec![1.0; 4];
        relu_bwd(&mut dx, &x);
        assert_eq!(dx, vec![0.0, 1.0, 1.0, 0.0]);
        let mut cs = vec![0f32; 2];
        colsum_acc(&x, 2, 2, &mut cs);
        assert_eq!(cs, vec![2.25, 0.25]);
    }

    #[test]
    fn coo_adjacency_matches_dense() {
        use crate::features::normalized_adjacency;
        use crate::graph::CompGraph;
        let mut rng = Rng::new(5);
        let g = CompGraph::random(&mut rng, 24, 8);
        let dense = normalized_adjacency(&g);
        let coo = normalized_adjacency_coo(g.n(), &g.edges);
        let mut rebuilt = vec![0f32; g.n() * g.n()];
        for &(i, j, w) in &coo {
            rebuilt[i as usize * g.n() + j as usize] += w;
        }
        for (a, b) in dense.iter().zip(&rebuilt) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn aggregate_is_coo_matmul() {
        // 2 nodes, operator [[0.5, 0.25], [0.25, 1.0]].
        let coo = vec![(0u32, 0u32, 0.5f32), (0, 1, 0.25), (1, 0, 0.25), (1, 1, 1.0)];
        let x = [2.0, 4.0, 8.0, 16.0]; // [2,2]
        let out = aggregate(&coo, &x, 2, 2);
        assert_eq!(out, vec![3.0, 6.0, 8.5, 17.0]);
    }

    #[test]
    fn csr_aggregate_matches_coo_bitwise() {
        use crate::graph::CompGraph;
        let mut rng = Rng::new(7);
        for &(nodes, extra) in &[(3usize, 1usize), (17, 5), (40, 12)] {
            let g = CompGraph::random(&mut rng, nodes, extra);
            let coo = normalized_adjacency_coo(g.n(), &g.edges);
            let csr = Csr::from_coo(g.n(), &coo);
            assert_eq!(csr.rows(), g.n());
            assert_eq!(csr.nnz(), coo.len());
            for cols in [1usize, 4, 7] {
                let x = random_mat(&mut rng, g.n() * cols, 0.2);
                let want = aggregate(&coo, &x, g.n(), cols);
                let mut got = vec![1f32; g.n() * cols]; // overwritten
                aggregate_into(&csr, &x, cols, &mut got);
                assert_eq!(got, want, "n={nodes} cols={cols}");
            }
        }
    }

    #[test]
    fn fused_gcn_layer_matches_separate_passes() {
        use crate::graph::CompGraph;
        let mut rng = Rng::new(9);
        let g = CompGraph::random(&mut rng, 21, 6);
        let coo = normalized_adjacency_coo(g.n(), &g.edges);
        let csr = Csr::from_coo(g.n(), &coo);
        let cols = 5;
        let x = random_mat(&mut rng, g.n() * cols, 0.0);
        let bias = random_mat(&mut rng, cols, 0.0);
        // Reference: aggregate -> add_bias -> relu, three passes.
        let mut want = aggregate(&coo, &x, g.n(), cols);
        add_bias(&mut want, &bias, g.n(), cols);
        relu(&mut want);
        let mut got = vec![-3f32; g.n() * cols];
        aggregate_bias_relu_into(&csr, &x, &bias, cols, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn segment_mean_pools_and_counts() {
        let z = [1., 2., 3., 4., 5., 6.]; // 3 rows of 2
        let (pooled, counts) = segment_mean(&z, &[0, 0, 1], 3, 2, 3);
        assert_eq!(counts, vec![2.0, 1.0, 0.0]);
        assert_eq!(&pooled[..2], &[2.0, 3.0]); // mean of rows 0,1
        assert_eq!(&pooled[2..4], &[5.0, 6.0]);
        assert_eq!(&pooled[4..], &[0.0, 0.0]); // empty segment
        // The into-variant clears stale buffer contents first.
        let mut pooled2 = vec![9f32; 6];
        let mut counts2 = vec![9f32; 3];
        segment_mean_into(&z, &[0, 0, 1], 3, 2, 3, &mut pooled2, &mut counts2);
        assert_eq!(pooled2, pooled);
        assert_eq!(counts2, counts);
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = lp.iter().map(|&x| (x as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!(lp[2] > lp[1] && lp[1] > lp[0]);
        // Stable under large offsets.
        let lp2 = log_softmax(&[1001.0, 1002.0, 1003.0]);
        for (a, b) in lp.iter().zip(&lp2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sigmoid_bounds() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(20.0) > 0.999);
        assert!(sigmoid(-20.0) < 0.001);
    }
}

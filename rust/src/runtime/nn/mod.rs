//! Native f32 tensor kernels: the building blocks of the pure-rust policy
//! backend (`NativePolicy`), mirroring the math of the AOT'd JAX/Pallas
//! kernels in `python/compile/kernels/` — dense matmuls, the GCN
//! message-passing aggregation over the DAG's normalized adjacency (kept
//! sparse as a COO list instead of the artifacts' dense `[V, V]` matrix),
//! segment mean-pooling, softmax/log-prob, and the transpose products the
//! hand-written backward passes need.
//!
//! Everything here is deterministic, allocation-simple, row-major and
//! unpadded: the native backend works at the *real* working-graph sizes,
//! not the artifacts' static padded capacities.

pub mod policy;

pub use policy::{NativeBatch, NativePolicy};

/// C[m,n] = A[m,k] @ B[k,n] (row-major).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue; // ReLU/one-hot inputs are sparse in practice
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
    c
}

/// C[k,n] += A[m,k]^T @ B[m,n] — the weight-gradient product, accumulated
/// into `c` so per-step gradients sum across a buffered batch.
pub fn matmul_at_b_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
}

/// C[m,k] = A[m,n] @ B[k,n]^T — the activation-gradient product
/// (`dX = dY @ W^T` with row-major W).
pub fn matmul_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * k];
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let crow = &mut c[i * k..(i + 1) * k];
        for (kk, cj) in crow.iter_mut().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            *cj = arow.iter().zip(brow).map(|(x, y)| x * y).sum();
        }
    }
    c
}

/// x[r, :] += bias for every row.
pub fn add_bias(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(bias.len(), cols);
    for r in 0..rows {
        for (xi, bi) in x[r * cols..(r + 1) * cols].iter_mut().zip(bias) {
            *xi += bi;
        }
    }
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero `dx` wherever the forward activation was zero
/// (`act` is the *post*-ReLU output).
pub fn relu_bwd(dx: &mut [f32], act: &[f32]) {
    debug_assert_eq!(dx.len(), act.len());
    for (d, &a) in dx.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

/// out[c] += sum over rows of x[r, c] (bias gradients).
pub fn colsum_acc(x: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), cols);
    for r in 0..rows {
        for (o, xi) in out.iter_mut().zip(&x[r * cols..(r + 1) * cols]) {
            *o += xi;
        }
    }
}

/// Message-passing aggregation over a sparse operator in COO form:
/// out[i, :] += w * x[j, :] for every (i, j, w). With the symmetric
/// normalized adjacency this is Â @ X — and, Â being symmetric, its own
/// transpose, so forward and backward use the same call.
pub fn aggregate(coo: &[(u32, u32, f32)], x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * cols);
    let mut out = vec![0f32; rows * cols];
    for &(i, j, w) in coo {
        let (i, j) = (i as usize, j as usize);
        let src = &x[j * cols..(j + 1) * cols];
        let dst = &mut out[i * cols..(i + 1) * cols];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += w * s;
        }
    }
    out
}

/// Build the symmetric-normalized adjacency with self-loops (Eq. 6) as a
/// COO list over the *undirected* support of A + I — the sparse twin of
/// `features::normalized_adjacency` (duplicate edges deduplicate, exactly
/// like the dense construction).
pub fn normalized_adjacency_coo(n: usize, edges: &[(usize, usize)]) -> Vec<(u32, u32, f32)> {
    let mut und = std::collections::HashSet::new();
    for &(s, t) in edges {
        if s != t {
            und.insert((s.min(t), s.max(t)));
        }
    }
    let mut deg = vec![1f32; n]; // self-loop
    for &(a, b) in &und {
        deg[a] += 1.0;
        deg[b] += 1.0;
    }
    let dinv: Vec<f32> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
    let mut coo = Vec::with_capacity(n + 2 * und.len());
    for (v, di) in dinv.iter().enumerate() {
        coo.push((v as u32, v as u32, di * di));
    }
    let mut pairs: Vec<(usize, usize)> = und.into_iter().collect();
    pairs.sort_unstable(); // deterministic accumulation order
    for (a, b) in pairs {
        let w = dinv[a] * dinv[b];
        coo.push((a as u32, b as u32, w));
        coo.push((b as u32, a as u32, w));
    }
    coo
}

/// Mean-pool rows of `z` into `slots` segments by id (the segment_mean of
/// Alg. 1); returns (pooled [slots, cols], counts [slots]). Empty segments
/// pool to zero.
pub fn segment_mean(
    z: &[f32],
    ids: &[i32],
    rows: usize,
    cols: usize,
    slots: usize,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(z.len(), rows * cols);
    debug_assert_eq!(ids.len(), rows);
    let mut pooled = vec![0f32; slots * cols];
    let mut counts = vec![0f32; slots];
    for (r, &id) in ids.iter().enumerate() {
        let c = id as usize;
        counts[c] += 1.0;
        let src = &z[r * cols..(r + 1) * cols];
        let dst = &mut pooled[c * cols..(c + 1) * cols];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += s;
        }
    }
    for (c, &cnt) in counts.iter().enumerate() {
        if cnt > 1.0 {
            for v in pooled[c * cols..(c + 1) * cols].iter_mut() {
                *v /= cnt;
            }
        }
    }
    (pooled, counts)
}

/// Numerically-stable log-softmax of one row.
pub fn log_softmax(row: &[f32]) -> Vec<f32> {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = row.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln() as f32;
    row.iter().map(|&x| x - mx - lse).collect()
}

/// Logistic sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x as f64).exp() as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn transpose_products_agree_with_matmul() {
        // A^T B via matmul_at_b_acc == matmul of the explicit transpose.
        let a = [1., 2., 3., 4., 5., 6.]; // [3,2]
        let b = [1., 0., 2., 1., 0., 3.]; // [3,2]
        let at = [1., 3., 5., 2., 4., 6.]; // [2,3]
        let mut c = vec![0f32; 4];
        matmul_at_b_acc(&a, &b, 3, 2, 2, &mut c);
        assert_eq!(c, matmul(&at, &b, 2, 3, 2));
        // A B^T via matmul_a_bt == matmul with the explicit transpose.
        let bt = [1., 2., 0., 0., 1., 3.]; // [2,3]
        assert_eq!(matmul_a_bt(&a, &b, 3, 2, 3), matmul(&a, &bt, 3, 2, 3));
    }

    #[test]
    fn bias_relu_and_backward() {
        let mut x = vec![-1.0, 0.5, 2.0, -0.25];
        add_bias(&mut x, &[0.25, -0.25], 2, 2);
        assert_eq!(x, vec![-0.75, 0.25, 2.25, -0.5]);
        relu(&mut x);
        assert_eq!(x, vec![0.0, 0.25, 2.25, 0.0]);
        let mut dx = vec![1.0; 4];
        relu_bwd(&mut dx, &x);
        assert_eq!(dx, vec![0.0, 1.0, 1.0, 0.0]);
        let mut cs = vec![0f32; 2];
        colsum_acc(&x, 2, 2, &mut cs);
        assert_eq!(cs, vec![2.25, 0.25]);
    }

    #[test]
    fn coo_adjacency_matches_dense() {
        use crate::features::normalized_adjacency;
        use crate::graph::CompGraph;
        use crate::util::Rng;
        let mut rng = Rng::new(5);
        let g = CompGraph::random(&mut rng, 24, 8);
        let dense = normalized_adjacency(&g);
        let coo = normalized_adjacency_coo(g.n(), &g.edges);
        let mut rebuilt = vec![0f32; g.n() * g.n()];
        for &(i, j, w) in &coo {
            rebuilt[i as usize * g.n() + j as usize] += w;
        }
        for (a, b) in dense.iter().zip(&rebuilt) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn aggregate_is_coo_matmul() {
        // 2 nodes, operator [[0.5, 0.25], [0.25, 1.0]].
        let coo = vec![(0u32, 0u32, 0.5f32), (0, 1, 0.25), (1, 0, 0.25), (1, 1, 1.0)];
        let x = [2.0, 4.0, 8.0, 16.0]; // [2,2]
        let out = aggregate(&coo, &x, 2, 2);
        assert_eq!(out, vec![3.0, 6.0, 8.5, 17.0]);
    }

    #[test]
    fn segment_mean_pools_and_counts() {
        let z = [1., 2., 3., 4., 5., 6.]; // 3 rows of 2
        let (pooled, counts) = segment_mean(&z, &[0, 0, 1], 3, 2, 3);
        assert_eq!(counts, vec![2.0, 1.0, 0.0]);
        assert_eq!(&pooled[..2], &[2.0, 3.0]); // mean of rows 0,1
        assert_eq!(&pooled[2..4], &[5.0, 6.0]);
        assert_eq!(&pooled[4..], &[0.0, 0.0]); // empty segment
    }

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = lp.iter().map(|&x| (x as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!(lp[2] > lp[1] && lp[1] > lp[0]);
        // Stable under large offsets.
        let lp2 = log_softmax(&[1001.0, 1002.0, 1003.0]);
        for (a, b) in lp.iter().zip(&lp2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn sigmoid_bounds() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(20.0) > 0.999);
        assert!(sigmoid(-20.0) < 0.001);
    }
}

//! The native (pure-rust) HSDAG policy: the same model the AOT artifacts
//! implement — input MLP (layer_trans=2) → feedback add → 2 GCN layers
//! (Eq. 6) → GPN edge scorer (Eq. 7) and group placer head — plus a
//! hand-written backward pass and Adam, so the full Eq. 14 REINFORCE
//! update runs with zero external dependencies.
//!
//! Unlike the PJRT path, everything here works at the *real* working-graph
//! sizes (no static padding) and the GCN aggregation is sparse (CSR over
//! A+I), so a training step costs O((V + E) · H + V · H²) instead of
//! O(V_pad² · H). Parameter layout and initialization mirror
//! `python/compile/model.py::hsdag_param_spec` exactly (Glorot-uniform
//! weights, zero biases) via [`ParamStore::init_hsdag`], drawn from the
//! deterministic seeded [`Rng`], so runs reproduce bit-for-bit from a
//! fixed seed.
//!
//! ## Hot-path memory discipline (PR 6)
//!
//! The policy owns a [`Scratch`] arena: every forward/backward
//! intermediate lives in a pre-sized reusable buffer, so steady-state
//! `fwd` / `placer` / `loss_and_grads` calls allocate nothing (buffers
//! grow monotonically to the largest batch seen). Three consequences:
//!
//! - The hot entry points take `&mut self` (they scribble in the arena).
//! - Parameters are private behind [`NativePolicy::params`] /
//!   [`NativePolicy::params_mut`]: the arena memoizes the input-MLP
//!   activations `h0`/`h1` (which depend only on X⁰ and the TRANS
//!   weights, *not* on feedback), keyed by a version counter that every
//!   mutable access bumps. During rollouts and serving — where weights
//!   are frozen — the first two matmuls of every forward are free.
//! - [`NativePolicy::fwd_many`] / [`NativePolicy::placer_many`] stack B
//!   rollouts into single `[B·n, h]` weight passes. Row independence of
//!   the matmul kernels makes the batched results bit-identical to B
//!   separate calls.

use anyhow::{ensure, Result};

use super::{
    add_bias, aggregate_bias_relu_into, aggregate_into, colsum_acc, dot_fast, log_softmax_into,
    matmul_a_bt_into, matmul_a_bt_into_fast, matmul_at_b_acc, matmul_at_b_acc_sparse, matmul_into,
    matmul_into_fast, matmul_sparse_rows, normalized_adjacency_csr, relu, relu_bwd,
    segment_mean_into, sigmoid, Csr,
};
use crate::runtime::params::ParamStore;
use crate::util::Rng;

/// Dispatch between the exact and `--fast-math` matmul. The forward
/// stacks and the backward `dX = dY @ W^T` products switch together, so
/// a fast-math run is fast end to end; gradient *accumulation*
/// ([`matmul_at_b_acc`]) always stays exact — its saxpy rows have no
/// long dot chain to reassociate, so there is nothing to win. The
/// sparse one-hot input kernels likewise never switch (the skip beats
/// lanes on X⁰).
fn mm_into(fast: bool, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    if fast {
        matmul_into_fast(a, b, m, k, n, c);
    } else {
        matmul_into(a, b, m, k, n, c);
    }
}

/// [`mm_into`]'s twin for the A·Bᵀ activation-gradient product.
fn mm_a_bt_into(fast: bool, a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    if fast {
        matmul_a_bt_into_fast(a, b, m, n, k, c);
    } else {
        matmul_a_bt_into(a, b, m, n, k, c);
    }
}

/// GPN partition log-likelihood weight in the REINFORCE objective
/// (`shapes.PARTITION_LOSS_WEIGHT`).
const LAMBDA: f32 = 0.1;
/// Edge-score clip for the partition log-likelihood (`model.py` eps).
const SCORE_EPS: f32 = 1e-6;
/// Train-time dropout on the input MLP (`shapes.DROPOUT`).
const TRAIN_DROPOUT: f64 = 0.2;
/// Adam moments (`shapes.ADAM_B1/B2/EPS`).
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;

// Parameter indices, in `hsdag_param_spec` order.
const TRANS_W0: usize = 0;
const TRANS_B0: usize = 1;
const TRANS_W1: usize = 2;
const TRANS_B1: usize = 3;
const GCN_W0: usize = 4;
const GCN_B0: usize = 5;
const GCN_W1: usize = 6;
const GCN_B1: usize = 7;
const EDGE_W0: usize = 8;
const EDGE_B0: usize = 9;
const EDGE_W1: usize = 10;
const EDGE_B1: usize = 11;
const PLACE_W0: usize = 12;
const PLACE_B0: usize = 13;
const PLACE_W1: usize = 14;
const PLACE_B1: usize = 15;

/// One buffered REINFORCE window, viewed as plain slices. The planes use
/// the caller's slot strides (`v_stride` ≥ real nodes, `e_stride` ≥ real
/// edges) so the agent's padded replay buffer can be passed as-is; only
/// the first `n` / `e` entries of each step's plane are read.
pub struct NativeBatch<'a> {
    /// Buffered steps (coefficient slots; zero-coefficient steps skip).
    pub t: usize,
    /// Row stride of the per-step node planes.
    pub v_stride: usize,
    /// Row stride of the per-step edge planes.
    pub e_stride: usize,
    /// Feedback state each step's forward saw, `[t, v_stride, H]`.
    pub fb: &'a [f32],
    /// Group id per node, `[t, v_stride]`.
    pub cids: &'a [i32],
    /// Sampled device per group *slot*, `[t, v_stride]`.
    pub actions: &'a [i32],
    /// 1.0 for valid group slots, `[t, v_stride]`. Group ids are dense,
    /// so valid slots must lie in `0..max(cids)+1` (the agent's parser
    /// guarantees this).
    pub gmask: &'a [f32],
    /// 1.0 for retained (Eq. 9) edges, `[t, e_stride]`.
    pub retained: &'a [f32],
    /// Eq. 14 coefficients gamma^t · (r_t − baseline), `[t]`.
    pub coeff: &'a [f32],
    /// Dropout key for this update (two u32 halves, artifact convention).
    pub key: [u32; 2],
}

/// Grow-only buffer grab: returns `&mut v[..len]` without zeroing (the
/// `_into` kernels fully overwrite their output; accumulation buffers
/// `fill(0.0)` explicitly at the use site).
fn take(v: &mut Vec<f32>, len: usize) -> &mut [f32] {
    if v.len() < len {
        v.resize(len, 0.0);
    }
    &mut v[..len]
}

/// Reusable workspace for every forward/backward intermediate: grown on
/// first use (and when a larger rollout batch arrives), then reused so
/// steady-state policy calls allocate nothing.
///
/// Also holds the memoized input-MLP activations: `h0`/`h1` depend only
/// on X⁰ and the TRANS parameters, so they are recomputed only when
/// `trans_version` falls behind the policy's parameter version.
#[derive(Default)]
pub struct Scratch {
    /// Parameter version `h0`/`h1` were computed at (0 = never).
    trans_version: u64,
    h0: Vec<f32>,
    h1: Vec<f32>,
    /// Dropout multipliers (0 or 1/(1−p)) for the last train forward.
    keep: Vec<f32>,
    // Stacked encoder/edge-scorer activations, `[B·n, h]` / `[B·e, h]`.
    f: Vec<f32>,
    g: Vec<f32>,
    z1: Vec<f32>,
    z: Vec<f32>,
    pr: Vec<f32>,
    eh: Vec<f32>,
    scores: Vec<f32>,
    // Stacked placer-head activations, `[Σ slots, ·]`.
    pooled: Vec<f32>,
    counts: Vec<f32>,
    ph: Vec<f32>,
    logits: Vec<f32>,
    lsm: Vec<f32>,
    // Backward temporaries.
    dz: Vec<f32>,
    dg: Vec<f32>,
    dq: Vec<f32>,
    dh0: Vec<f32>,
    dlogits: Vec<f32>,
    dph: Vec<f32>,
    dpooled: Vec<f32>,
    deh: Vec<f32>,
    dpr: Vec<f32>,
}

/// The pure-rust HSDAG policy (parameters + graph constants + arena).
pub struct NativePolicy {
    /// Parameters + Adam state, `hsdag_param_spec` order. Private: all
    /// mutation goes through [`Self::params_mut`] so the memoized
    /// input-MLP cache can never go stale.
    params: ParamStore,
    /// Bumped on every mutable parameter access / train step.
    version: u64,
    n: usize,
    d: usize,
    h: usize,
    nd: usize,
    /// Node features X⁰, `[n, d]` (unpadded, genuinely sparse rows).
    x0: Vec<f32>,
    /// Real working-graph edges.
    edges: Vec<(usize, usize)>,
    /// Â = D̂^{-1/2}(A+I)D̂^{-1/2}, CSR with COO-stable row order
    /// (symmetric, so forward and backward share it).
    csr: Csr,
    /// Adam learning rate.
    lr: f64,
    /// Train-forward dropout probability (0 disables; tests use 0 for
    /// finite-difference gradient checks).
    pub train_dropout: f64,
    /// Opt-in `--fast-math` lane kernels (reassociated 8-wide sums in
    /// the matmuls and the edge-scorer dot). Deterministic, but only
    /// tolerance-equal to the default kernels. Private behind
    /// [`Self::set_fast_math`] so toggling can invalidate the memoized
    /// input MLP (which was computed with the previously-selected
    /// kernels).
    fast_math: bool,
    scratch: Scratch,
}

impl NativePolicy {
    /// Build a policy over a working graph: `x0` is the row-major `[n, d]`
    /// feature matrix, `edges` the real edge list. Parameters initialize
    /// Glorot-uniform from `rng` (deterministic per seed).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        x0: Vec<f32>,
        n: usize,
        d: usize,
        edges: Vec<(usize, usize)>,
        h: usize,
        nd: usize,
        lr: f64,
        rng: &mut Rng,
    ) -> Result<NativePolicy> {
        ensure!(x0.len() == n * d, "x0 is {} elems, want {}x{}", x0.len(), n, d);
        ensure!(n > 0 && h > 0 && nd > 0, "degenerate policy dims");
        for &(s, t) in &edges {
            ensure!(s < n && t < n, "edge ({s},{t}) out of range for {n} nodes");
        }
        let csr = normalized_adjacency_csr(n, &edges);
        let params = ParamStore::init_hsdag(d, h, nd, rng);
        Ok(NativePolicy {
            params,
            version: 1,
            n,
            d,
            h,
            nd,
            x0,
            edges,
            csr,
            lr,
            train_dropout: TRAIN_DROPOUT,
            fast_math: false,
            scratch: Scratch::default(),
        })
    }

    /// Toggle the `--fast-math` lane kernels. Bumps the version counter
    /// so the memoized input-MLP activations are recomputed with the
    /// newly-selected kernels instead of leaking the other mode's bits.
    pub fn set_fast_math(&mut self, on: bool) {
        if self.fast_math != on {
            self.version = self.version.wrapping_add(1);
            self.fast_math = on;
        }
    }

    /// Whether the `--fast-math` lane kernels are active.
    pub fn fast_math(&self) -> bool {
        self.fast_math
    }

    pub fn n_nodes(&self) -> usize {
        self.n
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Read-only parameter access.
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// Mutable parameter access. Bumps the version counter so the
    /// memoized input-MLP activations are recomputed on the next forward
    /// — required for correctness, cheap when called around real updates.
    pub fn params_mut(&mut self) -> &mut ParamStore {
        self.version = self.version.wrapping_add(1);
        &mut self.params
    }

    /// Replace the whole parameter store (checkpoint import).
    pub fn set_params(&mut self, ps: ParamStore) {
        self.version = self.version.wrapping_add(1);
        self.params = ps;
    }

    /// Encoder over `B = fbs.len()` stacked rollouts (shared graph,
    /// per-rollout feedback): fills `scratch.{f, z1, z}` as `[B·n, h]`
    /// planes. The input MLP is memoized (feedback enters *after* it);
    /// the GCN matmuls run as single stacked `[B·n, h] @ [h, h]` passes
    /// and the sparse aggregation runs per `[n, h]` block. Dropout
    /// (train path) is only meaningful for B = 1.
    fn encode_stack(&mut self, fbs: &[&[f32]], mut drop_rng: Option<&mut Rng>) {
        let (n, d, h) = (self.n, self.d, self.h);
        let fast = self.fast_math;
        let b = fbs.len();
        debug_assert!(drop_rng.is_none() || b == 1, "dropout is a train-path (B=1) feature");
        // Memoized input MLP: h0 = relu(X⁰ W + b), h1 = relu(h0 W + b).
        if self.scratch.trans_version != self.version {
            let s = &mut self.scratch;
            let ps = &self.params;
            matmul_sparse_rows(
                &self.x0,
                ps.params[TRANS_W0].as_f32(),
                n,
                d,
                h,
                take(&mut s.h0, n * h),
            );
            add_bias(&mut s.h0[..n * h], ps.params[TRANS_B0].as_f32(), n, h);
            relu(&mut s.h0[..n * h]);
            mm_into(
                fast,
                &s.h0[..n * h],
                ps.params[TRANS_W1].as_f32(),
                n,
                h,
                h,
                take(&mut s.h1, n * h),
            );
            add_bias(&mut s.h1[..n * h], ps.params[TRANS_B1].as_f32(), n, h);
            relu(&mut s.h1[..n * h]);
            s.trans_version = self.version;
        }
        let s = &mut self.scratch;
        let ps = &self.params;
        // f_b = h1 (·keep) + fb_b, stacked.
        let use_drop = drop_rng.is_some() && self.train_dropout > 0.0;
        if use_drop {
            let rng = drop_rng.as_deref_mut().expect("checked");
            let inv = (1.0 / (1.0 - self.train_dropout)) as f32;
            let keep = take(&mut s.keep, n * h);
            for k in keep.iter_mut() {
                *k = if rng.next_f64() < self.train_dropout { 0.0 } else { inv };
            }
        }
        let f = take(&mut s.f, b * n * h);
        for (bi, fb) in fbs.iter().enumerate() {
            let dst = &mut f[bi * n * h..(bi + 1) * n * h];
            if use_drop {
                for ((o, (&h1v, &kv)), fbv) in
                    dst.iter_mut().zip(s.h1.iter().zip(&s.keep)).zip(&fb[..n * h])
                {
                    *o = h1v * kv + fbv;
                }
            } else {
                for ((o, &h1v), fbv) in dst.iter_mut().zip(&s.h1[..n * h]).zip(&fb[..n * h]) {
                    *o = h1v + fbv;
                }
            }
        }
        // GCN layer 1: stacked weight pass, per-block fused aggregation.
        mm_into(fast, f, ps.params[GCN_W0].as_f32(), b * n, h, h, take(&mut s.g, b * n * h));
        let z1 = take(&mut s.z1, b * n * h);
        for bi in 0..b {
            aggregate_bias_relu_into(
                &self.csr,
                &s.g[bi * n * h..(bi + 1) * n * h],
                ps.params[GCN_B0].as_f32(),
                h,
                &mut z1[bi * n * h..(bi + 1) * n * h],
            );
        }
        // GCN layer 2.
        mm_into(fast, z1, ps.params[GCN_W1].as_f32(), b * n, h, h, &mut s.g[..b * n * h]);
        let z = take(&mut s.z, b * n * h);
        for bi in 0..b {
            aggregate_bias_relu_into(
                &self.csr,
                &s.g[bi * n * h..(bi + 1) * n * h],
                ps.params[GCN_B1].as_f32(),
                h,
                &mut z[bi * n * h..(bi + 1) * n * h],
            );
        }
    }

    /// GPN edge scorer over the stacked embeddings in `scratch.z`: fills
    /// `scratch.{pr, eh, scores}` (`[B·e, h]` / `[B·e]`).
    fn edge_fwd_stack(&mut self, b: usize) {
        let (e, h, n) = (self.edges.len(), self.h, self.n);
        let fast = self.fast_math;
        let s = &mut self.scratch;
        let ps = &self.params;
        let pr = take(&mut s.pr, b * e * h);
        for bi in 0..b {
            let z = &s.z[bi * n * h..(bi + 1) * n * h];
            for (ei, &(src, dst)) in self.edges.iter().enumerate() {
                let zs = &z[src * h..(src + 1) * h];
                let zd = &z[dst * h..(dst + 1) * h];
                let row = &mut pr[(bi * e + ei) * h..(bi * e + ei + 1) * h];
                for ((o, a), c) in row.iter_mut().zip(zs).zip(zd) {
                    *o = a * c;
                }
            }
        }
        mm_into(fast, pr, ps.params[EDGE_W0].as_f32(), b * e, h, h, take(&mut s.eh, b * e * h));
        add_bias(&mut s.eh[..b * e * h], ps.params[EDGE_B0].as_f32(), b * e, h);
        relu(&mut s.eh[..b * e * h]);
        let w1 = ps.params[EDGE_W1].as_f32(); // [h, 1]
        let b1 = ps.params[EDGE_B1].as_f32()[0];
        let scores = take(&mut s.scores, b * e);
        for (row, out) in s.eh.chunks_exact(h).take(b * e).zip(scores.iter_mut()) {
            let logit: f32 = if fast {
                dot_fast(row, w1) + b1
            } else {
                row.iter().zip(w1).map(|(a, w)| a * w).sum::<f32>() + b1
            };
            *out = sigmoid(logit);
        }
    }

    /// Search-path forward: node embeddings Z `[n, h]` and edge scores
    /// `[e]` over the real edges. No dropout (greedy/sampling path).
    pub fn fwd(&mut self, fb: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.encode_stack(&[fb], None);
        self.edge_fwd_stack(1);
        let s = &self.scratch;
        (s.z[..self.n * self.h].to_vec(), s.scores[..self.edges.len()].to_vec())
    }

    /// Batched search-path forward: B rollouts' feedback states through
    /// one stacked weight pass. Bit-identical to B separate [`Self::fwd`]
    /// calls (matmul rows are independent), ~B× cheaper on weights and
    /// with the input MLP computed zero times (memoized) instead of B.
    pub fn fwd_many(&mut self, fbs: &[&[f32]]) -> Vec<(Vec<f32>, Vec<f32>)> {
        if fbs.is_empty() {
            return Vec::new();
        }
        let (n, h, e) = (self.n, self.h, self.edges.len());
        self.encode_stack(fbs, None);
        self.edge_fwd_stack(fbs.len());
        let s = &self.scratch;
        (0..fbs.len())
            .map(|bi| {
                (
                    s.z[bi * n * h..(bi + 1) * n * h].to_vec(),
                    s.scores[bi * e..(bi + 1) * e].to_vec(),
                )
            })
            .collect()
    }

    /// Placer head over the stacked per-rollout groupings: segment-means
    /// each rollout's `z` by its `cids` into a shared `[Σ slots, h]` row
    /// block, runs the head MLP as single stacked matmuls, then splits
    /// and masks per rollout. Returns `slots_b` row offsets via the
    /// per-rollout logits lengths (`slots_b · nd` each).
    fn placer_fwd_stack(&mut self, zs: &[&[f32]], cids: &[&[i32]]) -> Vec<usize> {
        let (n, h, nd) = (self.n, self.h, self.nd);
        let fast = self.fast_math;
        let b = zs.len();
        let slots_per: Vec<usize> = cids
            .iter()
            .map(|c| c[..n].iter().map(|&x| x.max(0) as usize + 1).max().unwrap_or(1))
            .collect();
        let total: usize = slots_per.iter().sum();
        let s = &mut self.scratch;
        let ps = &self.params;
        let pooled = take(&mut s.pooled, total * h);
        let counts = take(&mut s.counts, total);
        let mut off = 0usize;
        for bi in 0..b {
            let sl = slots_per[bi];
            segment_mean_into(
                &zs[bi][..n * h],
                &cids[bi][..n],
                n,
                h,
                sl,
                &mut pooled[off * h..(off + sl) * h],
                &mut counts[off..off + sl],
            );
            off += sl;
        }
        mm_into(
            fast,
            pooled,
            ps.params[PLACE_W0].as_f32(),
            total,
            h,
            h,
            take(&mut s.ph, total * h),
        );
        add_bias(&mut s.ph[..total * h], ps.params[PLACE_B0].as_f32(), total, h);
        relu(&mut s.ph[..total * h]);
        mm_into(
            fast,
            &s.ph[..total * h],
            ps.params[PLACE_W1].as_f32(),
            total,
            h,
            nd,
            take(&mut s.logits, total * nd),
        );
        add_bias(&mut s.logits[..total * nd], ps.params[PLACE_B1].as_f32(), total, nd);
        slots_per
    }

    /// Placer: per-group-slot device logits, row-major `[slots, nd]`
    /// with `slots = max(cids) + 1` (== `n_groups` for the parser's
    /// dense ids, so every valid group has a row); slots with
    /// `gmask <= 0` get −1e9 so softmax mass stays on valid groups.
    pub fn placer(&mut self, z: &[f32], cids: &[i32], gmask: &[f32]) -> Vec<f32> {
        self.placer_many(&[z], &[cids], &[gmask]).pop().expect("one rollout in, one out")
    }

    /// Batched placer over B rollouts (shared weights, per-rollout
    /// partitions). Bit-identical to B separate [`Self::placer`] calls.
    pub fn placer_many(
        &mut self,
        zs: &[&[f32]],
        cids: &[&[i32]],
        gmasks: &[&[f32]],
    ) -> Vec<Vec<f32>> {
        debug_assert!(zs.len() == cids.len() && zs.len() == gmasks.len());
        if zs.is_empty() {
            return Vec::new();
        }
        let nd = self.nd;
        let slots_per = self.placer_fwd_stack(zs, cids);
        let s = &self.scratch;
        let mut out = Vec::with_capacity(zs.len());
        let mut off = 0usize;
        for (bi, &sl) in slots_per.iter().enumerate() {
            let mut logits = s.logits[off * nd..(off + sl) * nd].to_vec();
            for g in 0..sl {
                if gmasks[bi][g] <= 0.0 {
                    for l in logits[g * nd..(g + 1) * nd].iter_mut() {
                        *l = -1e9;
                    }
                }
            }
            out.push(logits);
            off += sl;
        }
        out
    }

    /// Eq. 14 loss over a buffered window, forward only (tests and
    /// gradient checks). `with_dropout` matches the train-step forward.
    pub fn loss(&mut self, batch: &NativeBatch, with_dropout: bool) -> f32 {
        self.loss_and_grads(batch, with_dropout).0
    }

    /// One full REINFORCE/Adam update (Eq. 14) over the buffered window.
    /// Returns the loss; errors if it is non-finite.
    pub fn train(&mut self, batch: &NativeBatch) -> Result<f32> {
        let (loss, grads) = self.loss_and_grads(batch, true);
        ensure!(loss.is_finite(), "non-finite native training loss {loss}");
        let lr = self.lr;
        self.params_mut().adam_step(&grads, lr, ADAM_B1, ADAM_B2, ADAM_EPS);
        Ok(loss)
    }

    /// loss = −Σ_t coeff[t] · log p(P_t | G'; θ), with log p = placer
    /// log-likelihood + λ · partition (GPN) log-likelihood; gradients for
    /// every parameter by hand-written reverse-mode over the arena
    /// caches. Only the returned gradient vectors are allocated; all
    /// intermediates run through [`Scratch`].
    pub fn loss_and_grads(&mut self, batch: &NativeBatch, with_dropout: bool) -> (f32, Vec<Vec<f32>>) {
        let (n, d, h, nd) = (self.n, self.d, self.h, self.nd);
        let fast = self.fast_math;
        let e = self.edges.len();
        debug_assert!(batch.v_stride >= n && batch.e_stride >= e);
        let mut grads: Vec<Vec<f32>> =
            self.params.params.iter().map(|t| vec![0f32; t.numel()]).collect();
        let mut rng = Rng::new(((batch.key[0] as u64) << 32) | batch.key[1] as u64);
        let mut loss = 0f64;
        let denom = e.max(1) as f32;

        for t in 0..batch.t {
            let c = batch.coeff[t];
            if c == 0.0 {
                continue; // zero-coefficient slots contribute nothing
            }
            let base_v = t * batch.v_stride;
            let fb_t = &batch.fb[base_v * h..base_v * h + n * h];
            let cids_t = &batch.cids[base_v..base_v + n];
            let actions_t = &batch.actions[base_v..base_v + n];
            let gmask_t = &batch.gmask[base_v..base_v + n];
            let ret_t = &batch.retained[t * batch.e_stride..t * batch.e_stride + e];

            // Re-forward this step through the arena. The placer stack
            // needs `z` as an input slice while writing other arena
            // fields, so run it via the stacked helper on split borrows.
            self.encode_stack(&[fb_t], if with_dropout { Some(&mut rng) } else { None });
            self.edge_fwd_stack(1);
            let used_dropout = with_dropout && self.train_dropout > 0.0;
            {
                let (n_, h_, nd_) = (n, h, nd);
                let s = &mut self.scratch;
                let ps = &self.params;
                let slots =
                    cids_t.iter().map(|&x| x.max(0) as usize + 1).max().unwrap_or(1);
                segment_mean_into(
                    &s.z[..n_ * h_],
                    cids_t,
                    n_,
                    h_,
                    slots,
                    take(&mut s.pooled, slots * h_),
                    take(&mut s.counts, slots),
                );
                mm_into(
                    fast,
                    &s.pooled[..slots * h_],
                    ps.params[PLACE_W0].as_f32(),
                    slots,
                    h_,
                    h_,
                    take(&mut s.ph, slots * h_),
                );
                add_bias(&mut s.ph[..slots * h_], ps.params[PLACE_B0].as_f32(), slots, h_);
                relu(&mut s.ph[..slots * h_]);
                mm_into(
                    fast,
                    &s.ph[..slots * h_],
                    ps.params[PLACE_W1].as_f32(),
                    slots,
                    h_,
                    nd_,
                    take(&mut s.logits, slots * nd_),
                );
                add_bias(&mut s.logits[..slots * nd_], ps.params[PLACE_B1].as_f32(), slots, nd_);

                // d loss / d logp_t.
                let w = -c;

                // Placer log-likelihood + dlogits = w · (onehot − softmax).
                // Valid groups live in slots 0..slots (dense ids), so the
                // gmask scan stops there too.
                let mut lp_place = 0f64;
                let dlogits = take(&mut s.dlogits, slots * nd_);
                dlogits.fill(0.0);
                let lsm = take(&mut s.lsm, nd_);
                for g in 0..slots {
                    if gmask_t[g] <= 0.0 {
                        continue;
                    }
                    let row = &s.logits[g * nd_..(g + 1) * nd_];
                    log_softmax_into(row, lsm);
                    let a = actions_t[g] as usize;
                    lp_place += lsm[a] as f64;
                    for (j, lpj) in lsm.iter().enumerate() {
                        let onehot = if j == a { 1.0 } else { 0.0 };
                        dlogits[g * nd_ + j] = w * (onehot - lpj.exp());
                    }
                }

                // Partition (GPN) log-likelihood + per-edge logit grads.
                let mut lp_part = 0f64;
                let dlogit_e = take(&mut s.dpr, e); // reuse before dpr's real job
                dlogit_e.fill(0.0);
                let wl = w * LAMBDA / denom;
                for ei in 0..e {
                    let r = ret_t[ei];
                    let sr = s.scores[ei];
                    let sc = sr.clamp(SCORE_EPS, 1.0 - SCORE_EPS);
                    lp_part += (r * sc.ln() + (1.0 - r) * (1.0 - sc).ln()) as f64;
                    // Clip gradient: flat outside the clamp window.
                    if sr > SCORE_EPS && sr < 1.0 - SCORE_EPS {
                        let ds = wl * (r / sc - (1.0 - r) / (1.0 - sc));
                        dlogit_e[ei] = ds * sr * (1.0 - sr);
                    }
                }
                lp_part /= denom as f64;
                loss += -(c as f64) * (lp_place + LAMBDA as f64 * lp_part);

                // ---- backward: placer head → dz ----
                let dz = take(&mut s.dz, n_ * h_);
                dz.fill(0.0);
                matmul_at_b_acc(
                    &s.ph[..slots * h_],
                    &s.dlogits[..slots * nd_],
                    slots,
                    h_,
                    nd_,
                    &mut grads[PLACE_W1],
                );
                colsum_acc(&s.dlogits[..slots * nd_], slots, nd_, &mut grads[PLACE_B1]);
                let dph = take(&mut s.dph, slots * h_);
                mm_a_bt_into(
                    fast,
                    &s.dlogits[..slots * nd_],
                    ps.params[PLACE_W1].as_f32(),
                    slots,
                    nd_,
                    h_,
                    dph,
                );
                relu_bwd(dph, &s.ph[..slots * h_]);
                matmul_at_b_acc(
                    &s.pooled[..slots * h_],
                    dph,
                    slots,
                    h_,
                    h_,
                    &mut grads[PLACE_W0],
                );
                colsum_acc(dph, slots, h_, &mut grads[PLACE_B0]);
                let dpooled = take(&mut s.dpooled, slots * h_);
                mm_a_bt_into(
                    fast,
                    &s.dph[..slots * h_],
                    ps.params[PLACE_W0].as_f32(),
                    slots,
                    h_,
                    h_,
                    dpooled,
                );
                for (node, &cid) in cids_t.iter().enumerate() {
                    let cg = cid as usize;
                    let cnt = s.counts[cg].max(1.0);
                    let src = &s.dpooled[cg * h_..(cg + 1) * h_];
                    for (o, sv) in s.dz[node * h_..(node + 1) * h_].iter_mut().zip(src) {
                        *o += sv / cnt;
                    }
                }

                // ---- backward: edge scorer → dz ----
                let w1 = ps.params[EDGE_W1].as_f32();
                let deh = take(&mut s.deh, e * h_);
                deh.fill(0.0);
                for ei in 0..e {
                    let dl = s.dpr[ei]; // dlogit_e alias
                    if dl == 0.0 {
                        continue;
                    }
                    for (k, out) in deh[ei * h_..(ei + 1) * h_].iter_mut().enumerate() {
                        *out = dl * w1[k];
                    }
                    for (k, g) in grads[EDGE_W1].iter_mut().enumerate() {
                        *g += s.eh[ei * h_ + k] * dl;
                    }
                    grads[EDGE_B1][0] += dl;
                }
                relu_bwd(deh, &s.eh[..e * h_]);
                matmul_at_b_acc(&s.pr[..e * h_], deh, e, h_, h_, &mut grads[EDGE_W0]);
                colsum_acc(deh, e, h_, &mut grads[EDGE_B0]);
                let dpr = take(&mut s.dpr, e * h_);
                mm_a_bt_into(
                    fast,
                    &s.deh[..e * h_],
                    ps.params[EDGE_W0].as_f32(),
                    e,
                    h_,
                    h_,
                    dpr,
                );
                for (ei, &(src, t2)) in self.edges.iter().enumerate() {
                    let dpr_row = &s.dpr[ei * h_..(ei + 1) * h_];
                    for k in 0..h_ {
                        let zs = s.z[src * h_ + k];
                        let zd = s.z[t2 * h_ + k];
                        s.dz[src * h_ + k] += dpr_row[k] * zd;
                        s.dz[t2 * h_ + k] += dpr_row[k] * zs;
                    }
                }

                // ---- backward: encoder ----
                relu_bwd(&mut s.dz[..n_ * h_], &s.z[..n_ * h_]); // dq1, in place
                colsum_acc(&s.dz[..n_ * h_], n_, h_, &mut grads[GCN_B1]);
                let dg = take(&mut s.dg, n_ * h_);
                aggregate_into(&self.csr, &s.dz[..n_ * h_], h_, dg); // Â symmetric
                matmul_at_b_acc(&s.z1[..n_ * h_], dg, n_, h_, h_, &mut grads[GCN_W1]);
                let dq = take(&mut s.dq, n_ * h_);
                mm_a_bt_into(fast, &s.dg[..n_ * h_], ps.params[GCN_W1].as_f32(), n_, h_, h_, dq);
                relu_bwd(dq, &s.z1[..n_ * h_]);
                colsum_acc(dq, n_, h_, &mut grads[GCN_B0]);
                aggregate_into(&self.csr, &s.dq[..n_ * h_], h_, &mut s.dg[..n_ * h_]);
                matmul_at_b_acc(&s.f[..n_ * h_], &s.dg[..n_ * h_], n_, h_, h_, &mut grads[GCN_W0]);
                // df reuses dz (the encoder's dz is fully consumed above).
                mm_a_bt_into(
                    fast,
                    &s.dg[..n_ * h_],
                    ps.params[GCN_W0].as_f32(),
                    n_,
                    h_,
                    h_,
                    &mut s.dz[..n_ * h_],
                );
                if used_dropout {
                    for (x, k) in s.dz[..n_ * h_].iter_mut().zip(&s.keep) {
                        *x *= k;
                    }
                }
                relu_bwd(&mut s.dz[..n_ * h_], &s.h1[..n_ * h_]); // dp1, in place
                matmul_at_b_acc(
                    &s.h0[..n_ * h_],
                    &s.dz[..n_ * h_],
                    n_,
                    h_,
                    h_,
                    &mut grads[TRANS_W1],
                );
                colsum_acc(&s.dz[..n_ * h_], n_, h_, &mut grads[TRANS_B1]);
                let dh0 = take(&mut s.dh0, n_ * h_);
                mm_a_bt_into(
                    fast,
                    &s.dz[..n_ * h_],
                    ps.params[TRANS_W1].as_f32(),
                    n_,
                    h_,
                    h_,
                    dh0,
                );
                relu_bwd(dh0, &s.h0[..n_ * h_]);
                matmul_at_b_acc_sparse(&self.x0, dh0, n_, d, h_, &mut grads[TRANS_W0]);
                colsum_acc(dh0, n_, h_, &mut grads[TRANS_B0]);
            }
        }
        (loss as f32, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 6-node diamond-ish DAG with 6 edges.
    fn tiny() -> (usize, Vec<(usize, usize)>) {
        (6, vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5)])
    }

    fn tiny_policy(seed: u64) -> NativePolicy {
        let (n, edges) = tiny();
        let d = 3;
        let mut rng = Rng::new(seed);
        let x0: Vec<f32> = (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut p = NativePolicy::new(x0, n, d, edges, 4, 2, 1e-2, &mut rng).unwrap();
        p.train_dropout = 0.0; // deterministic forwards for the checks
        p
    }

    /// The tiny graph at h = 16 — at least two fast-math lanes wide, so
    /// the reassociated panels actually run (the h = 4 policy above only
    /// ever hits the serial tail, where fast == exact bitwise).
    fn lane_policy(seed: u64) -> NativePolicy {
        let (n, edges) = tiny();
        let d = 3;
        let mut rng = Rng::new(seed);
        let x0: Vec<f32> = (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut p = NativePolicy::new(x0, n, d, edges, 16, 2, 1e-2, &mut rng).unwrap();
        p.train_dropout = 0.0;
        p
    }

    /// A consistent batch over the tiny graph: 2 steps, padded strides.
    fn tiny_batch<'a>(bufs: &'a TinyBufs) -> NativeBatch<'a> {
        NativeBatch {
            t: 2,
            v_stride: 8,
            e_stride: 7,
            fb: &bufs.fb,
            cids: &bufs.cids,
            actions: &bufs.actions,
            gmask: &bufs.gmask,
            retained: &bufs.retained,
            coeff: &bufs.coeff,
            key: [7, 9],
        }
    }

    struct TinyBufs {
        fb: Vec<f32>,
        cids: Vec<i32>,
        actions: Vec<i32>,
        gmask: Vec<f32>,
        retained: Vec<f32>,
        coeff: Vec<f32>,
    }

    fn tiny_bufs() -> TinyBufs {
        let (h, vs, es, t) = (4usize, 8usize, 7usize, 2usize);
        let mut rng = Rng::new(99);
        let fb: Vec<f32> = (0..t * vs * h).map(|_| rng.next_f32() * 0.1).collect();
        // Step 0: 3 groups {0,1},{2,3},{4,5}; step 1: 2 groups.
        let mut cids = vec![0i32; t * vs];
        cids[..6].copy_from_slice(&[0, 0, 1, 1, 2, 2]);
        cids[vs..vs + 6].copy_from_slice(&[0, 0, 0, 1, 1, 1]);
        let mut gmask = vec![0f32; t * vs];
        gmask[..3].fill(1.0);
        gmask[vs..vs + 2].fill(1.0);
        let mut actions = vec![0i32; t * vs];
        actions[..3].copy_from_slice(&[1, 0, 1]);
        actions[vs..vs + 2].copy_from_slice(&[0, 1]);
        let mut retained = vec![0f32; t * es];
        retained[..6].copy_from_slice(&[1.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        retained[es..es + 6].copy_from_slice(&[1.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
        TinyBufs { fb, cids, actions, gmask, retained, coeff: vec![0.7, -0.4] }
    }

    #[test]
    fn fwd_shapes_and_score_range() {
        let mut p = tiny_policy(1);
        let fb = vec![0f32; 6 * 4];
        let (z, s) = p.fwd(&fb);
        assert_eq!(z.len(), 6 * 4);
        assert_eq!(s.len(), 6);
        assert!(s.iter().all(|&x| x > 0.0 && x < 1.0), "{s:?}");
        assert!(z.iter().all(|&x| x.is_finite() && x >= 0.0)); // post-ReLU
    }

    #[test]
    fn placer_masks_invalid_slots() {
        let mut p = tiny_policy(2);
        let fb = vec![0f32; 6 * 4];
        let (z, _) = p.fwd(&fb);
        // Three referenced group slots, but only the first two valid:
        // the head computes exactly max(cids)+1 rows and masks slot 2.
        let cids = [0, 0, 1, 1, 2, 2];
        let gmask = [1.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let logits = p.placer(&z, &cids, &gmask);
        assert_eq!(logits.len(), 3 * 2);
        assert!(logits[..4].iter().all(|&l| l > -1e8));
        assert!(logits[4..].iter().all(|&l| l <= -1e8));
    }

    #[test]
    fn fwd_many_matches_independent_fwd_calls_bitwise() {
        // The batched stacked pass must be observationally identical to
        // N separate forwards — down to the last bit.
        let mut p = tiny_policy(21);
        let (n, h) = (6usize, 4usize);
        let mut rng = Rng::new(33);
        let fbs: Vec<Vec<f32>> =
            (0..4).map(|_| (0..n * h).map(|_| rng.next_f32() * 0.2 - 0.1).collect()).collect();
        let singles: Vec<(Vec<f32>, Vec<f32>)> = fbs.iter().map(|fb| p.fwd(fb)).collect();
        let views: Vec<&[f32]> = fbs.iter().map(|v| v.as_slice()).collect();
        let batched = p.fwd_many(&views);
        assert_eq!(batched.len(), singles.len());
        for (bi, ((zb, sb), (zs, ss))) in batched.iter().zip(&singles).enumerate() {
            assert_eq!(zb, zs, "z mismatch in rollout {bi}");
            assert_eq!(sb, ss, "score mismatch in rollout {bi}");
        }
        // And a second batched call (arena reuse) still agrees.
        let again = p.fwd_many(&views);
        for ((za, sa), (zb, sb)) in again.iter().zip(&batched) {
            assert_eq!(za, zb);
            assert_eq!(sa, sb);
        }
    }

    #[test]
    fn placer_many_matches_independent_placer_calls_bitwise() {
        let mut p = tiny_policy(22);
        let fb = vec![0f32; 6 * 4];
        let fb2: Vec<f32> = (0..6 * 4).map(|i| (i as f32) * 0.01).collect();
        let rollouts = [
            (p.fwd(&fb).0, vec![0, 0, 1, 1, 2, 2], vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]),
            (p.fwd(&fb2).0, vec![0, 1, 1, 2, 3, 3], vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0]),
            (p.fwd(&fb).0, vec![0, 0, 0, 0, 0, 1], vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0]),
        ];
        let singles: Vec<Vec<f32>> =
            rollouts.iter().map(|(z, c, m)| p.placer(z, c, m)).collect();
        let zs: Vec<&[f32]> = rollouts.iter().map(|(z, _, _)| z.as_slice()).collect();
        let cs: Vec<&[i32]> = rollouts.iter().map(|(_, c, _)| c.as_slice()).collect();
        let ms: Vec<&[f32]> = rollouts.iter().map(|(_, _, m)| m.as_slice()).collect();
        let batched = p.placer_many(&zs, &cs, &ms);
        assert_eq!(batched, singles);
    }

    #[test]
    fn param_mutation_invalidates_memoized_input_mlp() {
        // The memoized h0/h1 must be recomputed after any parameter
        // mutation — a stale cache would silently freeze the input MLP.
        // Twin policies: `a` forwards first (priming its memo), `b` never
        // does; after the same mutation both must still agree bit-for-bit.
        let mut a = tiny_policy(23);
        let mut b = tiny_policy(23);
        let fb = vec![0f32; 6 * 4];
        let (z0, _) = a.fwd(&fb);
        let (z0b, _) = a.fwd(&fb); // memo hit: identical
        assert_eq!(z0, z0b);
        for p in [&mut a, &mut b] {
            for v in p.params_mut().params[TRANS_B1].as_f32_mut() {
                *v += 10.0; // large shift: guaranteed visible through ReLU
            }
        }
        let (za, sa) = a.fwd(&fb);
        let (zb, sb) = b.fwd(&fb);
        assert_eq!(za, zb, "stale memoized input MLP after params_mut");
        assert_eq!(sa, sb);
        assert_ne!(za, z0, "TRANS_B1 shift must reach the output");
        // set_params also invalidates: import b's snapshot into a after
        // perturbing a further, then both must agree again.
        a.params_mut().params[TRANS_W1].as_f32_mut()[0] -= 3.0;
        let _ = a.fwd(&fb);
        a.set_params(b.params().clone());
        let (za2, _) = a.fwd(&fb);
        assert_eq!(za2, zb, "stale memoized input MLP after set_params");
    }

    #[test]
    fn fast_math_policy_agrees_to_tolerance() {
        let mut exact = lane_policy(31);
        let mut fast = lane_policy(31);
        fast.set_fast_math(true);
        assert!(fast.fast_math() && !exact.fast_math());
        let (n, h) = (6usize, 16usize);
        let mut rng = Rng::new(77);
        let fb: Vec<f32> = (0..n * h).map(|_| rng.next_f32() * 0.2).collect();
        let (ze, se) = exact.fwd(&fb);
        let (zf, sf) = fast.fwd(&fb);
        for (i, (a, b)) in ze.iter().zip(&zf).enumerate() {
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "z[{i}]: {a} vs {b}");
        }
        for (i, (a, b)) in se.iter().zip(&sf).enumerate() {
            assert!((a - b).abs() <= 1e-4, "score[{i}]: {a} vs {b}");
        }
        // Placer head through the lane kernels too.
        let cids = [0, 0, 1, 1, 2, 2];
        let gmask = [1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        let le = exact.placer(&ze, &cids, &gmask);
        let lf = fast.placer(&zf, &cids, &gmask);
        for (i, (a, b)) in le.iter().zip(&lf).enumerate() {
            assert!((a - b).abs() <= 1e-3 * (1.0 + a.abs()), "logit[{i}]: {a} vs {b}");
        }
        // Gradients: fast-math training is tolerance-equal, not bitwise.
        let bufs = tiny_bufs();
        let fb16: Vec<f32> = (0..2 * 8 * h).map(|_| rng.next_f32() * 0.1).collect();
        let batch = NativeBatch {
            t: 2,
            v_stride: 8,
            e_stride: 7,
            fb: &fb16,
            cids: &bufs.cids,
            actions: &bufs.actions,
            gmask: &bufs.gmask,
            retained: &bufs.retained,
            coeff: &bufs.coeff,
            key: [7, 9],
        };
        let (loss_e, grads_e) = exact.loss_and_grads(&batch, false);
        let (loss_f, grads_f) = fast.loss_and_grads(&batch, false);
        assert!((loss_e - loss_f).abs() <= 1e-3 * (1.0 + loss_e.abs()), "{loss_e} vs {loss_f}");
        for (pi, (ge, gf)) in grads_e.iter().zip(&grads_f).enumerate() {
            for (i, (a, b)) in ge.iter().zip(gf).enumerate() {
                assert!((a - b).abs() <= 1e-3 * (1.0 + a.abs()), "grad[{pi}][{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fast_math_toggle_invalidates_memoized_input_mlp() {
        // h0/h1 memoized under the exact kernels must not leak into a
        // fast-math forward: the toggle bumps the version counter.
        let mut p = lane_policy(32);
        let fb = vec![0f32; 6 * 16];
        let _ = p.fwd(&fb); // primes the exact-kernel memo
        p.set_fast_math(true);
        let (zp, sp) = p.fwd(&fb);
        let mut q = lane_policy(32);
        q.set_fast_math(true);
        let (zq, sq) = q.fwd(&fb);
        assert_eq!(zp, zq, "stale exact-kernel memo leaked into the fast-math forward");
        assert_eq!(sp, sq);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut p = tiny_policy(3);
        let bufs = tiny_bufs();
        let batch = tiny_batch(&bufs);
        let (_, grads) = p.loss_and_grads(&batch, false);
        // Probe a few entries of every parameter tensor. Tolerances are
        // loose enough to absorb f32 noise and the occasional ReLU kink
        // inside the central-difference interval, but tight enough that a
        // wrong transpose / missing term / sign error fails loudly.
        let mut rng = Rng::new(17);
        let eps = 5e-3f32;
        for pi in 0..p.params().n() {
            let numel = p.params().params[pi].numel();
            for _ in 0..3.min(numel) {
                let idx = rng.below(numel);
                let orig = p.params().params[pi].as_f32()[idx];
                p.params_mut().params[pi].as_f32_mut()[idx] = orig + eps;
                let lp = p.loss(&batch, false);
                p.params_mut().params[pi].as_f32_mut()[idx] = orig - eps;
                let lm = p.loss(&batch, false);
                p.params_mut().params[pi].as_f32_mut()[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[pi][idx];
                let tol = (0.1 * (1.0 + fd.abs().max(an.abs()))).max(1e-2);
                assert!(
                    (fd - an).abs() < tol,
                    "param {pi} idx {idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn train_descends_on_fixed_batch() {
        let mut p = tiny_policy(4);
        let bufs = tiny_bufs();
        let l0 = {
            let batch = tiny_batch(&bufs);
            p.loss(&batch, false)
        };
        for _ in 0..30 {
            let batch = tiny_batch(&bufs);
            p.train(&batch).unwrap();
        }
        let l1 = {
            let batch = tiny_batch(&bufs);
            p.loss(&batch, false)
        };
        assert!(l1.is_finite() && l0.is_finite());
        assert!(l1 < l0, "loss should descend: {l0} -> {l1}");
        assert_eq!(p.params().step, 30.0);
    }

    #[test]
    fn zero_coefficients_leave_params_untouched() {
        let mut p = tiny_policy(5);
        let before: Vec<f32> = p.params().params[TRANS_W0].as_f32().to_vec();
        let mut bufs = tiny_bufs();
        bufs.coeff = vec![0.0, 0.0];
        let batch = tiny_batch(&bufs);
        let loss = p.train(&batch).unwrap();
        assert_eq!(loss, 0.0);
        // Adam still counts the step, but zero grads move nothing.
        assert_eq!(p.params().params[TRANS_W0].as_f32(), &before[..]);
    }

    #[test]
    fn deterministic_per_key() {
        let mut a = tiny_policy(6);
        let mut b = tiny_policy(6);
        a.train_dropout = 0.2;
        b.train_dropout = 0.2;
        let bufs = tiny_bufs();
        let la = a.train(&tiny_batch(&bufs)).unwrap();
        let lb = b.train(&tiny_batch(&bufs)).unwrap();
        assert_eq!(la, lb);
        assert_eq!(
            a.params().params[PLACE_W1].as_f32(),
            b.params().params[PLACE_W1].as_f32()
        );
    }
}

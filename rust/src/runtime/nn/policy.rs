//! The native (pure-rust) HSDAG policy: the same model the AOT artifacts
//! implement — input MLP (layer_trans=2) → feedback add → 2 GCN layers
//! (Eq. 6) → GPN edge scorer (Eq. 7) and group placer head — plus a
//! hand-written backward pass and Adam, so the full Eq. 14 REINFORCE
//! update runs with zero external dependencies.
//!
//! Unlike the PJRT path, everything here works at the *real* working-graph
//! sizes (no static padding) and the GCN aggregation is sparse (COO over
//! A+I), so a training step costs O((V + E) · H + V · H²) instead of
//! O(V_pad² · H). Parameter layout and initialization mirror
//! `python/compile/model.py::hsdag_param_spec` exactly (Glorot-uniform
//! weights, zero biases) via [`ParamStore::init_hsdag`], drawn from the
//! deterministic seeded [`Rng`], so runs reproduce bit-for-bit from a
//! fixed seed.

use anyhow::{ensure, Result};

use super::{
    add_bias, aggregate, colsum_acc, log_softmax, matmul, matmul_a_bt, matmul_at_b_acc,
    normalized_adjacency_coo, relu, relu_bwd, segment_mean, sigmoid,
};
use crate::runtime::params::ParamStore;
use crate::util::Rng;

/// GPN partition log-likelihood weight in the REINFORCE objective
/// (`shapes.PARTITION_LOSS_WEIGHT`).
const LAMBDA: f32 = 0.1;
/// Edge-score clip for the partition log-likelihood (`model.py` eps).
const SCORE_EPS: f32 = 1e-6;
/// Train-time dropout on the input MLP (`shapes.DROPOUT`).
const TRAIN_DROPOUT: f64 = 0.2;
/// Adam moments (`shapes.ADAM_B1/B2/EPS`).
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const ADAM_EPS: f64 = 1e-8;

// Parameter indices, in `hsdag_param_spec` order.
const TRANS_W0: usize = 0;
const TRANS_B0: usize = 1;
const TRANS_W1: usize = 2;
const TRANS_B1: usize = 3;
const GCN_W0: usize = 4;
const GCN_B0: usize = 5;
const GCN_W1: usize = 6;
const GCN_B1: usize = 7;
const EDGE_W0: usize = 8;
const EDGE_B0: usize = 9;
const EDGE_W1: usize = 10;
const EDGE_B1: usize = 11;
const PLACE_W0: usize = 12;
const PLACE_B0: usize = 13;
const PLACE_W1: usize = 14;
const PLACE_B1: usize = 15;

/// One buffered REINFORCE window, viewed as plain slices. The planes use
/// the caller's slot strides (`v_stride` ≥ real nodes, `e_stride` ≥ real
/// edges) so the agent's padded replay buffer can be passed as-is; only
/// the first `n` / `e` entries of each step's plane are read.
pub struct NativeBatch<'a> {
    /// Buffered steps (coefficient slots; zero-coefficient steps skip).
    pub t: usize,
    /// Row stride of the per-step node planes.
    pub v_stride: usize,
    /// Row stride of the per-step edge planes.
    pub e_stride: usize,
    /// Feedback state each step's forward saw, `[t, v_stride, H]`.
    pub fb: &'a [f32],
    /// Group id per node, `[t, v_stride]`.
    pub cids: &'a [i32],
    /// Sampled device per group *slot*, `[t, v_stride]`.
    pub actions: &'a [i32],
    /// 1.0 for valid group slots, `[t, v_stride]`. Group ids are dense,
    /// so valid slots must lie in `0..max(cids)+1` (the agent's parser
    /// guarantees this).
    pub gmask: &'a [f32],
    /// 1.0 for retained (Eq. 9) edges, `[t, e_stride]`.
    pub retained: &'a [f32],
    /// Eq. 14 coefficients gamma^t · (r_t − baseline), `[t]`.
    pub coeff: &'a [f32],
    /// Dropout key for this update (two u32 halves, artifact convention).
    pub key: [u32; 2],
}

/// Forward caches of the encoder (kept for the backward pass).
struct Encode {
    h0: Vec<f32>,
    h1: Vec<f32>,
    /// Per-element dropout multiplier (0 or 1/(1−p)); None outside train.
    keep: Option<Vec<f32>>,
    f: Vec<f32>,
    z1: Vec<f32>,
    z: Vec<f32>,
}

/// Forward caches of the edge scorer.
struct EdgeFwd {
    pr: Vec<f32>,
    eh: Vec<f32>,
    s: Vec<f32>,
}

/// Forward caches of the placer head (raw, unmasked logits).
struct PlacerFwd {
    /// Group slots actually computed (`max(cids) + 1` — with the dense
    /// group ids the parser produces, exactly `n_groups`).
    slots: usize,
    pooled: Vec<f32>,
    counts: Vec<f32>,
    ph: Vec<f32>,
    logits: Vec<f32>,
}

/// The pure-rust HSDAG policy (parameters + graph constants).
pub struct NativePolicy {
    /// Parameters + Adam state, `hsdag_param_spec` order.
    pub params: ParamStore,
    n: usize,
    d: usize,
    h: usize,
    nd: usize,
    /// Node features X⁰, `[n, d]` (unpadded).
    x0: Vec<f32>,
    /// Real working-graph edges.
    edges: Vec<(usize, usize)>,
    /// Â = D̂^{-1/2}(A+I)D̂^{-1/2} in COO form (symmetric).
    coo: Vec<(u32, u32, f32)>,
    /// Adam learning rate.
    lr: f64,
    /// Train-forward dropout probability (0 disables; tests use 0 for
    /// finite-difference gradient checks).
    pub train_dropout: f64,
}

impl NativePolicy {
    /// Build a policy over a working graph: `x0` is the row-major `[n, d]`
    /// feature matrix, `edges` the real edge list. Parameters initialize
    /// Glorot-uniform from `rng` (deterministic per seed).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        x0: Vec<f32>,
        n: usize,
        d: usize,
        edges: Vec<(usize, usize)>,
        h: usize,
        nd: usize,
        lr: f64,
        rng: &mut Rng,
    ) -> Result<NativePolicy> {
        ensure!(x0.len() == n * d, "x0 is {} elems, want {}x{}", x0.len(), n, d);
        ensure!(n > 0 && h > 0 && nd > 0, "degenerate policy dims");
        for &(s, t) in &edges {
            ensure!(s < n && t < n, "edge ({s},{t}) out of range for {n} nodes");
        }
        let coo = normalized_adjacency_coo(n, &edges);
        let params = ParamStore::init_hsdag(d, h, nd, rng);
        Ok(NativePolicy {
            params,
            n,
            d,
            h,
            nd,
            x0,
            edges,
            coo,
            lr,
            train_dropout: TRAIN_DROPOUT,
        })
    }

    pub fn n_nodes(&self) -> usize {
        self.n
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    fn p(&self, i: usize) -> &[f32] {
        self.params.params[i].as_f32()
    }

    /// Encoder: MLP → (optional dropout) → +fb → 2 GCN layers.
    /// `fb` is the evolving feedback state, at least `[n, h]` row-major.
    fn encode(&self, fb: &[f32], mut drop_rng: Option<&mut Rng>) -> Encode {
        let (n, d, h) = (self.n, self.d, self.h);
        let mut h0 = matmul(&self.x0, self.p(TRANS_W0), n, d, h);
        add_bias(&mut h0, self.p(TRANS_B0), n, h);
        relu(&mut h0);
        let mut h1 = matmul(&h0, self.p(TRANS_W1), n, h, h);
        add_bias(&mut h1, self.p(TRANS_B1), n, h);
        relu(&mut h1);
        let (mut f, keep) = match drop_rng.as_deref_mut() {
            Some(rng) if self.train_dropout > 0.0 => {
                let inv = (1.0 / (1.0 - self.train_dropout)) as f32;
                let keep: Vec<f32> = (0..n * h)
                    .map(|_| if rng.next_f64() < self.train_dropout { 0.0 } else { inv })
                    .collect();
                (h1.iter().zip(&keep).map(|(a, k)| a * k).collect::<Vec<f32>>(), Some(keep))
            }
            _ => (h1.clone(), None),
        };
        for (fi, fbv) in f.iter_mut().zip(&fb[..n * h]) {
            *fi += fbv;
        }
        let g0 = matmul(&f, self.p(GCN_W0), n, h, h);
        let mut z1 = aggregate(&self.coo, &g0, n, h);
        add_bias(&mut z1, self.p(GCN_B0), n, h);
        relu(&mut z1);
        let g1 = matmul(&z1, self.p(GCN_W1), n, h, h);
        let mut z = aggregate(&self.coo, &g1, n, h);
        add_bias(&mut z, self.p(GCN_B1), n, h);
        relu(&mut z);
        Encode { h0, h1, keep, f, z1, z }
    }

    /// GPN edge scorer: sigmoid(MLP(z_s ⊙ z_d)) per real edge.
    fn edge_fwd(&self, z: &[f32]) -> EdgeFwd {
        let (e, h) = (self.edges.len(), self.h);
        let mut pr = vec![0f32; e * h];
        for (ei, &(s, t)) in self.edges.iter().enumerate() {
            let zs = &z[s * h..(s + 1) * h];
            let zd = &z[t * h..(t + 1) * h];
            for (k, out) in pr[ei * h..(ei + 1) * h].iter_mut().enumerate() {
                *out = zs[k] * zd[k];
            }
        }
        let mut eh = matmul(&pr, self.p(EDGE_W0), e, h, h);
        add_bias(&mut eh, self.p(EDGE_B0), e, h);
        relu(&mut eh);
        let w1 = self.p(EDGE_W1); // [h, 1]
        let b1 = self.p(EDGE_B1)[0];
        let mut s = vec![0f32; e];
        for ei in 0..e {
            let logit: f32 =
                eh[ei * h..(ei + 1) * h].iter().zip(w1).map(|(a, b)| a * b).sum::<f32>() + b1;
            s[ei] = sigmoid(logit);
        }
        EdgeFwd { pr, eh, s }
    }

    /// Placer head over group slots (raw logits, no validity mask).
    /// Only slots up to `max(cids) + 1` are computed — with dense group
    /// ids that is exactly `n_groups`, so the head skips the (often ~10x
    /// more numerous) empty padding slots on every step and every train
    /// re-forward.
    fn placer_fwd(&self, z: &[f32], cids: &[i32]) -> PlacerFwd {
        let (n, h, nd) = (self.n, self.h, self.nd);
        let slots = cids[..n].iter().map(|&c| c.max(0) as usize + 1).max().unwrap_or(1);
        let (pooled, counts) = segment_mean(z, &cids[..n], n, h, slots);
        let mut ph = matmul(&pooled, self.p(PLACE_W0), slots, h, h);
        add_bias(&mut ph, self.p(PLACE_B0), slots, h);
        relu(&mut ph);
        let mut logits = matmul(&ph, self.p(PLACE_W1), slots, h, nd);
        add_bias(&mut logits, self.p(PLACE_B1), slots, nd);
        PlacerFwd { slots, pooled, counts, ph, logits }
    }

    /// Search-path forward: node embeddings Z `[n, h]` and edge scores
    /// `[e]` over the real edges. No dropout (greedy/sampling path).
    pub fn fwd(&self, fb: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let enc = self.encode(fb, None);
        let ef = self.edge_fwd(&enc.z);
        (enc.z, ef.s)
    }

    /// Placer: per-group-slot device logits, row-major `[slots, nd]`
    /// with `slots = max(cids) + 1` (== `n_groups` for the parser's
    /// dense ids, so every valid group has a row); slots with
    /// `gmask <= 0` get −1e9 so softmax mass stays on valid groups.
    pub fn placer(&self, z: &[f32], cids: &[i32], gmask: &[f32]) -> Vec<f32> {
        let nd = self.nd;
        let pf = self.placer_fwd(z, cids);
        let mut logits = pf.logits;
        for g in 0..pf.slots {
            if gmask[g] <= 0.0 {
                for l in logits[g * nd..(g + 1) * nd].iter_mut() {
                    *l = -1e9;
                }
            }
        }
        logits
    }

    /// Eq. 14 loss over a buffered window, forward only (tests and
    /// gradient checks). `with_dropout` matches the train-step forward.
    pub fn loss(&self, batch: &NativeBatch, with_dropout: bool) -> f32 {
        self.loss_and_grads(batch, with_dropout).0
    }

    /// One full REINFORCE/Adam update (Eq. 14) over the buffered window.
    /// Returns the loss; errors if it is non-finite.
    pub fn train(&mut self, batch: &NativeBatch) -> Result<f32> {
        let (loss, grads) = self.loss_and_grads(batch, true);
        ensure!(loss.is_finite(), "non-finite native training loss {loss}");
        self.params.adam_step(&grads, self.lr, ADAM_B1, ADAM_B2, ADAM_EPS);
        Ok(loss)
    }

    /// loss = −Σ_t coeff[t] · log p(P_t | G'; θ), with log p = placer
    /// log-likelihood + λ · partition (GPN) log-likelihood; gradients for
    /// every parameter by hand-written reverse-mode over the caches.
    fn loss_and_grads(&self, batch: &NativeBatch, with_dropout: bool) -> (f32, Vec<Vec<f32>>) {
        let (n, d, h, nd) = (self.n, self.d, self.h, self.nd);
        let e = self.edges.len();
        debug_assert!(batch.v_stride >= n && batch.e_stride >= e);
        let mut grads: Vec<Vec<f32>> =
            self.params.params.iter().map(|t| vec![0f32; t.numel()]).collect();
        let mut rng = Rng::new(((batch.key[0] as u64) << 32) | batch.key[1] as u64);
        let mut loss = 0f64;
        let denom = e.max(1) as f32;

        for t in 0..batch.t {
            let c = batch.coeff[t];
            if c == 0.0 {
                continue; // zero-coefficient slots contribute nothing
            }
            let base_v = t * batch.v_stride;
            let fb_t = &batch.fb[base_v * h..base_v * h + n * h];
            let cids_t = &batch.cids[base_v..base_v + n];
            let actions_t = &batch.actions[base_v..base_v + n];
            let gmask_t = &batch.gmask[base_v..base_v + n];
            let ret_t = &batch.retained[t * batch.e_stride..t * batch.e_stride + e];

            let enc = self.encode(fb_t, if with_dropout { Some(&mut rng) } else { None });
            let ef = self.edge_fwd(&enc.z);
            let pf = self.placer_fwd(&enc.z, cids_t);

            // d loss / d logp_t.
            let w = -c;

            // Placer log-likelihood + dlogits = w · (onehot − softmax).
            // Valid groups live in slots 0..pf.slots (dense ids), so the
            // gmask scan stops there too.
            let slots = pf.slots;
            let mut lp_place = 0f64;
            let mut dlogits = vec![0f32; slots * nd];
            for g in 0..slots {
                if gmask_t[g] <= 0.0 {
                    continue;
                }
                let row = &pf.logits[g * nd..(g + 1) * nd];
                let logp = log_softmax(row);
                let a = actions_t[g] as usize;
                lp_place += logp[a] as f64;
                for (j, lpj) in logp.iter().enumerate() {
                    let onehot = if j == a { 1.0 } else { 0.0 };
                    dlogits[g * nd + j] = w * (onehot - lpj.exp());
                }
            }

            // Partition (GPN) log-likelihood + per-edge logit gradients.
            let mut lp_part = 0f64;
            let mut dlogit_e = vec![0f32; e];
            let wl = w * LAMBDA / denom;
            for ei in 0..e {
                let r = ret_t[ei];
                let sr = ef.s[ei];
                let sc = sr.clamp(SCORE_EPS, 1.0 - SCORE_EPS);
                lp_part += (r * sc.ln() + (1.0 - r) * (1.0 - sc).ln()) as f64;
                // Clip gradient: flat outside the clamp window.
                if sr > SCORE_EPS && sr < 1.0 - SCORE_EPS {
                    let ds = wl * (r / sc - (1.0 - r) / (1.0 - sc));
                    dlogit_e[ei] = ds * sr * (1.0 - sr);
                }
            }
            lp_part /= denom as f64;
            loss += -(c as f64) * (lp_place + LAMBDA as f64 * lp_part);

            // ---- backward: placer head → dz ----
            let mut dz = vec![0f32; n * h];
            matmul_at_b_acc(&pf.ph, &dlogits, slots, h, nd, &mut grads[PLACE_W1]);
            colsum_acc(&dlogits, slots, nd, &mut grads[PLACE_B1]);
            let mut dph = matmul_a_bt(&dlogits, self.p(PLACE_W1), slots, nd, h);
            relu_bwd(&mut dph, &pf.ph);
            matmul_at_b_acc(&pf.pooled, &dph, slots, h, h, &mut grads[PLACE_W0]);
            colsum_acc(&dph, slots, h, &mut grads[PLACE_B0]);
            let dpooled = matmul_a_bt(&dph, self.p(PLACE_W0), slots, h, h);
            for (node, &cid) in cids_t.iter().enumerate() {
                let c = cid as usize;
                let cnt = pf.counts[c].max(1.0);
                let src = &dpooled[c * h..(c + 1) * h];
                for (o, s) in dz[node * h..(node + 1) * h].iter_mut().zip(src) {
                    *o += s / cnt;
                }
            }

            // ---- backward: edge scorer → dz ----
            let w1 = self.p(EDGE_W1);
            let mut deh = vec![0f32; e * h];
            for (ei, &dl) in dlogit_e.iter().enumerate() {
                if dl == 0.0 {
                    continue;
                }
                for (k, out) in deh[ei * h..(ei + 1) * h].iter_mut().enumerate() {
                    *out = dl * w1[k];
                }
                for (k, g) in grads[EDGE_W1].iter_mut().enumerate() {
                    *g += ef.eh[ei * h + k] * dl;
                }
                grads[EDGE_B1][0] += dl;
            }
            relu_bwd(&mut deh, &ef.eh);
            matmul_at_b_acc(&ef.pr, &deh, e, h, h, &mut grads[EDGE_W0]);
            colsum_acc(&deh, e, h, &mut grads[EDGE_B0]);
            let dpr = matmul_a_bt(&deh, self.p(EDGE_W0), e, h, h);
            for (ei, &(s, t2)) in self.edges.iter().enumerate() {
                let dpr_row = &dpr[ei * h..(ei + 1) * h];
                for k in 0..h {
                    let zs = enc.z[s * h + k];
                    let zd = enc.z[t2 * h + k];
                    dz[s * h + k] += dpr_row[k] * zd;
                    dz[t2 * h + k] += dpr_row[k] * zs;
                }
            }

            // ---- backward: encoder ----
            let mut dq1 = dz;
            relu_bwd(&mut dq1, &enc.z);
            colsum_acc(&dq1, n, h, &mut grads[GCN_B1]);
            let dg1 = aggregate(&self.coo, &dq1, n, h); // Â symmetric
            matmul_at_b_acc(&enc.z1, &dg1, n, h, h, &mut grads[GCN_W1]);
            let mut dq0 = matmul_a_bt(&dg1, self.p(GCN_W1), n, h, h);
            relu_bwd(&mut dq0, &enc.z1);
            colsum_acc(&dq0, n, h, &mut grads[GCN_B0]);
            let dg0 = aggregate(&self.coo, &dq0, n, h);
            matmul_at_b_acc(&enc.f, &dg0, n, h, h, &mut grads[GCN_W0]);
            let mut df = matmul_a_bt(&dg0, self.p(GCN_W0), n, h, h);
            if let Some(keep) = &enc.keep {
                for (x, k) in df.iter_mut().zip(keep) {
                    *x *= k;
                }
            }
            let mut dp1 = df;
            relu_bwd(&mut dp1, &enc.h1);
            matmul_at_b_acc(&enc.h0, &dp1, n, h, h, &mut grads[TRANS_W1]);
            colsum_acc(&dp1, n, h, &mut grads[TRANS_B1]);
            let mut dh0 = matmul_a_bt(&dp1, self.p(TRANS_W1), n, h, h);
            relu_bwd(&mut dh0, &enc.h0);
            matmul_at_b_acc(&self.x0, &dh0, n, d, h, &mut grads[TRANS_W0]);
            colsum_acc(&dh0, n, h, &mut grads[TRANS_B0]);
        }
        (loss as f32, grads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 6-node diamond-ish DAG with 6 edges.
    fn tiny() -> (usize, Vec<(usize, usize)>) {
        (6, vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5)])
    }

    fn tiny_policy(seed: u64) -> NativePolicy {
        let (n, edges) = tiny();
        let d = 3;
        let mut rng = Rng::new(seed);
        let x0: Vec<f32> = (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut p = NativePolicy::new(x0, n, d, edges, 4, 2, 1e-2, &mut rng).unwrap();
        p.train_dropout = 0.0; // deterministic forwards for the checks
        p
    }

    /// A consistent batch over the tiny graph: 2 steps, padded strides.
    fn tiny_batch<'a>(bufs: &'a TinyBufs) -> NativeBatch<'a> {
        NativeBatch {
            t: 2,
            v_stride: 8,
            e_stride: 7,
            fb: &bufs.fb,
            cids: &bufs.cids,
            actions: &bufs.actions,
            gmask: &bufs.gmask,
            retained: &bufs.retained,
            coeff: &bufs.coeff,
            key: [7, 9],
        }
    }

    struct TinyBufs {
        fb: Vec<f32>,
        cids: Vec<i32>,
        actions: Vec<i32>,
        gmask: Vec<f32>,
        retained: Vec<f32>,
        coeff: Vec<f32>,
    }

    fn tiny_bufs() -> TinyBufs {
        let (h, vs, es, t) = (4usize, 8usize, 7usize, 2usize);
        let mut rng = Rng::new(99);
        let fb: Vec<f32> = (0..t * vs * h).map(|_| rng.next_f32() * 0.1).collect();
        // Step 0: 3 groups {0,1},{2,3},{4,5}; step 1: 2 groups.
        let mut cids = vec![0i32; t * vs];
        cids[..6].copy_from_slice(&[0, 0, 1, 1, 2, 2]);
        cids[vs..vs + 6].copy_from_slice(&[0, 0, 0, 1, 1, 1]);
        let mut gmask = vec![0f32; t * vs];
        gmask[..3].fill(1.0);
        gmask[vs..vs + 2].fill(1.0);
        let mut actions = vec![0i32; t * vs];
        actions[..3].copy_from_slice(&[1, 0, 1]);
        actions[vs..vs + 2].copy_from_slice(&[0, 1]);
        let mut retained = vec![0f32; t * es];
        retained[..6].copy_from_slice(&[1.0, 0.0, 1.0, 0.0, 0.0, 1.0]);
        retained[es..es + 6].copy_from_slice(&[1.0, 1.0, 0.0, 1.0, 0.0, 1.0]);
        TinyBufs { fb, cids, actions, gmask, retained, coeff: vec![0.7, -0.4] }
    }

    #[test]
    fn fwd_shapes_and_score_range() {
        let p = tiny_policy(1);
        let fb = vec![0f32; 6 * 4];
        let (z, s) = p.fwd(&fb);
        assert_eq!(z.len(), 6 * 4);
        assert_eq!(s.len(), 6);
        assert!(s.iter().all(|&x| x > 0.0 && x < 1.0), "{s:?}");
        assert!(z.iter().all(|&x| x.is_finite() && x >= 0.0)); // post-ReLU
    }

    #[test]
    fn placer_masks_invalid_slots() {
        let p = tiny_policy(2);
        let fb = vec![0f32; 6 * 4];
        let (z, _) = p.fwd(&fb);
        // Three referenced group slots, but only the first two valid:
        // the head computes exactly max(cids)+1 rows and masks slot 2.
        let cids = [0, 0, 1, 1, 2, 2];
        let gmask = [1.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let logits = p.placer(&z, &cids, &gmask);
        assert_eq!(logits.len(), 3 * 2);
        assert!(logits[..4].iter().all(|&l| l > -1e8));
        assert!(logits[4..].iter().all(|&l| l <= -1e8));
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut p = tiny_policy(3);
        let bufs = tiny_bufs();
        let batch = tiny_batch(&bufs);
        let (_, grads) = p.loss_and_grads(&batch, false);
        // Probe a few entries of every parameter tensor. Tolerances are
        // loose enough to absorb f32 noise and the occasional ReLU kink
        // inside the central-difference interval, but tight enough that a
        // wrong transpose / missing term / sign error fails loudly.
        let mut rng = Rng::new(17);
        let eps = 5e-3f32;
        for pi in 0..p.params.n() {
            let numel = p.params.params[pi].numel();
            for _ in 0..3.min(numel) {
                let idx = rng.below(numel);
                let orig = p.params.params[pi].as_f32()[idx];
                p.params.params[pi].as_f32_mut()[idx] = orig + eps;
                let lp = p.loss(&batch, false);
                p.params.params[pi].as_f32_mut()[idx] = orig - eps;
                let lm = p.loss(&batch, false);
                p.params.params[pi].as_f32_mut()[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[pi][idx];
                let tol = (0.1 * (1.0 + fd.abs().max(an.abs()))).max(1e-2);
                assert!(
                    (fd - an).abs() < tol,
                    "param {pi} idx {idx}: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn train_descends_on_fixed_batch() {
        let mut p = tiny_policy(4);
        let bufs = tiny_bufs();
        let l0 = {
            let batch = tiny_batch(&bufs);
            p.loss(&batch, false)
        };
        for _ in 0..30 {
            let batch = tiny_batch(&bufs);
            p.train(&batch).unwrap();
        }
        let l1 = {
            let batch = tiny_batch(&bufs);
            p.loss(&batch, false)
        };
        assert!(l1.is_finite() && l0.is_finite());
        assert!(l1 < l0, "loss should descend: {l0} -> {l1}");
        assert_eq!(p.params.step, 30.0);
    }

    #[test]
    fn zero_coefficients_leave_params_untouched() {
        let mut p = tiny_policy(5);
        let before: Vec<f32> = p.params.params[TRANS_W0].as_f32().to_vec();
        let mut bufs = tiny_bufs();
        bufs.coeff = vec![0.0, 0.0];
        let batch = tiny_batch(&bufs);
        let loss = p.train(&batch).unwrap();
        assert_eq!(loss, 0.0);
        // Adam still counts the step, but zero grads move nothing.
        assert_eq!(p.params.params[TRANS_W0].as_f32(), &before[..]);
    }

    #[test]
    fn deterministic_per_key() {
        let mut a = tiny_policy(6);
        let mut b = tiny_policy(6);
        a.train_dropout = 0.2;
        b.train_dropout = 0.2;
        let bufs = tiny_bufs();
        let la = a.train(&tiny_batch(&bufs)).unwrap();
        let lb = b.train(&tiny_batch(&bufs)).unwrap();
        assert_eq!(la, lb);
        assert_eq!(
            a.params.params[PLACE_W1].as_f32(),
            b.params.params[PLACE_W1].as_f32()
        );
    }
}

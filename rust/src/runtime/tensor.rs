//! Host-side tensors: the coordinator's own buffers, converted to/from
//! PJRT literals at the executable boundary.

use anyhow::{bail, Result};

use super::spec::{DType, InputSpec};
use crate::util::Rng;

/// A host tensor (row-major).
#[derive(Debug, Clone)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U32 { dims: Vec<usize>, data: Vec<u32> },
}

impl Tensor {
    pub fn zeros(dtype: DType, dims: &[usize]) -> Tensor {
        let n = dims.iter().product::<usize>().max(1);
        match dtype {
            DType::F32 => Tensor::F32 { dims: dims.to_vec(), data: vec![0.0; n] },
            DType::I32 => Tensor::I32 { dims: dims.to_vec(), data: vec![0; n] },
            DType::U32 => Tensor::U32 { dims: dims.to_vec(), data: vec![0; n] },
        }
    }

    pub fn f32(dims: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        Tensor::F32 { dims: dims.to_vec(), data }
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        Tensor::I32 { dims: dims.to_vec(), data }
    }

    pub fn u32(dims: &[usize], data: Vec<u32>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>().max(1), data.len());
        Tensor::U32 { dims: dims.to_vec(), data }
    }

    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32 { dims: vec![], data: vec![x] }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } | Tensor::U32 { dims, .. } => dims,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
            Tensor::U32 { .. } => DType::U32,
        }
    }

    pub fn numel(&self) -> usize {
        self.dims().iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut Vec<f32> {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Tensor::I32 { data, .. } => data,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Verify this tensor matches an input slot of a spec.
    pub fn check_against(&self, spec: &InputSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("input '{}': dtype {:?} != spec {:?}", spec.name, self.dtype(), spec.dtype);
        }
        if self.dims() != spec.dims.as_slice() {
            bail!("input '{}': dims {:?} != spec {:?}", spec.name, self.dims(), spec.dims);
        }
        Ok(())
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
            Tensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a literal back into a host tensor of the same dtype/shape as
    /// `self` (used to round-trip params through the train step).
    pub fn from_literal(lit: &xla::Literal, dtype: DType, dims: &[usize]) -> Result<Tensor> {
        Ok(match dtype {
            DType::F32 => Tensor::f32(dims, lit.to_vec::<f32>()?),
            DType::I32 => Tensor::i32(dims, lit.to_vec::<i32>()?),
            DType::U32 => Tensor::u32(dims, lit.to_vec::<u32>()?),
        })
    }
}

/// Glorot-uniform matrix / zero vector initialization matching
/// `model.init_params` on the python side (distribution match; the exact
/// draws differ, which is fine — training starts from scratch in rust).
pub fn glorot_init(dims: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = dims.iter().product::<usize>().max(1);
    if dims.len() <= 1 {
        return Tensor::f32(dims, vec![0.0; n]);
    }
    let fan_in = dims[0] as f64;
    let fan_out = dims[dims.len() - 1] as f64;
    let lim = (6.0 / (fan_in + fan_out)).sqrt();
    let data = (0..n).map(|_| rng.range_f64(-lim, lim) as f32).collect();
    Tensor::f32(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_accessors() {
        let t = Tensor::zeros(DType::F32, &[2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.as_f32(), &[0.0; 6]);
        let s = Tensor::scalar_f32(5.0);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.dims(), &[] as &[usize]);
    }

    #[test]
    fn check_against_spec() {
        let spec = InputSpec { name: "x".into(), dtype: DType::F32, dims: vec![2, 2] };
        assert!(Tensor::zeros(DType::F32, &[2, 2]).check_against(&spec).is_ok());
        assert!(Tensor::zeros(DType::I32, &[2, 2]).check_against(&spec).is_err());
        assert!(Tensor::zeros(DType::F32, &[4]).check_against(&spec).is_err());
    }

    #[test]
    fn glorot_bounds_and_zero_bias() {
        let mut rng = Rng::new(1);
        let w = glorot_init(&[64, 32], &mut rng);
        let lim = (6.0f64 / 96.0).sqrt() as f32;
        assert!(w.as_f32().iter().all(|&x| x.abs() <= lim));
        assert!(w.as_f32().iter().any(|&x| x != 0.0));
        let b = glorot_init(&[32], &mut rng);
        assert!(b.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, DType::F32, &[2, 2]).unwrap();
        assert_eq!(back.as_f32(), t.as_f32());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::i32(&[3], vec![7, -1, 2]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, DType::I32, &[3]).unwrap();
        assert_eq!(back.as_i32(), t.as_i32());
    }
}

//! hsdag — the L3 coordinator binary.
//!
//! Reproduces "A Structure-Aware Framework for Learning Device Placements
//! on Computation Graphs" (NeurIPS 2024). See `hsdag --help` / README.md.

use anyhow::Result;
use hsdag::baselines;
use hsdag::cli::{self, Cli};
use hsdag::graph::dot;
use hsdag::harness::{figure2, generalize, table1, table2, table3, table4, table5};
use hsdag::models::{Benchmark, Workload};
use hsdag::rl::{BackendFactory, Env, HsdagAgent};
use hsdag::sim::execute;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") || args.is_empty() {
        println!("{}", cli::usage());
        return;
    }
    match cli::parse(&args).and_then(run) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(c: Cli) -> Result<()> {
    let cfg = c.config()?;
    match c.command.as_str() {
        "table1" => println!("{}", table1::run().render()),
        "table2" => {
            let episodes = c.usize_flag("episodes", 30)?;
            let (t, results) = table2::run(&cfg, episodes)?;
            println!("{}", t.render());
            println!("{}", table2::render_feasibility(&results).render());
            println!("{}", table5::render(&results).render());
        }
        "table3" => {
            let episodes = c.usize_flag("episodes", 30)?;
            println!("{}", table3::run(&cfg, episodes)?.render());
        }
        "table4" => {
            let (t, acc) = table4::run(&cfg, None)?;
            println!("{}", t.render());
            println!("{}", acc.render());
        }
        "table5" => {
            let episodes = c.usize_flag("episodes", 30)?;
            println!("{}", table5::run(&cfg, episodes)?.render());
        }
        "figure2" => {
            let out = c.str_flag("out-dir", "results");
            let episodes = c.usize_flag("episodes", 5)?;
            println!("{}", figure2::run(&cfg, &out, episodes)?.render());
        }
        "train" => {
            let workload = c.workload()?;
            let episodes = c.usize_flag("episodes", 30)?;
            let mut factory = BackendFactory::new(&cfg)?;
            let env = Env::for_workload(workload, &cfg)?;
            let mut agent = HsdagAgent::with_backend(&env, factory.create(&env, &cfg)?, &cfg)?;
            println!(
                "searching {} ({} working nodes, {} edges) on testbed {} ({} placement targets) \
                 for {episodes} episodes on backend {}",
                env.workload.display,
                env.n_nodes,
                env.n_edges,
                env.testbed.id,
                env.n_actions(),
                agent.backend_desc(),
            );
            let res = agent.search(&env, episodes)?;
            for p in &res.curve {
                println!(
                    "  episode {:>3}  best {:.5}s  mean-reward {:.3}  loss {:+.4}",
                    p.episode, p.best_latency, p.mean_reward, p.loss
                );
            }
            println!(
                "best latency {:.5}s  (speedup {:.1}% vs reference {:.5}s)  wall {:.1}s",
                res.best_latency,
                res.speedup_vs(env.ref_latency),
                env.ref_latency,
                res.wall_secs
            );
        }
        "place" => {
            let workload = c.workload()?;
            let method = c.str_flag("method", "gpu");
            let g = &workload.graph;
            let tb = cfg.resolve_testbed()?;
            match baselines::baseline_latency(&method, g, &tb) {
                Some(lat) => {
                    let cpu = baselines::baseline_latency("cpu", g, &tb).unwrap();
                    println!(
                        "{} under {method} on testbed {}: {lat:.5}s ({:+.1}% vs reference)",
                        workload.display,
                        tb.id,
                        100.0 * (1.0 - lat / cpu)
                    );
                    // Feasibility / utilization / memory of the method's
                    // representative placement.
                    if method == "random" {
                        println!(
                            "(latency above is the mean over several fixed-seed draws; the \
                             report below describes one representative draw)"
                        );
                    }
                    let p = baselines::baseline_placement(&method, g, &tb).unwrap();
                    let rep = execute(g, &p, &tb);
                    println!(
                        "feasible: {}",
                        if rep.feasible() {
                            "yes".to_string()
                        } else {
                            format!("NO (OOM on devices {:?})", rep.oom_devices)
                        }
                    );
                    let util = rep.utilization(&tb);
                    for (d, dev) in tb.devices.iter().enumerate() {
                        let cap = if dev.mem_capacity.is_finite() {
                            format!("{:.0} MB cap", dev.mem_capacity / 1e6)
                        } else {
                            "unbounded".to_string()
                        };
                        println!(
                            "  {:<22} util {:>5.1}%  mem high-water {:>8.1} MB ({cap})",
                            dev.name,
                            100.0 * util[d],
                            rep.mem_peak[d] / 1e6
                        );
                    }
                    // Placement-aware DOT dump for visual inspection.
                    if let Some(path) = c.flags.get("dump-dot") {
                        let names: Vec<String> =
                            tb.devices.iter().map(|dev| dev.name.clone()).collect();
                        std::fs::write(path, dot::to_dot_placed(g, &p.0, &names))?;
                        println!("placement DOT written to {path}");
                    }
                }
                None => anyhow::bail!(
                    "unknown method '{method}' ({})",
                    baselines::BASELINE_NAMES.join("|")
                ),
            }
        }
        "generalize" => {
            let train = c.str_list_flag("train", "seq:48,layered:6x4,random:48:7");
            let eval = c.str_list_flag("eval", "layered:8x8,transformer:2:2");
            let episodes = c.usize_flag("episodes", 10)?;
            let rollouts = c.usize_flag("rollouts", 8)?;
            let (t, _) = generalize::run(&cfg, &train, &eval, episodes, rollouts)?;
            println!("{}", t.render());
        }
        "export" => {
            let workload = c.workload()?;
            // Default filename: sanitized spec, without doubling the
            // extension for `file:` specs.
            let mut stem = workload.spec.replace([':', '/', '\\'], "_");
            for ext in [".json", ".dot", ".gv"] {
                if let Some(trimmed) = stem.strip_suffix(ext) {
                    stem = trimmed.to_string();
                    break;
                }
            }
            let default_name = format!("{stem}.json");
            let out = c.str_flag("out", &default_name);
            std::fs::write(&out, hsdag::graph::json::to_json(&workload.graph))?;
            println!(
                "wrote {} ({} nodes, {} edges) to {out}",
                workload.display,
                workload.graph.n(),
                workload.graph.m()
            );
        }
        "graph-stats" => {
            // A named workload (--workload, or its --bench alias), or the
            // three paper benchmarks by default.
            let spec = c.flags.get("workload").or_else(|| c.flags.get("bench"));
            let workloads: Vec<Workload> = match spec {
                Some(spec) => vec![Workload::resolve(spec)?],
                None => Benchmark::ALL.iter().map(|&b| Workload::from_bench(b)).collect(),
            };
            for w in workloads {
                let g = &w.graph;
                g.validate().map_err(|e| anyhow::anyhow!("{}: {e}", w.spec))?;
                println!(
                    "{:<14} |V|={:<5} |E|={:<5} d̄={:.2}  critical-path={}  GFLOP={:.2}",
                    w.display,
                    g.n(),
                    g.m(),
                    g.avg_degree(),
                    g.critical_path_len(),
                    g.total_flops() / 1e9
                );
            }
        }
        "config" => print!("{}", cfg.table6()),
        other => anyhow::bail!("unknown command '{other}'\n\n{}", cli::usage()),
    }
    Ok(())
}

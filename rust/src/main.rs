//! hsdag — the L3 coordinator binary.
//!
//! Reproduces "A Structure-Aware Framework for Learning Device Placements
//! on Computation Graphs" (NeurIPS 2024). See `hsdag --help` / README.md.

use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};
use hsdag::baselines;
use hsdag::cli::{self, Cli};
use hsdag::config::Config;
use hsdag::features::FeatureConfig;
use hsdag::graph::{dot, CompGraph};
use hsdag::harness::{figure2, generalize, table1, table2, table3, table4, table5};
use hsdag::models::{Benchmark, Workload};
use hsdag::obs::{log as obslog, metrics, trace::TraceSink};
use hsdag::rl::{BackendFactory, CurvePoint, Env, HsdagAgent, NativeBackend};
use hsdag::serve::{
    client, discover_testbed, fingerprint, protocol, shard_for, sighup_flag, Checkpoint,
    CheckpointMeta, PlacementService, Router, ServeOptions, Server, DEFAULT_QUEUE_DEPTH,
};
use hsdag::sim::{execute, ExecReport, Placement, Testbed};
use hsdag::util::json::Json;
use hsdag::{log_error, log_info, log_warn};

fn main() {
    // Adopt HSDAG_LOG before anything can log (a parse error below goes
    // through the leveled logger); the --log-level flag wins inside run.
    obslog::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") || args.is_empty() {
        println!("{}", cli::usage());
        return;
    }
    match cli::parse(&args).and_then(run) {
        Ok(()) => {}
        Err(e) => {
            log_error!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(c: Cli) -> Result<()> {
    let cfg = c.config()?;
    // One --workers flag steers every data-parallel path: install it as
    // the process-global pool knob so the kernel pool, the batched cost
    // model and the router scatter all resolve "auto" through it.
    hsdag::util::pool::set_global_workers(cfg.workers);
    // Same pattern for the telemetry knobs: the env var was adopted in
    // main(); an explicit --log-level overrides it, and --profile turns
    // the opt-in kernel/pool profiling counters on process-wide.
    if c.flags.contains_key("log-level") {
        if let Some(l) = obslog::Level::parse(&cfg.log_level) {
            obslog::set_level(l);
        }
    }
    metrics::set_profiling(cfg.profile);
    match c.command.as_str() {
        "table1" => println!("{}", table1::run().render()),
        "table2" => {
            let episodes = c.usize_flag("episodes", 30)?;
            let (t, results) = table2::run(&cfg, episodes)?;
            println!("{}", t.render());
            println!("{}", table2::render_feasibility(&results).render());
            println!("{}", table5::render(&results).render());
        }
        "table3" => {
            let episodes = c.usize_flag("episodes", 30)?;
            println!("{}", table3::run(&cfg, episodes)?.render());
        }
        "table4" => {
            let (t, acc) = table4::run(&cfg, None)?;
            println!("{}", t.render());
            println!("{}", acc.render());
        }
        "table5" => {
            let episodes = c.usize_flag("episodes", 30)?;
            println!("{}", table5::run(&cfg, episodes)?.render());
        }
        "figure2" => {
            let out = c.str_flag("out-dir", "results");
            let episodes = c.usize_flag("episodes", 5)?;
            println!("{}", figure2::run(&cfg, &out, episodes)?.render());
        }
        "train" => {
            let workload = c.workload()?;
            let episodes = c.usize_flag("episodes", 30)?;
            let save = c.flags.get("save").cloned();
            let mut factory = BackendFactory::new(&cfg)?;
            let env = Env::for_workload(workload, &cfg)?;
            let mut agent = HsdagAgent::with_backend(&env, factory.create(&env, &cfg)?, &cfg)?;
            // Warm start: resume / fine-tune from a saved checkpoint
            // (full Adam state travels with it). The run's own
            // --testbed / hidden must match the checkpoint's layout.
            if let Some(path) = c.flags.get("load") {
                let ckpt = Checkpoint::load(Path::new(path))?;
                ckpt.check_compatible(cfg.hidden, env.n_actions(), &cfg.testbed)?;
                agent.import_params(&ckpt.store)?;
                println!(
                    "resumed from {path} (trained on {}, Adam step {})",
                    ckpt.meta.workload, ckpt.store.step
                );
            }
            println!(
                "searching {} ({} working nodes, {} edges) on testbed {} ({} placement targets) \
                 for {episodes} episodes on backend {}",
                env.workload.display,
                env.n_nodes,
                env.n_edges,
                env.testbed.id,
                env.n_actions(),
                agent.backend_desc(),
            );
            // Training telemetry: every learning-curve point also goes to
            // the --run-log JSONL file (hsdag-run-v1) when asked. Strictly
            // observational — the console lines stay byte-identical and
            // the search trajectory never sees the writer.
            let mut run_log = match c.flags.get("run-log") {
                Some(path) => {
                    let f = std::fs::OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(path)
                        .with_context(|| format!("open run log {path}"))?;
                    log_info!("run log: {path} (hsdag-run-v1)");
                    Some(std::io::BufWriter::new(f))
                }
                None => None,
            };
            // One search call per episode so --save can checkpoint every
            // best-so-far improvement. The trajectory is identical to a
            // single search(episodes) call: the tracker is per-call
            // bookkeeping, and the interleaved greedy evaluations draw
            // no RNG.
            let mut best_latency = f64::INFINITY;
            let mut wall = 0.0;
            for ep in 0..episodes.max(1) {
                let res = agent.search(&env, episodes.min(1))?;
                wall += res.wall_secs;
                for p in &res.curve {
                    println!(
                        "  episode {:>3}  best {:.5}s  mean-reward {:.3}  loss {:+.4}",
                        ep,
                        p.best_latency.min(best_latency),
                        p.mean_reward,
                        p.loss
                    );
                    if let Some(w) = run_log.as_mut() {
                        writeln!(w, "{}", run_record(ep, p.best_latency.min(best_latency), p))
                            .context("write run log")?;
                    }
                }
                if res.best_latency < best_latency {
                    best_latency = res.best_latency;
                    if let Some(path) = &save {
                        save_checkpoint(path, &agent, &env, Some(best_latency))?;
                    }
                }
            }
            if let Some(w) = run_log.as_mut() {
                w.flush().context("flush run log")?;
            }
            println!(
                "best latency {:.5}s  (speedup {:.1}% vs reference {:.5}s)  wall {:.1}s",
                best_latency,
                100.0 * (1.0 - best_latency / env.ref_latency),
                env.ref_latency,
                wall
            );
            if let Some(path) = &save {
                save_checkpoint(path, &agent, &env, Some(best_latency))?;
                println!("checkpoint written to {path} (hsdag-params-v1)");
            }
        }
        "place" => {
            let workload = c.workload()?;
            if let Some(path) = c.flags.get("load") {
                // A loaded checkpoint IS the method: the learned policy's
                // greedy placement.
                anyhow::ensure!(
                    !c.flags.contains_key("method"),
                    "--load places with the learned policy; drop --method"
                );
                let (ckpt, run_cfg) = load_run_config(&c, &cfg)?;
                let env = Env::for_workload(workload, &run_cfg)?;
                let backend = NativeBackend::from_snapshot(&env, &run_cfg, &ckpt.store)?;
                let mut agent = HsdagAgent::with_backend(&env, Box::new(backend), &run_cfg)?;
                agent.reset_episode();
                let o = agent.step(&env, false)?;
                let mut p = env.expand(&o.actions)?;
                let mut rep = env.cost.evaluate(&env.graph, &p, &env.testbed);
                // Multi-level stacks (graphs coarsened past --coarsen-budget)
                // get a V-cycle refinement sweep: the policy's coarse
                // placement is locally improved level by level, each trial
                // re-simulated incrementally. Never worse than the plain
                // expansion.
                if env.levels.n_levels() > 1 {
                    let coarse: Vec<usize> =
                        o.actions.iter().map(|&a| env.testbed.action_device(a)).collect();
                    let refined = env.levels.refine_placement(
                        &env.graph,
                        &env.testbed,
                        &coarse,
                        &env.testbed.placeable,
                        c.usize_flag("refine-cap", 512)?,
                    )?;
                    let refined = Placement(refined);
                    let r2 = env.cost.evaluate(&env.graph, &refined, &env.testbed);
                    println!(
                        "multi-level refinement ({} levels): {:.5}s -> {:.5}s",
                        env.levels.n_levels(),
                        rep.makespan,
                        r2.makespan
                    );
                    p = refined;
                    rep = r2;
                }
                println!(
                    "{} under policy({path}) on testbed {}: {:.5}s ({:+.1}% vs reference)",
                    env.workload.display,
                    env.testbed.id,
                    rep.makespan,
                    100.0 * (1.0 - rep.makespan / env.ref_latency)
                );
                print_exec_report(&env.graph, &env.testbed, &p, &rep, c.flags.get("dump-dot"))?;
            } else {
                let method = c.str_flag("method", "gpu");
                let g = &workload.graph;
                let tb = cfg.resolve_testbed()?;
                match baselines::baseline_latency(&method, g, &tb) {
                    Some(lat) => {
                        let cpu = baselines::baseline_latency("cpu", g, &tb).unwrap();
                        println!(
                            "{} under {method} on testbed {}: {lat:.5}s ({:+.1}% vs reference)",
                            workload.display,
                            tb.id,
                            100.0 * (1.0 - lat / cpu)
                        );
                        // Feasibility / utilization / memory of the
                        // method's representative placement.
                        if method == "random" {
                            println!(
                                "(latency above is the mean over several fixed-seed draws; the \
                                 report below describes one representative draw)"
                            );
                        }
                        let p = baselines::baseline_placement(&method, g, &tb).unwrap();
                        let rep = execute(g, &p, &tb);
                        print_exec_report(g, &tb, &p, &rep, c.flags.get("dump-dot"))?;
                    }
                    None => anyhow::bail!(
                        "unknown method '{method}' ({})",
                        baselines::BASELINE_NAMES.join("|")
                    ),
                }
            }
        }
        "generalize" => {
            let eval = c.str_list_flag("eval", "layered:8x8,transformer:2:2");
            let rollouts = c.usize_flag("rollouts", 8)?;
            if c.flags.contains_key("eval-only") {
                // Zero-shot evaluate a loaded checkpoint, no training.
                let (ckpt, run_cfg) = load_run_config(&c, &cfg)?;
                let (t, _) = generalize::eval_only(&run_cfg, &eval, &ckpt.store, rollouts)?;
                println!("{}", t.render());
                println!(
                    "(policy loaded from {}; trained on {})",
                    c.str_flag("load", "?"),
                    ckpt.meta.workload
                );
            } else {
                let train = c.str_list_flag("train", "seq:48,layered:6x4,random:48:7");
                let episodes = c.usize_flag("episodes", 10)?;
                let save = c.flags.get("save").map(String::as_str);
                let (t, _) = generalize::run(&cfg, &train, &eval, episodes, rollouts, save)?;
                println!("{}", t.render());
                if let Some(path) = save {
                    println!("checkpoint written to {path} (hsdag-params-v1)");
                }
            }
        }
        "export" => {
            let workload = c.workload()?;
            // Default filename: sanitized spec, without doubling the
            // extension for `file:` specs.
            let mut stem = workload.spec.replace([':', '/', '\\'], "_");
            for ext in [".json", ".dot", ".gv"] {
                if let Some(trimmed) = stem.strip_suffix(ext) {
                    stem = trimmed.to_string();
                    break;
                }
            }
            let default_name = format!("{stem}.json");
            let out = c.str_flag("out", &default_name);
            std::fs::write(&out, hsdag::graph::json::to_json(&workload.graph))?;
            println!(
                "wrote {} ({} nodes, {} edges) to {out}",
                workload.display,
                workload.graph.n(),
                workload.graph.m()
            );
        }
        "graph-stats" => {
            // A named workload (--workload, or its --bench alias), or the
            // three paper benchmarks by default.
            let spec = c.flags.get("workload").or_else(|| c.flags.get("bench"));
            let workloads: Vec<Workload> = match spec {
                Some(spec) => vec![Workload::resolve(spec)?],
                None => Benchmark::ALL.iter().map(|&b| Workload::from_bench(b)).collect(),
            };
            for w in workloads {
                let g = &w.graph;
                g.validate().map_err(|e| anyhow::anyhow!("{}: {e}", w.spec))?;
                println!(
                    "{:<14} |V|={:<5} |E|={:<5} d̄={:.2}  critical-path={}  GFLOP={:.2}",
                    w.display,
                    g.n(),
                    g.m(),
                    g.avg_degree(),
                    g.critical_path_len(),
                    g.total_flops() / 1e9
                );
                // Total-degree histogram in power-of-two buckets — the
                // quick eyeball check that a generated graph has the
                // intended shape before a long run.
                let mut hist: Vec<usize> = Vec::new();
                for v in 0..g.n() {
                    let deg = g.in_degree(v) + g.out_degree(v);
                    let bucket = (usize::BITS - deg.leading_zeros()) as usize; // 0 -> 0, 1 -> 1, 2-3 -> 2, ...
                    if bucket >= hist.len() {
                        hist.resize(bucket + 1, 0);
                    }
                    hist[bucket] += 1;
                }
                let mut line = String::from("  degree histogram:");
                for (b, &count) in hist.iter().enumerate() {
                    if count == 0 {
                        continue;
                    }
                    let (lo, hi) = if b == 0 { (0, 0) } else { (1usize << (b - 1), (1 << b) - 1) };
                    if lo == hi {
                        line.push_str(&format!("  [{lo}]={count}"));
                    } else {
                        line.push_str(&format!("  [{lo}-{hi}]={count}"));
                    }
                }
                println!("{line}");
            }
        }
        "serve" => {
            let (ckpt, run_cfg) = load_run_config(&c, &cfg)?;
            let addr = c.str_flag("addr", "127.0.0.1:7477");
            let workers = serve_workers(&c, &cfg)?;
            let budget_ms = match c.flags.get("budget-ms") {
                None => None,
                Some(v) => {
                    let b: f64 = v.parse().context("--budget-ms must be a number")?;
                    anyhow::ensure!(b.is_finite() && b >= 0.0, "--budget-ms must be >= 0");
                    Some(b)
                }
            };
            let opts = ServeOptions {
                cache_capacity: c.usize_flag("cache-capacity", 256)?,
                budget_ms,
                rollouts: c.usize_flag("rollouts", 4)?,
            };
            let trained_on = ckpt.meta.workload.clone();
            let cache_capacity = opts.cache_capacity;
            let mut service = PlacementService::new(ckpt, &run_cfg, opts)?;
            if let Some(path) = c.flags.get("trace-log") {
                service.set_trace_sink(Arc::new(TraceSink::open(path)?));
                log_info!("trace log: {path} (hsdag-trace-v1)");
            }
            let service = Arc::new(service);
            // A bare `ctrl: reload` (or SIGHUP) re-reads the --load path:
            // the runbook is "atomically replace the file, poke the
            // daemon" — no client-side path plumbing needed.
            service.set_default_checkpoint(Path::new(&c.str_flag("load", "")));
            if let Some(flag) = sighup_flag() {
                let svc = Arc::clone(&service);
                thread::spawn(move || loop {
                    thread::sleep(Duration::from_millis(200));
                    if flag.swap(false, Ordering::Relaxed) {
                        match svc.reload(None) {
                            Ok((generation, cache_kept, on)) => println!(
                                "SIGHUP reload: generation {generation}, cache {}, trained on {on}",
                                if cache_kept { "kept" } else { "flushed" }
                            ),
                            Err(e) => log_warn!("SIGHUP reload failed (old policy kept): {e:#}"),
                        }
                    }
                });
            }
            let mut server = Server::bind(Arc::clone(&service), &addr)?;
            server.set_queue_depth(c.usize_flag("queue-depth", DEFAULT_QUEUE_DEPTH)?);
            // The banner is the contract scripts parse for the (possibly
            // ephemeral) port — keep "listening on <addr>" stable.
            println!(
                "hsdag-serve listening on {} (testbed {}, hidden {}, trained on {}, \
                 {workers} workers, cache {cache_capacity})",
                server.local_addr(),
                run_cfg.testbed,
                run_cfg.hidden,
                trained_on,
            );
            server.run(workers)?;
            let s = service.stats_view();
            println!(
                "shutdown after {:.1}s: {} requests ({} placements, {} cache hits, \
                 {} fallbacks, {} errors), hit rate {:.0}%, p50 {:.2} ms, p99 {:.2} ms, \
                 generation {}, {} reloads, {} busy rejects",
                s.uptime_s,
                s.requests,
                s.placements,
                s.cache_hits,
                s.fallbacks,
                s.errors,
                100.0 * s.cache_hit_rate,
                s.p50_ms,
                s.p99_ms,
                s.checkpoint_generation,
                s.reloads,
                s.busy_rejects
            );
        }
        "route" => {
            let shards = c.str_list_flag("shards", "");
            anyhow::ensure!(
                !shards.is_empty(),
                "route needs --shards addr,addr,... (the shard daemons to front)"
            );
            let addr = c.str_flag("addr", "127.0.0.1:7480");
            let workers = serve_workers(&c, &cfg)?;
            let timeout = Duration::from_secs_f64(c.f64_flag("timeout-s", 10.0)?);
            let mut router = Router::new(shards.clone(), timeout)?;
            if let Some(path) = c.flags.get("trace-log") {
                router.set_trace_sink(Arc::new(TraceSink::open(path)?));
                log_info!("trace log: {path} (hsdag-trace-v1)");
            }
            let router = Arc::new(router);
            let mut server = Server::bind(Arc::clone(&router), &addr)?;
            server.set_queue_depth(c.usize_flag("queue-depth", DEFAULT_QUEUE_DEPTH)?);
            // Same "listening on <addr>" banner contract as serve.
            println!(
                "hsdag-route listening on {} ({} shards, testbed {}, {workers} workers)",
                server.local_addr(),
                shards.len(),
                router.testbed(),
            );
            server.run(workers)?;
            println!("router shutdown ({} shards left running)", shards.len());
        }
        "request" => {
            let timeout = Duration::from_secs_f64(c.f64_flag("timeout-s", 10.0)?);
            let retries = c.usize_flag("retries", 0)?;
            let shards = c.str_list_flag("shards", "");
            // Resolved graph of a place request, kept for client-side
            // routing (fingerprints are computed over the graph itself).
            let mut routed_graph: Option<CompGraph> = None;
            let line = if c.flags.contains_key("stats") {
                protocol::render_stats_request()
            } else if c.flags.contains_key("metrics") {
                protocol::render_metrics_request()
            } else if c.flags.contains_key("shutdown") {
                protocol::render_shutdown_request()
            } else if c.flags.contains_key("reload") {
                protocol::render_reload_request(c.flags.get("checkpoint").map(String::as_str))
            } else if c.flags.contains_key("clear-cache") {
                protocol::render_clear_cache_request()
            } else {
                // --graph reuses the `file:` workload source (one
                // format-sniffing loader for .json / .dot / .gv).
                let graph: Option<CompGraph> = match c.flags.get("graph") {
                    Some(path) => Some(Workload::resolve(&format!("file:{path}"))?.graph),
                    None => None,
                };
                let spec = c.flags.get("workload").or_else(|| c.flags.get("bench"));
                anyhow::ensure!(
                    graph.is_some() != spec.is_some(),
                    "request needs exactly one of --workload <spec> or --graph <file> \
                     (or --stats / --metrics / --shutdown / --reload / --clear-cache)"
                );
                if !shards.is_empty() {
                    routed_graph = Some(match (&graph, spec) {
                        (Some(g), _) => g.clone(),
                        (None, Some(s)) => Workload::resolve(s)?.graph,
                        (None, None) => unreachable!("ensured above"),
                    });
                }
                let id = c.flags.get("id").map(|s| Json::Str(s.clone()));
                let budget_ms = match c.flags.get("budget-ms") {
                    None => None,
                    Some(v) => Some(v.parse::<f64>().context("--budget-ms must be a number")?),
                };
                let rollouts = match c.flags.get("rollouts") {
                    None => None,
                    Some(v) => Some(v.parse::<usize>().context("--rollouts must be an integer")?),
                };
                let line = protocol::render_place_request_for(
                    spec.map(String::as_str),
                    graph.as_ref(),
                    id.as_ref(),
                    budget_ms,
                    rollouts,
                    c.flags.contains_key("no-cache"),
                    c.flags.contains_key("fast-math"),
                    c.flags.get("tenant").map(String::as_str),
                );
                // Client-minted trace id: propagated on the wire, echoed
                // in the response, and keyed into any server-side trace
                // log the request crosses.
                match c.flags.get("trace-id") {
                    Some(tid) => protocol::with_trace_id(&line, tid)?,
                    None => line,
                }
            };
            // Router-less deployments: --shards picks the owning shard
            // client-side with the same rendezvous hash the router uses,
            // so either topology partitions the fleet's caches
            // identically.
            let addr = if shards.is_empty() {
                c.str_flag("addr", "127.0.0.1:7477")
            } else {
                let graph = routed_graph.as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "--shards routes place requests by fingerprint; fleet-wide \
                         --stats/--shutdown/--reload/--clear-cache go through --addr \
                         (a shard, or a router that fans out)"
                    )
                })?;
                let testbed = discover_testbed(&shards, timeout)?;
                let fp = fingerprint(graph, &testbed);
                let addr = shards[shard_for(fp, &shards)].clone();
                // Routing note on stderr: stdout stays exactly one
                // response line for scripts.
                log_info!("routing {fp:016x} to shard {addr} (testbed {testbed})");
                addr
            };
            let response = client::roundtrip_retry(&addr, &line, timeout, retries)?;
            println!("{response}");
            // Exit non-zero (with the server's message) on an error
            // response, so scripts can just check the status.
            protocol::parse_response(&response)?;
        }
        "trace" => match c.args.first().map(String::as_str) {
            Some("summarize") => {
                let path = c.args.get(1).ok_or_else(|| {
                    anyhow::anyhow!("usage: hsdag trace summarize <log.jsonl>")
                })?;
                print!("{}", hsdag::obs::trace::summarize_file(Path::new(path))?);
            }
            _ => anyhow::bail!("usage: hsdag trace summarize <log.jsonl>"),
        },
        "config" => print!("{}", cfg.table6()),
        other => anyhow::bail!("unknown command '{other}'\n\n{}", cli::usage()),
    }
    Ok(())
}

/// Connection-handler thread count for serve / route: explicit
/// `--serve-workers`, else the unified `--workers` knob (when nonzero),
/// else 4 — so one flag sizes both the compute pool and the accept loop
/// unless the operator splits them deliberately.
fn serve_workers(c: &Cli, cfg: &Config) -> Result<usize> {
    let default = if cfg.workers > 0 { cfg.workers } else { 4 };
    Ok(c.usize_flag("serve-workers", default)?.max(1))
}

/// One `hsdag-run-v1` training-telemetry record (compact JSON, one per
/// line in the --run-log file). Non-finite values (no update yet, no
/// feasible placement yet) become JSON null.
fn run_record(episode: usize, best_latency: f64, p: &CurvePoint) -> String {
    fn num(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }
    Json::Obj(vec![
        ("format".to_string(), Json::Str("hsdag-run-v1".to_string())),
        ("episode".to_string(), Json::Num(episode as f64)),
        ("best_latency".to_string(), num(best_latency)),
        ("mean_reward".to_string(), num(p.mean_reward)),
        ("loss".to_string(), num(p.loss)),
        ("entropy".to_string(), num(p.entropy)),
        ("param_norm".to_string(), num(p.param_norm)),
    ])
    .to_string_compact()
}

/// Write the agent's current learning state as an hsdag-params-v1
/// checkpoint for `env`'s deployment (testbed id, action width).
fn save_checkpoint(
    path: &str,
    agent: &HsdagAgent,
    env: &Env,
    best_latency: Option<f64>,
) -> Result<()> {
    Checkpoint::new(
        agent.export_params(),
        CheckpointMeta {
            hidden: agent.cfg.hidden,
            feature_dim: FeatureConfig::dim(),
            actions: env.n_actions(),
            testbed: env.testbed.id.clone(),
            workload: env.workload.spec.clone(),
            best_latency,
        },
    )
    .save(Path::new(path))
}

/// Load `--load <ckpt>` and derive the run config it pins: native
/// backend, the checkpoint's hidden size, and (unless `--testbed`
/// overrides it) the checkpoint's testbed — with the width pre-flight
/// that turns a mismatched deployment into a clear error.
fn load_run_config(c: &Cli, cfg: &Config) -> Result<(Checkpoint, Config)> {
    let path = c
        .flags
        .get("load")
        .ok_or_else(|| anyhow::anyhow!("this mode needs --load <checkpoint.json>"))?;
    let ckpt = Checkpoint::load(Path::new(path))?;
    let mut run_cfg = cfg.clone();
    run_cfg.backend = "native".to_string();
    run_cfg.hidden = ckpt.meta.hidden;
    if !c.flags.contains_key("testbed") {
        run_cfg.testbed = ckpt.meta.testbed.clone();
    }
    let tb = run_cfg.resolve_testbed()?;
    ckpt.check_compatible(run_cfg.hidden, tb.n_actions(), &run_cfg.testbed)?;
    Ok((ckpt, run_cfg))
}

/// Shared feasibility / utilization / memory report of one placement,
/// plus the optional placement-aware DOT dump.
fn print_exec_report(
    g: &CompGraph,
    tb: &Testbed,
    p: &Placement,
    rep: &ExecReport,
    dump_dot: Option<&String>,
) -> Result<()> {
    println!(
        "feasible: {}",
        if rep.feasible() {
            "yes".to_string()
        } else {
            format!("NO (OOM on devices {:?})", rep.oom_devices)
        }
    );
    let util = rep.utilization(tb);
    for (d, dev) in tb.devices.iter().enumerate() {
        let cap = if dev.mem_capacity.is_finite() {
            format!("{:.0} MB cap", dev.mem_capacity / 1e6)
        } else {
            "unbounded".to_string()
        };
        println!(
            "  {:<22} util {:>5.1}%  mem high-water {:>8.1} MB ({cap})",
            dev.name,
            100.0 * util[d],
            rep.mem_peak[d] / 1e6
        );
    }
    if let Some(path) = dump_dot {
        let names: Vec<String> = tb.devices.iter().map(|dev| dev.name.clone()).collect();
        std::fs::write(path, dot::to_dot_placed(g, &p.0, &names))?;
        println!("placement DOT written to {path}");
    }
    Ok(())
}

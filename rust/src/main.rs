//! hsdag — the L3 coordinator binary.
//!
//! Reproduces "A Structure-Aware Framework for Learning Device Placements
//! on Computation Graphs" (NeurIPS 2024). See `hsdag --help` / README.md.

use anyhow::Result;
use hsdag::baselines;
use hsdag::cli::{self, Cli};
use hsdag::harness::{figure2, table1, table2, table3, table4, table5};
use hsdag::models::Benchmark;
use hsdag::rl::{BackendFactory, Env, HsdagAgent};
use hsdag::sim::execute;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") || args.is_empty() {
        println!("{}", cli::usage());
        return;
    }
    match cli::parse(&args).and_then(run) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(c: Cli) -> Result<()> {
    let cfg = c.config()?;
    match c.command.as_str() {
        "table1" => println!("{}", table1::run().render()),
        "table2" => {
            let episodes = c.usize_flag("episodes", 30)?;
            let (t, results) = table2::run(&cfg, episodes)?;
            println!("{}", t.render());
            println!("{}", table2::render_feasibility(&results).render());
            println!("{}", table5::render(&results).render());
        }
        "table3" => {
            let episodes = c.usize_flag("episodes", 30)?;
            println!("{}", table3::run(&cfg, episodes)?.render());
        }
        "table4" => {
            let (t, acc) = table4::run(&cfg, None)?;
            println!("{}", t.render());
            println!("{}", acc.render());
        }
        "table5" => {
            let episodes = c.usize_flag("episodes", 30)?;
            println!("{}", table5::run(&cfg, episodes)?.render());
        }
        "figure2" => {
            let out = c.str_flag("out-dir", "results");
            let episodes = c.usize_flag("episodes", 5)?;
            println!("{}", figure2::run(&cfg, &out, episodes)?.render());
        }
        "train" => {
            let bench = c.bench()?;
            let episodes = c.usize_flag("episodes", 30)?;
            let mut factory = BackendFactory::new(&cfg)?;
            let env = Env::new(bench, &cfg)?;
            let mut agent = HsdagAgent::with_backend(&env, factory.create(&env, &cfg)?, &cfg)?;
            println!(
                "searching {} ({} working nodes, {} edges) on testbed {} ({} placement targets) \
                 for {episodes} episodes on backend {}",
                bench.display(),
                env.n_nodes,
                env.n_edges,
                env.testbed.id,
                env.n_actions(),
                agent.backend_desc(),
            );
            let res = agent.search(&env, episodes)?;
            for p in &res.curve {
                println!(
                    "  episode {:>3}  best {:.5}s  mean-reward {:.3}  loss {:+.4}",
                    p.episode, p.best_latency, p.mean_reward, p.loss
                );
            }
            println!(
                "best latency {:.5}s  (speedup {:.1}% vs reference {:.5}s)  wall {:.1}s",
                res.best_latency,
                res.speedup_vs(env.ref_latency),
                env.ref_latency,
                res.wall_secs
            );
        }
        "place" => {
            let bench = c.bench()?;
            let method = c.str_flag("method", "gpu");
            let g = bench.build();
            let tb = cfg.resolve_testbed()?;
            match baselines::baseline_latency(&method, &g, &tb) {
                Some(lat) => {
                    let cpu = baselines::baseline_latency("cpu", &g, &tb).unwrap();
                    println!(
                        "{} under {method} on testbed {}: {lat:.5}s ({:+.1}% vs reference)",
                        bench.display(),
                        tb.id,
                        100.0 * (1.0 - lat / cpu)
                    );
                    // Feasibility / utilization / memory of the method's
                    // representative placement.
                    if method == "random" {
                        println!(
                            "(latency above is the mean over several fixed-seed draws; the \
                             report below describes one representative draw)"
                        );
                    }
                    let p = baselines::baseline_placement(&method, &g, &tb).unwrap();
                    let rep = execute(&g, &p, &tb);
                    println!(
                        "feasible: {}",
                        if rep.feasible() {
                            "yes".to_string()
                        } else {
                            format!("NO (OOM on devices {:?})", rep.oom_devices)
                        }
                    );
                    let util = rep.utilization(&tb);
                    for (d, dev) in tb.devices.iter().enumerate() {
                        let cap = if dev.mem_capacity.is_finite() {
                            format!("{:.0} MB cap", dev.mem_capacity / 1e6)
                        } else {
                            "unbounded".to_string()
                        };
                        println!(
                            "  {:<22} util {:>5.1}%  mem high-water {:>8.1} MB ({cap})",
                            dev.name,
                            100.0 * util[d],
                            rep.mem_peak[d] / 1e6
                        );
                    }
                }
                None => anyhow::bail!(
                    "unknown method '{method}' ({})",
                    baselines::BASELINE_NAMES.join("|")
                ),
            }
        }
        "graph-stats" => {
            for b in Benchmark::ALL {
                let g = b.build();
                g.validate().map_err(|e| anyhow::anyhow!("{}: {e}", b.id()))?;
                println!(
                    "{:<14} |V|={:<5} |E|={:<5} d̄={:.2}  critical-path={}  GFLOP={:.2}",
                    b.display(),
                    g.n(),
                    g.m(),
                    g.avg_degree(),
                    g.critical_path_len(),
                    g.total_flops() / 1e9
                );
            }
        }
        "config" => print!("{}", cfg.table6()),
        other => anyhow::bail!("unknown command '{other}'\n\n{}", cli::usage()),
    }
    Ok(())
}

//! Reinforcement-learning coordinator (Algorithm 1): the placement
//! environment, the HSDAG agent, the learned baselines, and search
//! bookkeeping. All neural compute happens in AOT-compiled HLO artifacts
//! executed via the PJRT runtime; this module owns everything else.

pub mod baseline_agents;
pub mod env;
pub mod hsdag;
pub mod search;

pub use baseline_agents::{BaselineAgent, BaselineKind};
pub use env::Env;
pub use hsdag::{HsdagAgent, StepOutcome};
pub use search::{CurvePoint, SearchResult};

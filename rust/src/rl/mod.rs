//! Reinforcement-learning coordinator (Algorithm 1): the placement
//! environment, the HSDAG agent, the learned baselines, search
//! bookkeeping, and the policy-backend layer. Neural compute happens
//! behind the [`PolicyBackend`] trait — pure-rust kernels by default
//! (`backend::NativeBackend`), AOT-compiled HLO via PJRT when artifacts
//! are available (`backend::PjrtBackend`); this module owns everything
//! else.

pub mod backend;
pub mod baseline_agents;
pub mod env;
pub mod hsdag;
pub mod search;

pub use backend::{
    BackendFactory, BackendKind, NativeBackend, PjrtBackend, PolicyBackend, PolicyFwd, TrainBatch,
};
pub use baseline_agents::{BaselineAgent, BaselineKind};
pub use env::{Env, WorkloadInfo};
pub use hsdag::{HsdagAgent, StepOutcome};
pub use search::{CurvePoint, SearchResult};

//! The HSDAG agent: Algorithm 1's end-to-end loop, driven from rust with
//! all neural compute in AOT-compiled HLO (fwd / placer / train).
//!
//! Per step:
//!   1. `*_hsdag_fwd`    -> node embeddings Z, GPN edge scores S
//!   2. rust parsing     -> groups (Eq. 9 + union-find), exploration edge
//!                          dropout (dropout_network)
//!   3. `*_hsdag_placer` -> per-group device logits
//!   4. rust sampling    -> placement, simulator -> latency -> reward
//!   5. feedback update  -> fb_v += mean Z of v's group (Alg. 1 line 10)
//!   6. buffer; every `update_timestep` steps one `*_hsdag_train` call
//!      applies the Eq. 14 REINFORCE update (Adam inside the artifact).

use anyhow::{Context, Result};

use super::env::Env;
use super::search::{reinforce_coefficients, SearchResult, Tracker};
use crate::config::Config;
use crate::parsing::{parse, Partition};
use crate::runtime::{Engine, ParamStore, Tensor};
use crate::sim::measure_from;
use crate::util::stats::Ema;
use crate::util::Rng;

const H: usize = 128; // hidden_channel; verified against the spec at init

/// Replay buffer for one update window (T steps).
struct Buffer {
    fb: Vec<f32>,       // [T, V, H]
    cids: Vec<i32>,     // [T, V]
    actions: Vec<i32>,  // [T, V]
    gmask: Vec<f32>,    // [T, V]
    retained: Vec<f32>, // [T, E]
    rewards: Vec<f64>,
    len: usize,
    t_cap: usize,
    v: usize,
    e: usize,
}

impl Buffer {
    fn new(t_cap: usize, v: usize, e: usize) -> Buffer {
        Buffer {
            fb: vec![0.0; t_cap * v * H],
            cids: vec![0; t_cap * v],
            actions: vec![0; t_cap * v],
            gmask: vec![0.0; t_cap * v],
            retained: vec![0.0; t_cap * e],
            rewards: Vec::with_capacity(t_cap),
            len: 0,
            t_cap,
            v,
            e,
        }
    }

    fn clear(&mut self) {
        // Only `len` gates reads; zero the mask-like planes for safety.
        self.gmask.iter_mut().for_each(|x| *x = 0.0);
        self.retained.iter_mut().for_each(|x| *x = 0.0);
        self.rewards.clear();
        self.len = 0;
    }

    fn full(&self) -> bool {
        self.len == self.t_cap
    }

    fn bytes(&self) -> usize {
        4 * (self.fb.len() + self.cids.len() + self.actions.len() + self.gmask.len() + self.retained.len())
    }
}

/// One step's outcome (shared with `BaselineAgent` and the figure2 /
/// quickstart paths).
pub struct StepOutcome {
    pub actions: Vec<usize>,
    /// Latency the reward was computed from (noisy under exploration).
    pub latency: f64,
    /// Deterministic makespan of the same placement (no measurement
    /// noise) — what best-placement tracking uses; computed from the one
    /// simulation the step already ran.
    pub det_latency: f64,
    pub reward: f64,
    /// Placement groups this step acted on (for per-node policies, the
    /// node count).
    pub n_groups: usize,
    /// Whether the sampled placement fits every device's memory capacity
    /// (always true on the unbounded default testbeds). Infeasible steps
    /// earn `Config::oom_penalty` as their reward and are never tracked
    /// as the best placement.
    pub feasible: bool,
}

/// The HSDAG policy agent.
pub struct HsdagAgent {
    pub cfg: Config,
    pub params: ParamStore,
    fb: Vec<f32>, // [V, H] evolving feedback state
    buffer: Buffer,
    baseline: Ema,
    rng: Rng,
    fwd_name: String,
    placer_name: String,
    train_name: String,
    /// Cached literal forms of the parameters (invalidated on update).
    param_lits: Vec<xla::Literal>,
    /// Last partition (exposed for Figure 2 dumps).
    pub last_partition: Option<Partition>,
}

impl HsdagAgent {
    pub fn new(env: &Env, engine: &mut Engine, cfg: &Config) -> Result<HsdagAgent> {
        let bench = env.bench.id();
        let train_name = format!("{bench}_hsdag_train");
        let train = engine.load(&train_name).context("loading train artifact")?;
        anyhow::ensure!(train.spec.v == env.v_pad, "artifact V mismatch");
        anyhow::ensure!(train.spec.e == env.e_pad, "artifact E mismatch");
        anyhow::ensure!(train.spec.t == cfg.update_timestep, "artifact T mismatch");
        // The placer head's logit width must match the testbed's action
        // space.
        let artifact_nd = train.spec.nd_or_legacy();
        anyhow::ensure!(
            artifact_nd == env.n_actions(),
            "artifact lowered for {} devices but testbed '{}' exposes {} placement targets \
             (re-run `make artifacts` with ND={})",
            artifact_nd,
            env.testbed.id,
            env.n_actions(),
            env.n_actions()
        );
        let mut rng = Rng::new(cfg.seed ^ 0x45DA6);
        let params = ParamStore::init_from_spec(&train.spec, &mut rng)?;
        let param_lits = params
            .params
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(HsdagAgent {
            cfg: cfg.clone(),
            params,
            fb: vec![0.0; env.v_pad * H],
            buffer: Buffer::new(cfg.update_timestep, env.v_pad, env.e_pad),
            baseline: Ema::new(0.1),
            rng,
            fwd_name: format!("{bench}_hsdag_fwd"),
            placer_name: format!("{bench}_hsdag_placer"),
            train_name,
            param_lits,
            last_partition: None,
        })
    }

    /// Reset episode state (fb persists across steps within an episode;
    /// Alg. 1 renews it per outer iteration).
    pub fn reset_episode(&mut self) {
        self.fb.iter_mut().for_each(|x| *x = 0.0);
    }

    /// One Alg. 1 step. `explore` enables sampling + edge dropout;
    /// greedy argmax otherwise.
    pub fn step(&mut self, env: &Env, engine: &mut Engine, explore: bool) -> Result<StepOutcome> {
        let v_pad = env.v_pad;

        // (1) Forward: Z + edge scores. Constant tensors (params between
        // updates, features, adjacency) go in as cached literals; only the
        // evolving feedback state is serialized per step.
        let fb_used = self.fb.clone();
        let fb_lit = Tensor::f32(&[v_pad, H], self.fb.clone()).to_literal()?;
        let mut refs: Vec<&xla::Literal> = self.param_lits.iter().collect();
        refs.push(&env.lit.x0);
        refs.push(&env.lit.a_norm);
        refs.push(&fb_lit);
        refs.push(&env.lit.edge_src);
        refs.push(&env.lit.edge_dst);
        refs.push(&env.lit.node_mask);
        let fwd = engine.load(&self.fwd_name)?;
        let outs = fwd.run_refs(&refs)?;
        let z: Vec<f32> = outs[0].to_vec()?;
        let scores_padded: Vec<f32> = outs[1].to_vec()?;

        // (2) Parse on real edges, with exploration dropout.
        let mut scores: Vec<f32> = scores_padded[..env.n_edges].to_vec();
        if explore && self.cfg.dropout_network > 0.0 {
            for s in scores.iter_mut() {
                if self.rng.next_f64() < self.cfg.dropout_network {
                    *s = -1.0;
                }
            }
        }
        let part = parse(env.working_graph(), &scores);

        // (3) Placer: group logits.
        let mut cids = vec![0i32; v_pad];
        let mut gmask = vec![0f32; v_pad];
        for (node, &c) in part.cluster_of.iter().enumerate() {
            cids[node] = c as i32;
        }
        for m in gmask.iter_mut().take(part.n_groups) {
            *m = 1.0;
        }
        let cids_lit = Tensor::i32(&[v_pad], cids.clone()).to_literal()?;
        let gmask_lit = Tensor::f32(&[v_pad], gmask.clone()).to_literal()?;
        let mut prefs: Vec<&xla::Literal> = self.param_lits.iter().collect();
        prefs.push(&outs[0]); // Z straight from the fwd output, no copy
        prefs.push(&cids_lit);
        prefs.push(&gmask_lit);
        let placer = engine.load(&self.placer_name)?;
        let pouts = placer.run_refs(&prefs)?;
        let logits: Vec<f32> = pouts[0].to_vec()?;
        // Action-space width comes from the env's testbed, not the config:
        // the artifact contract was validated against it at construction.
        let nd = env.n_actions();

        // (4) Sample (or argmax) a device per group; expand; simulate.
        let mut group_devices = vec![0usize; part.n_groups];
        for g in 0..part.n_groups {
            let row = &logits[g * nd..(g + 1) * nd];
            group_devices[g] = if explore {
                sample_softmax(row, self.cfg.temperature, &mut self.rng)
            } else {
                argmax(row)
            };
        }
        let actions: Vec<usize> = part.cluster_of.iter().map(|&c| group_devices[c]).collect();
        let report = env.report(&actions);
        let feasible = report.feasible();
        let latency = if explore && self.cfg.measure_sigma > 0.0 {
            measure_from(report.makespan, self.cfg.measure_sigma, &mut self.rng)
        } else {
            report.makespan
        };
        // OOM placements earn the flat penalty, never a latency reward.
        let reward = env.reward_with_penalty(&report, latency, self.cfg.oom_penalty);

        // (5) Feedback update: fb_v += mean Z of v's group.
        let mut gsum = vec![0f32; part.n_groups * H];
        let mut gcount = vec![0f32; part.n_groups];
        for (node, &c) in part.cluster_of.iter().enumerate() {
            gcount[c] += 1.0;
            for k in 0..H {
                gsum[c * H + k] += z[node * H + k];
            }
        }
        for (node, &c) in part.cluster_of.iter().enumerate() {
            let cnt = gcount[c].max(1.0);
            for k in 0..H {
                self.fb[node * H + k] += gsum[c * H + k] / cnt;
            }
        }

        // (6) Buffer (skip when full: the caller decides when to flush
        // via `update`; extra exploration steps are still valid rollouts).
        if explore && !self.buffer.full() {
            let t = self.buffer.len;
            let (v, e) = (self.buffer.v, self.buffer.e);
            // Store the fb that THIS forward actually saw (pre-update).
            self.buffer.fb[t * v * H..(t + 1) * v * H].copy_from_slice(&fb_used);
            self.buffer.cids[t * v..(t + 1) * v].copy_from_slice(&cids);
            for (node, &a) in actions.iter().enumerate() {
                // Store per-group actions in group-slot order (the loss
                // indexes logits by group id).
                let g = part.cluster_of[node];
                self.buffer.actions[t * v + g] = group_devices[g] as i32;
                let _ = (node, a);
            }
            self.buffer.gmask[t * v..(t + 1) * v].copy_from_slice(&gmask);
            for (ei, &r) in part.retained.iter().enumerate() {
                self.buffer.retained[t * e + ei] = if r { 1.0 } else { 0.0 };
            }
            self.buffer.rewards.push(reward);
            self.buffer.len += 1;
        }

        self.last_partition = Some(part.clone());
        Ok(StepOutcome {
            actions,
            latency,
            det_latency: report.makespan,
            reward,
            n_groups: part.n_groups,
            feasible,
        })
    }

    /// Flush the buffer through the train artifact (Eq. 14). Returns the
    /// loss, or None if the buffer was empty.
    pub fn update(&mut self, env: &Env, engine: &mut Engine) -> Result<Option<f32>> {
        if self.buffer.len == 0 {
            return Ok(None);
        }
        // Pad the reward tail with zero-coefficients if the episode ended
        // short of a full window.
        let mut rewards = self.buffer.rewards.clone();
        rewards.resize(self.buffer.t_cap, 0.0);
        let mut coeff = reinforce_coefficients(
            &rewards,
            self.cfg.gamma,
            if self.cfg.use_baseline { Some(&mut self.baseline) } else { None },
        );
        for c in coeff.iter_mut().skip(self.buffer.len) {
            *c = 0.0;
        }

        let (v, e, t) = (self.buffer.v, self.buffer.e, self.buffer.t_cap);
        let mut loss = 0.0;
        for _ in 0..self.cfg.k_epochs {
            let mut inputs = self.params.train_prefix();
            inputs.push(env.x0.clone());
            inputs.push(env.a_norm.clone());
            inputs.push(env.edge_src.clone());
            inputs.push(env.edge_dst.clone());
            inputs.push(env.node_mask.clone());
            inputs.push(env.edge_mask.clone());
            inputs.push(Tensor::f32(&[t, v, H], self.buffer.fb.clone()));
            inputs.push(Tensor::i32(&[t, v], self.buffer.cids.clone()));
            inputs.push(Tensor::i32(&[t, v], self.buffer.actions.clone()));
            inputs.push(Tensor::f32(&[t, v], self.buffer.gmask.clone()));
            inputs.push(Tensor::f32(&[t, e], self.buffer.retained.clone()));
            inputs.push(Tensor::f32(&[t], coeff.clone()));
            inputs.push(Tensor::u32(&[2], vec![self.rng.next_u64() as u32, self.rng.next_u64() as u32]));
            let train = engine.load(&self.train_name)?;
            let outs = train.run(&inputs)?;
            loss = self.params.apply_train_outputs(&outs)?;
        }
        // Refresh the cached parameter literals for the next steps.
        self.param_lits = self
            .params
            .params
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.buffer.clear();
        Ok(Some(loss))
    }

    /// Full search: `episodes` episodes of `update_timestep` steps each,
    /// followed by one greedy evaluation step.
    pub fn search(&mut self, env: &Env, engine: &mut Engine, episodes: usize) -> Result<SearchResult> {
        let start = std::time::Instant::now();
        let mut tracker = Tracker::new();
        for ep in 0..episodes {
            self.reset_episode();
            for _ in 0..self.cfg.update_timestep {
                let o = self.step(env, engine, true)?;
                // Track with the *deterministic* latency of the sampled
                // placement so "best" is noise-free; infeasible (OOM)
                // placements are never candidates for "best".
                let det = if o.feasible { o.det_latency } else { f64::INFINITY };
                tracker.observe(&o.actions, det, o.reward);
            }
            if self.buffer.full() {
                if let Some(loss) = self.update(env, engine)? {
                    tracker.record_loss(loss as f64);
                }
            }
            tracker.end_episode(ep);
        }
        // Greedy final placement under the trained policy.
        self.reset_episode();
        let greedy = self.step(env, engine, false)?;
        let det = if greedy.feasible { greedy.det_latency } else { f64::INFINITY };
        tracker.observe(&greedy.actions, det, greedy.reward);

        let peak = self.buffer.bytes() + env.v_pad * env.v_pad * 4 + self.params.n_scalars() * 12;
        Ok(tracker.finish(start.elapsed().as_secs_f64(), peak))
    }
}

/// Sample an index from softmax(logits / temperature).
pub fn sample_softmax(logits: &[f32], temperature: f64, rng: &mut Rng) -> usize {
    let t = temperature.max(1e-6) as f32;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits.iter().map(|&l| (((l - mx) / t) as f64).exp()).collect();
    rng.categorical(&weights)
}

/// Argmax index (ties to the first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sampling_respects_logits() {
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[sample_softmax(&[0.0, 2.0], 1.0, &mut rng)] += 1;
        }
        // softmax(0,2) ~ (0.12, 0.88)
        let frac = counts[1] as f64 / 2000.0;
        assert!((frac - 0.88).abs() < 0.04, "{frac}");
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn buffer_layout() {
        let mut b = Buffer::new(2, 4, 3);
        assert!(!b.full());
        b.len = 2;
        assert!(b.full());
        b.clear();
        assert_eq!(b.len, 0);
        assert!(b.bytes() > 0);
    }
}

//! The HSDAG agent: Algorithm 1's end-to-end loop, driven from rust with
//! all neural compute behind a [`PolicyBackend`] (native pure-rust
//! kernels by default; AOT-compiled HLO via PJRT when artifacts exist).
//!
//! Per step:
//!   1. `backend.fwd`    -> node embeddings Z, GPN edge scores S
//!   2. rust parsing     -> groups (Eq. 9 + union-find), exploration edge
//!                          dropout (dropout_network)
//!   3. `backend.placer` -> per-group device logits
//!   4. rust sampling    -> placement, simulator -> latency -> reward
//!   5. feedback update  -> fb_v += mean Z of v's group (Alg. 1 line 10)
//!   6. buffer; every `update_timestep` steps one `backend.train` call
//!      applies the Eq. 14 REINFORCE update (Adam inside the backend).

use anyhow::Result;

use super::backend::{BackendFactory, PolicyBackend, PolicyFwd, TrainBatch};
use super::env::Env;
use super::search::{reinforce_coefficients, SearchResult, Tracker};
use crate::config::Config;
use crate::parsing::{parse, Partition};
use crate::runtime::ParamStore;
use crate::sim::{measure_from, request_rng};
use crate::util::stats::Ema;
use crate::util::Rng;

/// Replay buffer for one update window (T steps).
struct Buffer {
    fb: Vec<f32>,       // [T, V, H]
    cids: Vec<i32>,     // [T, V]
    actions: Vec<i32>,  // [T, V]
    gmask: Vec<f32>,    // [T, V]
    retained: Vec<f32>, // [T, E]
    rewards: Vec<f64>,
    len: usize,
    t_cap: usize,
    v: usize,
    e: usize,
}

impl Buffer {
    fn new(t_cap: usize, v: usize, e: usize, h: usize) -> Buffer {
        Buffer {
            fb: vec![0.0; t_cap * v * h],
            cids: vec![0; t_cap * v],
            actions: vec![0; t_cap * v],
            gmask: vec![0.0; t_cap * v],
            retained: vec![0.0; t_cap * e],
            rewards: Vec::with_capacity(t_cap),
            len: 0,
            t_cap,
            v,
            e,
        }
    }

    fn clear(&mut self) {
        // Only `len` gates reads; zero the mask-like planes for safety.
        self.gmask.iter_mut().for_each(|x| *x = 0.0);
        self.retained.iter_mut().for_each(|x| *x = 0.0);
        self.rewards.clear();
        self.len = 0;
    }

    fn full(&self) -> bool {
        self.len == self.t_cap
    }

    /// Working-set bytes of one full window, including the f64 reward
    /// buffer (Table 5's memory column counts the whole replay state).
    fn bytes(&self) -> usize {
        4 * (self.fb.len()
            + self.cids.len()
            + self.actions.len()
            + self.gmask.len()
            + self.retained.len())
            + 8 * self.t_cap
    }
}

/// One step's outcome (shared with `BaselineAgent` and the figure2 /
/// quickstart paths).
pub struct StepOutcome {
    pub actions: Vec<usize>,
    /// Latency the reward was computed from (noisy under exploration).
    pub latency: f64,
    /// Deterministic makespan of the same placement (no measurement
    /// noise) — what best-placement tracking uses; computed from the one
    /// simulation the step already ran.
    pub det_latency: f64,
    pub reward: f64,
    /// Placement groups this step acted on (for per-node policies, the
    /// node count).
    pub n_groups: usize,
    /// Whether the sampled placement fits every device's memory capacity
    /// (always true on the unbounded default testbeds). Infeasible steps
    /// earn `Config::oom_penalty` as their reward and are never tracked
    /// as the best placement.
    pub feasible: bool,
    /// Mean policy entropy (nats) of the per-group device distributions
    /// this step sampled from, computed deterministically from the placer
    /// logits at the sampling temperature. Telemetry only — never feeds
    /// back into training or sampling. NaN for agents that don't report
    /// it.
    pub entropy: f64,
}

/// The HSDAG policy agent.
pub struct HsdagAgent {
    pub cfg: Config,
    backend: Box<dyn PolicyBackend>,
    h: usize,
    fb: Vec<f32>, // [V, H] evolving feedback state
    buffer: Buffer,
    baseline: Ema,
    rng: Rng,
    /// Last partition (exposed for Figure 2 dumps).
    pub last_partition: Option<Partition>,
    // Reusable per-step buffers (PR 6: the hot step loop allocates
    // nothing beyond the simulator report).
    step_cids: Vec<i32>,  // [V] — padded tail stays zero
    step_gmask: Vec<f32>, // [V]
    gsum: Vec<f32>,       // [n_groups, H], grow-only
    gcount: Vec<f32>,     // [n_groups], grow-only
}

impl HsdagAgent {
    /// Construct with the backend the config resolves to (`cfg.backend`:
    /// native / pjrt / auto).
    pub fn new(env: &Env, cfg: &Config) -> Result<HsdagAgent> {
        let backend = BackendFactory::new(cfg)?.create(env, cfg)?;
        Self::with_backend(env, backend, cfg)
    }

    /// Construct over an explicit backend (harness runs share a
    /// [`BackendFactory`] so the PJRT engine compiles each artifact once).
    pub fn with_backend(
        env: &Env,
        backend: Box<dyn PolicyBackend>,
        cfg: &Config,
    ) -> Result<HsdagAgent> {
        let h = cfg.hidden;
        Ok(HsdagAgent {
            cfg: cfg.clone(),
            backend,
            h,
            fb: vec![0.0; env.v_pad * h],
            buffer: Buffer::new(cfg.update_timestep, env.v_pad, env.e_pad, h),
            baseline: Ema::new(0.1),
            rng: Rng::new(cfg.seed ^ 0xA6E27),
            last_partition: None,
            step_cids: vec![0; env.v_pad],
            step_gmask: vec![0.0; env.v_pad],
            gsum: Vec::new(),
            gcount: Vec::new(),
        })
    }

    /// The active backend's human-readable identity.
    pub fn backend_desc(&self) -> String {
        self.backend.describe()
    }

    /// Policy parameters + optimizer state (diagnostics).
    pub fn params(&self) -> &ParamStore {
        self.backend.params()
    }

    /// Snapshot the backend's full learning state (params + Adam moments)
    /// for transfer to an agent bound to another workload.
    pub fn export_params(&self) -> ParamStore {
        self.backend.export_params()
    }

    /// Install a snapshot taken by [`HsdagAgent::export_params`] on a
    /// layout-compatible agent (same hidden size and action-space width).
    pub fn import_params(&mut self, snapshot: &ParamStore) -> Result<()> {
        self.backend.import_params(snapshot)
    }

    /// Reset episode state (fb persists across steps within an episode;
    /// Alg. 1 renews it per outer iteration).
    pub fn reset_episode(&mut self) {
        self.fb.iter_mut().for_each(|x| *x = 0.0);
    }

    /// One Alg. 1 step. `explore` enables sampling + edge dropout;
    /// greedy argmax otherwise.
    pub fn step(&mut self, env: &Env, explore: bool) -> Result<StepOutcome> {
        let h = self.h;
        let will_buffer = explore && !self.buffer.full();

        // (1) Forward: Z + edge scores on the current feedback state.
        // Stash the fb this forward sees straight into its replay plane
        // (pre-update), instead of a temporary clone.
        if will_buffer {
            let (t, v) = (self.buffer.len, self.buffer.v);
            self.buffer.fb[t * v * h..(t + 1) * v * h].copy_from_slice(&self.fb);
        }
        let out = self.backend.fwd(env, &self.fb)?;

        // (2) Parse on real edges, with exploration dropout.
        let mut scores = out.scores.clone();
        if explore && self.cfg.dropout_network > 0.0 {
            for s in scores.iter_mut() {
                if self.rng.next_f64() < self.cfg.dropout_network {
                    *s = -1.0;
                }
            }
        }
        let part = parse(env.working_graph(), &scores);

        // (3) Placer: group logits. The cids/gmask planes are reusable
        // agent buffers: every real node slot is overwritten, the padded
        // tail stays zero, and the group mask is re-zeroed per step.
        for (node, &c) in part.cluster_of.iter().enumerate() {
            self.step_cids[node] = c as i32;
        }
        self.step_gmask.iter_mut().for_each(|m| *m = 0.0);
        for m in self.step_gmask.iter_mut().take(part.n_groups) {
            *m = 1.0;
        }
        let logits = self.backend.placer(env, &out, &self.step_cids, &self.step_gmask)?;
        // Action-space width comes from the env's testbed, not the config:
        // the backend contract was validated against it at construction.
        let nd = env.n_actions();

        // (4) Sample (or argmax) a device per group; expand; simulate.
        let mut group_devices = vec![0usize; part.n_groups];
        for g in 0..part.n_groups {
            let row = &logits[g * nd..(g + 1) * nd];
            group_devices[g] = if explore {
                sample_softmax(row, self.cfg.temperature, &mut self.rng)
            } else {
                argmax(row)
            };
        }
        let entropy = mean_entropy(&logits, part.n_groups, nd, self.cfg.temperature);
        let actions: Vec<usize> = part.cluster_of.iter().map(|&c| group_devices[c]).collect();
        let report = env.report(&actions)?;
        let feasible = report.feasible();
        let latency = if explore && self.cfg.measure_sigma > 0.0 {
            measure_from(report.makespan, self.cfg.measure_sigma, &mut self.rng)
        } else {
            report.makespan
        };
        // OOM placements earn the flat penalty, never a latency reward.
        let reward = env.reward_with_penalty(&report, latency, self.cfg.oom_penalty);

        // (5) Feedback update: fb_v += mean Z of v's group (grow-only
        // group accumulators, zeroed per step).
        let ng = part.n_groups;
        if self.gsum.len() < ng * h {
            self.gsum.resize(ng * h, 0.0);
        }
        if self.gcount.len() < ng {
            self.gcount.resize(ng, 0.0);
        }
        self.gsum[..ng * h].iter_mut().for_each(|x| *x = 0.0);
        self.gcount[..ng].iter_mut().for_each(|x| *x = 0.0);
        for (node, &c) in part.cluster_of.iter().enumerate() {
            self.gcount[c] += 1.0;
            for k in 0..h {
                self.gsum[c * h + k] += out.z[node * h + k];
            }
        }
        for (node, &c) in part.cluster_of.iter().enumerate() {
            let cnt = self.gcount[c].max(1.0);
            for k in 0..h {
                self.fb[node * h + k] += self.gsum[c * h + k] / cnt;
            }
        }

        // (6) Buffer (skip when full: the caller decides when to flush
        // via `update`; extra exploration steps are still valid rollouts).
        // The fb plane was already stored before the forward.
        if will_buffer {
            let t = self.buffer.len;
            let (v, e) = (self.buffer.v, self.buffer.e);
            self.buffer.cids[t * v..(t + 1) * v].copy_from_slice(&self.step_cids);
            for g in 0..part.n_groups {
                // Store per-group actions in group-slot order (the loss
                // indexes logits by group id).
                self.buffer.actions[t * v + g] = group_devices[g] as i32;
            }
            self.buffer.gmask[t * v..(t + 1) * v].copy_from_slice(&self.step_gmask);
            for (ei, &r) in part.retained.iter().enumerate() {
                self.buffer.retained[t * e + ei] = if r { 1.0 } else { 0.0 };
            }
            self.buffer.rewards.push(reward);
            self.buffer.len += 1;
        }

        self.last_partition = Some(part.clone());
        Ok(StepOutcome {
            actions,
            latency,
            det_latency: report.makespan,
            reward,
            n_groups: part.n_groups,
            feasible,
            entropy,
        })
    }

    /// Execute `1 + n_stochastic` *independent* single-step rollouts from
    /// a fresh (zero) feedback state: rollout 0 is greedy, the rest
    /// sample with exploration edge dropout. Because every rollout sees
    /// the same zero feedback, ONE backend forward serves all of them;
    /// the per-rollout partitions then go through one batched
    /// [`PolicyBackend::placer_many`] weight pass. This is the serve
    /// daemon's per-request policy path: B rollouts cost one encoder pass
    /// + one stacked placer pass instead of B of each.
    ///
    /// Nothing is buffered for training and the feedback state is left
    /// reset; `last_partition` reflects the greedy rollout.
    ///
    /// Every stochastic rollout draws its dropout and sampling decisions
    /// from a counter-derived RNG stream ([`request_rng`] over one base
    /// draw), so rollout `bi`'s trajectory is a pure function of (policy,
    /// base, `bi`) — bit-identical no matter how many rollouts share the
    /// batch or how many workers simulate it. The greedy rollout (bi = 0)
    /// draws nothing.
    pub fn rollout_batch(&mut self, env: &Env, n_stochastic: usize) -> Result<Vec<StepOutcome>> {
        let b = 1 + n_stochastic;
        let v_pad = env.v_pad;
        let nd = env.n_actions();
        self.reset_episode();
        let out = self.backend.fwd(env, &self.fb)?;
        let base = self.rng.next_u64();

        // Parse each rollout (rollout 0 greedy: raw scores; the rest with
        // exploration edge dropout on a scratch copy, each from its own
        // counter-derived stream).
        let mut parts = Vec::with_capacity(b);
        let mut rngs: Vec<Rng> = (0..b).map(|bi| request_rng(base, bi)).collect();
        let mut cids_all = vec![0i32; b * v_pad];
        let mut gmask_all = vec![0f32; b * v_pad];
        let mut scores = out.scores.clone();
        for bi in 0..b {
            if bi > 0 {
                scores.copy_from_slice(&out.scores);
                if self.cfg.dropout_network > 0.0 {
                    for s in scores.iter_mut() {
                        if rngs[bi].next_f64() < self.cfg.dropout_network {
                            *s = -1.0;
                        }
                    }
                }
            }
            let part = parse(env.working_graph(), &scores);
            let cids = &mut cids_all[bi * v_pad..(bi + 1) * v_pad];
            for (node, &c) in part.cluster_of.iter().enumerate() {
                cids[node] = c as i32;
            }
            gmask_all[bi * v_pad..bi * v_pad + part.n_groups].fill(1.0);
            parts.push(part);
        }

        // One stacked placer pass over all rollouts (shared Z).
        let fwds: Vec<&PolicyFwd> = vec![&out; b];
        let cids_refs: Vec<&[i32]> =
            cids_all.chunks_exact(v_pad).take(b).collect();
        let gmask_refs: Vec<&[f32]> =
            gmask_all.chunks_exact(v_pad).take(b).collect();
        let logits_all = self.backend.placer_many(env, &fwds, &cids_refs, &gmask_refs)?;

        // Sample / argmax and expand per rollout, then simulate the whole
        // batch through one `Env::report_many` call — the env's
        // `ParallelCostModel` spreads the B simulations across the worker
        // pool. Serving ranks placements by deterministic makespan, so no
        // measurement noise.
        let mut actions_all = Vec::with_capacity(b);
        let mut entropy_all = Vec::with_capacity(b);
        for (bi, part) in parts.iter().enumerate() {
            let logits = &logits_all[bi];
            entropy_all.push(mean_entropy(logits, part.n_groups, nd, self.cfg.temperature));
            let mut group_devices = vec![0usize; part.n_groups];
            for g in 0..part.n_groups {
                let row = &logits[g * nd..(g + 1) * nd];
                group_devices[g] = if bi > 0 {
                    sample_softmax(row, self.cfg.temperature, &mut rngs[bi])
                } else {
                    argmax(row)
                };
            }
            let actions: Vec<usize> =
                part.cluster_of.iter().map(|&c| group_devices[c]).collect();
            actions_all.push(actions);
        }
        let action_refs: Vec<&[usize]> = actions_all.iter().map(|a| a.as_slice()).collect();
        let reports = env.report_many(&action_refs)?;

        let mut outs = Vec::with_capacity(b);
        for (bi, ((actions, report), part)) in
            actions_all.into_iter().zip(reports).zip(parts.iter()).enumerate()
        {
            let feasible = report.feasible();
            let reward = env.reward_with_penalty(&report, report.makespan, self.cfg.oom_penalty);
            outs.push(StepOutcome {
                actions,
                latency: report.makespan,
                det_latency: report.makespan,
                reward,
                n_groups: part.n_groups,
                feasible,
                entropy: entropy_all[bi],
            });
        }
        self.last_partition = parts.into_iter().next();
        Ok(outs)
    }

    /// Flush the buffer through the backend's train step (Eq. 14).
    /// Returns the loss, or None if the buffer was empty.
    pub fn update(&mut self, env: &Env) -> Result<Option<f32>> {
        if self.buffer.len == 0 {
            return Ok(None);
        }
        // Pad the reward tail with zero-coefficients if the episode ended
        // short of a full window.
        let mut rewards = self.buffer.rewards.clone();
        rewards.resize(self.buffer.t_cap, 0.0);
        let mut coeff = reinforce_coefficients(
            &rewards,
            self.cfg.gamma,
            if self.cfg.use_baseline { Some(&mut self.baseline) } else { None },
        );
        for c in coeff.iter_mut().skip(self.buffer.len) {
            *c = 0.0;
        }

        let mut loss = 0.0;
        for _ in 0..self.cfg.k_epochs {
            let key = [self.rng.next_u64() as u32, self.rng.next_u64() as u32];
            let batch = TrainBatch {
                t: self.buffer.t_cap,
                v: self.buffer.v,
                e: self.buffer.e,
                fb: &self.buffer.fb,
                cids: &self.buffer.cids,
                actions: &self.buffer.actions,
                gmask: &self.buffer.gmask,
                retained: &self.buffer.retained,
                coeff: &coeff,
                key,
            };
            loss = self.backend.train(env, &batch)?;
        }
        self.buffer.clear();
        Ok(Some(loss))
    }

    /// Full search: `episodes` episodes of `update_timestep` steps each,
    /// followed by one greedy evaluation step.
    pub fn search(&mut self, env: &Env, episodes: usize) -> Result<SearchResult> {
        let start = std::time::Instant::now();
        let mut tracker = Tracker::new();
        for ep in 0..episodes {
            self.reset_episode();
            for _ in 0..self.cfg.update_timestep {
                let o = self.step(env, true)?;
                // Track with the *deterministic* latency of the sampled
                // placement so "best" is noise-free; infeasible (OOM)
                // placements are never candidates for "best".
                let det = if o.feasible { o.det_latency } else { f64::INFINITY };
                tracker.observe(&o.actions, det, o.reward);
                tracker.observe_entropy(o.entropy);
            }
            if self.buffer.full() {
                if let Some(loss) = self.update(env)? {
                    tracker.record_loss(loss as f64);
                    tracker.record_param_norm(self.backend.params().l2_norm());
                }
            }
            tracker.end_episode(ep);
        }
        // Final evaluation under the trained policy: the greedy placement
        // plus `update_timestep` stochastic rollouts, simulated as one
        // parallel batch (`rollout_batch` -> `Env::report_many` -> worker
        // pool). Rollout 0 is bit-identical to the old single greedy
        // step; the extra samples can only improve the tracked best.
        let finals = self.rollout_batch(env, self.cfg.update_timestep)?;
        for o in &finals {
            let det = if o.feasible { o.det_latency } else { f64::INFINITY };
            tracker.observe(&o.actions, det, o.reward);
        }

        // Peak working set: replay buffer (incl. rewards), the evolving
        // feedback state, the dense adjacency (when materialized — see
        // `Env::a_norm`), parameters + Adam moments.
        let peak = self.buffer.bytes()
            + self.fb.len() * 4
            + env.a_norm.numel() * 4
            + self.backend.params().n_scalars() * 12;
        Ok(tracker.finish(start.elapsed().as_secs_f64(), peak))
    }
}

/// Sample an index from softmax(logits / temperature).
pub fn sample_softmax(logits: &[f32], temperature: f64, rng: &mut Rng) -> usize {
    let t = temperature.max(1e-6) as f32;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits.iter().map(|&l| (((l - mx) / t) as f64).exp()).collect();
    rng.categorical(&weights)
}

/// Mean Shannon entropy (nats) of the first `n_groups` per-group device
/// distributions softmax(row / temperature) in a `[groups, nd]` logits
/// plane. Deterministic in the logits — draws nothing from any RNG — so
/// reporting it cannot perturb a seeded trajectory. Returns NaN when
/// there are no groups.
pub fn mean_entropy(logits: &[f32], n_groups: usize, nd: usize, temperature: f64) -> f64 {
    if n_groups == 0 || nd == 0 {
        return f64::NAN;
    }
    let t = temperature.max(1e-6);
    let mut total = 0.0;
    for g in 0..n_groups {
        let row = &logits[g * nd..(g + 1) * nd];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        // H = ln Z - (1/Z) * sum w_i * s_i with s_i = (l_i - mx)/t,
        // w_i = exp(s_i): numerically stable for any logit scale.
        let mut z = 0.0;
        let mut ws = 0.0;
        for &l in row {
            let s = (l as f64 - mx) / t;
            let w = s.exp();
            z += w;
            ws += w * s;
        }
        total += z.ln() - ws / z;
    }
    total / n_groups as f64
}

/// Argmax index (ties to the first).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sampling_respects_logits() {
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[sample_softmax(&[0.0, 2.0], 1.0, &mut rng)] += 1;
        }
        // softmax(0,2) ~ (0.12, 0.88)
        let frac = counts[1] as f64 / 2000.0;
        assert!((frac - 0.88).abs() < 0.04, "{frac}");
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
    }

    #[test]
    fn buffer_layout() {
        let mut b = Buffer::new(2, 4, 3, 8);
        assert!(!b.full());
        b.len = 2;
        assert!(b.full());
        b.clear();
        assert_eq!(b.len, 0);
        // fb + cids + actions + gmask + retained in f32/i32, rewards f64.
        let f32_bytes = 4 * (2 * 4 * 8 + 2 * 4 + 2 * 4 + 2 * 4 + 2 * 3);
        assert_eq!(b.bytes(), f32_bytes + 8 * 2);
    }
}

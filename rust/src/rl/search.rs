//! Search-result bookkeeping shared by the HSDAG agent and the learned
//! baselines: reward curves, best-placement tracking, Eq. 14 coefficients.

use crate::util::stats::Ema;

/// One point on the learning curve.
#[derive(Debug, Clone)]
pub struct CurvePoint {
    pub episode: usize,
    /// Best (lowest) latency seen so far, seconds.
    pub best_latency: f64,
    /// Mean reward over the episode.
    pub mean_reward: f64,
    /// Last training loss in this episode (NaN if no update yet).
    pub loss: f64,
    /// Mean policy entropy (nats per group) over the episode's sampled
    /// steps (NaN when the agent does not report entropy).
    pub entropy: f64,
    /// L2 norm of the policy parameters after the episode's last update
    /// (NaN if no update yet or the backend does not expose parameters).
    pub param_norm: f64,
}

/// Outcome of a policy search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Working-graph action per node group/node for the best placement.
    pub best_actions: Vec<usize>,
    /// Deterministic latency of the best placement, seconds.
    pub best_latency: f64,
    /// Learning curve, one point per episode.
    pub curve: Vec<CurvePoint>,
    /// Wall-clock search time, seconds (Table 5).
    pub wall_secs: f64,
    /// Peak approximate working-set bytes of the search (Table 5 OOM col).
    pub peak_bytes: usize,
}

impl SearchResult {
    pub fn speedup_vs(&self, ref_latency: f64) -> f64 {
        100.0 * (1.0 - self.best_latency / ref_latency)
    }
}

/// Tracks best placement + curve during a search.
pub struct Tracker {
    pub best_actions: Vec<usize>,
    pub best_latency: f64,
    pub curve: Vec<CurvePoint>,
    episode_rewards: Vec<f64>,
    episode_entropy: Vec<f64>,
    last_loss: f64,
    last_param_norm: f64,
}

impl Tracker {
    pub fn new() -> Tracker {
        Tracker {
            best_actions: Vec::new(),
            best_latency: f64::INFINITY,
            curve: Vec::new(),
            episode_rewards: Vec::new(),
            episode_entropy: Vec::new(),
            last_loss: f64::NAN,
            last_param_norm: f64::NAN,
        }
    }

    pub fn observe(&mut self, actions: &[usize], latency: f64, reward: f64) {
        if latency < self.best_latency {
            self.best_latency = latency;
            self.best_actions = actions.to_vec();
        }
        self.episode_rewards.push(reward);
    }

    /// Record one step's mean policy entropy (nats per group). Purely
    /// observational — agents that don't report entropy simply never call
    /// this and the curve carries NaN.
    pub fn observe_entropy(&mut self, entropy: f64) {
        if entropy.is_finite() {
            self.episode_entropy.push(entropy);
        }
    }

    pub fn record_loss(&mut self, loss: f64) {
        self.last_loss = loss;
    }

    /// Record the parameter L2 norm after an update (telemetry only).
    pub fn record_param_norm(&mut self, norm: f64) {
        self.last_param_norm = norm;
    }

    pub fn end_episode(&mut self, episode: usize) {
        let mean_reward = if self.episode_rewards.is_empty() {
            0.0
        } else {
            self.episode_rewards.iter().sum::<f64>() / self.episode_rewards.len() as f64
        };
        let entropy = if self.episode_entropy.is_empty() {
            f64::NAN
        } else {
            self.episode_entropy.iter().sum::<f64>() / self.episode_entropy.len() as f64
        };
        self.curve.push(CurvePoint {
            episode,
            best_latency: self.best_latency,
            mean_reward,
            loss: self.last_loss,
            entropy,
            param_norm: self.last_param_norm,
        });
        self.episode_rewards.clear();
        self.episode_entropy.clear();
    }

    pub fn finish(self, wall_secs: f64, peak_bytes: usize) -> SearchResult {
        SearchResult {
            best_actions: self.best_actions,
            best_latency: self.best_latency,
            curve: self.curve,
            wall_secs,
            peak_bytes,
        }
    }
}

impl Default for Tracker {
    fn default() -> Self {
        Self::new()
    }
}

/// Eq. 14 coefficients: coeff[i] = gamma^i * (r_i - baseline_i). The
/// baseline (EMA of rewards) is the standard REINFORCE variance reduction;
/// pass `None` for the paper's literal baseline-free form.
pub fn reinforce_coefficients(
    rewards: &[f64],
    gamma: f64,
    baseline: Option<&mut Ema>,
) -> Vec<f32> {
    let mut coeff = Vec::with_capacity(rewards.len());
    match baseline {
        Some(ema) => {
            for (i, &r) in rewards.iter().enumerate() {
                let b = ema.get().unwrap_or(r);
                coeff.push((gamma.powi(i as i32) * (r - b)) as f32);
                ema.update(r);
            }
        }
        None => {
            for (i, &r) in rewards.iter().enumerate() {
                coeff.push((gamma.powi(i as i32) * r) as f32);
            }
        }
    }
    coeff
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_keeps_best() {
        let mut t = Tracker::new();
        t.observe(&[0, 0], 2.0, 0.5);
        t.observe(&[1, 1], 1.0, 1.0);
        t.observe(&[0, 1], 1.5, 0.7);
        t.end_episode(0);
        assert_eq!(t.best_latency, 1.0);
        assert_eq!(t.best_actions, vec![1, 1]);
        assert!((t.curve[0].mean_reward - (0.5 + 1.0 + 0.7) / 3.0).abs() < 1e-12);
        // No entropy/param-norm reported -> NaN placeholders.
        assert!(t.curve[0].entropy.is_nan());
        assert!(t.curve[0].param_norm.is_nan());
    }

    #[test]
    fn tracker_averages_entropy_per_episode() {
        let mut t = Tracker::new();
        t.observe(&[0], 1.0, 1.0);
        t.observe_entropy(0.6);
        t.observe_entropy(0.2);
        t.observe_entropy(f64::NAN); // ignored
        t.record_param_norm(3.5);
        t.end_episode(0);
        assert!((t.curve[0].entropy - 0.4).abs() < 1e-12);
        assert_eq!(t.curve[0].param_norm, 3.5);
        // Entropy buffer resets per episode.
        t.observe(&[0], 1.0, 1.0);
        t.end_episode(1);
        assert!(t.curve[1].entropy.is_nan());
        assert_eq!(t.curve[1].param_norm, 3.5); // norm persists until next update
    }

    #[test]
    fn coefficients_discount() {
        let c = reinforce_coefficients(&[1.0, 1.0, 1.0], 0.9, None);
        assert!((c[0] - 1.0).abs() < 1e-6);
        assert!((c[1] - 0.9).abs() < 1e-6);
        assert!((c[2] - 0.81).abs() < 1e-6);
    }

    #[test]
    fn baseline_centers_rewards() {
        let mut ema = Ema::new(0.5);
        let c = reinforce_coefficients(&[1.0, 2.0, 2.0], 1.0, Some(&mut ema));
        assert_eq!(c[0], 0.0); // first reward is its own baseline
        assert!(c[1] > 0.0); // better than baseline -> positive
        assert!(c[2] > 0.0 && c[2] < c[1]); // baseline catching up
    }

    #[test]
    fn speedup_formula() {
        let r = SearchResult {
            best_actions: vec![],
            best_latency: 0.5,
            curve: vec![],
            wall_secs: 0.0,
            peak_bytes: 0,
        };
        assert!((r.speedup_vs(1.0) - 50.0).abs() < 1e-9);
    }
}

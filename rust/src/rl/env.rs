//! Placement environment: one workload prepared for the search loop.
//!
//! Pipeline (§2.2-2.3): resolve the workload's computation graph (paper
//! benchmark, on-disk file, or synthetic generator — see
//! [`crate::models::Workload`]) -> apply the Appendix-G co-location
//! heuristic -> extract §2.3 features and the normalized adjacency on the
//! *co-located* graph -> pad everything to static capacities. The policy
//! then works on the co-located graph; placements are expanded back to
//! original nodes for simulation.
//!
//! Padded capacities come from the AOT artifact contract when the
//! workload is a paper benchmark (so the pjrt backend keeps working), and
//! are rounded up to the next multiple of 64 otherwise — the native
//! backend works at real sizes and only ever sees the padding through
//! tensor shapes.
//!
//! The action space is owned by the injected `Testbed`: action index `a`
//! means "place this group on `testbed.placeable[a]`", and the reward is
//! normalized by the latency of the testbed's reference device. The
//! default `cpu_gpu` testbed reproduces the paper's 2-way CPU/dGPU
//! placement exactly; `paper3` / `multi_gpu:<k>` widen the action space
//! without touching any other layer.
//!
//! Placement-vector plumbing is fallible (`expand` / `report` / `latency`
//! return `Result`): a mis-sized action vector — the failure mode of
//! pairing a policy with the wrong user-supplied graph — is a message,
//! not a panic.

use anyhow::{anyhow, bail, Result};

use crate::coarsen::{coarsen_to_budget, Coarsening, MultiLevel, DEFAULT_COARSEN_BUDGET};
use crate::config::Config;
use crate::features::{extract, FeatureConfig, Features};
use crate::graph::CompGraph;
use crate::models::{Benchmark, Workload};
use crate::runtime::nn::normalized_adjacency_coo;
use crate::runtime::Tensor;
use crate::sim::{
    execute, measure_from, AnalyticCostModel, CostModel, ExecReport, ParallelCostModel, Placement,
    Testbed,
};
use crate::util::Rng;

/// Identity of the workload an [`Env`] was built from (the graph itself
/// lives in [`Env::graph`]).
#[derive(Debug, Clone)]
pub struct WorkloadInfo {
    /// Registry spec (`resnet50`, `layered:8x8`, `file:g.json`, ...).
    pub spec: String,
    /// Display label for tables and logs.
    pub display: String,
    /// The paper benchmark behind this workload, if any — keys the AOT
    /// policy artifacts; `None` means native-backend-only.
    pub bench: Option<Benchmark>,
}

/// Pad a real size up to the next multiple of 64 (at least 64) — the
/// static capacity used for workloads without an artifact contract.
fn pad_cap(n: usize) -> usize {
    n.max(1).div_ceil(64) * 64
}

/// A fully-prepared placement environment.
pub struct Env {
    /// Identity of the workload being placed.
    pub workload: WorkloadInfo,
    /// Original computation graph.
    pub graph: CompGraph,
    /// Co-location coarsening original -> working graph. For multi-level
    /// stacks this is the *flattened* composition (original node ->
    /// coarsest set), so every single-level consumer keeps working.
    pub colo: Coarsening,
    /// The full coarsening stack ([`coarsen_to_budget`]); one level on
    /// paper-scale graphs, deeper on 100k+-node graphs whose co-located
    /// form still exceeds `Config::coarsen_budget`. Kept for V-cycle
    /// refinement ([`MultiLevel::refine_placement`]).
    pub levels: MultiLevel,
    /// Feature extraction output on the working (co-located) graph.
    pub features: Features,
    /// The device set this environment places onto (action space + links).
    pub testbed: Testbed,
    /// Pluggable placement cost model (default: the analytic list
    /// scheduler). Swap with [`Env::set_cost_model`].
    pub cost: Box<dyn CostModel>,
    /// Padded capacities (artifact contract for paper benchmarks,
    /// round-to-64 otherwise).
    pub v_pad: usize,
    pub e_pad: usize,
    /// Real sizes of the working graph.
    pub n_nodes: usize,
    pub n_edges: usize,
    // Padded, artifact-ready tensors (constant across the whole search).
    pub x0: Tensor,
    /// Dense normalized adjacency `[v_pad, v_pad]` for the AOT artifact
    /// contract; a `[1, 1]` placeholder on workloads without an artifact
    /// bench (the native backend uses sparse COO instead).
    pub a_norm: Tensor,
    pub edge_src: Tensor,
    pub edge_dst: Tensor,
    pub node_mask: Tensor,
    pub edge_mask: Tensor,
    /// Reference-device latency (deterministic), the speedup denominator.
    /// On the paper testbeds the reference device is the CPU.
    pub ref_latency: f64,
    /// Pre-converted PJRT literals for the constant tensors (perf: avoids
    /// re-serializing ~8 MB of features/adjacency on every policy call).
    pub lit: EnvLiterals,
}

/// Cached literal forms of the environment's constant tensors.
pub struct EnvLiterals {
    pub x0: xla::Literal,
    pub a_norm: xla::Literal,
    pub edge_src: xla::Literal,
    pub edge_dst: xla::Literal,
    pub node_mask: xla::Literal,
    pub edge_mask: xla::Literal,
}

impl Env {
    pub fn new(bench: Benchmark, cfg: &Config) -> Result<Env> {
        Self::with_features(bench, cfg, cfg.features)
    }

    /// Build with explicit feature ablation switches (Table 3). The
    /// testbed is taken from `cfg.testbed` (registry id) and the cost
    /// model honors `cfg.workers` (`--workers`): batched calls
    /// through `Env::cost` fan out over the configured pool width, while
    /// single-placement `evaluate` stays inline and bit-identical.
    pub fn with_features(bench: Benchmark, cfg: &Config, fcfg: FeatureConfig) -> Result<Env> {
        Self::for_workload_with_features(Workload::from_bench(bench), cfg, fcfg)
    }

    /// Build an environment for any resolved workload under `cfg`
    /// (testbed, feature ablations, eval-worker pool) — the
    /// `--workload <spec>` path.
    pub fn for_workload(workload: Workload, cfg: &Config) -> Result<Env> {
        Self::for_workload_with_features(workload, cfg, cfg.features)
    }

    /// [`Env::for_workload`] with explicit feature switches.
    pub fn for_workload_with_features(
        workload: Workload,
        cfg: &Config,
        fcfg: FeatureConfig,
    ) -> Result<Env> {
        let mut env = Self::build(workload, fcfg, cfg.resolve_testbed()?, cfg.coarsen_budget)?;
        env.set_cost_model(Box::new(ParallelCostModel::new(AnalyticCostModel, cfg.workers)));
        Ok(env)
    }

    /// Build an environment for an arbitrary computation graph on the
    /// default `cpu_gpu` testbed, reusing the AOT artifacts of `bench`
    /// (the graph's co-located form must fit that benchmark's padded
    /// capacities). This is how downstream users place their own models
    /// on the pjrt backend without re-lowering artifacts.
    pub fn from_graph(bench: Benchmark, graph: CompGraph, fcfg: FeatureConfig) -> Result<Env> {
        Self::from_graph_on(bench, graph, fcfg, Testbed::cpu_gpu())
    }

    /// Fully-injected construction: arbitrary graph *and* testbed, pinned
    /// to `bench`'s artifact capacities.
    pub fn from_graph_on(
        bench: Benchmark,
        graph: CompGraph,
        fcfg: FeatureConfig,
        testbed: Testbed,
    ) -> Result<Env> {
        Self::build(Workload::from_graph(graph, Some(bench)), fcfg, testbed, DEFAULT_COARSEN_BUDGET)
    }

    /// Core constructor: coarsen (multi-level, to `budget` working
    /// nodes), featurize, pad, and simulate the reference placement for
    /// any workload.
    fn build(
        workload: Workload,
        fcfg: FeatureConfig,
        testbed: Testbed,
        budget: usize,
    ) -> Result<Env> {
        let Workload { spec, display, bench, graph } = workload;
        let info = WorkloadInfo { spec, display, bench };
        let levels = coarsen_to_budget(&graph, budget);
        let colo = levels.flatten();
        let wg = &colo.coarse;
        let (v_pad, e_pad) = match info.bench {
            Some(b) => {
                let caps = (b.padded_nodes(), b.padded_edges());
                if wg.n() > caps.0 || wg.m() > caps.1 {
                    bail!(
                        "{}: co-located graph ({} nodes, {} edges) exceeds the {} artifact \
                         capacity ({}, {})",
                        info.spec,
                        wg.n(),
                        wg.m(),
                        b.id(),
                        caps.0,
                        caps.1
                    );
                }
                caps
            }
            None => (pad_cap(wg.n()), pad_cap(wg.m())),
        };
        let features = extract(wg, fcfg);
        let d = FeatureConfig::dim();

        // Pad X0 [v_pad, d].
        let mut x0 = vec![0f32; v_pad * d];
        x0[..wg.n() * d].copy_from_slice(&features.x);

        // Dense Â [v_pad, v_pad] exists for the AOT artifact contract
        // only — the native backend (the only one that can run registry
        // workloads) message-passes over sparse CSR at real size, so
        // workloads without an artifact bench skip the O(v_pad²)
        // allocation (a 1x1 placeholder stands in; every consumer sits
        // behind `artifact_bench()`). Even on the artifact path the
        // padded buffer is scattered straight from COO — no second
        // dense [n, n] intermediate.
        let a_norm = if info.bench.is_some() {
            let mut a = vec![0f32; v_pad * v_pad];
            for &(r, c, w) in &normalized_adjacency_coo(wg.n(), &wg.edges) {
                a[r as usize * v_pad + c as usize] = w;
            }
            a
        } else {
            vec![0f32]
        };
        let a_dims: [usize; 2] = if info.bench.is_some() { [v_pad, v_pad] } else { [1, 1] };

        // Edge index tensors; padded slots point at node 0 and are masked.
        let mut esrc = vec![0i32; e_pad];
        let mut edst = vec![0i32; e_pad];
        let mut emask = vec![0f32; e_pad];
        for (i, &(s, t)) in wg.edges.iter().enumerate() {
            esrc[i] = s as i32;
            edst[i] = t as i32;
            emask[i] = 1.0;
        }
        let mut nmask = vec![0f32; v_pad];
        for m in nmask.iter_mut().take(wg.n()) {
            *m = 1.0;
        }

        // Reward denominator: the testbed's designated reference device.
        let ref_latency =
            execute(&graph, &Placement::all(graph.n(), testbed.reference), &testbed).makespan;

        let x0_t = Tensor::f32(&[v_pad, d], x0);
        let a_norm_t = Tensor::f32(&a_dims, a_norm);
        let esrc_t = Tensor::i32(&[e_pad], esrc);
        let edst_t = Tensor::i32(&[e_pad], edst);
        let nmask_t = Tensor::f32(&[v_pad], nmask);
        let emask_t = Tensor::f32(&[e_pad], emask);
        let lit = EnvLiterals {
            x0: x0_t.to_literal()?,
            a_norm: a_norm_t.to_literal()?,
            edge_src: esrc_t.to_literal()?,
            edge_dst: edst_t.to_literal()?,
            node_mask: nmask_t.to_literal()?,
            edge_mask: emask_t.to_literal()?,
        };

        Ok(Env {
            workload: info,
            n_nodes: wg.n(),
            n_edges: wg.m(),
            features,
            colo,
            levels,
            graph,
            testbed,
            cost: Box::new(AnalyticCostModel),
            v_pad,
            e_pad,
            x0: x0_t,
            a_norm: a_norm_t,
            edge_src: esrc_t,
            edge_dst: edst_t,
            node_mask: nmask_t,
            edge_mask: emask_t,
            ref_latency,
            lit,
        })
    }

    /// The paper benchmark whose AOT artifact family covers this env —
    /// an error for registry workloads without one (the pjrt backend's
    /// construction path; the native backend never asks).
    pub fn artifact_bench(&self) -> Result<Benchmark> {
        self.workload.bench.ok_or_else(|| {
            anyhow!(
                "workload '{}' has no AOT artifacts (only the paper benchmarks do) — \
                 use --backend native",
                self.workload.spec
            )
        })
    }

    /// The working graph the policy sees.
    pub fn working_graph(&self) -> &CompGraph {
        &self.colo.coarse
    }

    /// Size of the per-group action space (number of placement targets).
    pub fn n_actions(&self) -> usize {
        self.testbed.n_actions()
    }

    /// Expand a working-graph placement (action indices) to a full
    /// original-node placement (simulator device ids). Errors on a
    /// mis-sized action vector or an action outside the testbed's
    /// placeable range.
    pub fn expand(&self, working_actions: &[usize]) -> Result<Placement> {
        let nd = self.n_actions();
        let devices: Vec<usize> = working_actions
            .iter()
            .map(|&a| {
                if a < nd {
                    Ok(self.testbed.action_device(a))
                } else {
                    Err(anyhow!(
                        "action {a} out of range for testbed '{}' ({nd} placement targets)",
                        self.testbed.id
                    ))
                }
            })
            .collect::<Result<_>>()?;
        Ok(Placement(self.colo.expand_placement(&devices)?))
    }

    /// Swap the placement cost model (default: [`AnalyticCostModel`]).
    /// The reference latency is re-derived under the new model so rewards
    /// stay consistently normalized.
    pub fn set_cost_model(&mut self, model: Box<dyn CostModel>) {
        let all_ref = Placement::all(self.graph.n(), self.testbed.reference);
        self.ref_latency = model.evaluate(&self.graph, &all_ref, &self.testbed).makespan;
        self.cost = model;
    }

    /// Full simulator report for a working-graph placement: latency, busy
    /// time, transfer volume, memory high-water, feasibility.
    pub fn report(&self, working_actions: &[usize]) -> Result<ExecReport> {
        Ok(self.cost.evaluate(&self.graph, &self.expand(working_actions)?, &self.testbed))
    }

    /// Batched [`Env::report`]: expand every working-graph placement,
    /// then simulate them through one [`CostModel::evaluate_many`] call —
    /// the configured [`ParallelCostModel`] spreads the batch over the
    /// worker pool, and each report is element-wise identical to a serial
    /// `report` call on the same placement.
    pub fn report_many(&self, working_actions: &[&[usize]]) -> Result<Vec<ExecReport>> {
        let placements = working_actions
            .iter()
            .map(|a| self.expand(a))
            .collect::<Result<Vec<_>>>()?;
        Ok(self.cost.evaluate_many(&self.graph, &placements, &self.testbed))
    }

    /// Whether a placement fits every device's memory capacity. Always
    /// true on the unbounded default testbeds.
    pub fn feasible(&self, working_actions: &[usize]) -> Result<bool> {
        Ok(self.report(working_actions)?.feasible())
    }

    /// Deterministic latency of a working-graph placement.
    pub fn latency(&self, working_actions: &[usize]) -> Result<f64> {
        Ok(self.report(working_actions)?.makespan)
    }

    /// Measured latency (paper's 10-run protocol with noise).
    pub fn measured_latency(
        &self,
        working_actions: &[usize],
        sigma: f64,
        rng: &mut Rng,
    ) -> Result<f64> {
        Ok(measure_from(self.latency(working_actions)?, sigma, rng))
    }

    /// Reward (the paper's r = 1/l, normalized by the reference device so
    /// rewards sit in a sane range: r = l_ref / l = speedup factor).
    pub fn reward(&self, latency: f64) -> f64 {
        self.ref_latency / latency
    }

    /// Search-time reward of a simulated step: feasible placements earn
    /// the normalized speedup reward, infeasible (OOM) ones earn the flat
    /// `oom_penalty` instead of a latency-based score (`Config::oom_penalty`;
    /// the Mirhoseini-style handling of placements that fail to run).
    /// Pass a non-positive penalty to rank OOM strictly below every
    /// feasible placement — a positive value acts as a reward floor.
    pub fn reward_with_penalty(&self, report: &ExecReport, latency: f64, oom_penalty: f64) -> f64 {
        if report.feasible() {
            self.reward(latency)
        } else {
            oom_penalty
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DGPU;

    fn env(bench: Benchmark) -> Env {
        Env::new(bench, &Config::default()).unwrap()
    }

    fn env_on(bench: Benchmark, testbed_id: &str) -> Env {
        let cfg = Config { testbed: testbed_id.to_string(), ..Config::default() };
        Env::new(bench, &cfg).unwrap()
    }

    #[test]
    fn all_benchmarks_fit_padding() {
        for b in Benchmark::ALL {
            let e = env(b);
            assert!(e.n_nodes <= e.v_pad, "{}", b.id());
            assert!(e.n_edges <= e.e_pad, "{}", b.id());
            assert!(e.n_nodes > 16, "{}: coarsening degenerate", b.id());
            assert_eq!(e.workload.bench, Some(b));
            assert_eq!(e.workload.spec, b.id());
            assert_eq!(e.artifact_bench().unwrap(), b);
            // Artifact-backed envs keep the dense adjacency the pjrt
            // backend feeds to the AOT'd policies.
            assert_eq!(e.a_norm.dims(), &[e.v_pad, e.v_pad]);
        }
    }

    #[test]
    fn registry_workload_envs_pad_dynamically() {
        let cfg = Config::default();
        let w = Workload::resolve("layered:5x4:2").unwrap();
        let e = Env::for_workload(w, &cfg).unwrap();
        assert!(e.workload.bench.is_none());
        assert!(e.artifact_bench().is_err());
        assert_eq!(e.v_pad % 64, 0);
        assert_eq!(e.e_pad % 64, 0);
        assert!(e.v_pad >= e.n_nodes && e.e_pad >= e.n_edges);
        assert!(e.ref_latency > 0.0);
        // No artifact bench -> the dense adjacency is a placeholder (the
        // native backend message-passes over sparse COO instead).
        assert_eq!(e.a_norm.numel(), 1);
        // The placement pipeline works end to end on a non-paper graph.
        let lat = e.latency(&vec![1; e.n_nodes]).unwrap();
        assert!(lat.is_finite() && lat > 0.0);
    }

    #[test]
    fn chain_workload_coarsens_to_one_group() {
        let cfg = Config::default();
        let e = Env::for_workload(Workload::resolve("seq:32").unwrap(), &cfg).unwrap();
        assert_eq!(e.n_nodes, 1, "a pure chain is one co-location set");
        assert_eq!(e.n_edges, 0);
        assert_eq!(e.e_pad, 64, "zero-edge graphs keep a non-empty edge capacity");
        let lat = e.latency(&[1]).unwrap();
        assert!(lat < e.ref_latency, "all-on-accelerator beats the reference CPU");
    }

    #[test]
    fn multi_level_budget_bounds_the_working_graph() {
        let cfg = Config { coarsen_budget: 64, ..Config::default() };
        let e = Env::for_workload(Workload::resolve("layered:48x24:7").unwrap(), &cfg).unwrap();
        assert!(e.n_nodes <= 64, "working graph has {} nodes", e.n_nodes);
        assert!(e.levels.n_levels() > 1, "expected a multi-level stack");
        assert_eq!(e.n_nodes, e.levels.n_sets());
        // The flattened expansion still covers every original node, and
        // the whole place path works on the coarsest graph.
        let actions = vec![1usize; e.n_nodes];
        let p = e.expand(&actions).unwrap();
        assert_eq!(p.0.len(), e.graph.n());
        assert!(e.latency(&actions).unwrap().is_finite());
        // Paper benchmarks stay single-level under the default budget, so
        // every artifact-contract test upstream is untouched.
        let e = env(Benchmark::ResNet50);
        assert_eq!(e.levels.n_levels(), 1);
    }

    #[test]
    fn masks_match_sizes() {
        let e = env(Benchmark::ResNet50);
        let nm = e.node_mask.as_f32();
        assert_eq!(nm.iter().filter(|&&x| x == 1.0).count(), e.n_nodes);
        let em = e.edge_mask.as_f32();
        assert_eq!(em.iter().filter(|&&x| x == 1.0).count(), e.n_edges);
    }

    #[test]
    fn expand_roundtrip_covers_all_nodes() {
        let e = env(Benchmark::ResNet50);
        let actions = vec![1usize; e.n_nodes];
        let p = e.expand(&actions).unwrap();
        assert_eq!(p.0.len(), e.graph.n());
        assert!(p.0.iter().all(|&d| d == DGPU));
    }

    #[test]
    fn mis_sized_or_out_of_range_actions_are_errors() {
        let e = env(Benchmark::ResNet50);
        // Wrong length: error mentions the set counts, no panic.
        let err = e.expand(&vec![0; e.n_nodes + 5]).unwrap_err();
        assert!(format!("{err:#}").contains("co-location sets"), "{err:#}");
        assert!(e.latency(&vec![0; e.n_nodes + 5]).is_err());
        assert!(e.report(&[]).is_err());
        // Action index beyond the testbed's width.
        let mut actions = vec![0usize; e.n_nodes];
        actions[0] = 99;
        let err = e.expand(&actions).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }

    #[test]
    fn all_reference_actions_reproduce_reference_latency() {
        let e = env(Benchmark::InceptionV3);
        let lat = e.latency(&vec![0; e.n_nodes]).unwrap();
        assert!((lat - e.ref_latency).abs() / e.ref_latency < 1e-9);
        assert!((e.reward(lat) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_actions_beat_cpu_on_bert() {
        let e = env(Benchmark::BertBase);
        let lat = e.latency(&vec![1; e.n_nodes]).unwrap();
        assert!(lat < e.ref_latency);
        assert!(e.reward(lat) > 1.5);
    }

    #[test]
    fn default_env_uses_two_actions() {
        let e = env(Benchmark::ResNet50);
        assert_eq!(e.n_actions(), 2);
        assert_eq!(e.testbed.id, "cpu_gpu");
    }

    #[test]
    fn paper3_env_widens_action_space() {
        let e = env_on(Benchmark::ResNet50, "paper3");
        assert_eq!(e.n_actions(), 3);
        // Action 1 is the iGPU on paper3; every expanded device must be a
        // valid testbed device.
        let actions: Vec<usize> = (0..e.n_nodes).map(|v| v % 3).collect();
        let p = e.expand(&actions).unwrap();
        assert!(p.0.iter().all(|&d| d < e.testbed.n_devices()));
        assert!(e.latency(&actions).unwrap().is_finite());
    }

    #[test]
    fn multi_gpu_env_places_on_k_devices() {
        let e = env_on(Benchmark::ResNet50, "multi_gpu:3");
        assert_eq!(e.n_actions(), 4); // CPU + 3 GPUs
        let actions: Vec<usize> = (0..e.n_nodes).map(|v| v % e.n_actions()).collect();
        let lat = e.latency(&actions).unwrap();
        assert!(lat.is_finite() && lat > 0.0);
        // Reference is still the CPU.
        let cpu = e.latency(&vec![0; e.n_nodes]).unwrap();
        assert!((cpu - e.ref_latency).abs() / e.ref_latency < 1e-9);
    }

    #[test]
    fn default_testbed_everything_feasible() {
        let e = env(Benchmark::ResNet50);
        for actions in [vec![0usize; e.n_nodes], vec![1usize; e.n_nodes]] {
            let rep = e.report(&actions).unwrap();
            assert!(rep.feasible());
            assert!(e.feasible(&actions).unwrap());
            assert_eq!(rep.mem_peak.len(), e.testbed.n_devices());
            assert_eq!(rep.makespan, e.latency(&actions).unwrap());
        }
    }

    #[test]
    fn tight_testbed_flags_oom_and_applies_penalty() {
        let e = env_on(Benchmark::BertBase, "cpu_gpu_tight");
        // All-accelerator: the model's weights dwarf the 64 MB dGPU.
        let gpu_actions = vec![1usize; e.n_nodes];
        let rep = e.report(&gpu_actions).unwrap();
        assert!(!rep.feasible());
        assert!(!e.feasible(&gpu_actions).unwrap());
        assert_eq!(e.reward_with_penalty(&rep, rep.makespan, 0.25), 0.25);
        // All-CPU is feasible and earns the normal (reference) reward.
        let cpu_actions = vec![0usize; e.n_nodes];
        let rep = e.report(&cpu_actions).unwrap();
        assert!(rep.feasible());
        let r = e.reward_with_penalty(&rep, rep.makespan, 0.25);
        assert!((r - 1.0).abs() < 1e-9, "{r}");
    }

    #[test]
    fn cost_model_is_swappable() {
        use crate::sim::ReferenceCostModel;
        let mut e = env(Benchmark::InceptionV3);
        let actions: Vec<usize> = (0..e.n_nodes).map(|v| v % 2).collect();
        let before = e.latency(&actions).unwrap();
        let ref_before = e.ref_latency;
        e.set_cost_model(Box::new(ReferenceCostModel));
        // The reference scheduler is differential-tested bit-identical.
        assert_eq!(e.latency(&actions).unwrap(), before);
        assert_eq!(e.ref_latency, ref_before);
    }

    #[test]
    fn unknown_testbed_id_is_an_error() {
        let cfg = Config { testbed: "tpu_pod".to_string(), ..Config::default() };
        let err = Env::new(Benchmark::ResNet50, &cfg);
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("tpu_pod"), "{msg}");
    }
}

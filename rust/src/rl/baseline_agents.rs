//! Learned baseline agents: Placeto (GNN encoder-placer) and the
//! RNN-based grouper-placer of Mirhoseini et al. — both re-implemented (as
//! the paper itself did, §4 Limitations) and driven by the same rust RL
//! loop and simulator, with their own AOT'd fwd/train artifacts.

use anyhow::{Context, Result};

use super::env::Env;
use super::hsdag::{argmax, mean_entropy, sample_softmax, StepOutcome};
use super::search::{reinforce_coefficients, SearchResult, Tracker};
use crate::config::Config;
use crate::runtime::{Engine, ParamStore, Tensor};
use crate::sim::measure_from;
use crate::util::stats::Ema;
use crate::util::Rng;

/// Which baseline policy this agent runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// GNN encoder-placer (Placeto-like): per-node device logits.
    Placeto,
    /// Attentional seq2seq LSTM (RNN-based): per-node logits over the
    /// topological order.
    Rnn,
}

impl BaselineKind {
    pub fn id(self) -> &'static str {
        match self {
            BaselineKind::Placeto => "placeto",
            BaselineKind::Rnn => "rnn",
        }
    }
}

/// A per-node-policy agent (covers both baselines; they differ only in
/// artifacts and input assembly).
pub struct BaselineAgent {
    pub kind: BaselineKind,
    pub cfg: Config,
    pub params: ParamStore,
    actions_buf: Vec<i32>, // [T, V]
    rewards: Vec<f64>,
    baseline: Ema,
    rng: Rng,
    fwd_name: String,
    train_name: String,
    /// RNN only: features permuted into topological order.
    x0_topo: Option<Tensor>,
    /// RNN only: topo position -> working-graph node id.
    topo_to_node: Vec<usize>,
}

impl BaselineAgent {
    pub fn new(env: &Env, engine: &mut Engine, cfg: &Config, kind: BaselineKind) -> Result<BaselineAgent> {
        let bench = env.artifact_bench()?.id();
        let train_name = format!("{bench}_{}_train", kind.id());
        let train = engine.load(&train_name).context("loading baseline train artifact")?;
        anyhow::ensure!(train.spec.v == env.v_pad, "artifact V mismatch");
        let artifact_nd = train.spec.nd_or_legacy();
        anyhow::ensure!(
            artifact_nd == env.n_actions(),
            "artifact lowered for {} devices but testbed '{}' exposes {} placement targets \
             (re-run `make artifacts` with ND={})",
            artifact_nd,
            env.testbed.id,
            env.n_actions(),
            env.n_actions()
        );
        let mut rng = Rng::new(cfg.seed ^ 0xBA5E);
        let params = ParamStore::init_from_spec(&train.spec, &mut rng)?;

        // RNN wants the feature rows in topological order.
        let (x0_topo, topo_to_node) = if kind == BaselineKind::Rnn {
            let wg = env.working_graph();
            let order = wg.topo_order().expect("DAG");
            let d = env.x0.dims()[1];
            let src = env.x0.as_f32();
            let mut x = vec![0f32; env.v_pad * d];
            for (pos, &node) in order.iter().enumerate() {
                x[pos * d..(pos + 1) * d].copy_from_slice(&src[node * d..(node + 1) * d]);
            }
            (Some(Tensor::f32(&[env.v_pad, d], x)), order)
        } else {
            (None, Vec::new())
        };

        Ok(BaselineAgent {
            kind,
            cfg: cfg.clone(),
            params,
            actions_buf: vec![0; cfg.update_timestep * env.v_pad],
            rewards: Vec::new(),
            baseline: Ema::new(0.1),
            rng,
            fwd_name: format!("{bench}_{}_fwd", kind.id()),
            train_name,
            x0_topo,
            topo_to_node,
        })
    }

    fn fwd_inputs(&self, env: &Env) -> Vec<Tensor> {
        let mut inputs = self.params.params.clone();
        match self.kind {
            BaselineKind::Placeto => {
                inputs.push(env.x0.clone());
                inputs.push(env.a_norm.clone());
                inputs.push(env.node_mask.clone());
            }
            BaselineKind::Rnn => {
                inputs.push(self.x0_topo.clone().expect("rnn x0"));
                inputs.push(env.node_mask.clone());
            }
        }
        inputs
    }

    /// One step: sample a device per node, simulate, buffer. Infeasible
    /// (OOM) placements earn `Config::oom_penalty` as their reward.
    pub fn step(&mut self, env: &Env, engine: &mut Engine, explore: bool) -> Result<StepOutcome> {
        let fwd = engine.load(&self.fwd_name)?;
        let outs = fwd.run(&self.fwd_inputs(env))?;
        let logits: Vec<f32> = outs[0].to_vec()?;
        // K-device generalization: row stride follows the env's testbed.
        let nd = env.n_actions();

        // Sample per-node actions in the policy's own node order.
        let mut policy_actions = vec![0usize; env.n_nodes];
        for slot in 0..env.n_nodes {
            let row = &logits[slot * nd..(slot + 1) * nd];
            policy_actions[slot] = if explore {
                sample_softmax(row, self.cfg.temperature, &mut self.rng)
            } else {
                argmax(row)
            };
        }
        // Map to working-graph node order (RNN logits are topo-ordered).
        let actions: Vec<usize> = match self.kind {
            BaselineKind::Placeto => policy_actions.clone(),
            BaselineKind::Rnn => {
                let mut a = vec![0usize; env.n_nodes];
                for (pos, &node) in self.topo_to_node.iter().enumerate().take(env.n_nodes) {
                    a[node] = policy_actions[pos];
                }
                a
            }
        };

        let report = env.report(&actions)?;
        let feasible = report.feasible();
        let latency = if explore && self.cfg.measure_sigma > 0.0 {
            measure_from(report.makespan, self.cfg.measure_sigma, &mut self.rng)
        } else {
            report.makespan
        };
        let reward = env.reward_with_penalty(&report, latency, self.cfg.oom_penalty);

        if explore {
            let t = self.rewards.len();
            let v = env.v_pad;
            for (slot, &a) in policy_actions.iter().enumerate() {
                self.actions_buf[t * v + slot] = a as i32;
            }
            self.rewards.push(reward);
        }
        Ok(StepOutcome {
            n_groups: actions.len(),
            entropy: mean_entropy(&logits, env.n_nodes, nd, self.cfg.temperature),
            actions,
            latency,
            det_latency: report.makespan,
            reward,
            feasible,
        })
    }

    /// REINFORCE update through the train artifact.
    pub fn update(&mut self, env: &Env, engine: &mut Engine) -> Result<Option<f32>> {
        if self.rewards.is_empty() {
            return Ok(None);
        }
        let t_cap = self.cfg.update_timestep;
        let used = self.rewards.len();
        let mut rewards = self.rewards.clone();
        rewards.resize(t_cap, 0.0);
        let mut coeff = reinforce_coefficients(
            &rewards,
            self.cfg.gamma,
            if self.cfg.use_baseline { Some(&mut self.baseline) } else { None },
        );
        for c in coeff.iter_mut().skip(used) {
            *c = 0.0;
        }

        let v = env.v_pad;
        let mut inputs = self.params.train_prefix();
        match self.kind {
            BaselineKind::Placeto => {
                inputs.push(env.x0.clone());
                inputs.push(env.a_norm.clone());
                inputs.push(env.node_mask.clone());
            }
            BaselineKind::Rnn => {
                inputs.push(self.x0_topo.clone().expect("rnn x0"));
                inputs.push(env.node_mask.clone());
            }
        }
        inputs.push(Tensor::i32(&[t_cap, v], self.actions_buf.clone()));
        inputs.push(Tensor::f32(&[t_cap], coeff));
        let train = engine.load(&self.train_name)?;
        let outs = train.run(&inputs)?;
        let loss = self.params.apply_train_outputs(&outs)?;
        self.rewards.clear();
        self.actions_buf.iter_mut().for_each(|a| *a = 0);
        Ok(Some(loss))
    }

    /// Full search loop (same protocol as the HSDAG agent).
    pub fn search(&mut self, env: &Env, engine: &mut Engine, episodes: usize) -> Result<SearchResult> {
        let start = std::time::Instant::now();
        let mut tracker = Tracker::new();
        for ep in 0..episodes {
            for _ in 0..self.cfg.update_timestep {
                let o = self.step(env, engine, true)?;
                // Infeasible (OOM) placements never become "best".
                let det = if o.feasible { o.det_latency } else { f64::INFINITY };
                tracker.observe(&o.actions, det, o.reward);
                tracker.observe_entropy(o.entropy);
            }
            if let Some(loss) = self.update(env, engine)? {
                tracker.record_loss(loss as f64);
                tracker.record_param_norm(self.params.l2_norm());
            }
            tracker.end_episode(ep);
        }
        let o = self.step(env, engine, false)?;
        let det = if o.feasible { o.det_latency } else { f64::INFINITY };
        tracker.observe(&o.actions, det, o.reward);

        // The RNN's attention matrix is the memory hog the paper's Table 5
        // reports as OOM on BERT: [V, V] attention + LSTM states per
        // buffered step.
        let attn_bytes = if self.kind == BaselineKind::Rnn {
            env.v_pad * env.v_pad * 4 * self.cfg.update_timestep * 3
        } else {
            0
        };
        let peak = self.actions_buf.len() * 4
            + env.v_pad * env.v_pad * 4
            + self.params.n_scalars() * 12
            + attn_bytes;
        Ok(tracker.finish(start.elapsed().as_secs_f64(), peak))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_ids() {
        assert_eq!(BaselineKind::Placeto.id(), "placeto");
        assert_eq!(BaselineKind::Rnn.id(), "rnn");
    }
}

//! Policy backends: one trait, two implementations.
//!
//! [`PolicyBackend`] is the neural-compute boundary of the HSDAG agent —
//! three calls per Algorithm-1 step family:
//!
//! - `fwd`    — node embeddings Z + GPN edge scores S from the evolving
//!   feedback state;
//! - `placer` — per-group device logits after rust's discrete parse;
//! - `train`  — one Eq. 14 REINFORCE/Adam update over a buffered window.
//!
//! [`PjrtBackend`] executes the AOT-compiled HLO artifacts through the
//! PJRT [`Engine`] (the paper-faithful JAX/Pallas path; requires
//! `artifacts/` and a real xla crate). [`NativeBackend`] runs the same
//! model with the pure-rust kernels in [`crate::runtime::nn`] — no
//! artifacts, no python, works everywhere, at the real (unpadded)
//! working-graph sizes.
//!
//! [`BackendFactory`] resolves `--backend {native,pjrt,auto}` (auto picks
//! pjrt exactly when the artifacts directory holds compiled
//! `*.hlo.txt` artifacts) and constructs the
//! PJRT engine *lazily*, only when a pjrt backend is actually requested —
//! baseline-only and native runs never touch `artifacts/`.

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::env::Env;
use crate::config::Config;
use crate::runtime::{Engine, NativeBatch, NativePolicy, ParamStore, Tensor};
use crate::util::Rng;

/// Resolved backend flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust kernels (`runtime::nn`), no artifacts needed.
    Native,
    /// AOT HLO artifacts executed through the PJRT engine.
    Pjrt,
}

impl BackendKind {
    pub fn id(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Resolve a requested backend string (`native` | `pjrt` | `auto`).
    /// `auto` selects pjrt exactly when `artifacts_dir` holds at least
    /// one compiled artifact (`*.hlo.txt`), native otherwise — a merely
    /// existing (empty or stale) directory still trains out of the box.
    pub fn resolve(request: &str, artifacts_dir: &str) -> Result<BackendKind> {
        match request {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            "auto" | "" => Ok(if dir_has_artifacts(artifacts_dir) {
                BackendKind::Pjrt
            } else {
                BackendKind::Native
            }),
            other => bail!("unknown backend '{other}' (known: native | pjrt | auto)"),
        }
    }
}

/// Whether a directory holds at least one compiled HLO artifact.
fn dir_has_artifacts(dir: &str) -> bool {
    std::fs::read_dir(dir)
        .map(|entries| {
            entries
                .flatten()
                .any(|e| e.file_name().to_string_lossy().ends_with(".hlo.txt"))
        })
        .unwrap_or(false)
}

/// Output of one policy forward pass. `z` has at least `env.n_nodes` rows
/// of width `hidden`; `scores` covers exactly the real edges.
pub struct PolicyFwd {
    pub z: Vec<f32>,
    pub scores: Vec<f32>,
    /// PJRT keeps the device literal of Z so the placer can reuse it
    /// without a host round-trip.
    z_lit: Option<xla::Literal>,
}

/// One buffered Eq. 14 window, in the agent's padded-slot layout
/// (`v` = padded node slots, `e` = padded edge slots).
pub struct TrainBatch<'a> {
    pub t: usize,
    pub v: usize,
    pub e: usize,
    /// Feedback state each step's forward saw, `[t, v, hidden]`.
    pub fb: &'a [f32],
    /// Group id per node, `[t, v]`.
    pub cids: &'a [i32],
    /// Sampled device per group slot, `[t, v]`.
    pub actions: &'a [i32],
    /// Valid-group-slot mask, `[t, v]`.
    pub gmask: &'a [f32],
    /// Retained-edge (Eq. 9) mask, `[t, e]`.
    pub retained: &'a [f32],
    /// gamma^t · (r_t − baseline) coefficients, `[t]`.
    pub coeff: &'a [f32],
    /// Dropout key (two u32 halves, the artifact convention).
    pub key: [u32; 2],
}

/// The neural-compute boundary of the HSDAG agent.
pub trait PolicyBackend {
    fn kind(&self) -> BackendKind;

    /// Human-readable identity for logs (platform, mode).
    fn describe(&self) -> String;

    /// The policy parameters + optimizer state (diagnostics, Table 5
    /// memory accounting).
    fn params(&self) -> &ParamStore;

    /// Forward: Z + edge scores from the feedback state `fb`
    /// (`[v_pad, hidden]` row-major; backends may read only the real
    /// rows).
    fn fwd(&mut self, env: &Env, fb: &[f32]) -> Result<PolicyFwd>;

    /// Placer: device logits per group slot, row-major with stride
    /// `env.n_actions()`; at least `n_groups` valid rows.
    fn placer(
        &mut self,
        env: &Env,
        fwd: &PolicyFwd,
        cids: &[i32],
        gmask: &[f32],
    ) -> Result<Vec<f32>>;

    /// Batched forward over B independent feedback states. Backends that
    /// can stack the weight passes (native) override this; the default
    /// just loops. Results are element-wise identical to B [`Self::fwd`]
    /// calls.
    fn fwd_many(&mut self, env: &Env, fbs: &[&[f32]]) -> Result<Vec<PolicyFwd>> {
        fbs.iter().map(|fb| self.fwd(env, fb)).collect()
    }

    /// Batched placer over B rollouts (each with its own partition, and
    /// possibly its own forward). Element-wise identical to B
    /// [`Self::placer`] calls; the native backend runs the head as one
    /// stacked `[Σ groups, h]` weight pass.
    fn placer_many(
        &mut self,
        env: &Env,
        fwds: &[&PolicyFwd],
        cids: &[&[i32]],
        gmasks: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        (0..fwds.len()).map(|i| self.placer(env, fwds[i], cids[i], gmasks[i])).collect()
    }

    /// One Eq. 14 REINFORCE/Adam update over `batch`. Returns the loss.
    fn train(&mut self, env: &Env, batch: &TrainBatch) -> Result<f32>;

    /// Snapshot the parameters + optimizer state. The HSDAG layout is
    /// graph-independent (it depends only on feature width, hidden size
    /// and action count), which is what lets one policy train across
    /// workloads (the generalization harness) by exporting here and
    /// importing into a backend bound to a different graph.
    fn export_params(&self) -> ParamStore;

    /// Install a parameter snapshot taken by [`PolicyBackend::export_params`]
    /// on a layout-compatible backend. Errors on a tensor-shape mismatch
    /// (different hidden size or action-space width).
    fn import_params(&mut self, snapshot: &ParamStore) -> Result<()>;
}

/// Shape-check a snapshot against a backend's current parameter layout.
fn check_layout(current: &ParamStore, snapshot: &ParamStore) -> Result<()> {
    anyhow::ensure!(
        snapshot.params.len() == current.params.len(),
        "parameter snapshot has {} tensors, backend wants {}",
        snapshot.params.len(),
        current.params.len()
    );
    for (i, (a, b)) in current.params.iter().zip(snapshot.params.iter()).enumerate() {
        anyhow::ensure!(
            a.dims() == b.dims(),
            "parameter {i} ('{}') shape mismatch: snapshot {:?}, backend {:?} — the snapshot \
             was trained at a different hidden size or action-space width",
            current.names.get(i).map(String::as_str).unwrap_or("?"),
            b.dims(),
            a.dims()
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Pure-rust backend: the `runtime::nn` HSDAG policy bound to one
/// environment's working graph.
pub struct NativeBackend {
    policy: NativePolicy,
    hidden: usize,
}

impl NativeBackend {
    pub fn new(env: &Env, cfg: &Config) -> Result<NativeBackend> {
        let mut rng = Rng::new(cfg.seed ^ 0x45DA6);
        let wg = env.working_graph();
        let mut policy = NativePolicy::new(
            env.features.x.clone(),
            env.n_nodes,
            env.features.d,
            wg.edges.clone(),
            cfg.hidden,
            env.n_actions(),
            cfg.learning_rate,
            &mut rng,
        )?;
        // `--fast-math` rides the config into the kernels; from_snapshot
        // inherits it too since it constructs through here.
        policy.set_fast_math(cfg.fast_math);
        Ok(NativeBackend { policy, hidden: cfg.hidden })
    }

    /// Construct bound to `env` with the parameters (and Adam state) of a
    /// previously exported snapshot installed in place of fresh Glorot
    /// draws — the construct-from-checkpoint path used by `--load` and
    /// the placement server. Errors (clearly, never panics) when the
    /// snapshot's layout disagrees with this env/config's hidden size or
    /// action-space width.
    pub fn from_snapshot(env: &Env, cfg: &Config, snapshot: &ParamStore) -> Result<NativeBackend> {
        let mut backend = NativeBackend::new(env, cfg)?;
        backend
            .import_params(snapshot)
            .context("installing checkpoint parameters on the native backend")?;
        Ok(backend)
    }

    /// The underlying policy (benches probe the kernels directly).
    pub fn policy(&self) -> &NativePolicy {
        &self.policy
    }

    pub fn policy_mut(&mut self) -> &mut NativePolicy {
        &mut self.policy
    }
}

impl PolicyBackend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn describe(&self) -> String {
        format!(
            "native (pure-rust kernels, {} params, hidden {})",
            self.policy.params().n_scalars(),
            self.hidden
        )
    }

    fn params(&self) -> &ParamStore {
        self.policy.params()
    }

    fn fwd(&mut self, _env: &Env, fb: &[f32]) -> Result<PolicyFwd> {
        let (z, scores) = self.policy.fwd(fb);
        Ok(PolicyFwd { z, scores, z_lit: None })
    }

    fn fwd_many(&mut self, _env: &Env, fbs: &[&[f32]]) -> Result<Vec<PolicyFwd>> {
        Ok(self
            .policy
            .fwd_many(fbs)
            .into_iter()
            .map(|(z, scores)| PolicyFwd { z, scores, z_lit: None })
            .collect())
    }

    fn placer(
        &mut self,
        _env: &Env,
        fwd: &PolicyFwd,
        cids: &[i32],
        gmask: &[f32],
    ) -> Result<Vec<f32>> {
        Ok(self.policy.placer(&fwd.z, cids, gmask))
    }

    fn placer_many(
        &mut self,
        _env: &Env,
        fwds: &[&PolicyFwd],
        cids: &[&[i32]],
        gmasks: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let zs: Vec<&[f32]> = fwds.iter().map(|f| f.z.as_slice()).collect();
        Ok(self.policy.placer_many(&zs, cids, gmasks))
    }

    fn train(&mut self, _env: &Env, batch: &TrainBatch) -> Result<f32> {
        let native = NativeBatch {
            t: batch.t,
            v_stride: batch.v,
            e_stride: batch.e,
            fb: batch.fb,
            cids: batch.cids,
            actions: batch.actions,
            gmask: batch.gmask,
            retained: batch.retained,
            coeff: batch.coeff,
            key: batch.key,
        };
        self.policy.train(&native)
    }

    fn export_params(&self) -> ParamStore {
        self.policy.params().clone()
    }

    fn import_params(&mut self, snapshot: &ParamStore) -> Result<()> {
        check_layout(self.policy.params(), snapshot)?;
        // set_params bumps the policy's version counter, invalidating the
        // memoized input-MLP activations.
        self.policy.set_params(snapshot.clone());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Artifact-executing backend: the pre-refactor engine path, now behind
/// the trait. The engine is shared (`Rc<RefCell<_>>`) so one harness run
/// compiles each artifact once across agents.
pub struct PjrtBackend {
    engine: Rc<RefCell<Engine>>,
    params: ParamStore,
    param_lits: Vec<xla::Literal>,
    lits_dirty: bool,
    hidden: usize,
    fwd_name: String,
    placer_name: String,
    train_name: String,
}

impl PjrtBackend {
    pub fn new(engine: Rc<RefCell<Engine>>, env: &Env, cfg: &Config) -> Result<PjrtBackend> {
        // Artifacts exist per paper benchmark; registry workloads without
        // one can only run on the native backend.
        let bench = env.artifact_bench()?.id();
        let train_name = format!("{bench}_hsdag_train");
        {
            let mut eng = engine.borrow_mut();
            let train = eng.load(&train_name).context("loading train artifact")?;
            anyhow::ensure!(train.spec.v == env.v_pad, "artifact V mismatch");
            anyhow::ensure!(train.spec.e == env.e_pad, "artifact E mismatch");
            anyhow::ensure!(train.spec.t == cfg.update_timestep, "artifact T mismatch");
            // The placer head's logit width must match the testbed's
            // action space.
            let artifact_nd = train.spec.nd_or_legacy();
            anyhow::ensure!(
                artifact_nd == env.n_actions(),
                "artifact lowered for {} devices but testbed '{}' exposes {} placement targets \
                 (re-run `make artifacts` with ND={})",
                artifact_nd,
                env.testbed.id,
                env.n_actions(),
                env.n_actions()
            );
        }
        anyhow::ensure!(
            cfg.hidden == 128,
            "the AOT artifacts are lowered at hidden=128 (got --hidden {})",
            cfg.hidden
        );
        let mut rng = Rng::new(cfg.seed ^ 0x45DA6);
        let params = {
            let mut eng = engine.borrow_mut();
            let train = eng.load(&train_name)?;
            ParamStore::init_from_spec(&train.spec, &mut rng)?
        };
        let param_lits = params
            .params
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(PjrtBackend {
            engine,
            params,
            param_lits,
            lits_dirty: false,
            hidden: cfg.hidden,
            fwd_name: format!("{bench}_hsdag_fwd"),
            placer_name: format!("{bench}_hsdag_placer"),
            train_name,
        })
    }

    /// Refresh the cached parameter literals after a train step.
    fn refresh_lits(&mut self) -> Result<()> {
        if self.lits_dirty {
            self.param_lits = self
                .params
                .params
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?;
            self.lits_dirty = false;
        }
        Ok(())
    }
}

impl PolicyBackend for PjrtBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn describe(&self) -> String {
        format!("pjrt ({})", self.engine.borrow().platform())
    }

    fn params(&self) -> &ParamStore {
        &self.params
    }

    fn fwd(&mut self, env: &Env, fb: &[f32]) -> Result<PolicyFwd> {
        self.refresh_lits()?;
        // Constant tensors (params between updates, features, adjacency)
        // go in as cached literals; only the evolving feedback state is
        // serialized per step.
        let fb_lit = Tensor::f32(&[env.v_pad, self.hidden], fb.to_vec()).to_literal()?;
        let mut refs: Vec<&xla::Literal> = self.param_lits.iter().collect();
        refs.push(&env.lit.x0);
        refs.push(&env.lit.a_norm);
        refs.push(&fb_lit);
        refs.push(&env.lit.edge_src);
        refs.push(&env.lit.edge_dst);
        refs.push(&env.lit.node_mask);
        let mut eng = self.engine.borrow_mut();
        let fwd = eng.load(&self.fwd_name)?;
        let mut outs = fwd.run_refs(&refs)?;
        let z: Vec<f32> = outs[0].to_vec()?;
        let scores_padded: Vec<f32> = outs[1].to_vec()?;
        let z_lit = outs.swap_remove(0);
        Ok(PolicyFwd {
            z,
            scores: scores_padded[..env.n_edges].to_vec(),
            z_lit: Some(z_lit),
        })
    }

    fn placer(
        &mut self,
        env: &Env,
        fwd: &PolicyFwd,
        cids: &[i32],
        gmask: &[f32],
    ) -> Result<Vec<f32>> {
        self.refresh_lits()?;
        // Z straight from the fwd output when available (no copy).
        let owned_z;
        let z_lit = match &fwd.z_lit {
            Some(lit) => lit,
            None => {
                let mut z = fwd.z.clone();
                z.resize(env.v_pad * self.hidden, 0.0);
                owned_z = Tensor::f32(&[env.v_pad, self.hidden], z).to_literal()?;
                &owned_z
            }
        };
        let cids_lit = Tensor::i32(&[env.v_pad], cids.to_vec()).to_literal()?;
        let gmask_lit = Tensor::f32(&[env.v_pad], gmask.to_vec()).to_literal()?;
        let mut refs: Vec<&xla::Literal> = self.param_lits.iter().collect();
        refs.push(z_lit);
        refs.push(&cids_lit);
        refs.push(&gmask_lit);
        let mut eng = self.engine.borrow_mut();
        let placer = eng.load(&self.placer_name)?;
        let pouts = placer.run_refs(&refs)?;
        Ok(pouts[0].to_vec()?)
    }

    fn export_params(&self) -> ParamStore {
        self.params.clone()
    }

    fn import_params(&mut self, snapshot: &ParamStore) -> Result<()> {
        check_layout(&self.params, snapshot)?;
        self.params = snapshot.clone();
        self.lits_dirty = true;
        Ok(())
    }

    fn train(&mut self, env: &Env, batch: &TrainBatch) -> Result<f32> {
        let (t, v, e, h) = (batch.t, batch.v, batch.e, self.hidden);
        let mut inputs = self.params.train_prefix();
        inputs.push(env.x0.clone());
        inputs.push(env.a_norm.clone());
        inputs.push(env.edge_src.clone());
        inputs.push(env.edge_dst.clone());
        inputs.push(env.node_mask.clone());
        inputs.push(env.edge_mask.clone());
        inputs.push(Tensor::f32(&[t, v, h], batch.fb.to_vec()));
        inputs.push(Tensor::i32(&[t, v], batch.cids.to_vec()));
        inputs.push(Tensor::i32(&[t, v], batch.actions.to_vec()));
        inputs.push(Tensor::f32(&[t, v], batch.gmask.to_vec()));
        inputs.push(Tensor::f32(&[t, e], batch.retained.to_vec()));
        inputs.push(Tensor::f32(&[t], batch.coeff.to_vec()));
        inputs.push(Tensor::u32(&[2], vec![batch.key[0], batch.key[1]]));
        let outs = {
            let mut eng = self.engine.borrow_mut();
            let train = eng.load(&self.train_name)?;
            train.run(&inputs)?
        };
        let loss = self.params.apply_train_outputs(&outs)?;
        self.lits_dirty = true;
        Ok(loss)
    }
}

// ---------------------------------------------------------------------------
// Factory
// ---------------------------------------------------------------------------

/// Resolves the configured backend once and hands out backends per
/// environment. The PJRT engine is constructed lazily on first use and
/// shared across every backend (and baseline agent) of the run, so a
/// native or baseline-only run never requires `artifacts/` to exist.
pub struct BackendFactory {
    kind: BackendKind,
    /// Whether the kind came from an `auto` request: pjrt construction
    /// failures then fall back to the native backend instead of erroring
    /// (artifacts may exist but cover a different benchmark / testbed
    /// width than the one being run).
    auto: bool,
    artifacts_dir: String,
    engine: Option<Rc<RefCell<Engine>>>,
}

impl BackendFactory {
    pub fn new(cfg: &Config) -> Result<BackendFactory> {
        Ok(BackendFactory {
            kind: BackendKind::resolve(&cfg.backend, &cfg.artifacts_dir)?,
            auto: matches!(cfg.backend.as_str(), "auto" | ""),
            artifacts_dir: cfg.artifacts_dir.clone(),
            engine: None,
        })
    }

    /// The resolved backend flavor for this run.
    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    /// The shared PJRT engine, created on first call (errors when the
    /// artifacts directory is missing — callers should only ask for it
    /// when the pjrt backend is selected).
    pub fn engine(&mut self) -> Result<Rc<RefCell<Engine>>> {
        if self.engine.is_none() {
            self.engine = Some(Rc::new(RefCell::new(Engine::cpu(&self.artifacts_dir)?)));
        }
        Ok(self.engine.as_ref().unwrap().clone())
    }

    /// Build a policy backend for one environment. Under an `auto`
    /// request a pjrt backend that cannot construct for *this*
    /// environment (artifacts missing the benchmark, lowered at a
    /// different action-space width, stub xla, ...) falls back to the
    /// native backend with a note; an explicit `--backend pjrt` still
    /// fails hard.
    pub fn create(&mut self, env: &Env, cfg: &Config) -> Result<Box<dyn PolicyBackend>> {
        match self.kind {
            BackendKind::Native => Ok(Box::new(NativeBackend::new(env, cfg)?)),
            BackendKind::Pjrt => {
                let pjrt = self
                    .engine()
                    .and_then(|engine| Ok(Box::new(PjrtBackend::new(engine, env, cfg)?)));
                match pjrt {
                    Ok(backend) => Ok(backend),
                    Err(e) if self.auto => {
                        crate::log_warn!(
                            "note: auto backend falling back to native for {}: {e:#}",
                            env.workload.spec
                        );
                        Ok(Box::new(NativeBackend::new(env, cfg)?))
                    }
                    Err(e) => Err(e),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Benchmark;

    #[test]
    fn backend_kind_resolution() {
        // Explicit requests ignore the artifacts directory.
        assert_eq!(BackendKind::resolve("native", "/nope").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::resolve("pjrt", "/nope").unwrap(), BackendKind::Pjrt);
        // Auto: native without compiled artifacts, pjrt with.
        assert_eq!(
            BackendKind::resolve("auto", "/definitely/not/a/dir").unwrap(),
            BackendKind::Native
        );
        let dir = std::env::temp_dir().join("hsdag_backend_auto_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("x_hsdag_fwd.hlo.txt")).ok();
        // An empty (or stale) directory must NOT force the pjrt path.
        assert_eq!(
            BackendKind::resolve("auto", dir.to_str().unwrap()).unwrap(),
            BackendKind::Native
        );
        std::fs::write(dir.join("x_hsdag_fwd.hlo.txt"), "HloModule x").unwrap();
        assert_eq!(
            BackendKind::resolve("auto", dir.to_str().unwrap()).unwrap(),
            BackendKind::Pjrt
        );
        std::fs::remove_file(dir.join("x_hsdag_fwd.hlo.txt")).ok();
        assert!(BackendKind::resolve("tpu", "x").is_err());
    }

    #[test]
    fn factory_is_lazy_for_native() {
        // A native factory over a missing artifacts dir must construct
        // backends without ever touching the engine.
        let cfg = Config {
            backend: "native".to_string(),
            artifacts_dir: "/definitely/not/a/dir".to_string(),
            hidden: 16,
            ..Config::default()
        };
        let mut factory = BackendFactory::new(&cfg).unwrap();
        assert_eq!(factory.kind(), BackendKind::Native);
        let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
        let backend = factory.create(&env, &cfg).unwrap();
        assert_eq!(backend.kind(), BackendKind::Native);
        assert!(backend.describe().contains("native"));
        assert_eq!(backend.params().n(), 16);
    }

    #[test]
    fn params_roundtrip_across_backends() {
        // Export from a backend bound to one workload, import into a
        // backend bound to a different graph: same layout, so the
        // snapshot transfers verbatim.
        let cfg = Config { backend: "native".to_string(), hidden: 16, ..Config::default() };
        let env_a = Env::new(Benchmark::ResNet50, &cfg).unwrap();
        let backend_a = NativeBackend::new(&env_a, &cfg).unwrap();
        let snap = backend_a.export_params();
        let w = crate::models::Workload::resolve("layered:4x3:1").unwrap();
        let env_b = Env::for_workload(w, &cfg).unwrap();
        let mut backend_b = NativeBackend::new(&env_b, &cfg).unwrap();
        backend_b.import_params(&snap).unwrap();
        for (a, b) in snap.params.iter().zip(backend_b.policy().params().params.iter()) {
            assert_eq!(a.as_f32(), b.as_f32());
        }
        // A snapshot from a different hidden size is rejected.
        let cfg32 = Config { backend: "native".to_string(), hidden: 32, ..Config::default() };
        let backend_c = NativeBackend::new(&env_a, &cfg32).unwrap();
        let err = backend_b.import_params(&backend_c.export_params()).unwrap_err();
        assert!(format!("{err:#}").contains("shape mismatch"), "{err:#}");
    }

    #[test]
    fn from_snapshot_installs_params_or_errors_clearly() {
        let cfg = Config { backend: "native".to_string(), hidden: 16, ..Config::default() };
        let w = crate::models::Workload::resolve("layered:3x3:2").unwrap();
        let env = Env::for_workload(w, &cfg).unwrap();
        let snap = NativeBackend::new(&env, &cfg).unwrap().export_params();
        let restored = NativeBackend::from_snapshot(&env, &cfg, &snap).unwrap();
        for (a, b) in snap.params.iter().zip(restored.policy().params().params.iter()) {
            assert_eq!(a.as_f32(), b.as_f32());
        }
        // Wrong hidden size: a message, not a panic.
        let cfg32 = Config { backend: "native".to_string(), hidden: 32, ..Config::default() };
        let err = NativeBackend::from_snapshot(&env, &cfg32, &snap).unwrap_err();
        assert!(format!("{err:#}").contains("shape mismatch"), "{err:#}");
    }

    #[test]
    fn native_backend_fwd_and_placer_shapes() {
        let cfg = Config { backend: "native".to_string(), hidden: 16, ..Config::default() };
        let env = Env::new(Benchmark::ResNet50, &cfg).unwrap();
        let mut backend = NativeBackend::new(&env, &cfg).unwrap();
        let fb = vec![0f32; env.v_pad * cfg.hidden];
        let out = PolicyBackend::fwd(&mut backend, &env, &fb).unwrap();
        assert_eq!(out.scores.len(), env.n_edges);
        assert!(out.z.len() >= env.n_nodes * cfg.hidden);
        assert!(out.scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        // Two groups: nodes 0..k -> 0, rest -> 1.
        let mut cids = vec![1i32; env.v_pad];
        for c in cids.iter_mut().take(env.n_nodes / 2) {
            *c = 0;
        }
        let mut gmask = vec![0f32; env.v_pad];
        gmask[..2].fill(1.0);
        let logits = backend.placer(&env, &out, &cids, &gmask).unwrap();
        let nd = env.n_actions();
        assert!(logits.len() >= 2 * nd);
        assert!(logits[..2 * nd].iter().all(|l| l.is_finite() && *l > -1e8));
    }
}

//! Process-wide metrics registry: named counters, gauges, and
//! log₂-bucketed histograms, all lock-free on the write path.
//!
//! Design constraints (the serve and kernel hot paths run through here):
//!
//! - **One relaxed atomic increment per event.** Counters are sharded
//!   across [`SHARDS`] cache-line-padded cells; each thread hashes to a
//!   stable shard once and then every `inc` is a single
//!   `fetch_add(Relaxed)` on a line no other shard writes.
//! - **Zero allocation when disabled.** Handles are interned once
//!   (leaked, `&'static`) and a disabled registry turns every write into
//!   one relaxed load + branch. Nothing on the write path allocates,
//!   enabled or not.
//! - **Strictly observational.** Nothing in this module feeds back into
//!   placement, scheduling, or cache decisions; `tests/obs.rs` pins that
//!   trajectories are bit-identical with telemetry on or off.
//!
//! Histograms bucket by bit width (`bucket_of`), so quantiles are
//! estimates interpolated within a power-of-two bucket — the right
//! trade for a stats endpoint that must not sort sample windows under a
//! mutex (see `serve::server`). The same bucket math backs the plain
//! (non-atomic) [`LogHist`] used under the serve stats lock.
//!
//! Everything lives behind string names (`serve.requests`,
//! `kernel.matmul.flops`, ...); `registry_json()` dumps the whole
//! registry as a `hsdag-metrics-v1` document for the `metrics` wire
//! command. Kernel profiling (`profile()`) is a second, off-by-default
//! tier: it additionally reads the monotonic clock per call, so it is
//! gated separately by `set_profiling`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Write-path shards per metric. 16 padded cells = 1 KiB per counter;
/// enough that a 16-worker serve pool almost never shares a line.
pub const SHARDS: usize = 16;

/// Histogram bucket count: bucket `k` holds values of bit width `k`
/// (`[2^(k-1), 2^k)`); 48 buckets cover u64 microsecond values up to
/// ~8.9 years, far past any latency this process can observe.
pub const BUCKETS: usize = 48;

/// Global on/off switch for metric writes (on by default — a write is
/// one relaxed increment). `bench_policy` flips it to measure the
/// enabled-vs-disabled hot-path delta.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Opt-in kernel/pool profiling tier (off by default — it reads the
/// monotonic clock per kernel call, which the default tier never does).
static PROFILING: AtomicBool = AtomicBool::new(false);

/// Enable or disable all metric writes. Reads (`get`, snapshots, the
/// `metrics` wire command) always work.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether metric writes are currently recorded.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Enable or disable the kernel/pool profiling tier (`--profile`).
pub fn set_profiling(on: bool) {
    PROFILING.store(on, Ordering::Relaxed);
}

/// Whether kernel/pool profiling is on.
pub fn profiling() -> bool {
    PROFILING.load(Ordering::Relaxed)
}

/// Stable per-thread shard index: assigned round-robin at first use so
/// a fixed worker pool spreads evenly across shards.
fn shard_idx() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
    }
    SHARD.with(|s| *s)
}

/// One cache line per shard cell so concurrent writers never contend.
#[repr(align(64))]
struct PadCell(AtomicU64);

impl PadCell {
    fn new() -> Self {
        PadCell(AtomicU64::new(0))
    }
}

/// Monotonic sharded counter.
pub struct Counter {
    name: &'static str,
    shards: [PadCell; SHARDS],
}

impl Counter {
    fn new(name: &'static str) -> Self {
        Counter { name, shards: std::array::from_fn(|_| PadCell::new()) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Add `n`; one relaxed load (the enable gate) + one relaxed
    /// `fetch_add` on this thread's shard.
    #[inline]
    pub fn add(&self, n: u64) {
        if !enabled() {
            return;
        }
        self.shards[shard_idx()].0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Sum across shards. Relaxed per-shard reads: exact once writers
    /// quiesce, monotone-approximate while they run.
    pub fn get(&self) -> u64 {
        self.shards.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }
}

/// Last-write-wins gauge (worker counts, cache sizes, ...).
pub struct Gauge {
    name: &'static str,
    value: AtomicU64,
}

impl Gauge {
    fn new(name: &'static str) -> Self {
        Gauge { name, value: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    pub fn set(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Bucket index for a value: its bit width, clamped to the table.
/// `0 → 0`, `1 → 1`, `[2,3] → 2`, `[4,7] → 3`, ... `[2^(k-1), 2^k) → k`.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lo(i: usize) -> u64 {
    if i <= 1 {
        i as u64
    } else {
        1u64 << (i - 1)
    }
}

/// Inclusive upper bound of bucket `i` (saturates at the top bucket).
pub fn bucket_hi(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Quantile estimate over a bucket table: find the bucket holding the
/// rank, then interpolate linearly inside its `[lo, hi]` range. Matches
/// `util::stats::percentile`'s `p/100 * (n-1)` rank convention.
fn quantile_from_buckets(buckets: &[u64], p: f64) -> f64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (total - 1) as f64;
    let mut seen = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let after = seen + c;
        if rank < after as f64 {
            let within = rank - seen as f64; // in [0, c)
            let frac = (within + 0.5) / c as f64;
            let (lo, hi) = (bucket_lo(i) as f64, bucket_hi(i).min(1 << 62) as f64);
            return lo + frac * (hi - lo);
        }
        seen = after;
    }
    bucket_hi(BUCKETS - 1).min(1 << 62) as f64
}

/// Sharded atomic histogram over u64 values (conventionally
/// microseconds). Three relaxed increments per record (bucket, count is
/// implicit in the buckets, sum) — used on per-request paths, not
/// per-kernel-inner-loop paths.
pub struct Histogram {
    name: &'static str,
    shards: [HistShard; SHARDS],
}

struct HistShard {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
}

impl HistShard {
    fn new() -> Self {
        HistShard { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }
}

impl Histogram {
    fn new(name: &'static str) -> Self {
        Histogram { name, shards: std::array::from_fn(|_| HistShard::new()) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        let s = &self.shards[shard_idx()];
        s.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Merge all shards into a point-in-time snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = vec![0u64; BUCKETS];
        let mut sum = 0u64;
        for s in &self.shards {
            for (b, a) in buckets.iter_mut().zip(&s.buckets) {
                *b += a.load(Ordering::Relaxed);
            }
            sum = sum.wrapping_add(s.sum.load(Ordering::Relaxed));
        }
        HistSnapshot { buckets, sum }
    }
}

/// Merged view of a [`Histogram`] (or a [`LogHist`]).
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub sum: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Interpolated quantile estimate, `p` in [0, 100].
    pub fn quantile(&self, p: f64) -> f64 {
        quantile_from_buckets(&self.buckets, p)
    }

    /// Non-empty buckets as `(lo, hi, count)` rows for wire documents.
    pub fn nonzero(&self) -> Vec<(u64, u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lo(i), bucket_hi(i), c))
            .collect()
    }
}

/// Plain (non-atomic) log₂ histogram for single-writer contexts — the
/// serve stats window lives in one of these *under its existing mutex*,
/// replacing the clone-and-sort-per-`stats`-call sample vector: record
/// is O(1), quantiles are O(BUCKETS), and nothing ever sorts.
#[derive(Clone)]
pub struct LogHist {
    buckets: [u64; BUCKETS],
    sum_us: u64,
}

impl Default for LogHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHist {
    pub fn new() -> Self {
        LogHist { buckets: [0; BUCKETS], sum_us: 0 }
    }

    #[inline]
    pub fn record_us(&mut self, us: u64) {
        self.buckets[bucket_of(us)] += 1;
        self.sum_us = self.sum_us.wrapping_add(us);
    }

    /// Record a duration in milliseconds at microsecond resolution.
    #[inline]
    pub fn record_ms(&mut self, ms: f64) {
        self.record_us((ms * 1000.0).max(0.0).round() as u64);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us as f64 / n as f64 / 1000.0
        }
    }

    /// Interpolated quantile in milliseconds, `p` in [0, 100].
    pub fn quantile_ms(&self, p: f64) -> f64 {
        quantile_from_buckets(&self.buckets, p) / 1000.0
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot { buckets: self.buckets.to_vec(), sum: self.sum_us }
    }
}

/// The process-global registry: interned handles, enumerable for dumps.
struct Registry {
    counters: Mutex<Vec<&'static Counter>>,
    gauges: Mutex<Vec<&'static Gauge>>,
    histograms: Mutex<Vec<&'static Histogram>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        counters: Mutex::new(Vec::new()),
        gauges: Mutex::new(Vec::new()),
        histograms: Mutex::new(Vec::new()),
    })
}

fn leak_name(name: &str) -> &'static str {
    Box::leak(name.to_string().into_boxed_str())
}

/// Intern a counter by name. Takes the registry lock — call once per
/// site (cache the returned `&'static`), never on a hot path.
pub fn counter(name: &str) -> &'static Counter {
    let mut v = registry().counters.lock().unwrap();
    if let Some(c) = v.iter().find(|c| c.name == name) {
        return c;
    }
    let c: &'static Counter = Box::leak(Box::new(Counter::new(leak_name(name))));
    v.push(c);
    c
}

/// Intern a gauge by name (same contract as [`counter`]).
pub fn gauge(name: &str) -> &'static Gauge {
    let mut v = registry().gauges.lock().unwrap();
    if let Some(g) = v.iter().find(|g| g.name == name) {
        return g;
    }
    let g: &'static Gauge = Box::leak(Box::new(Gauge::new(leak_name(name))));
    v.push(g);
    g
}

/// Intern a histogram by name (same contract as [`counter`]).
pub fn histogram(name: &str) -> &'static Histogram {
    let mut v = registry().histograms.lock().unwrap();
    if let Some(h) = v.iter().find(|h| h.name == name) {
        return h;
    }
    let h: &'static Histogram = Box::leak(Box::new(Histogram::new(leak_name(name))));
    v.push(h);
    h
}

/// Per-kernel profiling bundle: call count, accumulated wall nanos, and
/// accumulated floating-point-op count.
pub struct KernelStats {
    pub calls: &'static Counter,
    pub ns: &'static Counter,
    pub flops: &'static Counter,
}

/// Intern the three counters for kernel `name` (e.g. `kernel.matmul` →
/// `kernel.matmul.calls` / `.ns` / `.flops`).
pub fn kernel_stats(name: &str) -> &'static KernelStats {
    Box::leak(Box::new(KernelStats {
        calls: counter(&format!("{name}.calls")),
        ns: counter(&format!("{name}.ns")),
        flops: counter(&format!("{name}.flops")),
    }))
}

/// RAII kernel-profiling guard; records on drop.
pub struct ProfileGuard {
    stats: &'static KernelStats,
    flops: u64,
    start: Instant,
}

impl Drop for ProfileGuard {
    fn drop(&mut self) {
        self.stats.calls.inc();
        self.stats.flops.add(self.flops);
        self.stats.ns.add(self.start.elapsed().as_nanos() as u64);
    }
}

/// Kernel-profiling hook: returns `None` (one relaxed load, nothing
/// else) unless profiling is on; otherwise interns the kernel's stats
/// into `slot` once and starts a timer. Usage in a kernel entry point:
///
/// ```ignore
/// static STATS: OnceLock<&'static KernelStats> = OnceLock::new();
/// let _t = obs::metrics::profile(&STATS, "kernel.matmul", flops);
/// ```
#[inline]
pub fn profile(
    slot: &OnceLock<&'static KernelStats>,
    name: &str,
    flops: u64,
) -> Option<ProfileGuard> {
    if !profiling() {
        return None;
    }
    let stats = *slot.get_or_init(|| kernel_stats(name));
    Some(ProfileGuard { stats, flops, start: Instant::now() })
}

/// Dump the whole registry as a `hsdag-metrics-v1` document: counter
/// and gauge values plus count/mean/p50/p99 and non-empty buckets per
/// histogram. Names are sorted so the document is stable.
pub fn registry_json() -> Json {
    let mut counters: Vec<(String, Json)> = registry()
        .counters
        .lock()
        .unwrap()
        .iter()
        .map(|c| (c.name.to_string(), Json::Num(c.get() as f64)))
        .collect();
    counters.sort_by(|a, b| a.0.cmp(&b.0));
    let mut gauges: Vec<(String, Json)> = registry()
        .gauges
        .lock()
        .unwrap()
        .iter()
        .map(|g| (g.name.to_string(), Json::Num(g.get() as f64)))
        .collect();
    gauges.sort_by(|a, b| a.0.cmp(&b.0));
    let mut hists: Vec<(String, Json)> = registry()
        .histograms
        .lock()
        .unwrap()
        .iter()
        .map(|h| {
            let s = h.snapshot();
            (h.name.to_string(), hist_json(&s))
        })
        .collect();
    hists.sort_by(|a, b| a.0.cmp(&b.0));
    Json::Obj(vec![
        ("format".to_string(), Json::Str("hsdag-metrics-v1".to_string())),
        ("counters".to_string(), Json::Obj(counters)),
        ("gauges".to_string(), Json::Obj(gauges)),
        ("histograms".to_string(), Json::Obj(hists)),
    ])
}

/// Render one histogram snapshot as its wire object.
pub fn hist_json(s: &HistSnapshot) -> Json {
    let n = s.count();
    let mean = if n == 0 { 0.0 } else { s.sum as f64 / n as f64 };
    Json::Obj(vec![
        ("count".to_string(), Json::Num(n as f64)),
        ("mean".to_string(), Json::Num(mean)),
        ("p50".to_string(), Json::Num(s.quantile(50.0))),
        ("p99".to_string(), Json::Num(s.quantile(99.0))),
        (
            "buckets".to_string(),
            Json::Arr(
                s.nonzero()
                    .into_iter()
                    .map(|(lo, hi, c)| {
                        Json::Arr(vec![
                            Json::Num(lo as f64),
                            Json::Num(hi.min(1 << 62) as f64),
                            Json::Num(c as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Serializes unit tests that toggle the process-global switches
/// (`set_enabled`, `set_profiling`) or assert exact counter deltas —
/// unit tests share one process and one registry. Lock via
/// `lock_test_guard()`; never used outside `cfg(test)`.
#[cfg(test)]
pub(crate) static TEST_GUARD: Mutex<()> = Mutex::new(());

/// Acquire [`TEST_GUARD`], surviving poisoning from a failed test.
#[cfg(test)]
pub(crate) fn lock_test_guard() -> std::sync::MutexGuard<'static, ()> {
    TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_math_partitions_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // Every bucket's bounds round-trip through bucket_of.
        for i in 0..BUCKETS - 1 {
            assert_eq!(bucket_of(bucket_lo(i)), i, "lo of {i}");
            assert_eq!(bucket_of(bucket_hi(i)), i, "hi of {i}");
        }
    }

    #[test]
    fn counter_intern_is_idempotent() {
        let _g = lock_test_guard();
        let a = counter("test.intern.once");
        let b = counter("test.intern.once");
        assert!(std::ptr::eq(a, b));
        let before = a.get();
        b.add(3);
        assert_eq!(a.get(), before + 3);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        let _g = lock_test_guard();
        let g = gauge("test.gauge");
        g.set(7);
        g.set(42);
        assert_eq!(g.get(), 42);
    }

    #[test]
    fn disabled_registry_drops_writes() {
        let _g = lock_test_guard();
        let c = counter("test.disabled");
        let before = c.get();
        set_enabled(false);
        c.add(100);
        set_enabled(true);
        assert_eq!(c.get(), before);
        c.inc();
        assert_eq!(c.get(), before + 1);
    }

    #[test]
    fn loghist_quantiles_order_and_bound() {
        let mut h = LogHist::new();
        for ms in [1.0, 2.0, 3.0, 5.0, 8.0, 100.0] {
            h.record_ms(ms);
        }
        assert_eq!(h.count(), 6);
        let (p50, p99) = (h.quantile_ms(50.0), h.quantile_ms(99.0));
        assert!(p50 > 0.0 && p99 >= p50, "p50 {p50} p99 {p99}");
        // Estimates stay within the data's bucket envelope.
        assert!(p99 <= bucket_hi(bucket_of(100_000)) as f64 / 1000.0);
        assert!((h.mean_ms() - (1.0 + 2.0 + 3.0 + 5.0 + 8.0 + 100.0) / 6.0).abs() < 0.01);
    }

    #[test]
    fn atomic_histogram_snapshot_merges_shards() {
        let _g = lock_test_guard();
        let h = histogram("test.hist");
        let base = h.snapshot().count();
        for v in [1u64, 10, 100, 1000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), base + 4);
        assert!(s.quantile(99.0) >= s.quantile(50.0));
        assert!(!s.nonzero().is_empty());
    }

    #[test]
    fn registry_dump_is_valid_and_sorted() {
        counter("test.dump.a").inc();
        counter("test.dump.b").inc();
        histogram("test.dump.h").record(5);
        let doc = registry_json();
        assert_eq!(doc.get("format").and_then(|f| f.as_str()), Some("hsdag-metrics-v1"));
        let names: Vec<&str> = match doc.get("counters") {
            Some(Json::Obj(kv)) => kv.iter().map(|(k, _)| k.as_str()).collect(),
            _ => panic!("counters object"),
        };
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "counter names sorted");
        // Round-trips through the parser.
        let text = doc.to_string_compact();
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn quantile_single_value_lands_in_bucket() {
        let mut h = LogHist::new();
        h.record_us(700);
        let q = h.quantile_ms(50.0) * 1000.0;
        assert!(
            (bucket_lo(bucket_of(700)) as f64..=bucket_hi(bucket_of(700)) as f64).contains(&q),
            "{q}"
        );
    }
}

//! Request tracing: per-request ids, per-stage span timings, and a
//! `hsdag-trace-v1` JSONL sink.
//!
//! A trace id is minted where a request enters the system — the
//! `request` client (`--trace <id>` to supply one), else the router,
//! else the shard — and propagated on the wire in the `trace` field of
//! the place request, so one id follows a request through the router to
//! the shard that served it. Each process with `--trace-log PATH`
//! appends one JSON line per request:
//!
//! ```json
//! {"format":"hsdag-trace-v1","trace":"1f2e...","op":"place",
//!  "total_us":1234,"spans":[{"stage":"queue","start_us":0,"dur_us":41},
//!  {"stage":"cache","start_us":42,"dur_us":3}, ...],
//!  "provenance":"policy","fingerprint":"..."}
//! ```
//!
//! Spans carry their offset from request start (`start_us`) and
//! duration (`dur_us`), so nesting and ordering are reconstructible.
//! `hsdag trace summarize <log>` renders per-stage p50/p95/p99 from
//! such a log ([`summarize_file`]). Tracing is strictly observational:
//! span capture never branches the serving logic, and a process without
//! a sink pays only an `Option` check per stage.

use std::fs::OpenOptions;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::stats;

/// Wire format tag for trace log lines.
pub const TRACE_FORMAT: &str = "hsdag-trace-v1";

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Mint a fresh 16-hex-digit trace id: wall-clock nanos mixed with a
/// process-local counter, so ids are unique within a process and
/// collisions across processes need the same nanosecond. Ids never feed
/// into placement decisions, so their randomness is not load-bearing.
pub fn mint_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    format!("{:016x}", splitmix64(nanos ^ seq.rotate_left(32)))
}

/// One timed stage within a request.
#[derive(Debug, Clone)]
pub struct Span {
    pub stage: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
}

/// Span collector for one request. Create at ingress, close stages with
/// [`Trace::end`], then render with [`Trace::to_json`].
pub struct Trace {
    id: String,
    op: &'static str,
    t0: Instant,
    spans: Vec<Span>,
    fields: Vec<(&'static str, Json)>,
}

impl Trace {
    pub fn new(id: String, op: &'static str) -> Self {
        Trace { id, op, t0: Instant::now(), spans: Vec::new(), fields: Vec::new() }
    }

    pub fn id(&self) -> &str {
        &self.id
    }

    /// Mark the start of a stage (just a timestamp — pass it back to
    /// [`Trace::end`], which allows overlapping or nested stages).
    pub fn begin(&self) -> Instant {
        Instant::now()
    }

    /// Close a stage opened at `started`.
    pub fn end(&mut self, stage: &'static str, started: Instant) {
        let start_us = started.duration_since(self.t0).as_micros() as u64;
        let dur_us = started.elapsed().as_micros() as u64;
        self.spans.push(Span { stage, start_us, dur_us });
    }

    /// Record a pre-measured stage (e.g. queue wait measured by the
    /// accept loop before this trace existed); anchored at offset 0.
    pub fn span_before_start(&mut self, stage: &'static str, dur_us: u64) {
        self.spans.push(Span { stage, start_us: 0, dur_us });
    }

    /// Attach a scalar field to the trace line (provenance, fingerprint,
    /// shard index, ...).
    pub fn field(&mut self, key: &'static str, value: Json) {
        self.fields.push((key, value));
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Render the `hsdag-trace-v1` line object.
    pub fn to_json(&self) -> Json {
        let spans = self
            .spans
            .iter()
            .map(|s| {
                Json::Obj(vec![
                    ("stage".to_string(), Json::Str(s.stage.to_string())),
                    ("start_us".to_string(), Json::Num(s.start_us as f64)),
                    ("dur_us".to_string(), Json::Num(s.dur_us as f64)),
                ])
            })
            .collect();
        let mut obj = vec![
            ("format".to_string(), Json::Str(TRACE_FORMAT.to_string())),
            ("trace".to_string(), Json::Str(self.id.clone())),
            ("op".to_string(), Json::Str(self.op.to_string())),
            ("total_us".to_string(), Json::Num(self.t0.elapsed().as_micros() as f64)),
            ("spans".to_string(), Json::Arr(spans)),
        ];
        for (k, v) in &self.fields {
            obj.push((k.to_string(), v.clone()));
        }
        Json::Obj(obj)
    }
}

/// Append-mode JSONL sink shared by a process's request handlers.
/// Writes take a short mutex (one line render + one buffered write);
/// flushed per line so a killed daemon loses at most the in-flight one.
/// IO errors are swallowed after the first (tracing must never take
/// down serving) — the error is reported once at `warn`.
pub struct TraceSink {
    path: String,
    out: Mutex<SinkState>,
}

struct SinkState {
    w: BufWriter<std::fs::File>,
    failed: bool,
}

impl TraceSink {
    /// Open (append/create) a trace log at `path`.
    pub fn open(path: &str) -> Result<TraceSink> {
        let f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open trace log {path}"))?;
        Ok(TraceSink {
            path: path.to_string(),
            out: Mutex::new(SinkState { w: BufWriter::new(f), failed: false }),
        })
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    /// Append one trace line.
    pub fn write(&self, trace: &Trace) {
        let line = trace.to_json().to_string_compact();
        let mut s = self.out.lock().unwrap();
        if s.failed {
            return;
        }
        let res = writeln!(s.w, "{line}").and_then(|_| s.w.flush());
        if let Err(e) = res {
            s.failed = true;
            crate::log_warn!("trace log {}: write failed ({e}); tracing disabled", self.path);
        }
    }
}

/// Per-stage aggregate over one parsed trace log.
#[derive(Debug)]
pub struct StageSummary {
    pub stage: String,
    pub count: usize,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub total_ms: f64,
}

/// Parse a `hsdag-trace-v1` JSONL log into per-stage summaries plus the
/// request-total distribution (stage name `"total"`, sorted last).
/// Lines that fail to parse or carry another format are counted into
/// `skipped`, not fatal — logs may interleave with other output.
pub fn summarize_lines(text: &str) -> (Vec<StageSummary>, usize) {
    let mut stages: Vec<(String, Vec<f64>)> = Vec::new();
    let mut totals: Vec<f64> = Vec::new();
    let mut skipped = 0usize;
    let mut push = |name: &str, us: f64, stages: &mut Vec<(String, Vec<f64>)>| {
        match stages.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => v.push(us),
            None => stages.push((name.to_string(), vec![us])),
        }
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let doc = match Json::parse(line) {
            Ok(d) => d,
            Err(_) => {
                skipped += 1;
                continue;
            }
        };
        if doc.get("format").and_then(|f| f.as_str()) != Some(TRACE_FORMAT) {
            skipped += 1;
            continue;
        }
        if let Some(t) = doc.get("total_us").and_then(|v| v.as_f64()) {
            totals.push(t);
        }
        if let Some(spans) = doc.get("spans").and_then(|s| s.as_arr()) {
            for sp in spans {
                let stage = sp.get("stage").and_then(|s| s.as_str()).unwrap_or("?");
                let dur = sp.get("dur_us").and_then(|d| d.as_f64()).unwrap_or(0.0);
                push(stage, dur, &mut stages);
            }
        }
    }
    stages.sort_by(|a, b| a.0.cmp(&b.0));
    if !totals.is_empty() {
        stages.push(("total".to_string(), totals));
    }
    let out = stages
        .into_iter()
        .map(|(stage, v)| StageSummary {
            stage,
            count: v.len(),
            p50_us: stats::percentile(&v, 50.0),
            p95_us: stats::percentile(&v, 95.0),
            p99_us: stats::percentile(&v, 99.0),
            max_us: v.iter().cloned().fold(0.0, f64::max),
            total_ms: v.iter().sum::<f64>() / 1000.0,
        })
        .collect();
    (out, skipped)
}

/// `hsdag trace summarize <log>`: render the per-stage latency table.
pub fn summarize_file(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace log {}", path.display()))?;
    let (stages, skipped) = summarize_lines(&text);
    if stages.is_empty() {
        return Ok(format!(
            "no hsdag-trace-v1 lines in {} ({} line(s) skipped)\n",
            path.display(),
            skipped
        ));
    }
    let mut out = String::new();
    let requests = stages.last().map(|s| s.count).unwrap_or(0);
    out.push_str(&format!("trace summary: {} ({} request(s)", path.display(), requests));
    if skipped > 0 {
        out.push_str(&format!(", {skipped} non-trace line(s) skipped"));
    }
    out.push_str(")\n");
    out.push_str(&format!(
        "{:<12} {:>7} {:>12} {:>12} {:>12} {:>12} {:>12}\n",
        "stage", "count", "p50 us", "p95 us", "p99 us", "max us", "total ms"
    ));
    for s in &stages {
        out.push_str(&format!(
            "{:<12} {:>7} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12.2}\n",
            s.stage, s.count, s.p50_us, s.p95_us, s.p99_us, s.max_us, s.total_ms
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_renders_schema_fields() {
        let mut t = Trace::new("abc123".to_string(), "place");
        let s = t.begin();
        t.end("cache", s);
        t.field("provenance", Json::Str("policy".to_string()));
        let doc = t.to_json();
        assert_eq!(doc.get("format").and_then(|f| f.as_str()), Some(TRACE_FORMAT));
        assert_eq!(doc.get("trace").and_then(|f| f.as_str()), Some("abc123"));
        assert_eq!(doc.get("op").and_then(|f| f.as_str()), Some("place"));
        let spans = doc.get("spans").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("stage").and_then(|s| s.as_str()), Some("cache"));
        assert_eq!(doc.get("provenance").and_then(|f| f.as_str()), Some("policy"));
        // Round-trips through the parser.
        assert!(Json::parse(&doc.to_string_compact()).is_ok());
    }

    #[test]
    fn mint_ids_are_distinct_and_hex() {
        let a = mint_id();
        let b = mint_id();
        assert_ne!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn summarize_aggregates_per_stage() {
        let mut log = String::new();
        for dur in [100.0, 200.0, 300.0] {
            let mut t = Trace::new(mint_id(), "place");
            t.span_before_start("queue", dur as u64);
            let s = t.begin();
            t.end("rollout", s);
            log.push_str(&t.to_json().to_string_compact());
            log.push('\n');
        }
        log.push_str("not json\n");
        let (stages, skipped) = summarize_lines(&log);
        assert_eq!(skipped, 1);
        let queue = stages.iter().find(|s| s.stage == "queue").unwrap();
        assert_eq!(queue.count, 3);
        assert_eq!(queue.p50_us, 200.0);
        assert_eq!(queue.max_us, 300.0);
        assert_eq!(stages.last().unwrap().stage, "total");
        assert_eq!(stages.last().unwrap().count, 3);
    }

    #[test]
    fn sink_appends_parseable_lines() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hsdag-trace-test-{}.jsonl", mint_id()));
        let sink = TraceSink::open(path.to_str().unwrap()).unwrap();
        let mut t = Trace::new(mint_id(), "place");
        t.span_before_start("queue", 5);
        sink.write(&t);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        assert!(Json::parse(text.lines().next().unwrap()).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}

//! Minimal leveled logger for diagnostics (`error` > `warn` > `info` >
//! `debug`), replacing ad-hoc `eprintln!` call sites.
//!
//! Ground rules:
//!
//! - **stderr only, message verbatim.** The logger adds no prefix or
//!   timestamp at `error`/`warn`/`info`, so converted call sites emit
//!   byte-identical lines; `debug` lines get a `debug: ` prefix since
//!   they never existed before this tier. stdout stays reserved for
//!   user-facing output (tables, banners, JSON) and is never routed
//!   through here.
//! - **Off-by-default debug tier.** The default level is `info`; the
//!   `HSDAG_LOG` environment variable and the `--log-level` flag (flag
//!   wins) raise or lower it. `off` silences everything.
//! - **Cheap when silent.** The level gate is one relaxed atomic load
//!   and the macros skip formatting entirely when the level is off.
//!
//! Use the crate-root macros: `log_error!`, `log_warn!`, `log_info!`,
//! `log_debug!`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Severity levels; numeric rank orders them (`off` gates everything).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

/// Current level rank; `Info` by default.
static LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);

impl Level {
    /// Parse a level name (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" | "quiet" => Some(Level::Off),
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" | "trace" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Off => "off",
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Install the process-wide level. Called from `main::run` (flag) and
/// [`init_from_env`]; safe to call repeatedly (tests share a process).
pub fn set_level(l: Level) {
    LEVEL.store(l as usize, Ordering::Relaxed);
}

/// The currently installed level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Off,
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        _ => Level::Debug,
    }
}

/// Whether a message at `l` would currently be emitted. The macros call
/// this before formatting, so silent levels cost one relaxed load.
#[inline]
pub fn enabled(l: Level) -> bool {
    l != Level::Off && (l as usize) <= LEVEL.load(Ordering::Relaxed)
}

/// Adopt `HSDAG_LOG` if set and valid (unknown values are ignored, not
/// fatal — a bad env var must not break the CLI). Called once at CLI
/// startup, before the `--log-level` flag is applied on top.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("HSDAG_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

/// Emit one line to stderr. `error`/`warn`/`info` lines are verbatim
/// (converted `eprintln!` sites stay byte-identical); `debug` lines are
/// prefixed so ad-hoc tooling can filter them.
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    if l == Level::Debug {
        eprintln!("debug: {args}");
    } else {
        eprintln!("{args}");
    }
}

/// Log at `error` (always on unless the level is `off`).
#[macro_export]
macro_rules! log_error {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::log($crate::obs::log::Level::Error, format_args!($($a)*));
        }
    };
}

/// Log at `warn`.
#[macro_export]
macro_rules! log_warn {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::log($crate::obs::log::Level::Warn, format_args!($($a)*));
        }
    };
}

/// Log at `info` (the default tier).
#[macro_export]
macro_rules! log_info {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::log($crate::obs::log::Level::Info, format_args!($($a)*));
        }
    };
}

/// Log at `debug` (off by default; `HSDAG_LOG=debug` or
/// `--log-level debug` enables).
#[macro_export]
macro_rules! log_debug {
    ($($a:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::log($crate::obs::log::Level::Debug, format_args!($($a)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_rank() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("off"), Some(Level::Off));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn gate_respects_level() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        set_level(prev);
    }

    #[test]
    fn default_hides_debug() {
        let prev = level();
        set_level(Level::Info);
        assert!(!enabled(Level::Debug));
        assert!(enabled(Level::Info));
        set_level(prev);
    }
}

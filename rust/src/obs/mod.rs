//! Observability: the process-wide telemetry layer.
//!
//! Three tiers, all strictly observational — nothing here may feed back
//! into placement, scheduling, or cache decisions (`tests/obs.rs` pins
//! bit-identical trajectories with telemetry on vs off):
//!
//! - [`metrics`] — named sharded atomic counters/gauges and
//!   log₂-bucketed histograms. Always-on by default (a write is one
//!   relaxed increment); dumped by the `metrics` wire command as a
//!   `hsdag-metrics-v1` document. A separate opt-in profiling tier
//!   (`--profile`) adds per-kernel wall time / flops and worker-pool
//!   utilization, surfaced by `bench_policy`.
//! - [`trace`] — per-request ids propagated router → shard on the wire,
//!   per-stage spans (queue, cache, rollout, simulate, select), and a
//!   `hsdag-trace-v1` JSONL sink behind `--trace-log PATH`;
//!   `hsdag trace summarize <log>` renders p50/p95/p99 per stage.
//! - [`log`] — a leveled stderr logger (`--log-level`, `HSDAG_LOG`)
//!   with an off-by-default debug tier; converted `eprintln!` sites
//!   keep their output byte-identical.
//!
//! Training emits its own per-episode `hsdag-run-v1` records (reward /
//! loss / entropy / param-norm) through `train --run-log PATH`; see
//! `rl::search::CurvePoint`.

pub mod log;
pub mod metrics;
pub mod trace;

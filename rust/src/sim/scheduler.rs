//! Event-driven list scheduler: executes a placed computation graph on the
//! testbed and reports the makespan (the l_P(G) the reward is built from).
//!
//! Semantics:
//! - each device executes one op at a time (OpenVINO streams=1 inference);
//! - an op may start once all producers finished and their outputs arrived
//!   (cross-device tensors pay the link cost; weights/`Constant`s are
//!   pre-staged at model-load time and never transferred);
//! - among ready ops on the same device, the one with the highest
//!   critical-path priority runs first (classic HEFT-style list
//!   scheduling).
//!
//! The simulator is deterministic; the *measurement* model layers
//! multiplicative noise on top (`measure`) and applies the paper's
//! "10 runs, average last 5" protocol.

use super::device::{DeviceId, Testbed};
use crate::graph::{CompGraph, OpKind};
use crate::util::{stats, Rng};

/// A device assignment for every node of a graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement(pub Vec<DeviceId>);

impl Placement {
    pub fn all(n: usize, d: DeviceId) -> Placement {
        Placement(vec![d; n])
    }
}

/// Detailed outcome of one simulated execution.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// End-to-end latency, seconds.
    pub makespan: f64,
    /// Busy seconds per device.
    pub busy: Vec<f64>,
    /// Total bytes moved across device boundaries.
    pub bytes_transferred: f64,
    /// Number of cross-device tensor transfers.
    pub n_transfers: usize,
}

/// Simulate one execution of `g` under `placement` on `tb`.
pub fn execute(g: &CompGraph, placement: &Placement, tb: &Testbed) -> ExecReport {
    assert_eq!(placement.0.len(), g.n(), "one device per node");
    let order = g.topo_order().expect("simulator needs a DAG");

    // Critical-path upward rank (in expected-time terms, device-averaged)
    // for priority. Computed once per call; cheap relative to search.
    let avg_time: Vec<f64> = (0..g.n())
        .map(|v| {
            tb.devices.iter().map(|d| d.op_time(&g.nodes[v])).sum::<f64>() / tb.n_devices() as f64
        })
        .collect();
    let mut rank = vec![0f64; g.n()];
    for &v in order.iter().rev() {
        let best_child =
            g.out_neighbors(v).iter().map(|&w| rank[w]).fold(0f64, f64::max);
        rank[v] = avg_time[v] + best_child;
    }

    // Per-device ready queues processed in priority order. We schedule by
    // repeatedly picking, over all devices, the ready op whose device frees
    // earliest (then highest rank).
    let n = g.n();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n]; // data-ready time of each node's output
    // Per-device lane free times (a device runs `lanes` ops concurrently).
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;

    // Ready set as a Vec we re-scan: graphs are ~1k nodes, fine. (Perf note:
    // profiled in benches/bench_sim.rs; see EXPERIMENTS.md §Perf.)
    let mut ready: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut scheduled = 0usize;
    let mut makespan = 0f64;

    while scheduled < n {
        // Pick the ready op with the highest rank whose device is free
        // earliest: sort key (dev_free, -rank).
        let mut best: Option<(usize, f64)> = None; // (ready idx, start time)
        for (ri, &v) in ready.iter().enumerate() {
            let d = placement.0[v];
            // Earliest start: device free AND inputs arrived.
            let mut data_ready = 0f64;
            for &p in g.in_neighbors(v) {
                let arr = if placement.0[p] == d || g.nodes[p].kind == OpKind::Constant {
                    finish[p]
                } else {
                    finish[p] + tb.links[placement.0[p]][d].transfer_time(g.nodes[p].out_bytes())
                };
                data_ready = data_ready.max(arr);
            }
            // Earliest-free lane on the device.
            let dev_free = lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min);
            let start = dev_free.max(data_ready);
            let better = match best {
                None => true,
                Some((bri, bstart)) => {
                    start < bstart - 1e-15
                        || ((start - bstart).abs() <= 1e-15 && rank[v] > rank[ready[bri]])
                }
            };
            if better {
                best = Some((ri, start));
            }
        }
        let (ri, start) = best.expect("ready set non-empty while ops remain");
        let v = ready.swap_remove(ri);
        let d = placement.0[v];

        // Account transfers now (for the report; time already in `start`).
        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        // Occupy the earliest-free lane (recompute: `start` may exceed it).
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                ready.push(w);
            }
        }
    }

    ExecReport { makespan, busy, bytes_transferred, n_transfers }
}

/// The paper's measurement protocol: run 10 times with multiplicative
/// noise (~N(1, sigma)), average the last 5 (Table 2 caption). `sigma = 0`
/// gives the deterministic makespan.
pub fn measure(g: &CompGraph, placement: &Placement, tb: &Testbed, sigma: f64, rng: &mut Rng) -> f64 {
    let base = execute(g, placement, tb).makespan;
    if sigma == 0.0 {
        return base;
    }
    let samples: Vec<f64> =
        (0..10).map(|_| base * (1.0 + sigma * rng.next_gauss()).max(0.5)).collect();
    stats::paper_latency_protocol(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpAttrs, OpKind, OpNode};
    use crate::models::Benchmark;
    use crate::sim::device::{CPU, DGPU};
    use crate::util::prop::{check, PropConfig};

    fn conv_chain(k: usize) -> CompGraph {
        let mut g = CompGraph::new("cc");
        let mut prev = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 64, 56, 56]));
        for i in 0..k {
            let v = g.add_node(
                OpNode::new(format!("c{i}"), OpKind::Convolution, vec![1, 64, 56, 56])
                    .with_attrs(OpAttrs { taps: 9, reduce_dim: 64, groups: 1 }),
            );
            g.add_edge(prev, v);
            prev = v;
        }
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 64, 56, 56]));
        g.add_edge(prev, o);
        g
    }

    #[test]
    fn chain_makespan_is_sum_of_op_times() {
        let g = conv_chain(4);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let rep = execute(&g, &p, &tb);
        let expect: f64 = g.nodes.iter().map(|n| tb.devices[CPU].op_time(n)).sum();
        assert!((rep.makespan - expect).abs() < 1e-12);
        assert_eq!(rep.n_transfers, 0);
    }

    #[test]
    fn cross_device_chain_pays_transfers() {
        let g = conv_chain(2);
        let tb = Testbed::paper();
        // Alternate devices along the chain.
        let mut p = Placement::all(g.n(), CPU);
        p.0[2] = DGPU; // second conv on dGPU
        let rep = execute(&g, &p, &tb);
        assert!(rep.n_transfers >= 1);
        let all_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb);
        // Mixed placement of a pure chain can't beat... it CAN beat CPU if
        // the op runs much faster on dGPU; but must be >= critical path
        // with transfers. Sanity: strictly positive makespans.
        assert!(rep.makespan > 0.0 && all_cpu.makespan > 0.0);
        assert!(rep.bytes_transferred > 0.0);
    }

    #[test]
    fn parallel_branches_overlap_across_devices() {
        // Two heavy independent convs: placing them on different devices
        // must beat placing both on one device.
        let mut g = CompGraph::new("par");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 1]));
        let attrs = OpAttrs { taps: 9, reduce_dim: 256, groups: 1 };
        let a = g.add_node(
            OpNode::new("a", OpKind::Convolution, vec![1, 256, 64, 64]).with_attrs(attrs),
        );
        let b = g.add_node(
            OpNode::new("b", OpKind::Convolution, vec![1, 256, 64, 64]).with_attrs(attrs),
        );
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 1]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        // Single-lane twin devices: splitting the branches must overlap.
        let mut tb = Testbed::paper();
        tb.devices[CPU].lanes = 1;
        tb.devices[DGPU] = tb.devices[CPU].clone();
        let both_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let mut split = Placement::all(g.n(), CPU);
        split.0[b] = DGPU;
        let split_t = execute(&g, &split, &tb).makespan;
        assert!(split_t < both_cpu, "split {split_t} vs cpu {both_cpu}");

        // And the paper testbed's 2-lane CPU overlaps them natively: the
        // branch-parallelism that keeps Inception CPU-competitive.
        let tb2 = Testbed::paper();
        let overlap = execute(&g, &Placement::all(g.n(), CPU), &tb2).makespan;
        let serial: f64 = g.nodes.iter().map(|n| tb2.devices[CPU].op_time(n)).sum();
        assert!(overlap < 0.7 * serial, "overlap {overlap} vs serial {serial}");
    }

    #[test]
    fn gpu_only_beats_cpu_only_on_resnet() {
        // The calibration target shape of Table 2 (ratio checked precisely
        // in the harness tests).
        let g = Benchmark::ResNet50.build();
        let tb = Testbed::paper();
        let cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let gpu = execute(&g, &Placement::all(g.n(), DGPU), &tb).makespan;
        assert!(gpu < cpu, "gpu {gpu} cpu {cpu}");
    }

    #[test]
    fn measurement_protocol_close_to_deterministic() {
        let g = conv_chain(3);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let det = execute(&g, &p, &tb).makespan;
        let mut rng = crate::util::Rng::new(5);
        let meas = measure(&g, &p, &tb, 0.02, &mut rng);
        assert!((meas - det).abs() / det < 0.1);
        assert_eq!(measure(&g, &p, &tb, 0.0, &mut rng), det);
    }

    #[test]
    fn makespan_lower_bounded_by_critical_path_prop() {
        check("makespan-bounds", PropConfig { cases: 32, max_size: 60, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 4);
            let tb = Testbed::paper();
            let placement =
                Placement((0..g.n()).map(|_| [CPU, DGPU][rng.below(2)]).collect());
            let rep = execute(&g, &placement, &tb);
            // Lower bound: max over devices of its busy time.
            let max_busy = rep.busy.iter().cloned().fold(0f64, f64::max);
            if rep.makespan + 1e-12 < max_busy {
                return Err(format!("makespan {} < busy {}", rep.makespan, max_busy));
            }
            // Upper bound: sum of all op times on their device + all
            // transfer times (serial execution).
            let serial: f64 = (0..g.n())
                .map(|v| tb.devices[placement.0[v]].op_time(&g.nodes[v]))
                .sum::<f64>()
                + g.edges
                    .iter()
                    .map(|&(s, d)| {
                        if placement.0[s] != placement.0[d] {
                            tb.links[placement.0[s]][placement.0[d]]
                                .transfer_time(g.nodes[s].out_bytes())
                        } else {
                            0.0
                        }
                    })
                    .sum::<f64>();
            if rep.makespan > serial + 1e-9 {
                return Err(format!("makespan {} > serial {}", rep.makespan, serial));
            }
            Ok(())
        });
    }
}

//! Event-driven list scheduler: executes a placed computation graph on the
//! testbed and reports the makespan (the l_P(G) the reward is built from).
//!
//! Semantics:
//! - each device executes one op at a time per lane (OpenVINO streams=1
//!   inference);
//! - an op may start once all producers finished and their outputs arrived
//!   (cross-device tensors pay the link cost; weights/`Constant`s are
//!   pre-staged at model-load time and never transferred);
//! - among ready ops, the one that can start earliest runs first, ties
//!   broken by the highest critical-path priority, then by node id
//!   (classic HEFT-style list scheduling).
//!
//! Implementation: `execute` keeps the ready set in a lazy `BinaryHeap`
//! keyed by (earliest start = max(device-free time, data-ready time),
//! critical-path rank). A popped entry whose device got busier since it
//! was pushed is re-keyed and re-pushed; because device-free times only
//! grow, this is equivalent to rescanning the whole ready set every
//! iteration — which is exactly what `execute_reference` (the retained
//! pre-optimization implementation) does. The two are differential-tested
//! against each other, and `benches/bench_sim.rs` measures the before
//! (`execute_reference`, O(|ready|) re-scan per scheduled op) vs after
//! (`execute`, O(log |ready|) amortized).
//!
//! One deliberate semantic canonicalization versus the pre-heap code:
//! the old selection treated start times within 1e-15 s as tied (then
//! broke ties by rank, then by ready-Vec order). Epsilon comparisons are
//! not transitive and cannot key a heap, so both implementations now use
//! the exact total order (start, -rank, node id). Start-time differences
//! below 1e-15 s are far under the simulator's physical resolution, but
//! schedules produced across that boundary can in principle differ from
//! the pre-refactor binary; `tests/testbeds.rs` pins the refactored
//! default path against `execute_reference` under the canonical order.
//!
//! The simulator is deterministic; the *measurement* model layers
//! multiplicative noise on top (`measure`) and applies the paper's
//! "10 runs, average last 5" protocol.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::device::{DeviceId, Testbed};
use crate::graph::{CompGraph, OpKind};
use crate::util::{stats, Rng};

/// A device assignment for every node of a graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement(pub Vec<DeviceId>);

impl Placement {
    pub fn all(n: usize, d: DeviceId) -> Placement {
        Placement(vec![d; n])
    }
}

/// Detailed outcome of one simulated execution.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// End-to-end latency, seconds.
    pub makespan: f64,
    /// Busy seconds per device.
    pub busy: Vec<f64>,
    /// Total bytes moved across device boundaries.
    pub bytes_transferred: f64,
    /// Number of cross-device tensor transfers.
    pub n_transfers: usize,
}

/// Critical-path upward rank (in expected-time terms, device-averaged)
/// used as the list-scheduling priority. Computed once per `execute`;
/// cheap relative to search.
fn upward_rank(g: &CompGraph, tb: &Testbed, order: &[usize]) -> Vec<f64> {
    let avg_time: Vec<f64> = (0..g.n())
        .map(|v| {
            tb.devices.iter().map(|d| d.op_time(&g.nodes[v])).sum::<f64>() / tb.n_devices() as f64
        })
        .collect();
    let mut rank = vec![0f64; g.n()];
    for &v in order.iter().rev() {
        let best_child = g.out_neighbors(v).iter().map(|&w| rank[w]).fold(0f64, f64::max);
        rank[v] = avg_time[v] + best_child;
    }
    rank
}

/// Data-ready time of `v` on its device: all producers finished and their
/// outputs arrived (only valid once every predecessor has been scheduled).
fn data_ready_time(g: &CompGraph, placement: &Placement, tb: &Testbed, finish: &[f64], v: usize) -> f64 {
    let d = placement.0[v];
    let mut data_ready = 0f64;
    for &p in g.in_neighbors(v) {
        let arr = if placement.0[p] == d || g.nodes[p].kind == OpKind::Constant {
            finish[p]
        } else {
            finish[p] + tb.links[placement.0[p]][d].transfer_time(g.nodes[p].out_bytes())
        };
        data_ready = data_ready.max(arr);
    }
    data_ready
}

/// A ready-set entry. `BinaryHeap` is a max-heap, so `Ord` is arranged to
/// pop the smallest (start, -rank, node) first.
#[derive(Clone, Copy)]
struct ReadyOp {
    start: f64,
    rank: f64,
    node: usize,
}

impl PartialEq for ReadyOp {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for ReadyOp {}

impl PartialOrd for ReadyOp {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyOp {
    fn cmp(&self, other: &Self) -> Ordering {
        // Earliest start wins, then highest rank, then lowest node id
        // (total order -> deterministic schedules). Times are finite.
        other
            .start
            .partial_cmp(&self.start)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.rank.partial_cmp(&other.rank).unwrap_or(Ordering::Equal))
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Simulate one execution of `g` under `placement` on `tb`.
pub fn execute(g: &CompGraph, placement: &Placement, tb: &Testbed) -> ExecReport {
    assert_eq!(placement.0.len(), g.n(), "one device per node");
    let order = g.topo_order().expect("simulator needs a DAG");
    let rank = upward_rank(g, tb, &order);

    let n = g.n();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n]; // data-ready time of each node's output
    // Fixed once a node becomes ready (all producers scheduled).
    let mut data_ready = vec![0f64; n];
    // Per-device lane free times (a device runs `lanes` ops concurrently).
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;

    let dev_free = |lane_free: &[Vec<f64>], d: DeviceId| -> f64 {
        lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min)
    };

    let mut heap: BinaryHeap<ReadyOp> = BinaryHeap::with_capacity(n);
    for v in 0..n {
        if indeg[v] == 0 {
            // No producers: data-ready at t=0.
            heap.push(ReadyOp { start: dev_free(&lane_free, placement.0[v]), rank: rank[v], node: v });
        }
    }

    let mut scheduled = 0usize;
    let mut makespan = 0f64;

    while scheduled < n {
        let e = heap.pop().expect("ready heap non-empty while ops remain");
        let v = e.node;
        let d = placement.0[v];
        let start = dev_free(&lane_free, d).max(data_ready[v]);
        if start > e.start {
            // Stale key: the device got busier since this entry was
            // pushed. Re-key lazily; keys only grow, so correctness of
            // the global minimum is preserved.
            heap.push(ReadyOp { start, rank: e.rank, node: v });
            continue;
        }

        // Account transfers now (for the report; time already in `start`).
        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        // Occupy the earliest-free lane (recompute: `start` may exceed it).
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                data_ready[w] = data_ready_time(g, placement, tb, &finish, w);
                heap.push(ReadyOp {
                    start: dev_free(&lane_free, placement.0[w]).max(data_ready[w]),
                    rank: rank[w],
                    node: w,
                });
            }
        }
    }

    ExecReport { makespan, busy, bytes_transferred, n_transfers }
}

/// Reference implementation of `execute`: the ready set as a Vec that is
/// linearly re-scanned for every scheduled op. Kept as the behavioral
/// specification the heap scheduler is differential-tested against (see
/// `heap_matches_reference_prop` below) and as the "before" side of
/// `benches/bench_sim.rs`. Semantically identical to `execute` by
/// construction: same (start, -rank, node) selection order.
pub fn execute_reference(g: &CompGraph, placement: &Placement, tb: &Testbed) -> ExecReport {
    assert_eq!(placement.0.len(), g.n(), "one device per node");
    let order = g.topo_order().expect("simulator needs a DAG");
    let rank = upward_rank(g, tb, &order);

    let n = g.n();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n];
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;

    let mut ready: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut scheduled = 0usize;
    let mut makespan = 0f64;

    while scheduled < n {
        // Pick the ready op with the smallest (start, -rank, node).
        let mut best: Option<(usize, f64)> = None; // (ready idx, start time)
        for (ri, &v) in ready.iter().enumerate() {
            let d = placement.0[v];
            let data_ready = data_ready_time(g, placement, tb, &finish, v);
            let free = lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min);
            let start = free.max(data_ready);
            let better = match best {
                None => true,
                Some((bri, bstart)) => {
                    let bv = ready[bri];
                    start < bstart
                        || (start == bstart
                            && (rank[v] > rank[bv] || (rank[v] == rank[bv] && v < bv)))
                }
            };
            if better {
                best = Some((ri, start));
            }
        }
        let (ri, start) = best.expect("ready set non-empty while ops remain");
        let v = ready.swap_remove(ri);
        let d = placement.0[v];

        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                ready.push(w);
            }
        }
    }

    ExecReport { makespan, busy, bytes_transferred, n_transfers }
}

/// The paper's measurement protocol: run 10 times with multiplicative
/// noise (~N(1, sigma)), average the last 5 (Table 2 caption). `sigma = 0`
/// gives the deterministic makespan.
pub fn measure(g: &CompGraph, placement: &Placement, tb: &Testbed, sigma: f64, rng: &mut Rng) -> f64 {
    let base = execute(g, placement, tb).makespan;
    if sigma == 0.0 {
        return base;
    }
    let samples: Vec<f64> =
        (0..10).map(|_| base * (1.0 + sigma * rng.next_gauss()).max(0.5)).collect();
    stats::paper_latency_protocol(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpAttrs, OpKind, OpNode};
    use crate::models::Benchmark;
    use crate::sim::device::{CPU, DGPU};
    use crate::util::prop::{check, PropConfig};

    fn conv_chain(k: usize) -> CompGraph {
        let mut g = CompGraph::new("cc");
        let mut prev = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 64, 56, 56]));
        for i in 0..k {
            let v = g.add_node(
                OpNode::new(format!("c{i}"), OpKind::Convolution, vec![1, 64, 56, 56])
                    .with_attrs(OpAttrs { taps: 9, reduce_dim: 64, groups: 1 }),
            );
            g.add_edge(prev, v);
            prev = v;
        }
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 64, 56, 56]));
        g.add_edge(prev, o);
        g
    }

    #[test]
    fn chain_makespan_is_sum_of_op_times() {
        let g = conv_chain(4);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let rep = execute(&g, &p, &tb);
        let expect: f64 = g.nodes.iter().map(|n| tb.devices[CPU].op_time(n)).sum();
        assert!((rep.makespan - expect).abs() < 1e-12);
        assert_eq!(rep.n_transfers, 0);
    }

    #[test]
    fn cross_device_chain_pays_transfers() {
        let g = conv_chain(2);
        let tb = Testbed::paper();
        // Alternate devices along the chain.
        let mut p = Placement::all(g.n(), CPU);
        p.0[2] = DGPU; // second conv on dGPU
        let rep = execute(&g, &p, &tb);
        assert!(rep.n_transfers >= 1);
        let all_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb);
        // Mixed placement of a pure chain can't beat... it CAN beat CPU if
        // the op runs much faster on dGPU; but must be >= critical path
        // with transfers. Sanity: strictly positive makespans.
        assert!(rep.makespan > 0.0 && all_cpu.makespan > 0.0);
        assert!(rep.bytes_transferred > 0.0);
    }

    #[test]
    fn parallel_branches_overlap_across_devices() {
        // Two heavy independent convs: placing them on different devices
        // must beat placing both on one device.
        let mut g = CompGraph::new("par");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 1]));
        let attrs = OpAttrs { taps: 9, reduce_dim: 256, groups: 1 };
        let a = g.add_node(
            OpNode::new("a", OpKind::Convolution, vec![1, 256, 64, 64]).with_attrs(attrs),
        );
        let b = g.add_node(
            OpNode::new("b", OpKind::Convolution, vec![1, 256, 64, 64]).with_attrs(attrs),
        );
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 1]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        // Single-lane twin devices: splitting the branches must overlap.
        let mut tb = Testbed::paper();
        tb.devices[CPU].lanes = 1;
        tb.devices[DGPU] = tb.devices[CPU].clone();
        let both_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let mut split = Placement::all(g.n(), CPU);
        split.0[b] = DGPU;
        let split_t = execute(&g, &split, &tb).makespan;
        assert!(split_t < both_cpu, "split {split_t} vs cpu {both_cpu}");

        // And the paper testbed's 2-lane CPU overlaps them natively: the
        // branch-parallelism that keeps Inception CPU-competitive.
        let tb2 = Testbed::paper();
        let overlap = execute(&g, &Placement::all(g.n(), CPU), &tb2).makespan;
        let serial: f64 = g.nodes.iter().map(|n| tb2.devices[CPU].op_time(n)).sum();
        assert!(overlap < 0.7 * serial, "overlap {overlap} vs serial {serial}");
    }

    #[test]
    fn gpu_only_beats_cpu_only_on_resnet() {
        // The calibration target shape of Table 2 (ratio checked precisely
        // in the harness tests).
        let g = Benchmark::ResNet50.build();
        let tb = Testbed::paper();
        let cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let gpu = execute(&g, &Placement::all(g.n(), DGPU), &tb).makespan;
        assert!(gpu < cpu, "gpu {gpu} cpu {cpu}");
    }

    #[test]
    fn measurement_protocol_close_to_deterministic() {
        let g = conv_chain(3);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let det = execute(&g, &p, &tb).makespan;
        let mut rng = crate::util::Rng::new(5);
        let meas = measure(&g, &p, &tb, 0.02, &mut rng);
        assert!((meas - det).abs() / det < 0.1);
        assert_eq!(measure(&g, &p, &tb, 0.0, &mut rng), det);
    }

    #[test]
    fn heap_matches_reference_on_benchmarks() {
        // Exact agreement of the optimized scheduler with the retained
        // reference re-scan on the real benchmark graphs, across all
        // registered testbeds.
        for tb in Testbed::registered() {
            let mut rng = crate::util::Rng::new(0xD1FF);
            for b in Benchmark::ALL {
                let g = b.build();
                let p = Placement(
                    (0..g.n()).map(|_| tb.placeable[rng.below(tb.n_actions())]).collect(),
                );
                let fast = execute(&g, &p, &tb);
                let slow = execute_reference(&g, &p, &tb);
                assert_eq!(fast.makespan, slow.makespan, "{}/{}", tb.id, b.id());
                assert_eq!(fast.busy, slow.busy, "{}/{}", tb.id, b.id());
                assert_eq!(fast.n_transfers, slow.n_transfers, "{}/{}", tb.id, b.id());
                assert_eq!(
                    fast.bytes_transferred, slow.bytes_transferred,
                    "{}/{}",
                    tb.id,
                    b.id()
                );
            }
        }
    }

    #[test]
    fn heap_matches_reference_prop() {
        check(
            "heap-vs-reference",
            PropConfig { cases: 48, max_size: 80, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let tbs = Testbed::registered();
                let tb = &tbs[rng.below(tbs.len())];
                let placement = Placement(
                    (0..g.n()).map(|_| tb.placeable[rng.below(tb.n_actions())]).collect(),
                );
                let fast = execute(&g, &placement, tb);
                let slow = execute_reference(&g, &placement, tb);
                if fast.makespan != slow.makespan {
                    return Err(format!(
                        "{}: heap {} != reference {}",
                        tb.id, fast.makespan, slow.makespan
                    ));
                }
                if fast.busy != slow.busy || fast.n_transfers != slow.n_transfers {
                    return Err(format!("{}: report mismatch", tb.id));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn makespan_lower_bounded_by_critical_path_prop() {
        check("makespan-bounds", PropConfig { cases: 32, max_size: 60, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 4);
            let tb = Testbed::paper();
            let placement =
                Placement((0..g.n()).map(|_| [CPU, DGPU][rng.below(2)]).collect());
            let rep = execute(&g, &placement, &tb);
            // Lower bound: max over devices of its busy time.
            let max_busy = rep.busy.iter().cloned().fold(0f64, f64::max);
            if rep.makespan + 1e-12 < max_busy {
                return Err(format!("makespan {} < busy {}", rep.makespan, max_busy));
            }
            // Upper bound: sum of all op times on their device + all
            // transfer times (serial execution).
            let serial: f64 = (0..g.n())
                .map(|v| tb.devices[placement.0[v]].op_time(&g.nodes[v]))
                .sum::<f64>()
                + g.edges
                    .iter()
                    .map(|&(s, d)| {
                        if placement.0[s] != placement.0[d] {
                            tb.links[placement.0[s]][placement.0[d]]
                                .transfer_time(g.nodes[s].out_bytes())
                        } else {
                            0.0
                        }
                    })
                    .sum::<f64>();
            if rep.makespan > serial + 1e-9 {
                return Err(format!("makespan {} > serial {}", rep.makespan, serial));
            }
            Ok(())
        });
    }
}

//! Event-driven list scheduler: executes a placed computation graph on the
//! testbed and reports the makespan (the l_P(G) the reward is built from),
//! per-device busy time / transfer volume, and a per-device memory
//! high-water (see [`memory_highwater`]) checked against each device's
//! capacity — placements that overflow a device are reported infeasible
//! (`ExecReport::feasible`) instead of silently scored. The accounting is
//! observational: capacities never alter the schedule or the makespan.
//!
//! Semantics:
//! - each device executes one op at a time per lane (OpenVINO streams=1
//!   inference);
//! - an op may start once all producers finished and their outputs arrived
//!   (cross-device tensors pay the link cost; weights/`Constant`s are
//!   pre-staged at model-load time and never transferred);
//! - among ready ops, the one that can start earliest runs first, ties
//!   broken by the highest critical-path priority, then by node id
//!   (classic HEFT-style list scheduling).
//!
//! Implementation: `execute` keeps the ready set in a lazy `BinaryHeap`
//! keyed by (earliest start = max(device-free time, data-ready time),
//! critical-path rank). A popped entry whose device got busier since it
//! was pushed is re-keyed and re-pushed; because device-free times only
//! grow, this is equivalent to rescanning the whole ready set every
//! iteration — which is exactly what `execute_reference` (the retained
//! pre-optimization implementation) does. The two are differential-tested
//! against each other, and `benches/bench_sim.rs` measures the before
//! (`execute_reference`, O(|ready|) re-scan per scheduled op) vs after
//! (`execute`, O(log |ready|) amortized).
//!
//! One deliberate semantic canonicalization versus the pre-heap code:
//! the old selection treated start times within 1e-15 s as tied (then
//! broke ties by rank, then by ready-Vec order). Epsilon comparisons are
//! not transitive and cannot key a heap, so both implementations now use
//! the exact total order (start, -rank, node id). Start-time differences
//! below 1e-15 s are far under the simulator's physical resolution, but
//! schedules produced across that boundary can in principle differ from
//! the pre-refactor binary; `tests/testbeds.rs` pins the refactored
//! default path against `execute_reference` under the canonical order.
//!
//! The simulator is deterministic; the *measurement* model layers
//! multiplicative noise on top (`measure`) and applies the paper's
//! "10 runs, average last 5" protocol.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::device::{DeviceId, Testbed};
use crate::graph::{CompGraph, OpKind};
use crate::util::{stats, Rng};

/// A device assignment for every node of a graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement(pub Vec<DeviceId>);

impl Placement {
    pub fn all(n: usize, d: DeviceId) -> Placement {
        Placement(vec![d; n])
    }
}

/// Detailed outcome of one simulated execution.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecReport {
    /// End-to-end latency, seconds.
    pub makespan: f64,
    /// Busy seconds per device.
    pub busy: Vec<f64>,
    /// Total bytes moved across device boundaries.
    pub bytes_transferred: f64,
    /// Number of cross-device tensor transfers.
    pub n_transfers: usize,
    /// Steady-state resident-byte high-water per device (see
    /// [`memory_highwater`] for the residency model).
    pub mem_peak: Vec<f64>,
    /// Devices whose high-water exceeds their `mem_capacity`, ascending.
    /// Empty on the default testbeds (unbounded capacities).
    pub oom_devices: Vec<DeviceId>,
}

impl ExecReport {
    /// Whether the placement fits every device's memory capacity.
    pub fn feasible(&self) -> bool {
        self.oom_devices.is_empty()
    }

    /// Busy fraction per device: busy seconds over makespan × lanes, so
    /// a multi-lane device at full occupancy reads 1.0. All zeros when
    /// the makespan is zero.
    pub fn utilization(&self, tb: &Testbed) -> Vec<f64> {
        if self.makespan <= 0.0 {
            return vec![0.0; self.busy.len()];
        }
        self.busy
            .iter()
            .zip(&tb.devices)
            .map(|(&b, d)| b / (self.makespan * d.lanes.max(1) as f64))
            .collect()
    }
}

/// Per-device memory high-water of a completed schedule, plus the devices
/// it overflows.
///
/// Residency model (steady-state serving, one inference in flight):
/// - **weights**: every `Constant` output is pre-staged at model-load time
///   on each device hosting at least one of its consumers (on its own
///   device if it has none) and stays resident for the whole run;
/// - **intermediates**: a non-constant node's output is allocated on its
///   device when the op starts and freed once every consumer has finished
///   (held to the end of the run if it has no consumers);
/// - **transfers**: a cross-device edge materializes one copy per
///   (producer, consumer device) pair — consumers on the same remote
///   device share it — resident from the producer's finish until the
///   last such consumer finishes.
///
/// The sweep is purely observational — capacities never change the
/// schedule, so latency pins are unaffected by this accounting. It runs
/// on every `execute` (the report always carries `mem_peak`, bounded
/// testbed or not) and costs one event build plus a per-device sort on
/// top of the schedule itself; skipping it on unbounded testbeds would
/// leave the report's memory columns empty exactly where the harness
/// prints them, so completeness is preferred over the constant factor.
fn memory_highwater(
    g: &CompGraph,
    placement: &Placement,
    tb: &Testbed,
    finish: &[f64],
    makespan: f64,
) -> (Vec<f64>, Vec<DeviceId>) {
    let nd = tb.n_devices();
    let mut base = vec![0f64; nd];
    // Per-device (time, signed bytes) events. Frees sort before
    // allocations at equal timestamps (delta ascending), so back-to-back
    // buffer reuse at the same instant is not double-counted.
    let mut events: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nd];

    for v in 0..g.n() {
        let node = &g.nodes[v];
        let d = placement.0[v];
        let bytes = node.out_bytes();
        if node.kind == OpKind::Constant {
            let mut staged: Vec<DeviceId> =
                g.out_neighbors(v).iter().map(|&w| placement.0[w]).collect();
            staged.sort_unstable();
            staged.dedup();
            if staged.is_empty() {
                staged.push(d);
            }
            for s in staged {
                base[s] += bytes;
            }
            continue;
        }
        let start = finish[v] - tb.devices[d].op_time(node);
        let freed = if g.out_degree(v) == 0 {
            makespan
        } else {
            g.out_neighbors(v).iter().map(|&w| finish[w]).fold(0f64, f64::max)
        };
        events[d].push((start, bytes));
        events[d].push((freed, -bytes));
        // One copy per (producer, remote device): consumers sharing a
        // device share the copy, resident from the producer's finish
        // until the last of them finishes (mirrors the per-device dedup
        // of the constants above).
        let mut copies: Vec<(DeviceId, f64)> = Vec::new();
        for &w in g.out_neighbors(v) {
            let dw = placement.0[w];
            if dw != d {
                match copies.iter_mut().find(|(cd, _)| *cd == dw) {
                    Some((_, last)) => *last = last.max(finish[w]),
                    None => copies.push((dw, finish[w])),
                }
            }
        }
        for (dw, last) in copies {
            events[dw].push((finish[v], bytes));
            events[dw].push((last, -bytes));
        }
    }

    let mut peak = vec![0f64; nd];
    let mut oom = Vec::new();
    for d in 0..nd {
        // Unstable sort: the (time, delta) key is a total order and
        // equal events are interchangeable in a running sum.
        events[d].sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        let mut cur = base[d];
        let mut hi = base[d];
        for &(_, delta) in &events[d] {
            cur += delta;
            if cur > hi {
                hi = cur;
            }
        }
        peak[d] = hi;
        if hi > tb.devices[d].mem_capacity {
            oom.push(d);
        }
    }
    (peak, oom)
}

/// Critical-path upward rank (in expected-time terms, device-averaged)
/// used as the list-scheduling priority. Computed once per `execute`;
/// cheap relative to search.
fn upward_rank(g: &CompGraph, tb: &Testbed, order: &[usize]) -> Vec<f64> {
    let avg_time: Vec<f64> = (0..g.n())
        .map(|v| {
            tb.devices.iter().map(|d| d.op_time(&g.nodes[v])).sum::<f64>() / tb.n_devices() as f64
        })
        .collect();
    let mut rank = vec![0f64; g.n()];
    for &v in order.iter().rev() {
        let best_child = g.out_neighbors(v).iter().map(|&w| rank[w]).fold(0f64, f64::max);
        rank[v] = avg_time[v] + best_child;
    }
    rank
}

/// Data-ready time of `v` on its device: all producers finished and their
/// outputs arrived (only valid once every predecessor has been scheduled).
fn data_ready_time(g: &CompGraph, placement: &Placement, tb: &Testbed, finish: &[f64], v: usize) -> f64 {
    let d = placement.0[v];
    let mut data_ready = 0f64;
    for &p in g.in_neighbors(v) {
        let arr = if placement.0[p] == d || g.nodes[p].kind == OpKind::Constant {
            finish[p]
        } else {
            finish[p] + tb.links[placement.0[p]][d].transfer_time(g.nodes[p].out_bytes())
        };
        data_ready = data_ready.max(arr);
    }
    data_ready
}

/// A ready-set entry. `BinaryHeap` is a max-heap, so `Ord` is arranged to
/// pop the smallest (start, -rank, node) first.
#[derive(Clone, Copy)]
struct ReadyOp {
    start: f64,
    rank: f64,
    node: usize,
}

impl PartialEq for ReadyOp {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for ReadyOp {}

impl PartialOrd for ReadyOp {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyOp {
    fn cmp(&self, other: &Self) -> Ordering {
        // Earliest start wins, then highest rank, then lowest node id
        // (total order -> deterministic schedules). Times are finite.
        other
            .start
            .partial_cmp(&self.start)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.rank.partial_cmp(&other.rank).unwrap_or(Ordering::Equal))
            .then_with(|| other.node.cmp(&self.node))
    }
}

/// Simulate one execution of `g` under `placement` on `tb`.
pub fn execute(g: &CompGraph, placement: &Placement, tb: &Testbed) -> ExecReport {
    assert_eq!(placement.0.len(), g.n(), "one device per node");
    let order = g.topo_order().expect("simulator needs a DAG");
    let rank = upward_rank(g, tb, &order);

    let n = g.n();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n]; // data-ready time of each node's output
    // Fixed once a node becomes ready (all producers scheduled).
    let mut data_ready = vec![0f64; n];
    // Per-device lane free times (a device runs `lanes` ops concurrently).
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;

    let dev_free = |lane_free: &[Vec<f64>], d: DeviceId| -> f64 {
        lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min)
    };

    let mut heap: BinaryHeap<ReadyOp> = BinaryHeap::with_capacity(n);
    for v in 0..n {
        if indeg[v] == 0 {
            // No producers: data-ready at t=0.
            heap.push(ReadyOp { start: dev_free(&lane_free, placement.0[v]), rank: rank[v], node: v });
        }
    }

    let mut scheduled = 0usize;
    let mut makespan = 0f64;

    while scheduled < n {
        let e = heap.pop().expect("ready heap non-empty while ops remain");
        let v = e.node;
        let d = placement.0[v];
        let start = dev_free(&lane_free, d).max(data_ready[v]);
        if start > e.start {
            // Stale key: the device got busier since this entry was
            // pushed. Re-key lazily; keys only grow, so correctness of
            // the global minimum is preserved.
            heap.push(ReadyOp { start, rank: e.rank, node: v });
            continue;
        }

        // Account transfers now (for the report; time already in `start`).
        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        // Occupy the earliest-free lane (recompute: `start` may exceed it).
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                data_ready[w] = data_ready_time(g, placement, tb, &finish, w);
                heap.push(ReadyOp {
                    start: dev_free(&lane_free, placement.0[w]).max(data_ready[w]),
                    rank: rank[w],
                    node: w,
                });
            }
        }
    }

    let (mem_peak, oom_devices) = memory_highwater(g, placement, tb, &finish, makespan);
    ExecReport { makespan, busy, bytes_transferred, n_transfers, mem_peak, oom_devices }
}

/// Reference implementation of `execute`: the ready set as a Vec that is
/// linearly re-scanned for every scheduled op. Kept as the behavioral
/// specification the heap scheduler is differential-tested against (see
/// `heap_matches_reference_prop` below) and as the "before" side of
/// `benches/bench_sim.rs`. Semantically identical to `execute` by
/// construction: same (start, -rank, node) selection order.
pub fn execute_reference(g: &CompGraph, placement: &Placement, tb: &Testbed) -> ExecReport {
    assert_eq!(placement.0.len(), g.n(), "one device per node");
    let order = g.topo_order().expect("simulator needs a DAG");
    let rank = upward_rank(g, tb, &order);

    let n = g.n();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n];
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;

    let mut ready: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut scheduled = 0usize;
    let mut makespan = 0f64;

    while scheduled < n {
        // Pick the ready op with the smallest (start, -rank, node).
        let mut best: Option<(usize, f64)> = None; // (ready idx, start time)
        for (ri, &v) in ready.iter().enumerate() {
            let d = placement.0[v];
            let data_ready = data_ready_time(g, placement, tb, &finish, v);
            let free = lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min);
            let start = free.max(data_ready);
            let better = match best {
                None => true,
                Some((bri, bstart)) => {
                    let bv = ready[bri];
                    start < bstart
                        || (start == bstart
                            && (rank[v] > rank[bv] || (rank[v] == rank[bv] && v < bv)))
                }
            };
            if better {
                best = Some((ri, start));
            }
        }
        let (ri, start) = best.expect("ready set non-empty while ops remain");
        let v = ready.swap_remove(ri);
        let d = placement.0[v];

        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                ready.push(w);
            }
        }
    }

    let (mem_peak, oom_devices) = memory_highwater(g, placement, tb, &finish, makespan);
    ExecReport { makespan, busy, bytes_transferred, n_transfers, mem_peak, oom_devices }
}

/// Memoized schedule of one completed [`execute_with_memo`] /
/// [`execute_incremental`] run: the event order plus per-node start,
/// finish and lane assignment, the placement it describes, and the
/// (placement-independent) upward rank. Enough state to replay any
/// prefix of the schedule exactly.
#[derive(Debug, Clone)]
pub struct SimMemo {
    /// Nodes in the exact order the scheduler popped them.
    order: Vec<usize>,
    start: Vec<f64>,
    finish: Vec<f64>,
    /// Device lane each node occupied.
    lane: Vec<usize>,
    rank: Vec<f64>,
    placement: Vec<DeviceId>,
}

impl SimMemo {
    pub fn n(&self) -> usize {
        self.order.len()
    }
}

/// [`execute`] that additionally records a [`SimMemo`] for later
/// incremental re-evaluation. The report is bit-identical to `execute`'s
/// (same loop, same accumulation order); the differential tests pin it.
pub fn execute_with_memo(
    g: &CompGraph,
    placement: &Placement,
    tb: &Testbed,
) -> (ExecReport, SimMemo) {
    assert_eq!(placement.0.len(), g.n(), "one device per node");
    let order = g.topo_order().expect("simulator needs a DAG");
    let rank = upward_rank(g, tb, &order);

    let n = g.n();
    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n];
    let mut data_ready = vec![0f64; n];
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;

    let dev_free = |lane_free: &[Vec<f64>], d: DeviceId| -> f64 {
        lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min)
    };

    let mut heap: BinaryHeap<ReadyOp> = BinaryHeap::with_capacity(n);
    for v in 0..n {
        if indeg[v] == 0 {
            heap.push(ReadyOp { start: dev_free(&lane_free, placement.0[v]), rank: rank[v], node: v });
        }
    }

    let mut memo_order = Vec::with_capacity(n);
    let mut memo_start = vec![0f64; n];
    let mut memo_lane = vec![0usize; n];

    let mut scheduled = 0usize;
    let mut makespan = 0f64;

    while scheduled < n {
        let e = heap.pop().expect("ready heap non-empty while ops remain");
        let v = e.node;
        let d = placement.0[v];
        let start = dev_free(&lane_free, d).max(data_ready[v]);
        if start > e.start {
            heap.push(ReadyOp { start, rank: e.rank, node: v });
            continue;
        }

        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;
        memo_order.push(v);
        memo_start[v] = start;
        memo_lane[v] = lane;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                data_ready[w] = data_ready_time(g, placement, tb, &finish, w);
                heap.push(ReadyOp {
                    start: dev_free(&lane_free, placement.0[w]).max(data_ready[w]),
                    rank: rank[w],
                    node: w,
                });
            }
        }
    }

    let (mem_peak, oom_devices) = memory_highwater(g, placement, tb, &finish, makespan);
    let memo = SimMemo {
        order: memo_order,
        start: memo_start,
        finish: finish.clone(),
        lane: memo_lane,
        rank,
        placement: placement.0.clone(),
    };
    (ExecReport { makespan, busy, bytes_transferred, n_transfers, mem_peak, oom_devices }, memo)
}

/// Incremental re-simulation against a [`SimMemo`] of the *same graph
/// and testbed* under a different placement: replay the memoized event
/// prefix up to the first event any changed-placement node could have
/// entered the ready set, then resume the normal scheduler loop for the
/// suffix only.
///
/// Bit-identical to a full re-run, by two invariants the differential
/// tests pin:
/// 1. The prefix contains no node whose placement changed (divergence
///    index = min over changed nodes of their ready position, and a node
///    schedules no earlier than it becomes ready), and prefix events
///    depend only on prefix placements — so replaying memoized
///    (start, finish, lane) values and re-accumulating transfers/busy in
///    event order reproduces the full run's state at the divergence
///    point exactly.
/// 2. The scheduler's lazy heap pops in the exact (start, -rank, node)
///    order for *any* entry keys that lower-bound the true current start
///    times (stale entries are re-keyed on pop; device-free times only
///    grow). The reconstructed heap seeds exact current keys — valid
///    lower bounds — so the suffix continues exactly as the full run's.
pub fn execute_incremental(
    g: &CompGraph,
    placement: &Placement,
    tb: &Testbed,
    memo: &SimMemo,
) -> (ExecReport, SimMemo) {
    let n = g.n();
    assert_eq!(placement.0.len(), n, "one device per node");
    assert_eq!(memo.placement.len(), n, "memo is for a different graph");

    // Event index of each node in the memoized schedule.
    let mut pos = vec![0usize; n];
    for (t, &v) in memo.order.iter().enumerate() {
        pos[v] = t;
    }
    // Divergence: the earliest event at which a changed node is ready
    // (indeg-0 nodes are ready before event 0).
    let mut idx = n;
    for v in 0..n {
        if placement.0[v] != memo.placement[v] {
            let ready_pos = if g.in_degree(v) == 0 {
                0
            } else {
                g.in_neighbors(v).iter().map(|&p| pos[p] + 1).max().unwrap_or(0)
            };
            idx = idx.min(ready_pos);
        }
    }

    let mut indeg: Vec<usize> = (0..n).map(|v| g.in_degree(v)).collect();
    let mut finish = vec![0f64; n];
    let mut data_ready = vec![0f64; n];
    let mut lane_free: Vec<Vec<f64>> =
        tb.devices.iter().map(|d| vec![0f64; d.lanes.max(1)]).collect();
    let mut busy = vec![0f64; tb.n_devices()];
    let mut bytes_transferred = 0.0;
    let mut n_transfers = 0usize;
    let mut makespan = 0f64;
    let mut scheduled = 0usize;

    let mut memo_order = Vec::with_capacity(n);
    let mut memo_start = vec![0f64; n];
    let mut memo_lane = vec![0usize; n];

    // Replay the unaffected prefix from the memo (no changed node — and
    // hence no changed predecessor — appears in it).
    for &v in memo.order.iter().take(idx) {
        let d = placement.0[v];
        debug_assert_eq!(d, memo.placement[v], "changed node inside replay prefix");
        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }
        let t = tb.devices[d].op_time(&g.nodes[v]);
        finish[v] = memo.finish[v];
        lane_free[d][memo.lane[v]] = memo.finish[v];
        busy[d] += t;
        makespan = makespan.max(memo.finish[v]);
        scheduled += 1;
        memo_order.push(v);
        memo_start[v] = memo.start[v];
        memo_lane[v] = memo.lane[v];
        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
        }
    }

    let dev_free = |lane_free: &[Vec<f64>], d: DeviceId| -> f64 {
        lane_free[d].iter().cloned().fold(f64::INFINITY, f64::min)
    };

    // Seed the ready set: unscheduled nodes whose producers all finished
    // in the prefix. Their data-ready times recompute to exactly the
    // values the full run fixed when they became ready (all producer
    // finishes are prefix values).
    let mut heap: BinaryHeap<ReadyOp> = BinaryHeap::with_capacity(n - scheduled);
    let mut in_prefix = vec![false; n];
    for &v in memo.order.iter().take(idx) {
        in_prefix[v] = true;
    }
    for v in 0..n {
        if indeg[v] == 0 && !in_prefix[v] {
            data_ready[v] = data_ready_time(g, placement, tb, &finish, v);
            heap.push(ReadyOp {
                start: dev_free(&lane_free, placement.0[v]).max(data_ready[v]),
                rank: memo.rank[v],
                node: v,
            });
        }
    }

    // Resume the normal scheduler loop for the suffix.
    while scheduled < n {
        let e = heap.pop().expect("ready heap non-empty while ops remain");
        let v = e.node;
        let d = placement.0[v];
        let start = dev_free(&lane_free, d).max(data_ready[v]);
        if start > e.start {
            heap.push(ReadyOp { start, rank: e.rank, node: v });
            continue;
        }

        for &p in g.in_neighbors(v) {
            if placement.0[p] != d && g.nodes[p].kind != OpKind::Constant {
                bytes_transferred += g.nodes[p].out_bytes();
                n_transfers += 1;
            }
        }

        let t = tb.devices[d].op_time(&g.nodes[v]);
        let end = start + t;
        finish[v] = end;
        let lane = (0..lane_free[d].len())
            .min_by(|&a, &b| lane_free[d][a].partial_cmp(&lane_free[d][b]).unwrap())
            .unwrap();
        lane_free[d][lane] = end;
        busy[d] += t;
        makespan = makespan.max(end);
        scheduled += 1;
        memo_order.push(v);
        memo_start[v] = start;
        memo_lane[v] = lane;

        for &w in g.out_neighbors(v) {
            indeg[w] -= 1;
            if indeg[w] == 0 {
                data_ready[w] = data_ready_time(g, placement, tb, &finish, w);
                heap.push(ReadyOp {
                    start: dev_free(&lane_free, placement.0[w]).max(data_ready[w]),
                    rank: memo.rank[w],
                    node: w,
                });
            }
        }
    }

    let (mem_peak, oom_devices) = memory_highwater(g, placement, tb, &finish, makespan);
    let next = SimMemo {
        order: memo_order,
        start: memo_start,
        finish: finish.clone(),
        lane: memo_lane,
        rank: memo.rank.clone(),
        placement: placement.0.clone(),
    };
    (ExecReport { makespan, busy, bytes_transferred, n_transfers, mem_peak, oom_devices }, next)
}

/// Stateful incremental evaluator over one fixed (graph, testbed) pair:
/// the first [`IncrementalEvaluator::evaluate`] runs the full scheduler
/// and memoizes the schedule; every later call re-simulates only from
/// the first event the placement edit can affect. Reports are
/// bit-identical to fresh [`execute`] calls (differential-tested); the
/// win is proportional to how late in the schedule the edit lands —
/// e.g. the per-group device sweeps of multi-level refinement.
pub struct IncrementalEvaluator {
    g: CompGraph,
    tb: Testbed,
    memo: Option<SimMemo>,
}

impl IncrementalEvaluator {
    pub fn new(g: CompGraph, tb: Testbed) -> IncrementalEvaluator {
        IncrementalEvaluator { g, tb, memo: None }
    }

    pub fn graph(&self) -> &CompGraph {
        &self.g
    }

    /// Evaluate a placement given as one device id per node.
    pub fn evaluate(&mut self, actions: &[DeviceId]) -> ExecReport {
        let p = Placement(actions.to_vec());
        let (rep, memo) = match self.memo.take() {
            None => execute_with_memo(&self.g, &p, &self.tb),
            Some(m) => execute_incremental(&self.g, &p, &self.tb, &m),
        };
        self.memo = Some(memo);
        rep
    }
}

/// The paper's measurement protocol applied to an already-simulated
/// deterministic makespan: 10 runs with multiplicative noise
/// (~N(1, sigma)), average of the last 5 (Table 2 caption). `sigma = 0`
/// returns `base` unchanged and draws nothing from `rng`. Callers that
/// already hold an `ExecReport` use this to avoid a second simulation.
pub fn measure_from(base: f64, sigma: f64, rng: &mut Rng) -> f64 {
    if sigma == 0.0 {
        return base;
    }
    let samples: Vec<f64> =
        (0..10).map(|_| base * (1.0 + sigma * rng.next_gauss()).max(0.5)).collect();
    stats::paper_latency_protocol(&samples)
}

/// Simulate and measure in one call (see [`measure_from`]).
pub fn measure(g: &CompGraph, placement: &Placement, tb: &Testbed, sigma: f64, rng: &mut Rng) -> f64 {
    measure_from(execute(g, placement, tb).makespan, sigma, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpAttrs, OpKind, OpNode};
    use crate::models::Benchmark;
    use crate::sim::device::{CPU, DGPU};
    use crate::util::prop::{check, PropConfig};

    fn conv_chain(k: usize) -> CompGraph {
        let mut g = CompGraph::new("cc");
        let mut prev = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 64, 56, 56]));
        for i in 0..k {
            let v = g.add_node(
                OpNode::new(format!("c{i}"), OpKind::Convolution, vec![1, 64, 56, 56])
                    .with_attrs(OpAttrs { taps: 9, reduce_dim: 64, groups: 1 }),
            );
            g.add_edge(prev, v);
            prev = v;
        }
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 64, 56, 56]));
        g.add_edge(prev, o);
        g
    }

    #[test]
    fn chain_makespan_is_sum_of_op_times() {
        let g = conv_chain(4);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let rep = execute(&g, &p, &tb);
        let expect: f64 = g.nodes.iter().map(|n| tb.devices[CPU].op_time(n)).sum();
        assert!((rep.makespan - expect).abs() < 1e-12);
        assert_eq!(rep.n_transfers, 0);
    }

    #[test]
    fn cross_device_chain_pays_transfers() {
        let g = conv_chain(2);
        let tb = Testbed::paper();
        // Alternate devices along the chain.
        let mut p = Placement::all(g.n(), CPU);
        p.0[2] = DGPU; // second conv on dGPU
        let rep = execute(&g, &p, &tb);
        assert!(rep.n_transfers >= 1);
        let all_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb);
        // Mixed placement of a pure chain can't beat... it CAN beat CPU if
        // the op runs much faster on dGPU; but must be >= critical path
        // with transfers. Sanity: strictly positive makespans.
        assert!(rep.makespan > 0.0 && all_cpu.makespan > 0.0);
        assert!(rep.bytes_transferred > 0.0);
    }

    #[test]
    fn parallel_branches_overlap_across_devices() {
        // Two heavy independent convs: placing them on different devices
        // must beat placing both on one device.
        let mut g = CompGraph::new("par");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 1]));
        let attrs = OpAttrs { taps: 9, reduce_dim: 256, groups: 1 };
        let a = g.add_node(
            OpNode::new("a", OpKind::Convolution, vec![1, 256, 64, 64]).with_attrs(attrs),
        );
        let b = g.add_node(
            OpNode::new("b", OpKind::Convolution, vec![1, 256, 64, 64]).with_attrs(attrs),
        );
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 1]));
        g.add_edge(i, a);
        g.add_edge(i, b);
        g.add_edge(a, o);
        g.add_edge(b, o);
        // Single-lane twin devices: splitting the branches must overlap.
        let mut tb = Testbed::paper();
        tb.devices[CPU].lanes = 1;
        tb.devices[DGPU] = tb.devices[CPU].clone();
        let both_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let mut split = Placement::all(g.n(), CPU);
        split.0[b] = DGPU;
        let split_t = execute(&g, &split, &tb).makespan;
        assert!(split_t < both_cpu, "split {split_t} vs cpu {both_cpu}");

        // And the paper testbed's 2-lane CPU overlaps them natively: the
        // branch-parallelism that keeps Inception CPU-competitive.
        let tb2 = Testbed::paper();
        let overlap = execute(&g, &Placement::all(g.n(), CPU), &tb2).makespan;
        let serial: f64 = g.nodes.iter().map(|n| tb2.devices[CPU].op_time(n)).sum();
        assert!(overlap < 0.7 * serial, "overlap {overlap} vs serial {serial}");
    }

    #[test]
    fn gpu_only_beats_cpu_only_on_resnet() {
        // The calibration target shape of Table 2 (ratio checked precisely
        // in the harness tests).
        let g = Benchmark::ResNet50.build();
        let tb = Testbed::paper();
        let cpu = execute(&g, &Placement::all(g.n(), CPU), &tb).makespan;
        let gpu = execute(&g, &Placement::all(g.n(), DGPU), &tb).makespan;
        assert!(gpu < cpu, "gpu {gpu} cpu {cpu}");
    }

    #[test]
    fn measurement_protocol_close_to_deterministic() {
        let g = conv_chain(3);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let det = execute(&g, &p, &tb).makespan;
        let mut rng = crate::util::Rng::new(5);
        let meas = measure(&g, &p, &tb, 0.02, &mut rng);
        assert!((meas - det).abs() / det < 0.1);
        assert_eq!(measure(&g, &p, &tb, 0.0, &mut rng), det);
    }

    #[test]
    fn heap_matches_reference_on_benchmarks() {
        // Exact agreement of the optimized scheduler with the retained
        // reference re-scan on the real benchmark graphs, across all
        // registered testbeds.
        for tb in Testbed::registered() {
            let mut rng = crate::util::Rng::new(0xD1FF);
            for b in Benchmark::ALL {
                let g = b.build();
                let p = Placement(
                    (0..g.n()).map(|_| tb.placeable[rng.below(tb.n_actions())]).collect(),
                );
                let fast = execute(&g, &p, &tb);
                let slow = execute_reference(&g, &p, &tb);
                assert_eq!(fast.makespan, slow.makespan, "{}/{}", tb.id, b.id());
                assert_eq!(fast.busy, slow.busy, "{}/{}", tb.id, b.id());
                assert_eq!(fast.n_transfers, slow.n_transfers, "{}/{}", tb.id, b.id());
                assert_eq!(
                    fast.bytes_transferred, slow.bytes_transferred,
                    "{}/{}",
                    tb.id,
                    b.id()
                );
                assert_eq!(fast.mem_peak, slow.mem_peak, "{}/{}", tb.id, b.id());
                assert_eq!(fast.oom_devices, slow.oom_devices, "{}/{}", tb.id, b.id());
            }
        }
    }

    #[test]
    fn heap_matches_reference_prop() {
        check(
            "heap-vs-reference",
            PropConfig { cases: 48, max_size: 80, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let tbs = Testbed::registered();
                let tb = &tbs[rng.below(tbs.len())];
                let placement = Placement(
                    (0..g.n()).map(|_| tb.placeable[rng.below(tb.n_actions())]).collect(),
                );
                let fast = execute(&g, &placement, tb);
                let slow = execute_reference(&g, &placement, tb);
                if fast.makespan != slow.makespan {
                    return Err(format!(
                        "{}: heap {} != reference {}",
                        tb.id, fast.makespan, slow.makespan
                    ));
                }
                if fast.busy != slow.busy || fast.n_transfers != slow.n_transfers {
                    return Err(format!("{}: report mismatch", tb.id));
                }
                if fast.mem_peak != slow.mem_peak || fast.oom_devices != slow.oom_devices {
                    return Err(format!("{}: memory report mismatch", tb.id));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn unbounded_testbeds_always_feasible() {
        let g = Benchmark::ResNet50.build();
        let tb = Testbed::paper();
        for p in [Placement::all(g.n(), CPU), Placement::all(g.n(), DGPU)] {
            let rep = execute(&g, &p, &tb);
            assert!(rep.feasible(), "unbounded capacity can never OOM");
            assert_eq!(rep.mem_peak.len(), tb.n_devices());
            assert!(rep.mem_peak[p.0[0]] > 0.0, "placed device holds live bytes");
        }
    }

    #[test]
    fn chain_memory_peak_bounds() {
        let g = conv_chain(4);
        let tb = Testbed::paper();
        let rep = execute(&g, &Placement::all(g.n(), CPU), &tb);
        let per_node: Vec<f64> = g.nodes.iter().map(|n| n.out_bytes()).collect();
        let largest = per_node.iter().cloned().fold(0f64, f64::max);
        let total: f64 = per_node.iter().sum();
        assert!(rep.mem_peak[CPU] >= largest, "{} < {largest}", rep.mem_peak[CPU]);
        assert!(rep.mem_peak[CPU] <= total, "{} > {total}", rep.mem_peak[CPU]);
        // Unused devices hold nothing.
        assert_eq!(rep.mem_peak[DGPU], 0.0);
    }

    #[test]
    fn constants_prestaged_on_consumer_device() {
        let mut g = CompGraph::new("w");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 4]));
        let w = g.add_node(OpNode::new("w", OpKind::Constant, vec![4, 4]));
        let m = g.add_node(
            OpNode::new("mm", OpKind::MatMul, vec![1, 4])
                .with_attrs(OpAttrs { reduce_dim: 4, ..Default::default() }),
        );
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 4]));
        g.add_edge(i, m);
        g.add_edge(w, m);
        g.add_edge(m, o);
        let tb = Testbed::paper();
        // Weight nominally on CPU, its consumer on the dGPU: the 64-byte
        // weight is pre-staged on the consumer's device, not the CPU.
        let p = Placement(vec![CPU, CPU, DGPU, CPU]);
        let rep = execute(&g, &p, &tb);
        let w_bytes = g.nodes[w].out_bytes();
        assert_eq!(w_bytes, 64.0);
        assert!(rep.mem_peak[DGPU] >= w_bytes, "{}", rep.mem_peak[DGPU]);
        assert!(rep.mem_peak[CPU] < w_bytes, "{}", rep.mem_peak[CPU]);
    }

    #[test]
    fn cross_device_copy_counted_on_consumer() {
        let g = conv_chain(2);
        let tb = Testbed::paper();
        let mut p = Placement::all(g.n(), CPU);
        p.0[2] = DGPU; // second conv on the dGPU
        let rep = execute(&g, &p, &tb);
        // The dGPU holds its own output plus the copied producer output.
        let own = g.nodes[2].out_bytes();
        let copied = g.nodes[1].out_bytes();
        assert!(rep.mem_peak[DGPU] >= own + copied, "{} < {}", rep.mem_peak[DGPU], own + copied);
    }

    #[test]
    fn shared_remote_copy_counted_once_per_device() {
        // One producer on CPU feeding two consumers on the dGPU: the
        // consumers share a single copied buffer, so the dGPU peak is
        // bounded by copy + both outputs (per-edge counting would admit
        // four tensors).
        let mut g = CompGraph::new("fan");
        let i = g.add_node(OpNode::new("in", OpKind::Parameter, vec![1, 64]));
        let a = g.add_node(OpNode::new("a", OpKind::Relu, vec![1, 64]));
        let c1 = g.add_node(OpNode::new("c1", OpKind::Relu, vec![1, 64]));
        let c2 = g.add_node(OpNode::new("c2", OpKind::Sigmoid, vec![1, 64]));
        let o = g.add_node(OpNode::new("out", OpKind::Result, vec![1, 64]));
        g.add_edge(i, a);
        g.add_edge(a, c1);
        g.add_edge(a, c2);
        g.add_edge(c1, o);
        g.add_edge(c2, o);
        let tb = Testbed::paper();
        let p = Placement(vec![CPU, CPU, DGPU, DGPU, DGPU]);
        let rep = execute(&g, &p, &tb);
        let b = g.nodes[a].out_bytes();
        assert!(rep.mem_peak[DGPU] <= 3.0 * b + 1e-9, "{}", rep.mem_peak[DGPU]);
        assert!(rep.mem_peak[DGPU] >= 2.0 * b, "{}", rep.mem_peak[DGPU]);
    }

    #[test]
    fn oom_flagged_without_changing_the_schedule() {
        let g = Benchmark::ResNet50.build();
        let mut tight = Testbed::paper();
        tight.devices[DGPU].mem_capacity = 1.0; // one byte: everything OOMs
        let p = Placement::all(g.n(), DGPU);
        let constrained = execute(&g, &p, &tight);
        let unbounded = execute(&g, &p, &Testbed::paper());
        assert!(!constrained.feasible());
        assert_eq!(constrained.oom_devices, vec![DGPU]);
        assert_eq!(constrained.makespan, unbounded.makespan);
        assert_eq!(constrained.mem_peak, unbounded.mem_peak);
    }

    #[test]
    fn utilization_in_unit_range() {
        let g = Benchmark::InceptionV3.build();
        let tb = Testbed::paper();
        let mut rng = crate::util::Rng::new(3);
        let p = Placement((0..g.n()).map(|_| [CPU, DGPU][rng.below(2)]).collect());
        let rep = execute(&g, &p, &tb);
        for (d, u) in rep.utilization(&tb).iter().enumerate() {
            assert!((0.0..=1.0).contains(u), "device {d}: utilization {u}");
        }
        // The 2-lane CPU can host more busy-seconds than the makespan;
        // lane normalization is what keeps the fraction in [0, 1].
        let all_cpu = execute(&g, &Placement::all(g.n(), CPU), &tb);
        assert!(all_cpu.utilization(&tb)[CPU] <= 1.0);
    }

    #[test]
    fn measure_from_matches_measure() {
        let g = conv_chain(3);
        let tb = Testbed::paper();
        let p = Placement::all(g.n(), CPU);
        let base = execute(&g, &p, &tb).makespan;
        let mut a = crate::util::Rng::new(42);
        let mut b = crate::util::Rng::new(42);
        assert_eq!(measure(&g, &p, &tb, 0.05, &mut a), measure_from(base, 0.05, &mut b));
        assert_eq!(measure_from(base, 0.0, &mut b), base);
    }

    #[test]
    fn with_memo_report_matches_execute() {
        for tb in Testbed::registered() {
            let mut rng = crate::util::Rng::new(0xBEEF);
            let g = Benchmark::InceptionV3.build();
            let p = Placement(
                (0..g.n()).map(|_| tb.placeable[rng.below(tb.n_actions())]).collect(),
            );
            let plain = execute(&g, &p, &tb);
            let (rep, memo) = execute_with_memo(&g, &p, &tb);
            assert_eq!(plain, rep, "{}", tb.id);
            assert_eq!(memo.n(), g.n());
        }
    }

    #[test]
    fn incremental_matches_full_on_randomized_edit_sequences() {
        // THE differential test of the incremental mode: random graphs,
        // random starting placements, then a sequence of random edits
        // (single-node flips and small batches); after every edit the
        // incremental report must equal a fresh full run bit-for-bit,
        // on every field of the report.
        check(
            "incremental-vs-full",
            PropConfig { cases: 24, max_size: 70, ..Default::default() },
            |rng, size| {
                let g = CompGraph::random(rng, size, size / 3);
                let tbs = Testbed::registered();
                let tb = tbs[rng.below(tbs.len())].clone();
                let mut actions: Vec<usize> =
                    (0..g.n()).map(|_| tb.placeable[rng.below(tb.n_actions())]).collect();
                let mut eval = IncrementalEvaluator::new(g.clone(), tb.clone());
                for step in 0..8 {
                    // Edit: flip 1..4 random nodes (step 0 evaluates the
                    // unedited placement to seed the memo).
                    if step > 0 {
                        for _ in 0..1 + rng.below(3) {
                            let v = rng.below(g.n());
                            actions[v] = tb.placeable[rng.below(tb.n_actions())];
                        }
                    }
                    let inc = eval.evaluate(&actions);
                    let full = execute(&g, &Placement(actions.clone()), &tb);
                    if inc != full {
                        return Err(format!(
                            "step {step}: incremental {:?} != full {:?} ({})",
                            inc.makespan, full.makespan, tb.id
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn incremental_noop_edit_is_exact() {
        let g = Benchmark::ResNet50.build();
        let tb = Testbed::paper();
        let p: Vec<usize> = (0..g.n()).map(|v| [CPU, DGPU][v % 2]).collect();
        let mut eval = IncrementalEvaluator::new(g.clone(), tb.clone());
        let a = eval.evaluate(&p);
        let b = eval.evaluate(&p); // no edit: pure prefix replay
        assert_eq!(a, b);
        assert_eq!(a, execute(&g, &Placement(p), &tb));
    }

    #[test]
    fn makespan_lower_bounded_by_critical_path_prop() {
        check("makespan-bounds", PropConfig { cases: 32, max_size: 60, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 4);
            let tb = Testbed::paper();
            let placement =
                Placement((0..g.n()).map(|_| [CPU, DGPU][rng.below(2)]).collect());
            let rep = execute(&g, &placement, &tb);
            // Lower bound: max over devices of its busy time.
            let max_busy = rep.busy.iter().cloned().fold(0f64, f64::max);
            if rep.makespan + 1e-12 < max_busy {
                return Err(format!("makespan {} < busy {}", rep.makespan, max_busy));
            }
            // Upper bound: sum of all op times on their device + all
            // transfer times (serial execution).
            let serial: f64 = (0..g.n())
                .map(|v| tb.devices[placement.0[v]].op_time(&g.nodes[v]))
                .sum::<f64>()
                + g.edges
                    .iter()
                    .map(|&(s, d)| {
                        if placement.0[s] != placement.0[d] {
                            tb.links[placement.0[s]][placement.0[d]]
                                .transfer_time(g.nodes[s].out_bytes())
                        } else {
                            0.0
                        }
                    })
                    .sum::<f64>();
            if rep.makespan > serial + 1e-9 {
                return Err(format!("makespan {} > serial {}", rep.makespan, serial));
            }
            Ok(())
        });
    }
}

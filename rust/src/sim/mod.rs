//! Heterogeneous execution engine: the substitute for the paper's
//! CPU + iGPU + dGPU OpenVINO testbed (DESIGN.md §4). Device roofline
//! models, link models, a registry of `Testbed`s addressable by string id
//! (`cpu_gpu`, `paper3`, `multi_gpu:<k>`), an event-driven list scheduler
//! producing the latency l_P(G) the RL reward is built from, and the
//! downstream numeric drift model behind Table 4.

pub mod device;
pub mod numerics;
pub mod scheduler;

pub use device::{DeviceId, DeviceKind, DeviceModel, LinkModel, Testbed, CPU, DGPU, IGPU};
pub use scheduler::{execute, execute_reference, measure, ExecReport, Placement};

//! Heterogeneous execution engine: the substitute for the paper's
//! CPU + iGPU + dGPU OpenVINO testbed (DESIGN.md §4). Device roofline
//! models with memory capacities, link models, a registry of `Testbed`s
//! addressable by string id (`cpu_gpu`, `paper3`, `cpu_gpu_tight`,
//! `multi_gpu:<k>[:<mem_gb>]`), an event-driven list scheduler producing
//! the latency l_P(G) the RL reward is built from plus per-device memory
//! high-water / feasibility, a pluggable `CostModel` layer with batched
//! (`evaluate_many`) and parallel request-stream (`measure_many`)
//! evaluation over a scoped worker pool, an incremental re-simulation
//! mode (`IncrementalEvaluator`: memoized schedules replay the
//! unaffected event prefix and re-simulate only from the first event a
//! placement edit can reach — bit-identical to full re-evaluation), and
//! the downstream numeric drift model behind Table 4.

pub mod cost;
pub mod device;
pub mod numerics;
pub mod scheduler;

/// The worker pool moved to [`crate::util::pool`] when the `runtime/nn`
/// kernels and the serve router started sharing it; re-exported so
/// `sim::pool` call sites keep working.
pub use crate::util::pool;

pub use cost::{request_rng, AnalyticCostModel, CostModel, ParallelCostModel, ReferenceCostModel};
pub use device::{DeviceId, DeviceKind, DeviceModel, LinkModel, Testbed, CPU, DGPU, IGPU};
pub use scheduler::{
    execute, execute_incremental, execute_reference, execute_with_memo, measure, measure_from,
    ExecReport, IncrementalEvaluator, Placement, SimMemo,
};

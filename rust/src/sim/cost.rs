//! Pluggable placement cost models — the seam between search and the
//! simulator.
//!
//! `CostModel` abstracts how a placement is scored: `AnalyticCostModel`
//! (the default) runs the event-driven lazy-heap list scheduler
//! (`scheduler::execute`); `ReferenceCostModel` runs the retained linear
//! re-scan (the behavioral specification, for differential testing);
//! `ParallelCostModel` wraps any model and fans the batched entry points
//! out over the shared scoped `std::thread` worker pool
//! (`crate::util::pool`).
//!
//! Batched entry points:
//! - [`CostModel::evaluate_many`]: one graph, many placements — the shape
//!   of a search step / population evaluation;
//! - [`CostModel::measure_many`]: one placement, many noisy requests —
//!   the shape of a serving stream. The invariant base simulation runs
//!   once and each request draws its noise from a counter-derived RNG
//!   ([`request_rng`]), so the stream is bit-identical to the naive
//!   per-request `measure` loop, order-independent, and parallelizes
//!   without changing a single result.
//!
//! Contract: implementations are deterministic, and batched calls return
//! exactly what the serial default bodies below return, in the same
//! order — parallel implementations included. `tests/cost_model.rs` and
//! `benches/bench_sim.rs` enforce this.

use super::device::Testbed;
use super::scheduler::{execute, execute_reference, measure_from, ExecReport, Placement};
use crate::graph::CompGraph;
use crate::util::{pool, Rng};

/// A placement cost model: maps (graph, placement, testbed) to a full
/// [`ExecReport`] (latency, busy time, transfer volume, memory
/// high-water, feasibility).
pub trait CostModel: Send + Sync {
    /// Short id for reports and logs.
    fn id(&self) -> &'static str;

    /// Simulate one placement.
    fn evaluate(&self, g: &CompGraph, p: &Placement, tb: &Testbed) -> ExecReport;

    /// Evaluate a batch of placements (default: the serial loop).
    fn evaluate_many(&self, g: &CompGraph, ps: &[Placement], tb: &Testbed) -> Vec<ExecReport> {
        ps.iter().map(|p| self.evaluate(g, p, tb)).collect()
    }

    /// Serve a stream of `n_requests` measurements of one placement.
    /// The deterministic base simulation runs once — the measurement
    /// noise is multiplicative on an invariant makespan, so this is
    /// bit-identical to the naive per-request `measure` loop it
    /// replaces (`benches/bench_sim.rs` asserts the identity and
    /// quotes the speedup). Request `i` draws from its own
    /// [`request_rng`]-derived generator, making the stream
    /// order-independent; `sigma = 0` yields the deterministic makespan
    /// for every request.
    fn measure_many(
        &self,
        g: &CompGraph,
        p: &Placement,
        tb: &Testbed,
        sigma: f64,
        base_seed: u64,
        n_requests: usize,
    ) -> Vec<f64> {
        let base = self.evaluate(g, p, tb).makespan;
        self.measure_many_from(base, sigma, base_seed, n_requests)
    }

    /// Noise-only variant of [`CostModel::measure_many`] for callers that
    /// already hold the placement's deterministic makespan (e.g. from an
    /// `evaluate` they needed anyway): applies the measurement protocol
    /// per request without re-running the simulator. Same per-request
    /// RNGs, so `measure_many(g, p, tb, ...) ==
    /// measure_many_from(evaluate(g, p, tb).makespan, ...)`.
    fn measure_many_from(
        &self,
        base: f64,
        sigma: f64,
        base_seed: u64,
        n_requests: usize,
    ) -> Vec<f64> {
        (0..n_requests)
            .map(|i| measure_from(base, sigma, &mut request_rng(base_seed, i)))
            .collect()
    }
}

/// Per-request RNG: one independent generator per (stream seed, request
/// index), so a request's noise never depends on the requests scheduled
/// before it — the property that lets `measure_many` parallelize with
/// bit-identical results.
pub fn request_rng(base_seed: u64, i: usize) -> Rng {
    Rng::new(base_seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15))
}

/// The default analytic model: the lazy-heap list scheduler.
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticCostModel;

impl CostModel for AnalyticCostModel {
    fn id(&self) -> &'static str {
        "analytic"
    }

    fn evaluate(&self, g: &CompGraph, p: &Placement, tb: &Testbed) -> ExecReport {
        execute(g, p, tb)
    }
}

/// The retained pre-optimization re-scan scheduler as a cost model (the
/// behavioral specification `AnalyticCostModel` is differential-tested
/// against).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReferenceCostModel;

impl CostModel for ReferenceCostModel {
    fn id(&self) -> &'static str {
        "reference"
    }

    fn evaluate(&self, g: &CompGraph, p: &Placement, tb: &Testbed) -> ExecReport {
        execute_reference(g, p, tb)
    }
}

/// Wraps any cost model and parallelizes the batched entry points over a
/// scoped worker pool; single-placement `evaluate` stays inline. Results
/// are positionally identical to the wrapped model's serial loop.
#[derive(Debug, Clone, Copy)]
pub struct ParallelCostModel<M: CostModel> {
    inner: M,
    /// Worker threads for batched calls (0 = one per available core).
    workers: usize,
}

impl<M: CostModel> ParallelCostModel<M> {
    pub fn new(inner: M, workers: usize) -> Self {
        ParallelCostModel { inner, workers }
    }
}

impl<M: CostModel> CostModel for ParallelCostModel<M> {
    fn id(&self) -> &'static str {
        "parallel"
    }

    fn evaluate(&self, g: &CompGraph, p: &Placement, tb: &Testbed) -> ExecReport {
        self.inner.evaluate(g, p, tb)
    }

    fn evaluate_many(&self, g: &CompGraph, ps: &[Placement], tb: &Testbed) -> Vec<ExecReport> {
        pool::map_indexed(ps.len(), self.workers, |i| self.inner.evaluate(g, &ps[i], tb))
    }

    fn measure_many(
        &self,
        g: &CompGraph,
        p: &Placement,
        tb: &Testbed,
        sigma: f64,
        base_seed: u64,
        n_requests: usize,
    ) -> Vec<f64> {
        let base = self.inner.evaluate(g, p, tb).makespan;
        self.measure_many_from(base, sigma, base_seed, n_requests)
    }

    fn measure_many_from(
        &self,
        base: f64,
        sigma: f64,
        base_seed: u64,
        n_requests: usize,
    ) -> Vec<f64> {
        if n_requests < PAR_STREAM_MIN {
            // A post-hoisting request is ~10 RNG draws: below this the
            // pool's spawn/join overhead exceeds the work. Same results
            // either way (counter-derived RNGs).
            return (0..n_requests)
                .map(|i| measure_from(base, sigma, &mut request_rng(base_seed, i)))
                .collect();
        }
        pool::map_indexed(n_requests, self.workers, |i| {
            measure_from(base, sigma, &mut request_rng(base_seed, i))
        })
    }
}

/// Minimum stream length before `ParallelCostModel::measure_many_from`
/// fans the (cheap, post-hoisting) noise loop out over the pool.
const PAR_STREAM_MIN: usize = 4096;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::random_placement;
    use crate::models::Benchmark;

    fn random_placements(g: &CompGraph, tb: &Testbed, n: usize, seed: u64) -> Vec<Placement> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| random_placement(g, tb, &mut rng)).collect()
    }

    #[test]
    fn analytic_matches_execute() {
        let g = Benchmark::ResNet50.build();
        let tb = Testbed::cpu_gpu();
        let p = Placement::all(g.n(), tb.accel());
        let a = AnalyticCostModel.evaluate(&g, &p, &tb);
        let b = execute(&g, &p, &tb);
        assert_eq!(a, b);
        assert_eq!(AnalyticCostModel.id(), "analytic");
    }

    #[test]
    fn reference_matches_reference_scheduler() {
        let g = Benchmark::InceptionV3.build();
        let tb = Testbed::paper3();
        let p = random_placements(&g, &tb, 1, 7).pop().unwrap();
        assert_eq!(
            ReferenceCostModel.evaluate(&g, &p, &tb),
            execute_reference(&g, &p, &tb)
        );
    }

    #[test]
    fn parallel_evaluate_many_identical_to_serial() {
        let g = Benchmark::ResNet50.build();
        for tb in Testbed::registered() {
            let ps = random_placements(&g, &tb, 12, 0xBA7C);
            let serial = AnalyticCostModel.evaluate_many(&g, &ps, &tb);
            let parallel = ParallelCostModel::new(AnalyticCostModel, 0).evaluate_many(&g, &ps, &tb);
            assert_eq!(serial, parallel, "{}", tb.id);
        }
    }

    #[test]
    fn parallel_measure_many_identical_to_serial() {
        let g = Benchmark::BertBase.build();
        let tb = Testbed::cpu_gpu();
        let p = Placement::all(g.n(), tb.accel());
        let serial = AnalyticCostModel.measure_many(&g, &p, &tb, 0.03, 99, 32);
        let parallel =
            ParallelCostModel::new(AnalyticCostModel, 4).measure_many(&g, &p, &tb, 0.03, 99, 32);
        assert_eq!(serial, parallel);
        // ... and both equal the naive per-request measure loop they
        // replace (same per-request RNGs, base re-simulated every time).
        let naive: Vec<f64> = (0..32)
            .map(|i| crate::sim::measure(&g, &p, &tb, 0.03, &mut request_rng(99, i)))
            .collect();
        assert_eq!(naive, serial);
        // ... and the noise-only variant off a precomputed base agrees.
        let base = execute(&g, &p, &tb).makespan;
        assert_eq!(serial, AnalyticCostModel.measure_many_from(base, 0.03, 99, 32));
        let par = ParallelCostModel::new(AnalyticCostModel, 2);
        assert_eq!(serial, par.measure_many_from(base, 0.03, 99, 32));
        // sigma = 0: every request is the deterministic makespan.
        let det = AnalyticCostModel.measure_many(&g, &p, &tb, 0.0, 99, 4);
        let base = execute(&g, &p, &tb).makespan;
        assert!(det.iter().all(|&l| l == base));
    }

    #[test]
    fn request_rng_is_deterministic_and_independent() {
        let a: Vec<u64> = (0..4).map(|i| request_rng(5, i).next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|i| request_rng(5, i).next_u64()).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len(), "request streams must decorrelate");
    }
}

//! Downstream-numerics model (Table 4): how much do the model's *outputs*
//! drift when ops run on different devices?
//!
//! The paper checks that placements do not change task accuracy: BERT
//! output embeddings under CPU-only / GPU-only / HSDAG placements are
//! compared by MSE, cosine similarity and L2 distance (Table 4), and
//! Inception/ResNet classification accuracy is unchanged (§3.5).
//!
//! Substitution: we cannot run the real models, so we model per-op numeric
//! error accumulation. Each op contributes a deterministic pseudo-random
//! perturbation whose magnitude scales with the op's FLOPs (more
//! accumulation -> more rounding) and a device-class factor (GPU math
//! (fused, reordered reductions) diverges from the CPU reference more than
//! CPU math does). A placement's output embedding is the reference
//! embedding plus the accumulated perturbation of every op on a non-CPU
//! device. This reproduces the *shape* of Table 4: placements that keep
//! most FLOPs on the CPU stay closest to CPU outputs, and all differences
//! are tiny (cosine ~ 0.999).

use super::scheduler::Placement;
use crate::graph::CompGraph;
use crate::sim::device::{DeviceId, CPU};
use crate::util::Rng;

/// Dimension of the pseudo output embedding (BERT pooler width).
pub const EMB_DIM: usize = 768;

/// Relative rounding scale per accumulated FLOP^(1/2) on a non-reference
/// device. Chosen so Table 4's magnitudes (MSE ~ 3e-5 CPU-vs-GPU) emerge.
const DEVICE_EPS: [f64; 3] = [0.0, 2.5e-7, 3.0e-7];

/// Deterministic reference embedding for a graph (what the "true" CPU
/// output would be) — a unit-ish vector seeded by the graph name.
pub fn reference_embedding(g: &CompGraph) -> Vec<f64> {
    let seed = g.name.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    let mut rng = Rng::new(seed);
    (0..EMB_DIM).map(|_| rng.next_gauss()).collect()
}

/// Output embedding of `g` under `placement`.
pub fn output_embedding(g: &CompGraph, placement: &Placement) -> Vec<f64> {
    let mut out = reference_embedding(g);
    for (v, node) in g.nodes.iter().enumerate() {
        let d: DeviceId = placement.0[v];
        if d == CPU {
            continue;
        }
        let eps = DEVICE_EPS[d.min(DEVICE_EPS.len() - 1)];
        if eps == 0.0 || node.flops() == 0.0 {
            continue;
        }
        // Per-op deterministic direction, magnitude ~ eps * sqrt(flops).
        let seed = (v as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (d as u64);
        let mut rng = Rng::new(seed);
        let mag = eps * node.flops().sqrt();
        for o in out.iter_mut() {
            *o += mag * rng.next_gauss() / (EMB_DIM as f64).sqrt();
        }
    }
    out
}

/// Table 4 metrics between two embeddings.
#[derive(Debug, Clone, Copy)]
pub struct DriftMetrics {
    pub mse: f64,
    pub cosine: f64,
    pub l2: f64,
}

pub fn drift(a: &[f64], b: &[f64]) -> DriftMetrics {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let mse = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / n;
    let l2 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    DriftMetrics { mse, cosine: dot / (na * nb), l2 }
}

/// Classification-accuracy model (§3.5 sanity check): accuracy under a
/// placement differs from the reference accuracy by a sub-0.5% deterministic
/// wobble driven by the same drift model.
pub fn classification_accuracy(g: &CompGraph, placement: &Placement, base_acc: f64) -> f64 {
    let emb = output_embedding(g, placement);
    let reference = reference_embedding(g);
    let m = drift(&reference, &emb);
    // Map L2 drift to a tiny accuracy wobble (sign from parity of bits).
    let wobble = (m.l2 * 100.0).min(0.5);
    let sign = if (m.l2 * 1e9) as u64 % 2 == 0 { 1.0 } else { -1.0 };
    (base_acc + sign * wobble).clamp(0.0, 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Benchmark;
    use crate::sim::device::{CPU, DGPU};

    #[test]
    fn cpu_placement_is_exact_reference() {
        let g = Benchmark::BertBase.build();
        let p = Placement::all(g.n(), CPU);
        let m = drift(&reference_embedding(&g), &output_embedding(&g, &p));
        assert_eq!(m.mse, 0.0);
        assert_eq!(m.l2, 0.0);
        assert!((m.cosine - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gpu_drift_small_but_nonzero() {
        let g = Benchmark::BertBase.build();
        let gpu = output_embedding(&g, &Placement::all(g.n(), DGPU));
        let cpu = output_embedding(&g, &Placement::all(g.n(), CPU));
        let m = drift(&cpu, &gpu);
        assert!(m.mse > 0.0 && m.mse < 1e-2, "mse {}", m.mse);
        assert!(m.cosine > 0.995, "cos {}", m.cosine);
    }

    #[test]
    fn mostly_cpu_placement_closer_to_cpu_than_gpu_is() {
        // The Table 4 shape: CPU-vs-HSDAG << CPU-vs-GPU when HSDAG keeps
        // most FLOPs on CPU.
        let g = Benchmark::BertBase.build();
        let cpu = output_embedding(&g, &Placement::all(g.n(), CPU));
        let gpu = output_embedding(&g, &Placement::all(g.n(), DGPU));
        // Mixed: only the first quarter of nodes on GPU.
        let mut mix = Placement::all(g.n(), CPU);
        for v in 0..g.n() / 4 {
            mix.0[v] = DGPU;
        }
        let mixed = output_embedding(&g, &mix);
        let d_gpu = drift(&cpu, &gpu);
        let d_mix = drift(&cpu, &mixed);
        assert!(d_mix.mse < d_gpu.mse, "mix {} vs gpu {}", d_mix.mse, d_gpu.mse);
    }

    #[test]
    fn drift_metrics_identity() {
        let a = vec![1.0, 2.0, 3.0];
        let m = drift(&a, &a);
        assert_eq!(m.mse, 0.0);
        assert_eq!(m.l2, 0.0);
        assert!((m.cosine - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_wobble_bounded() {
        let g = Benchmark::InceptionV3.build();
        for p in [Placement::all(g.n(), CPU), Placement::all(g.n(), DGPU)] {
            let acc = classification_accuracy(&g, &p, 82.7);
            assert!((acc - 82.7).abs() <= 0.5);
        }
    }

    #[test]
    fn deterministic() {
        let g = Benchmark::BertBase.build();
        let p = Placement::all(g.n(), DGPU);
        assert_eq!(output_embedding(&g, &p), output_embedding(&g, &p));
    }
}

//! Scoped `std::thread` worker pool for batched placement evaluation.
//!
//! The offline crate set has no rayon; this is the minimal deterministic
//! fan-out the `CostModel` batched paths need: an atomic work counter,
//! scoped workers (one per core, capped by the item count), and
//! index-ordered result assembly — so parallel results are positionally
//! identical to the serial loop, which the cost-model contract requires.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of workers a batched call should actually use: the explicit
/// request if nonzero, else one per available core; never more than the
/// item count and never zero.
pub fn effective_workers(requested: usize, n_items: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let w = if requested == 0 { hw } else { requested };
    w.min(n_items).max(1)
}

/// Compute `f(i)` for `i in 0..n` on `workers` scoped threads and return
/// the results in index order. `workers == 0` means one per core; one
/// worker (or one item) degenerates to the plain serial loop. Work is
/// claimed from a shared counter, so uneven item costs balance
/// automatically.
pub fn map_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = effective_workers(workers, n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(i)));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("pool worker panicked") {
                slots[i] = Some(v);
            }
        }
    });
    slots.into_iter().map(|o| o.expect("every index computed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map() {
        let serial: Vec<usize> = (0..100).map(|i| i * i).collect();
        for workers in [0, 1, 3, 7] {
            assert_eq!(map_indexed(100, workers, |i| i * i), serial, "workers {workers}");
        }
    }

    #[test]
    fn handles_fewer_items_than_workers() {
        assert_eq!(map_indexed(2, 16, |i| i + 1), vec![1, 2]);
        assert_eq!(map_indexed(1, 16, |i| i), vec![0]);
    }

    #[test]
    fn empty_input_is_empty_output() {
        assert_eq!(map_indexed(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn effective_workers_bounds() {
        assert_eq!(effective_workers(4, 100), 4);
        assert_eq!(effective_workers(4, 2), 2);
        assert!(effective_workers(0, 100) >= 1);
        assert_eq!(effective_workers(0, 1), 1);
        assert_eq!(effective_workers(9, 0), 1);
    }

    #[test]
    fn balances_uneven_work() {
        // Items with wildly different costs still all complete and land in
        // order (the counter-based claim makes this safe by construction;
        // this is a smoke test that nothing deadlocks or reorders).
        let out = map_indexed(64, 8, |i| {
            if i % 9 == 0 {
                std::hint::black_box((0..20_000).sum::<usize>());
            }
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }
}

//! Device cost models for the heterogeneous execution simulator.
//!
//! Substitutes for the paper's testbed (§3.2): a 12th-gen Intel i9-12900K
//! CPU, UHD 770 iGPU and Data Center GPU Flex 170 dGPU running OpenVINO.
//! Each device is a roofline-style model: per-op launch overhead plus
//! max(compute time, memory time), with separate effective throughputs for
//! contraction ops (conv/matmul — what GPUs accelerate) and everything
//! else. Constants are calibrated (see `calibration` tests in
//! `harness::table2`) so the single-device latency *ratios* land near
//! Table 2: GPU ≈ 1.07x CPU on Inception-V3, ≈ 2.05x on ResNet-50,
//! ≈ 2.30x on BERT.
//!
//! A `Testbed` is the full Definition-2.2 device set `D`: cost models,
//! link matrix, the subset of devices a placer may target (`placeable`,
//! one action per entry) and the reference device the reward is
//! normalized against. Testbeds are addressable by string id through
//! `Testbed::by_id` (`cpu_gpu`, `paper3`, `cpu_gpu_tight`,
//! `multi_gpu:<k>[:<mem_gb>]`), so the number of placement targets is a
//! runtime parameter of the whole pipeline. Each device carries a memory
//! capacity; the paper testbeds are unbounded (so their latency pins are
//! untouched), while the `_tight` / `:<mem_gb>` variants bound it and
//! make the simulator report OOM placements as infeasible.

use crate::graph::{OpKind, OpNode};

/// Device identifier: index into the device list `D` (Definition 2.2).
pub type DeviceId = usize;

/// Device class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    IntegratedGpu,
    DiscreteGpu,
}

/// A roofline cost model for one device.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: String,
    pub kind: DeviceKind,
    /// Effective FLOP/s on convolution ops at full occupancy.
    pub flops_conv: f64,
    /// Effective FLOP/s on matmul ops at full occupancy.
    pub flops_matmul: f64,
    /// Effective FLOP/s on all other compute ops.
    pub flops_other: f64,
    /// Effective memory bandwidth, bytes/s (drives data-movement ops).
    pub mem_bw: f64,
    /// Fixed per-op dispatch overhead, seconds. This is what makes deep
    /// sequential graphs (Inception) GPU-unfriendly in the paper.
    pub launch_overhead: f64,
    /// Occupancy-saturation half point in *output elements*, applied to
    /// contraction ops only: effective throughput is
    /// peak * e / (e + sat_half_elems) for an op producing e elements.
    /// A conv with a small spatial output cannot fill a wide GPU (few
    /// parallel work items); elementwise ops are bandwidth-bound and
    /// unaffected. 0 disables the term.
    pub sat_half_elems: f64,
    /// Independent execution lanes. A 16-core CPU runs independent branches
    /// of the graph concurrently (OpenVINO CPU streams); GPU queues
    /// serialize. This is what makes Inception-V3's wide blocks
    /// CPU-friendly in Table 2.
    pub lanes: usize,
    /// Device memory capacity, bytes. `f64::INFINITY` (the paper
    /// testbeds' value) disables the constraint; bounded values make the
    /// simulator flag placements whose steady-state high-water overflows
    /// the device (`ExecReport::feasible`). Capacities never change the
    /// schedule itself.
    pub mem_capacity: f64,
}

impl DeviceModel {
    /// Execution time of `op` on this device, seconds.
    pub fn op_time(&self, op: &OpNode) -> f64 {
        match op.kind {
            // Graph boundary pseudo-ops cost nothing to "execute".
            OpKind::Parameter | OpKind::Result | OpKind::Constant => 0.0,
            _ => {
                let fl = op.flops();
                let peak = match op.kind {
                    OpKind::Convolution | OpKind::GroupConvolution => self.flops_conv,
                    OpKind::MatMul => self.flops_matmul,
                    _ => self.flops_other,
                };
                // Occupancy saturation: contractions with few output
                // elements see a fraction of peak.
                let eff = if op.kind.is_contraction() && self.sat_half_elems > 0.0 && fl > 0.0 {
                    let e = op.out_elems() as f64;
                    peak * e / (e + self.sat_half_elems)
                } else {
                    peak
                };
                let compute = if fl > 0.0 { fl / eff } else { 0.0 };
                let memory = op.out_bytes() / self.mem_bw;
                self.launch_overhead + compute.max(memory)
            }
        }
    }
}

/// The interconnect between two devices (PCIe-like for the dGPU; shared
/// memory for CPU<->iGPU).
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Bandwidth, bytes/s.
    pub bandwidth: f64,
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }
}

/// The full testbed: device list + link matrix + placement contract.
#[derive(Debug, Clone)]
pub struct Testbed {
    /// Registry id (`cpu_gpu`, `paper3`, `cpu_gpu_tight`,
    /// `multi_gpu:<k>[:<mem_gb>]`, ...).
    pub id: String,
    pub devices: Vec<DeviceModel>,
    /// links[a][b] = cost model for moving a tensor from device a to b.
    pub links: Vec<Vec<LinkModel>>,
    /// Devices the placer chooses between: action index -> device id.
    /// The paper excludes the iGPU from placement (§4 Limitations), which
    /// is why `cpu_gpu` models three devices but exposes two actions.
    pub placeable: Vec<DeviceId>,
    /// Reference device the reward denominator is computed on (the
    /// "CPU-only" row of Table 2).
    pub reference: DeviceId,
}

/// Device ids of the *paper* testbeds (`cpu_gpu` / `paper3`). Other
/// testbeds (e.g. `multi_gpu:<k>`) define their own indexing; only
/// device 0 is guaranteed to be the host CPU everywhere.
pub const CPU: DeviceId = 0;
pub const IGPU: DeviceId = 1;
pub const DGPU: DeviceId = 2;

/// The calibrated i9-12900K / UHD 770 / Flex 170 roofline models (see
/// module docs) shared by the paper testbeds.
fn paper_hardware() -> (Vec<DeviceModel>, Vec<Vec<LinkModel>>) {
    let cpu = DeviceModel {
        name: "CPU (i9-12900K)".to_string(),
        kind: DeviceKind::Cpu,
        flops_conv: 1.15e12,
        flops_matmul: 1.05e12,
        flops_other: 2.4e11,
        mem_bw: 6.0e10,
        launch_overhead: 1.2e-6,
        sat_half_elems: 2.0e3,
        lanes: 2,
        mem_capacity: f64::INFINITY,
    };
    let igpu = DeviceModel {
        name: "GPU.0 (UHD 770)".to_string(),
        kind: DeviceKind::IntegratedGpu,
        flops_conv: 7.0e11,
        flops_matmul: 6.0e11,
        flops_other: 1.5e11,
        mem_bw: 5.0e10,
        launch_overhead: 9.0e-6,
        sat_half_elems: 2.0e5,
        lanes: 1,
        mem_capacity: f64::INFINITY,
    };
    let dgpu = DeviceModel {
        name: "GPU.1 (Flex 170)".to_string(),
        kind: DeviceKind::DiscreteGpu,
        flops_conv: 5.5e12,
        flops_matmul: 1.2e13,
        flops_other: 1.5e12,
        mem_bw: 4.5e11,
        launch_overhead: 3.5e-6,
        sat_half_elems: 1.0e5,
        lanes: 1,
        mem_capacity: f64::INFINITY,
    };
    let same = LinkModel { latency: 0.0, bandwidth: f64::INFINITY };
    let shared = LinkModel { latency: 4.0e-6, bandwidth: 2.5e10 };
    let pcie = LinkModel { latency: 1.1e-5, bandwidth: 1.1e10 };
    let links = vec![
        vec![same, shared, pcie],
        vec![shared, same, pcie],
        vec![pcie, pcie, same],
    ];
    (vec![cpu, igpu, dgpu], links)
}

impl Testbed {
    /// The default testbed: the paper's hardware with the paper's 2-way
    /// CPU/dGPU action space (the iGPU is simulated but not placeable).
    pub fn cpu_gpu() -> Testbed {
        let (devices, links) = paper_hardware();
        Testbed {
            id: "cpu_gpu".to_string(),
            devices,
            links,
            placeable: vec![CPU, DGPU],
            reference: CPU,
        }
    }

    /// Backwards-compatible alias for the calibrated default testbed.
    pub fn paper() -> Testbed {
        Self::cpu_gpu()
    }

    /// The paper's hardware with all three devices placeable — the
    /// configuration §4 calls out as future work.
    pub fn paper3() -> Testbed {
        let (devices, links) = paper_hardware();
        Testbed {
            id: "paper3".to_string(),
            devices,
            links,
            placeable: vec![CPU, IGPU, DGPU],
            reference: CPU,
        }
    }

    /// A serving-style homogeneous cluster: one host CPU plus `k` dGPUs
    /// behind PCIe, every device placeable, CPU as the reference.
    pub fn multi_gpu(k: usize) -> Testbed {
        let k = k.max(1);
        let (paper_devices, _) = paper_hardware();
        let cpu = paper_devices[CPU].clone();
        let gpu_proto = paper_devices[DGPU].clone();
        let mut devices = vec![cpu];
        for i in 0..k {
            let mut g = gpu_proto.clone();
            g.name = format!("GPU.{i} (Flex 170)");
            devices.push(g);
        }
        let n = devices.len();
        let same = LinkModel { latency: 0.0, bandwidth: f64::INFINITY };
        let pcie = LinkModel { latency: 1.1e-5, bandwidth: 1.1e10 };
        // Peer-to-peer GPU copies still cross the PCIe switch.
        let links: Vec<Vec<LinkModel>> = (0..n)
            .map(|a| (0..n).map(|b| if a == b { same } else { pcie }).collect())
            .collect();
        Testbed {
            id: format!("multi_gpu:{k}"),
            devices,
            links,
            placeable: (0..n).collect(),
            reference: CPU,
        }
    }

    /// Memory-constrained variant of the paper testbed: same roofline
    /// models and 2-way CPU/dGPU action space as `cpu_gpu`, but the dGPU
    /// is capped at 64 MB — far below any benchmark's resident weights —
    /// while the host keeps 32 GB. All-accelerator placements OOM here;
    /// this is the registry entry that exercises the feasibility path
    /// end to end.
    pub fn cpu_gpu_tight() -> Testbed {
        let (mut devices, links) = paper_hardware();
        devices[CPU].mem_capacity = 32e9;
        devices[IGPU].mem_capacity = 32e9; // shares host memory
        devices[DGPU].mem_capacity = 64e6;
        Testbed {
            id: "cpu_gpu_tight".to_string(),
            devices,
            links,
            placeable: vec![CPU, DGPU],
            reference: CPU,
        }
    }

    /// [`Testbed::multi_gpu`] with each GPU capped at `mem_gb` GB
    /// (decimal, 1e9 bytes) and the host CPU at 64 GB.
    pub fn multi_gpu_mem(k: usize, mem_gb: usize) -> Testbed {
        let mut tb = Self::multi_gpu(k);
        tb.id = format!("multi_gpu:{}:{mem_gb}", tb.n_devices() - 1);
        tb.devices[CPU].mem_capacity = 64e9;
        for d in tb.devices[1..].iter_mut() {
            d.mem_capacity = mem_gb as f64 * 1e9;
        }
        tb
    }

    /// Resolve a testbed from its registry id: `cpu_gpu` (alias `paper`),
    /// `paper3`, `cpu_gpu_tight`, or `multi_gpu:<k>[:<mem_gb>]` (bare
    /// `multi_gpu` defaults to k=4; the optional third field caps each
    /// GPU's memory).
    pub fn by_id(id: &str) -> Option<Testbed> {
        match id {
            "cpu_gpu" | "paper" => Some(Self::cpu_gpu()),
            "paper3" => Some(Self::paper3()),
            "cpu_gpu_tight" => Some(Self::cpu_gpu_tight()),
            _ => {
                let rest = id.strip_prefix("multi_gpu")?;
                if rest.is_empty() {
                    return Some(Self::multi_gpu(4));
                }
                let mut parts = rest.strip_prefix(':')?.split(':');
                let k: usize = parts.next()?.parse().ok()?;
                if k == 0 {
                    return None;
                }
                match parts.next() {
                    None => Some(Self::multi_gpu(k)),
                    Some(gb) => {
                        let gb: usize = gb.parse().ok()?;
                        if gb == 0 || parts.next().is_some() {
                            return None;
                        }
                        Some(Self::multi_gpu_mem(k, gb))
                    }
                }
            }
        }
    }

    /// The registry ids `by_id` understands (for `--help` / error text).
    pub fn registry_help() -> &'static str {
        "cpu_gpu | paper3 | cpu_gpu_tight | multi_gpu:<k>[:<mem_gb>]"
    }

    /// One representative of each registered testbed family (used by the
    /// plumbing property tests and the serving sweep).
    pub fn registered() -> Vec<Testbed> {
        vec![
            Self::cpu_gpu(),
            Self::paper3(),
            Self::multi_gpu(4),
            Self::cpu_gpu_tight(),
            Self::multi_gpu_mem(2, 8),
        ]
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Size of the policy action space.
    pub fn n_actions(&self) -> usize {
        self.placeable.len()
    }

    /// Map a policy action index to a simulator device id.
    pub fn action_device(&self, action: usize) -> DeviceId {
        self.placeable[action]
    }

    /// The designated accelerator: the placeable device with the highest
    /// matmul throughput, first on ties (the "GPU-only" row of Table 2).
    pub fn accel(&self) -> DeviceId {
        let mut best = self.placeable[0];
        for &d in &self.placeable[1..] {
            if self.devices[d].flops_matmul > self.devices[best].flops_matmul {
                best = d;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpAttrs, OpNode};

    fn big_conv() -> OpNode {
        OpNode::new("c", OpKind::Convolution, vec![1, 256, 56, 56])
            .with_attrs(OpAttrs { taps: 9, reduce_dim: 256, groups: 1 })
    }

    fn tiny_relu() -> OpNode {
        OpNode::new("r", OpKind::Relu, vec![1, 16])
    }

    #[test]
    fn dgpu_faster_on_big_contractions() {
        let tb = Testbed::paper();
        let op = big_conv();
        assert!(tb.devices[DGPU].op_time(&op) < tb.devices[CPU].op_time(&op));
    }

    #[test]
    fn cpu_faster_on_tiny_ops() {
        // Launch overhead dominates tiny ops: CPU wins.
        let tb = Testbed::paper();
        let op = tiny_relu();
        assert!(tb.devices[CPU].op_time(&op) < tb.devices[DGPU].op_time(&op));
    }

    #[test]
    fn igpu_never_best_on_either_class() {
        // Matches the paper's limitation note: iGPU always dominated.
        let tb = Testbed::paper();
        for op in [big_conv(), tiny_relu()] {
            let t = [CPU, IGPU, DGPU].map(|d| tb.devices[d].op_time(&op));
            assert!(t[1] > t[0].min(t[2]), "iGPU best on {:?}", op.kind);
        }
    }

    #[test]
    fn boundary_ops_free() {
        let tb = Testbed::paper();
        let p = OpNode::new("p", OpKind::Parameter, vec![1, 3, 299, 299]);
        assert_eq!(tb.devices[CPU].op_time(&p), 0.0);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let tb = Testbed::paper();
        let l = tb.links[CPU][DGPU];
        assert!(l.transfer_time(1e6) < l.transfer_time(1e7));
        assert!(l.transfer_time(0.0) >= l.latency);
    }

    #[test]
    fn same_device_transfer_free() {
        let tb = Testbed::paper();
        assert_eq!(tb.links[CPU][CPU].transfer_time(1e9), 0.0);
    }

    #[test]
    fn cpu_gpu_matches_paper_contract() {
        let tb = Testbed::cpu_gpu();
        assert_eq!(tb.id, "cpu_gpu");
        assert_eq!(tb.n_devices(), 3);
        assert_eq!(tb.n_actions(), 2);
        assert_eq!(tb.action_device(0), CPU);
        assert_eq!(tb.action_device(1), DGPU);
        assert_eq!(tb.reference, CPU);
        assert_eq!(tb.accel(), DGPU);
    }

    #[test]
    fn paper3_exposes_all_devices() {
        let tb = Testbed::paper3();
        assert_eq!(tb.n_actions(), 3);
        assert_eq!(tb.placeable, vec![CPU, IGPU, DGPU]);
        assert_eq!(tb.accel(), DGPU);
    }

    #[test]
    fn multi_gpu_shape() {
        let tb = Testbed::multi_gpu(4);
        assert_eq!(tb.id, "multi_gpu:4");
        assert_eq!(tb.n_devices(), 5);
        assert_eq!(tb.n_actions(), 5);
        assert_eq!(tb.reference, CPU);
        assert_eq!(tb.accel(), 1); // first GPU (homogeneous tie -> first)
        assert_eq!(tb.links.len(), 5);
        for row in &tb.links {
            assert_eq!(row.len(), 5);
        }
        for d in 0..tb.n_devices() {
            assert_eq!(tb.links[d][d].transfer_time(1e9), 0.0);
        }
        // Degenerate k is clamped, never empty.
        assert_eq!(Testbed::multi_gpu(0).n_devices(), 2);
    }

    #[test]
    fn registry_resolves_ids() {
        assert_eq!(Testbed::by_id("cpu_gpu").unwrap().id, "cpu_gpu");
        assert_eq!(Testbed::by_id("paper").unwrap().id, "cpu_gpu");
        assert_eq!(Testbed::by_id("paper3").unwrap().id, "paper3");
        assert_eq!(Testbed::by_id("multi_gpu:8").unwrap().n_devices(), 9);
        assert_eq!(Testbed::by_id("multi_gpu").unwrap().n_devices(), 5);
        assert!(Testbed::by_id("multi_gpu:0").is_none());
        assert!(Testbed::by_id("multi_gpu:x").is_none());
        assert!(Testbed::by_id("tpu_pod").is_none());
    }

    #[test]
    fn paper_testbeds_have_unbounded_memory() {
        // The pre-existing registry entries must keep infinite capacities:
        // that is what keeps their latency pins / feasibility unchanged.
        for tb in [Testbed::cpu_gpu(), Testbed::paper3(), Testbed::multi_gpu(4)] {
            for d in &tb.devices {
                assert!(d.mem_capacity.is_infinite(), "{}: {}", tb.id, d.name);
            }
        }
    }

    #[test]
    fn tight_testbed_caps_the_accelerator() {
        let tb = Testbed::cpu_gpu_tight();
        assert_eq!(tb.id, "cpu_gpu_tight");
        assert_eq!(tb.n_actions(), 2);
        assert_eq!(tb.accel(), DGPU);
        assert_eq!(tb.devices[DGPU].mem_capacity, 64e6);
        assert!(tb.devices[CPU].mem_capacity > tb.devices[DGPU].mem_capacity);
        // Same hardware as cpu_gpu otherwise: op times agree.
        let loose = Testbed::cpu_gpu();
        let op = big_conv();
        for d in [CPU, IGPU, DGPU] {
            assert_eq!(tb.devices[d].op_time(&op), loose.devices[d].op_time(&op));
        }
    }

    #[test]
    fn registry_resolves_memory_capped_ids() {
        let tb = Testbed::by_id("multi_gpu:2:8").unwrap();
        assert_eq!(tb.id, "multi_gpu:2:8");
        assert_eq!(tb.n_devices(), 3);
        assert_eq!(tb.devices[1].mem_capacity, 8e9);
        assert_eq!(tb.devices[2].mem_capacity, 8e9);
        assert!(tb.devices[CPU].mem_capacity.is_finite());
        assert_eq!(Testbed::by_id("cpu_gpu_tight").unwrap().id, "cpu_gpu_tight");
        assert!(Testbed::by_id("multi_gpu:2:0").is_none());
        assert!(Testbed::by_id("multi_gpu:2:x").is_none());
        assert!(Testbed::by_id("multi_gpu:2:8:1").is_none());
    }

    #[test]
    fn registered_testbeds_are_well_formed() {
        for tb in Testbed::registered() {
            assert!(tb.n_actions() >= 2, "{}", tb.id);
            assert_eq!(tb.links.len(), tb.n_devices(), "{}", tb.id);
            for row in &tb.links {
                assert_eq!(row.len(), tb.n_devices(), "{}", tb.id);
            }
            assert!(tb.reference < tb.n_devices(), "{}", tb.id);
            for &d in &tb.placeable {
                assert!(d < tb.n_devices(), "{}: placeable {d}", tb.id);
            }
            assert!(Testbed::by_id(&tb.id).is_some(), "{} not addressable", tb.id);
        }
    }
}

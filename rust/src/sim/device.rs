//! Device cost models for the heterogeneous execution simulator.
//!
//! Substitutes for the paper's testbed (§3.2): a 12th-gen Intel i9-12900K
//! CPU, UHD 770 iGPU and Data Center GPU Flex 170 dGPU running OpenVINO.
//! Each device is a roofline-style model: per-op launch overhead plus
//! max(compute time, memory time), with separate effective throughputs for
//! contraction ops (conv/matmul — what GPUs accelerate) and everything
//! else. Constants are calibrated (see `calibration` tests in
//! `harness::table2`) so the single-device latency *ratios* land near
//! Table 2: GPU ≈ 1.07x CPU on Inception-V3, ≈ 2.05x on ResNet-50,
//! ≈ 2.30x on BERT.

use crate::graph::{OpKind, OpNode};

/// Device identifier: index into the device list `D` (Definition 2.2).
pub type DeviceId = usize;

/// Device class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    IntegratedGpu,
    DiscreteGpu,
}

/// A roofline cost model for one device.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: &'static str,
    pub kind: DeviceKind,
    /// Effective FLOP/s on convolution ops at full occupancy.
    pub flops_conv: f64,
    /// Effective FLOP/s on matmul ops at full occupancy.
    pub flops_matmul: f64,
    /// Effective FLOP/s on all other compute ops.
    pub flops_other: f64,
    /// Effective memory bandwidth, bytes/s (drives data-movement ops).
    pub mem_bw: f64,
    /// Fixed per-op dispatch overhead, seconds. This is what makes deep
    /// sequential graphs (Inception) GPU-unfriendly in the paper.
    pub launch_overhead: f64,
    /// Occupancy-saturation half point in *output elements*, applied to
    /// contraction ops only: effective throughput is
    /// peak * e / (e + sat_half_elems) for an op producing e elements.
    /// A conv with a small spatial output cannot fill a wide GPU (few
    /// parallel work items); elementwise ops are bandwidth-bound and
    /// unaffected. 0 disables the term.
    pub sat_half_elems: f64,
    /// Independent execution lanes. A 16-core CPU runs independent branches
    /// of the graph concurrently (OpenVINO CPU streams); GPU queues
    /// serialize. This is what makes Inception-V3's wide blocks
    /// CPU-friendly in Table 2.
    pub lanes: usize,
}

impl DeviceModel {
    /// Execution time of `op` on this device, seconds.
    pub fn op_time(&self, op: &OpNode) -> f64 {
        match op.kind {
            // Graph boundary pseudo-ops cost nothing to "execute".
            OpKind::Parameter | OpKind::Result | OpKind::Constant => 0.0,
            _ => {
                let fl = op.flops();
                let peak = match op.kind {
                    OpKind::Convolution | OpKind::GroupConvolution => self.flops_conv,
                    OpKind::MatMul => self.flops_matmul,
                    _ => self.flops_other,
                };
                // Occupancy saturation: contractions with few output
                // elements see a fraction of peak.
                let eff = if op.kind.is_contraction() && self.sat_half_elems > 0.0 && fl > 0.0 {
                    let e = op.out_elems() as f64;
                    peak * e / (e + self.sat_half_elems)
                } else {
                    peak
                };
                let compute = if fl > 0.0 { fl / eff } else { 0.0 };
                let memory = op.out_bytes() / self.mem_bw;
                self.launch_overhead + compute.max(memory)
            }
        }
    }
}

/// The interconnect between two devices (PCIe-like for the dGPU; shared
/// memory for CPU<->iGPU).
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Per-transfer latency, seconds.
    pub latency: f64,
    /// Bandwidth, bytes/s.
    pub bandwidth: f64,
}

impl LinkModel {
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }
}

/// The full testbed: device list + link matrix.
#[derive(Debug, Clone)]
pub struct Testbed {
    pub devices: Vec<DeviceModel>,
    /// links[a][b] = cost model for moving a tensor from device a to b.
    pub links: Vec<Vec<LinkModel>>,
}

/// Devices the *placer* chooses between (the paper excludes the iGPU from
/// placement — §4 Limitations — but OpenVINO baselines may still pick it).
pub const PLACEABLE: [DeviceId; 2] = [CPU, DGPU];

pub const CPU: DeviceId = 0;
pub const IGPU: DeviceId = 1;
pub const DGPU: DeviceId = 2;

impl Testbed {
    /// The calibrated default testbed (see module docs).
    pub fn paper() -> Testbed {
        let cpu = DeviceModel {
            name: "CPU (i9-12900K)",
            kind: DeviceKind::Cpu,
            flops_conv: 1.15e12,
            flops_matmul: 1.05e12,
            flops_other: 2.4e11,
            mem_bw: 6.0e10,
            launch_overhead: 1.2e-6,
            sat_half_elems: 2.0e3,
            lanes: 2,
        };
        let igpu = DeviceModel {
            name: "GPU.0 (UHD 770)",
            kind: DeviceKind::IntegratedGpu,
            flops_conv: 7.0e11,
            flops_matmul: 6.0e11,
            flops_other: 1.5e11,
            mem_bw: 5.0e10,
            launch_overhead: 9.0e-6,
            sat_half_elems: 2.0e5,
            lanes: 1,
        };
        let dgpu = DeviceModel {
            name: "GPU.1 (Flex 170)",
            kind: DeviceKind::DiscreteGpu,
            flops_conv: 5.5e12,
            flops_matmul: 1.2e13,
            flops_other: 1.5e12,
            mem_bw: 4.5e11,
            launch_overhead: 3.5e-6,
            sat_half_elems: 1.0e5,
            lanes: 1,
        };
        let same = LinkModel { latency: 0.0, bandwidth: f64::INFINITY };
        let shared = LinkModel { latency: 4.0e-6, bandwidth: 2.5e10 };
        let pcie = LinkModel { latency: 1.1e-5, bandwidth: 1.1e10 };
        let links = vec![
            vec![same, shared, pcie],
            vec![shared, same, pcie],
            vec![pcie, pcie, same],
        ];
        Testbed { devices: vec![cpu, igpu, dgpu], links }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpAttrs, OpNode};

    fn big_conv() -> OpNode {
        OpNode::new("c", OpKind::Convolution, vec![1, 256, 56, 56])
            .with_attrs(OpAttrs { taps: 9, reduce_dim: 256, groups: 1 })
    }

    fn tiny_relu() -> OpNode {
        OpNode::new("r", OpKind::Relu, vec![1, 16])
    }

    #[test]
    fn dgpu_faster_on_big_contractions() {
        let tb = Testbed::paper();
        let op = big_conv();
        assert!(tb.devices[DGPU].op_time(&op) < tb.devices[CPU].op_time(&op));
    }

    #[test]
    fn cpu_faster_on_tiny_ops() {
        // Launch overhead dominates tiny ops: CPU wins.
        let tb = Testbed::paper();
        let op = tiny_relu();
        assert!(tb.devices[CPU].op_time(&op) < tb.devices[DGPU].op_time(&op));
    }

    #[test]
    fn igpu_never_best_on_either_class() {
        // Matches the paper's limitation note: iGPU always dominated.
        let tb = Testbed::paper();
        for op in [big_conv(), tiny_relu()] {
            let t = [CPU, IGPU, DGPU].map(|d| tb.devices[d].op_time(&op));
            assert!(t[1] > t[0].min(t[2]), "iGPU best on {:?}", op.kind);
        }
    }

    #[test]
    fn boundary_ops_free() {
        let tb = Testbed::paper();
        let p = OpNode::new("p", OpKind::Parameter, vec![1, 3, 299, 299]);
        assert_eq!(tb.devices[CPU].op_time(&p), 0.0);
    }

    #[test]
    fn transfer_monotone_in_bytes() {
        let tb = Testbed::paper();
        let l = tb.links[CPU][DGPU];
        assert!(l.transfer_time(1e6) < l.transfer_time(1e7));
        assert!(l.transfer_time(0.0) >= l.latency);
    }

    #[test]
    fn same_device_transfer_free() {
        let tb = Testbed::paper();
        assert_eq!(tb.links[CPU][CPU].transfer_time(1e9), 0.0);
    }
}

//! Node fractal dimension (Eq. 4): the mass-distribution exponent.
//!
//! For node v with BFS distances {r_k} and box masses N(v, r_k) = #nodes
//! within distance r_k, D(v) is the least-squares slope of
//! log N(v, r) against log r:
//!
//!   D(v) = Σ_k (log r_k - mean(log r)) (log N_k - mean(log N))
//!          ───────────────────────────────────────────────────
//!                       Σ_k (log r_k - mean(log r))²
//!
//! Degenerate cases (isolated nodes, eccentricity < 2) get D(v) = 0 — no
//! multi-scale structure to measure.
//!
//! Two evaluation paths share the per-node estimator:
//!   * exact — one undirected BFS per node, O(n·(n+m)). Bit-exact, used
//!     for every graph at or below [`FRACTAL_EXACT_THRESHOLD`] nodes (or
//!     always, when pinned via `FeatureConfig::exact_fractal`).
//!   * sampled — BFS from O(√n·log n) landmark seeds (capped at
//!     [`LANDMARK_CAP`] so 100k+-node extraction stays near-linear);
//!     landmarks get their exact dimension, every other node an
//!     inverse-distance-weighted blend of its nearest landmarks. With
//!     every node as a landmark the sampled path degenerates to the
//!     exact one bit-for-bit, which is what the differential tests pin.

use crate::graph::CompGraph;
use crate::util::Rng;

/// Fractal dimension of a single node given its undirected BFS distances.
pub fn fractal_dimension_from_dists(dists: &[usize]) -> f64 {
    // Mass at each radius r >= 1 (cumulative node count within distance r).
    let max_r = dists.iter().filter(|&&d| d != usize::MAX).max().copied().unwrap_or(0);
    if max_r < 2 {
        return 0.0;
    }
    let mut mass = vec![0usize; max_r + 1];
    for &d in dists {
        if d != usize::MAX {
            mass[d] += 1;
        }
    }
    // Cumulative.
    for r in 1..=max_r {
        mass[r] += mass[r - 1];
    }
    let pts: Vec<(f64, f64)> =
        (1..=max_r).map(|r| ((r as f64).ln(), (mass[r] as f64).ln())).collect();
    let n = pts.len() as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let num: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let den: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Fractal dimension for every node of `g` (Eq. 4), via per-node BFS.
pub fn fractal_dimensions(g: &CompGraph) -> Vec<f64> {
    (0..g.n()).map(|v| fractal_dimension_from_dists(&g.bfs_undirected(v))).collect()
}

/// Graphs at or below this size always take the exact per-node BFS path.
pub const FRACTAL_EXACT_THRESHOLD: usize = 4096;

/// Ceiling on the landmark budget. √n·ln n is the nominal seed count;
/// the cap keeps total BFS work near-linear at 100k+ nodes.
pub const LANDMARK_CAP: usize = 512;

/// How many non-landmark interpolation anchors each node keeps.
const NEAR_SLOTS: usize = 3;

/// Landmark budget for an `n`-node graph: min(n, ⌈√n·ln n⌉, cap).
pub fn landmark_count(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let nf = n as f64;
    let k = (nf.sqrt() * nf.ln().max(1.0)).ceil() as usize;
    k.clamp(1, LANDMARK_CAP).min(n)
}

/// Exact below [`FRACTAL_EXACT_THRESHOLD`] (or when `pin_exact`), sampled
/// landmarks above — the default feature-extraction entry point.
pub fn fractal_dimensions_auto(g: &CompGraph, pin_exact: bool) -> Vec<f64> {
    if pin_exact || g.n() <= FRACTAL_EXACT_THRESHOLD {
        fractal_dimensions(g)
    } else {
        fractal_dimensions_sampled(g, landmark_count(g.n()))
    }
}

/// Sampled fractal dimensions from `n_landmarks` BFS seeds.
///
/// Landmarks keep their exact per-node dimension; every other node blends
/// the dimensions of its [`NEAR_SLOTS`] nearest landmarks with weights
/// 1/(1+dist). Nodes no landmark reaches (landmarks all in other
/// undirected components) fall back to their own exact BFS, so coverage
/// never silently degrades to a constant. With `n_landmarks >= n` every
/// node is a landmark and the result equals [`fractal_dimensions`]
/// bit-for-bit.
pub fn fractal_dimensions_sampled(g: &CompGraph, n_landmarks: usize) -> Vec<f64> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let k = n_landmarks.clamp(1, n);
    let landmarks = pick_landmarks(n, k);
    let mut is_landmark = vec![false; n];
    let mut exact = vec![0.0f64; n];
    // Per-node (distance, landmark dimension) anchors, ascending by
    // distance; usize::MAX marks an empty slot.
    let mut near = vec![[(usize::MAX, 0.0f64); NEAR_SLOTS]; n];
    for &l in &landmarks {
        is_landmark[l] = true;
        let dists = g.bfs_undirected(l);
        let dim = fractal_dimension_from_dists(&dists);
        exact[l] = dim;
        for (v, &d) in dists.iter().enumerate() {
            if d != usize::MAX {
                insert_anchor(&mut near[v], d, dim);
            }
        }
    }
    (0..n)
        .map(|v| {
            if is_landmark[v] {
                return exact[v];
            }
            let anchors = &near[v];
            if anchors[0].0 == usize::MAX {
                // Unreached: isolated pocket without a landmark.
                return fractal_dimension_from_dists(&g.bfs_undirected(v));
            }
            let mut wsum = 0.0;
            let mut acc = 0.0;
            for &(d, dim) in anchors.iter() {
                if d == usize::MAX {
                    break;
                }
                let w = 1.0 / (1.0 + d as f64);
                wsum += w;
                acc += w * dim;
            }
            acc / wsum
        })
        .collect()
}

/// Keep the slot array sorted ascending by distance; ties keep the
/// earlier landmark (landmark iteration order is deterministic).
fn insert_anchor(slots: &mut [(usize, f64); NEAR_SLOTS], d: usize, dim: f64) {
    let mut i = NEAR_SLOTS;
    while i > 0 && d < slots[i - 1].0 {
        i -= 1;
    }
    if i < NEAR_SLOTS {
        for j in (i..NEAR_SLOTS - 1).rev() {
            slots[j + 1] = slots[j];
        }
        slots[i] = (d, dim);
    }
}

/// Deterministic landmark choice: a seeded partial Fisher–Yates over
/// 0..n keyed on n, so the same graph size always samples the same
/// seed set (results are reproducible run to run).
fn pick_landmarks(n: usize, k: usize) -> Vec<usize> {
    let mut rng = Rng::new(0x5EED_F2AC ^ (n as u64));
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.below(n - i);
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpNode, OpKind};

    fn path(n: usize) -> CompGraph {
        let mut g = CompGraph::new("path");
        let mut prev = g.add_node(OpNode::new("n0", OpKind::Parameter, vec![1]));
        for i in 1..n {
            let v = g.add_node(OpNode::new(format!("n{i}"), OpKind::Relu, vec![1]));
            g.add_edge(prev, v);
            prev = v;
        }
        g
    }

    #[test]
    fn path_endpoint_dimension_near_one() {
        // From the end of a long path, N(r) = r + 1: slope -> ~1.
        let g = path(64);
        let d = fractal_dimension_from_dists(&g.bfs_undirected(0));
        assert!((d - 1.0).abs() < 0.15, "got {d}");
    }

    #[test]
    fn star_center_dimension_small() {
        // Star: everything at distance 1 from the hub; from a leaf, mass
        // saturates at r=2 -> slope well below 1.
        let mut g = CompGraph::new("star");
        let hub = g.add_node(OpNode::new("hub", OpKind::Parameter, vec![1]));
        for i in 0..32 {
            let v = g.add_node(OpNode::new(format!("leaf{i}"), OpKind::Relu, vec![1]));
            g.add_edge(hub, v);
        }
        let d_leaf = fractal_dimension_from_dists(&g.bfs_undirected(1));
        // Leaf: N(1)=2, N(2)=33 -> slope = ln(33/2)/ln(2) ~ 4; hub has
        // eccentricity 1 -> 0 by convention.
        let d_hub = fractal_dimension_from_dists(&g.bfs_undirected(0));
        assert_eq!(d_hub, 0.0);
        assert!(d_leaf > 2.0, "leaf {d_leaf}");
    }

    #[test]
    fn grid_like_higher_than_path() {
        // Binary in-tree has higher mass growth than a path.
        let mut g = CompGraph::new("tree");
        let root = g.add_node(OpNode::new("r", OpKind::Parameter, vec![1]));
        let mut frontier = vec![root];
        let mut idx = 0;
        for _ in 0..5 {
            let mut next = Vec::new();
            for &p in &frontier {
                for _ in 0..2 {
                    idx += 1;
                    let v = g.add_node(OpNode::new(format!("t{idx}"), OpKind::Relu, vec![1]));
                    g.add_edge(p, v);
                    next.push(v);
                }
            }
            frontier = next;
        }
        let d_tree = fractal_dimension_from_dists(&g.bfs_undirected(0));
        let p = path(g.n());
        let d_path = fractal_dimension_from_dists(&p.bfs_undirected(0));
        assert!(d_tree > d_path, "tree {d_tree} vs path {d_path}");
    }

    #[test]
    fn isolated_node_zero() {
        let mut g = CompGraph::new("iso");
        g.add_node(OpNode::new("x", OpKind::Parameter, vec![1]));
        assert_eq!(fractal_dimensions(&g), vec![0.0]);
    }

    #[test]
    fn all_values_finite_on_random_graphs() {
        use crate::util::prop::{check, PropConfig};
        check("fractal-finite", PropConfig { cases: 24, max_size: 80, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 4);
            for d in fractal_dimensions(&g) {
                if !d.is_finite() {
                    return Err("non-finite fractal dimension".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sampled_with_all_landmarks_is_exact() {
        // The differential anchor: k >= n makes every node a landmark,
        // so the sampled path must reproduce the exact one bit-for-bit.
        use crate::util::prop::{check, PropConfig};
        check("fractal-sampled-exact", PropConfig { cases: 16, max_size: 60, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 3);
            let exact = fractal_dimensions(&g);
            let sampled = fractal_dimensions_sampled(&g, g.n());
            if exact != sampled {
                return Err("sampled(k=n) diverged from exact".into());
            }
            Ok(())
        });
    }

    #[test]
    fn sampled_is_deterministic_and_finite() {
        use crate::util::Rng;
        let mut rng = Rng::new(11);
        let g = CompGraph::random(&mut rng, 120, 40);
        let a = fractal_dimensions_sampled(&g, landmark_count(g.n()));
        let b = fractal_dimensions_sampled(&g, landmark_count(g.n()));
        assert_eq!(a, b);
        assert!(a.iter().all(|d| d.is_finite()));
    }

    #[test]
    fn sampled_tracks_exact_on_paths() {
        // On a long path the exact dimension is ~1 everywhere away from
        // the ends; the landmark blend must stay close.
        let g = path(200);
        let exact = fractal_dimensions(&g);
        let sampled = fractal_dimensions_sampled(&g, 24);
        let mae: f64 = exact
            .iter()
            .zip(&sampled)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
            / exact.len() as f64;
        assert!(mae < 0.25, "mean abs err {mae}");
    }

    #[test]
    fn landmark_budget_envelope() {
        assert_eq!(landmark_count(0), 0);
        assert_eq!(landmark_count(1), 1);
        assert!(landmark_count(100) <= 100);
        // √n·ln n at 1e4 is ~921, already above the cap.
        assert_eq!(landmark_count(10_000), LANDMARK_CAP);
        assert_eq!(landmark_count(100_000), LANDMARK_CAP);
        // Below the cap the nominal √n·ln n budget applies.
        let k = landmark_count(1000);
        assert!((200..=250).contains(&k), "k(1000) = {k}");
    }

    #[test]
    fn auto_switches_on_threshold() {
        // Small graph: auto == exact regardless of the pin flag.
        let g = path(32);
        assert_eq!(fractal_dimensions_auto(&g, false), fractal_dimensions(&g));
        assert_eq!(fractal_dimensions_auto(&g, true), fractal_dimensions(&g));
    }
}

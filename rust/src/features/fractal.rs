//! Node fractal dimension (Eq. 4): the mass-distribution exponent.
//!
//! For node v with BFS distances {r_k} and box masses N(v, r_k) = #nodes
//! within distance r_k, D(v) is the least-squares slope of
//! log N(v, r) against log r:
//!
//!   D(v) = Σ_k (log r_k - mean(log r)) (log N_k - mean(log N))
//!          ───────────────────────────────────────────────────
//!                       Σ_k (log r_k - mean(log r))²
//!
//! Degenerate cases (isolated nodes, eccentricity < 2) get D(v) = 0 — no
//! multi-scale structure to measure.

use crate::graph::CompGraph;

/// Fractal dimension of a single node given its undirected BFS distances.
pub fn fractal_dimension_from_dists(dists: &[usize]) -> f64 {
    // Mass at each radius r >= 1 (cumulative node count within distance r).
    let max_r = dists.iter().filter(|&&d| d != usize::MAX).max().copied().unwrap_or(0);
    if max_r < 2 {
        return 0.0;
    }
    let mut mass = vec![0usize; max_r + 1];
    for &d in dists {
        if d != usize::MAX {
            mass[d] += 1;
        }
    }
    // Cumulative.
    for r in 1..=max_r {
        mass[r] += mass[r - 1];
    }
    let pts: Vec<(f64, f64)> =
        (1..=max_r).map(|r| ((r as f64).ln(), (mass[r] as f64).ln())).collect();
    let n = pts.len() as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let num: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let den: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Fractal dimension for every node of `g` (Eq. 4), via per-node BFS.
pub fn fractal_dimensions(g: &CompGraph) -> Vec<f64> {
    (0..g.n()).map(|v| fractal_dimension_from_dists(&g.bfs_undirected(v))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CompGraph, OpNode, OpKind};

    fn path(n: usize) -> CompGraph {
        let mut g = CompGraph::new("path");
        let mut prev = g.add_node(OpNode::new("n0", OpKind::Parameter, vec![1]));
        for i in 1..n {
            let v = g.add_node(OpNode::new(format!("n{i}"), OpKind::Relu, vec![1]));
            g.add_edge(prev, v);
            prev = v;
        }
        g
    }

    #[test]
    fn path_endpoint_dimension_near_one() {
        // From the end of a long path, N(r) = r + 1: slope -> ~1.
        let g = path(64);
        let d = fractal_dimension_from_dists(&g.bfs_undirected(0));
        assert!((d - 1.0).abs() < 0.15, "got {d}");
    }

    #[test]
    fn star_center_dimension_small() {
        // Star: everything at distance 1 from the hub; from a leaf, mass
        // saturates at r=2 -> slope well below 1.
        let mut g = CompGraph::new("star");
        let hub = g.add_node(OpNode::new("hub", OpKind::Parameter, vec![1]));
        for i in 0..32 {
            let v = g.add_node(OpNode::new(format!("leaf{i}"), OpKind::Relu, vec![1]));
            g.add_edge(hub, v);
        }
        let d_leaf = fractal_dimension_from_dists(&g.bfs_undirected(1));
        // Leaf: N(1)=2, N(2)=33 -> slope = ln(33/2)/ln(2) ~ 4; hub has
        // eccentricity 1 -> 0 by convention.
        let d_hub = fractal_dimension_from_dists(&g.bfs_undirected(0));
        assert_eq!(d_hub, 0.0);
        assert!(d_leaf > 2.0, "leaf {d_leaf}");
    }

    #[test]
    fn grid_like_higher_than_path() {
        // Binary in-tree has higher mass growth than a path.
        let mut g = CompGraph::new("tree");
        let root = g.add_node(OpNode::new("r", OpKind::Parameter, vec![1]));
        let mut frontier = vec![root];
        let mut idx = 0;
        for _ in 0..5 {
            let mut next = Vec::new();
            for &p in &frontier {
                for _ in 0..2 {
                    idx += 1;
                    let v = g.add_node(OpNode::new(format!("t{idx}"), OpKind::Relu, vec![1]));
                    g.add_edge(p, v);
                    next.push(v);
                }
            }
            frontier = next;
        }
        let d_tree = fractal_dimension_from_dists(&g.bfs_undirected(0));
        let p = path(g.n());
        let d_path = fractal_dimension_from_dists(&p.bfs_undirected(0));
        assert!(d_tree > d_path, "tree {d_tree} vs path {d_path}");
    }

    #[test]
    fn isolated_node_zero() {
        let mut g = CompGraph::new("iso");
        g.add_node(OpNode::new("x", OpKind::Parameter, vec![1]));
        assert_eq!(fractal_dimensions(&g), vec![0.0]);
    }

    #[test]
    fn all_values_finite_on_random_graphs() {
        use crate::util::prop::{check, PropConfig};
        check("fractal-finite", PropConfig { cases: 24, max_size: 80, ..Default::default() }, |rng, size| {
            let g = CompGraph::random(rng, size, size / 4);
            for d in fractal_dimensions(&g) {
                if !d.is_finite() {
                    return Err("non-finite fractal dimension".into());
                }
            }
            Ok(())
        });
    }
}
